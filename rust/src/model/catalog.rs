//! The model catalog: the six paper networks, their deployment targets,
//! and the paper's published reference numbers (Tables I–III) used for
//! calibration and for the paper-vs-measured columns in EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, Precision};
use crate::util::json::Json;

/// Which accelerator the paper deploys a model on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Vitis-AI DPU (INT8) — VAE encoder, CNetPlusScalar.
    Dpu,
    /// Vitis-HLS custom IP (fp32) — ESPERTA + MMS networks.
    Hls,
}

impl Target {
    pub fn as_str(&self) -> &'static str {
        match self {
            Target::Dpu => "vitis-ai",
            Target::Hls => "hls",
        }
    }
}

/// Paper Table III row (the published measurements we reproduce).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub cpu_fps: f64,
    pub accel_fps: f64,
    pub speedup: f64,
    pub cpu_p_board: f64,
    pub cpu_p_mpsoc: f64,
    pub accel_p_board: f64,
    pub accel_p_mpsoc: f64,
    pub cpu_energy_mj: f64,
    pub accel_energy_mj: f64,
}

/// Static description of one use-case network.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// Catalog name ("vae", "cnet", "esperta", "logistic", "reduced",
    /// "baseline").
    pub name: &'static str,
    /// Paper's display name.
    pub display: &'static str,
    pub target: Target,
    /// Table I parameter count (ground truth; manifests must match).
    pub table1_params: u64,
    /// Table I operation count (paper's Netron convention).
    pub table1_ops: u64,
    pub paper: PaperRow,
}

/// The six evaluated networks, Table I + Table III of the paper.
pub const MODELS: &[ModelInfo] = &[
    ModelInfo {
        name: "vae",
        display: "VAE Encoder",
        target: Target::Dpu,
        table1_params: 395_692,
        table1_ops: 83_417_100,
        paper: PaperRow {
            cpu_fps: 25.21, accel_fps: 606.65, speedup: 24.06,
            cpu_p_board: 12.125, cpu_p_mpsoc: 2.75,
            accel_p_board: 15.337, accel_p_mpsoc: 5.75,
            cpu_energy_mj: 109.08, accel_energy_mj: 9.48,
        },
    },
    ModelInfo {
        name: "cnet",
        display: "CNetPlusScalar",
        target: Target::Dpu,
        table1_params: 3_061_966,
        table1_ops: 918_241_400,
        paper: PaperRow {
            cpu_fps: 4.79, accel_fps: 163.51, speedup: 34.16,
            cpu_p_board: 12.862, cpu_p_mpsoc: 2.75,
            accel_p_board: 15.987, accel_p_mpsoc: 6.75,
            cpu_energy_mj: 574.11, accel_energy_mj: 41.28,
        },
    },
    ModelInfo {
        name: "esperta",
        display: "ESPERTA",
        target: Target::Hls,
        table1_params: 24,
        table1_ops: 60,
        paper: PaperRow {
            cpu_fps: 6932.0, accel_fps: 37231.0, speedup: 5.33,
            cpu_p_board: 11.725, cpu_p_mpsoc: 2.0,
            accel_p_board: 10.6, accel_p_mpsoc: 1.5,
            cpu_energy_mj: 0.29, accel_energy_mj: 0.04,
        },
    },
    ModelInfo {
        name: "logistic",
        display: "LogisticNet",
        target: Target::Hls,
        table1_params: 8_196,
        table1_ops: 30_720,
        paper: PaperRow {
            cpu_fps: 319.0, accel_fps: 646.0, speedup: 2.03,
            cpu_p_board: 11.725, cpu_p_mpsoc: 2.25,
            accel_p_board: 10.7, accel_p_mpsoc: 1.75,
            cpu_energy_mj: 7.03, accel_energy_mj: 2.71,
        },
    },
    ModelInfo {
        name: "reduced",
        display: "ReducedNet",
        target: Target::Hls,
        table1_params: 44_624,
        table1_ops: 502_961,
        paper: PaperRow {
            cpu_fps: 186.0, accel_fps: 30.0, speedup: 0.16,
            cpu_p_board: 11.9, cpu_p_mpsoc: 2.25,
            accel_p_board: 10.512, accel_p_mpsoc: 1.5,
            cpu_energy_mj: 12.05, accel_energy_mj: 49.73,
        },
    },
    ModelInfo {
        name: "baseline",
        display: "BaselineNet",
        target: Target::Hls,
        table1_params: 915_492,
        table1_ops: 110_541_696,
        paper: PaperRow {
            cpu_fps: 42.0, accel_fps: 0.21, speedup: 0.01,
            cpu_p_board: 12.725, cpu_p_mpsoc: 2.75,
            accel_p_board: 10.537, accel_p_mpsoc: 1.75,
            cpu_energy_mj: 63.45, accel_energy_mj: 8467.82,
        },
    },
];

/// Look up a catalog entry by name.
pub fn model_info(name: &str) -> Result<&'static ModelInfo> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .with_context(|| format!("unknown model {name:?}"))
}

/// The artifact catalog on disk: manifests (+ HLO paths) under `artifacts/`.
#[derive(Debug)]
pub struct Catalog {
    pub dir: PathBuf,
    /// tag ("vae.fp32") -> manifest
    pub manifests: BTreeMap<String, Manifest>,
    /// tags that also have an executable `.hlo.txt`
    pub executable: Vec<String>,
}

impl Catalog {
    /// Load `artifacts/index.json` and every referenced manifest.
    pub fn load(dir: &Path) -> Result<Catalog> {
        let index_path = dir.join("index.json");
        let text = std::fs::read_to_string(&index_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                index_path.display()
            )
        })?;
        let index = Json::parse(&text)?;
        let mut manifests = BTreeMap::new();
        let mut executable = Vec::new();
        for tag in index.req("artifacts")?.as_arr()? {
            executable.push(tag.as_str()?.to_string());
        }
        let mut tags: Vec<String> = executable.clone();
        for tag in index.req("manifests")?.as_arr()? {
            tags.push(tag.as_str()?.to_string());
        }
        tags.sort();
        tags.dedup();
        for tag in tags {
            let path = dir.join(format!("{tag}.manifest.json"));
            let man = Manifest::load(&path)?;
            manifests.insert(tag, man);
        }
        Ok(Catalog { dir: dir.to_path_buf(), manifests, executable })
    }

    /// Manifest for `name` at `precision`.
    pub fn manifest(&self, name: &str, precision: Precision) -> Result<&Manifest> {
        let tag = format!("{name}.{}", precision.as_str());
        match self.manifests.get(&tag) {
            Some(m) => Ok(m),
            None => bail!("no manifest {tag:?} in {}", self.dir.display()),
        }
    }

    /// Manifest for a model's *deployed* variant (DPU models are int8,
    /// HLS models fp32 — paper §III-B).
    pub fn deployed(&self, info: &ModelInfo) -> Result<&Manifest> {
        let prec = match info.target {
            Target::Dpu => Precision::Int8,
            Target::Hls => Precision::Fp32,
        };
        self.manifest(info.name, prec)
    }

    /// Path of the executable HLO for a tag, if present.
    pub fn hlo_path(&self, tag: &str) -> Option<PathBuf> {
        if self.executable.iter().any(|t| t == tag) {
            Some(self.dir.join(format!("{tag}.hlo.txt")))
        } else {
            None
        }
    }

    /// Path of the golden-IO JSON for a tag.
    pub fn io_path(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.io.json"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_models_with_paper_rows() {
        assert_eq!(MODELS.len(), 6);
        for m in MODELS {
            assert!(m.paper.cpu_fps > 0.0);
            assert!(m.paper.accel_fps > 0.0);
            // E = P * t must hold for the published rows within rounding
            let t_cpu_ms = 1000.0 / m.paper.cpu_fps;
            let e = m.paper.cpu_p_mpsoc * t_cpu_ms;
            // 5% slack: the paper's FPS column is rounded (42 FPS x
            // 2.75 W gives 65.5 mJ vs the printed 63.45)
            let rel = (e - m.paper.cpu_energy_mj).abs() / m.paper.cpu_energy_mj;
            assert!(rel < 0.05, "{}: E=P*t violated ({e} vs {})",
                    m.name, m.paper.cpu_energy_mj);
        }
    }

    #[test]
    fn speedups_consistent_with_fps() {
        for m in MODELS {
            let s = m.paper.accel_fps / m.paper.cpu_fps;
            // BaselineNet: the paper prints 0.01x for a 0.005 fps ratio
            // (one significant digit); allow that rounding.
            let rel = (s - m.paper.speedup).abs() / m.paper.speedup;
            assert!(rel < 0.55, "{}: speedup {} vs fps ratio {s}",
                    m.name, m.paper.speedup);
        }
    }

    #[test]
    fn lookup() {
        assert!(model_info("vae").is_ok());
        assert!(model_info("nope").is_err());
        assert_eq!(model_info("cnet").unwrap().target, Target::Dpu);
        assert_eq!(model_info("baseline").unwrap().target, Target::Hls);
    }
}
