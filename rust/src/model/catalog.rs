//! The model catalog: the six paper networks, their deployment targets,
//! and the paper's published reference numbers (Tables I–III) used for
//! calibration and for the paper-vs-measured columns in EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::{Activation, Layer, LayerKind, Manifest, Precision};
use crate::util::json::Json;

/// The four paper use cases (§III-A), as a type.
///
/// Replaces the stringly-typed names previously threaded through the
/// router, dispatcher, and pipeline: a typo is now a compile error (or
/// a parse error at the CLI boundary) instead of a silent fall-through
/// into a catch-all match arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum UseCase {
    /// Solar-magnetogram compression: VAE encoder latents.
    Vae,
    /// Solar X-ray flux forecasting: CNetPlusScalar.
    Cnet,
    /// SEP early warning: the multi-ESPERTA bank.
    Esperta,
    /// Magnetospheric region classification: the MMS networks.
    Mms,
}

impl UseCase {
    /// All use cases, report order.
    pub const ALL: [UseCase; 4] =
        [UseCase::Vae, UseCase::Cnet, UseCase::Esperta, UseCase::Mms];

    /// Parse the CLI spelling.
    ///
    /// ```
    /// use spaceinfer::model::UseCase;
    /// assert_eq!(UseCase::parse("mms").unwrap(), UseCase::Mms);
    /// assert!(UseCase::parse("radar").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<UseCase> {
        Ok(match s {
            "vae" => UseCase::Vae,
            "cnet" => UseCase::Cnet,
            "esperta" => UseCase::Esperta,
            "mms" => UseCase::Mms,
            other => bail!("unknown use case {other:?} (vae | cnet | esperta | mms)"),
        })
    }

    /// The CLI / report spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            UseCase::Vae => "vae",
            UseCase::Cnet => "cnet",
            UseCase::Esperta => "esperta",
            UseCase::Mms => "mms",
        }
    }
}

impl std::fmt::Display for UseCase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which accelerator the paper deploys a model on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Vitis-AI DPU (INT8) — VAE encoder, CNetPlusScalar.
    Dpu,
    /// Vitis-HLS custom IP (fp32) — ESPERTA + MMS networks.
    Hls,
}

impl Target {
    /// Report spelling of the target.
    pub fn as_str(&self) -> &'static str {
        match self {
            Target::Dpu => "vitis-ai",
            Target::Hls => "hls",
        }
    }
}

/// Paper Table III row (the published measurements we reproduce).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    /// Published CPU inferences/s.
    pub cpu_fps: f64,
    /// Published accelerator inferences/s.
    pub accel_fps: f64,
    /// Published speedup column (accel over CPU).
    pub speedup: f64,
    /// Published CPU board power (W).
    pub cpu_p_board: f64,
    /// Published CPU MPSoC power (W).
    pub cpu_p_mpsoc: f64,
    /// Published accelerator board power (W).
    pub accel_p_board: f64,
    /// Published accelerator MPSoC power (W).
    pub accel_p_mpsoc: f64,
    /// Published CPU energy per inference (mJ).
    pub cpu_energy_mj: f64,
    /// Published accelerator energy per inference (mJ).
    pub accel_energy_mj: f64,
}

/// Static description of one use-case network.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// Catalog name ("vae", "cnet", "esperta", "logistic", "reduced",
    /// "baseline").
    pub name: &'static str,
    /// Paper's display name.
    pub display: &'static str,
    /// Accelerator the paper deploys this model on.
    pub target: Target,
    /// Table I parameter count (ground truth; manifests must match).
    pub table1_params: u64,
    /// Table I operation count (paper's Netron convention).
    pub table1_ops: u64,
    /// Published Table III measurements for the model.
    pub paper: PaperRow,
}

/// The six evaluated networks, Table I + Table III of the paper.
pub const MODELS: &[ModelInfo] = &[
    ModelInfo {
        name: "vae",
        display: "VAE Encoder",
        target: Target::Dpu,
        table1_params: 395_692,
        table1_ops: 83_417_100,
        paper: PaperRow {
            cpu_fps: 25.21, accel_fps: 606.65, speedup: 24.06,
            cpu_p_board: 12.125, cpu_p_mpsoc: 2.75,
            accel_p_board: 15.337, accel_p_mpsoc: 5.75,
            cpu_energy_mj: 109.08, accel_energy_mj: 9.48,
        },
    },
    ModelInfo {
        name: "cnet",
        display: "CNetPlusScalar",
        target: Target::Dpu,
        table1_params: 3_061_966,
        table1_ops: 918_241_400,
        paper: PaperRow {
            cpu_fps: 4.79, accel_fps: 163.51, speedup: 34.16,
            cpu_p_board: 12.862, cpu_p_mpsoc: 2.75,
            accel_p_board: 15.987, accel_p_mpsoc: 6.75,
            cpu_energy_mj: 574.11, accel_energy_mj: 41.28,
        },
    },
    ModelInfo {
        name: "esperta",
        display: "ESPERTA",
        target: Target::Hls,
        table1_params: 24,
        table1_ops: 60,
        paper: PaperRow {
            cpu_fps: 6932.0, accel_fps: 37231.0, speedup: 5.33,
            cpu_p_board: 11.725, cpu_p_mpsoc: 2.0,
            accel_p_board: 10.6, accel_p_mpsoc: 1.5,
            cpu_energy_mj: 0.29, accel_energy_mj: 0.04,
        },
    },
    ModelInfo {
        name: "logistic",
        display: "LogisticNet",
        target: Target::Hls,
        table1_params: 8_196,
        table1_ops: 30_720,
        paper: PaperRow {
            cpu_fps: 319.0, accel_fps: 646.0, speedup: 2.03,
            cpu_p_board: 11.725, cpu_p_mpsoc: 2.25,
            accel_p_board: 10.7, accel_p_mpsoc: 1.75,
            cpu_energy_mj: 7.03, accel_energy_mj: 2.71,
        },
    },
    ModelInfo {
        name: "reduced",
        display: "ReducedNet",
        target: Target::Hls,
        table1_params: 44_624,
        table1_ops: 502_961,
        paper: PaperRow {
            cpu_fps: 186.0, accel_fps: 30.0, speedup: 0.16,
            cpu_p_board: 11.9, cpu_p_mpsoc: 2.25,
            accel_p_board: 10.512, accel_p_mpsoc: 1.5,
            cpu_energy_mj: 12.05, accel_energy_mj: 49.73,
        },
    },
    ModelInfo {
        name: "baseline",
        display: "BaselineNet",
        target: Target::Hls,
        table1_params: 915_492,
        table1_ops: 110_541_696,
        paper: PaperRow {
            cpu_fps: 42.0, accel_fps: 0.21, speedup: 0.01,
            cpu_p_board: 12.725, cpu_p_mpsoc: 2.75,
            accel_p_board: 10.537, accel_p_mpsoc: 1.75,
            cpu_energy_mj: 63.45, accel_energy_mj: 8467.82,
        },
    },
];

/// Look up a catalog entry by name.
pub fn model_info(name: &str) -> Result<&'static ModelInfo> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .with_context(|| format!("unknown model {name:?}"))
}

/// The artifact catalog on disk: manifests (+ HLO paths) under `artifacts/`.
#[derive(Debug)]
pub struct Catalog {
    /// Artifact directory the catalog was loaded from.
    pub dir: PathBuf,
    /// tag ("vae.fp32") -> manifest
    pub manifests: BTreeMap<String, Manifest>,
    /// tags that also have an executable `.hlo.txt`
    pub executable: Vec<String>,
}

impl Catalog {
    /// Load `artifacts/index.json` and every referenced manifest.
    pub fn load(dir: &Path) -> Result<Catalog> {
        let index_path = dir.join("index.json");
        let text = std::fs::read_to_string(&index_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                index_path.display()
            )
        })?;
        let index = Json::parse(&text)?;
        let mut manifests = BTreeMap::new();
        let mut executable = Vec::new();
        for tag in index.req("artifacts")?.as_arr()? {
            executable.push(tag.as_str()?.to_string());
        }
        let mut tags: Vec<String> = executable.clone();
        for tag in index.req("manifests")?.as_arr()? {
            tags.push(tag.as_str()?.to_string());
        }
        tags.sort();
        tags.dedup();
        for tag in tags {
            let path = dir.join(format!("{tag}.manifest.json"));
            let man = Manifest::load(&path)?;
            manifests.insert(tag, man);
        }
        Ok(Catalog { dir: dir.to_path_buf(), manifests, executable })
    }

    /// Manifest for `name` at `precision`.
    pub fn manifest(&self, name: &str, precision: Precision) -> Result<&Manifest> {
        let tag = format!("{name}.{}", precision.as_str());
        match self.manifests.get(&tag) {
            Some(m) => Ok(m),
            None => bail!("no manifest {tag:?} in {}", self.dir.display()),
        }
    }

    /// Manifest for a model's *deployed* variant (DPU models are int8,
    /// HLS models fp32 — paper §III-B).
    pub fn deployed(&self, info: &ModelInfo) -> Result<&Manifest> {
        let prec = match info.target {
            Target::Dpu => Precision::Int8,
            Target::Hls => Precision::Fp32,
        };
        self.manifest(info.name, prec)
    }

    /// Path of the executable HLO for a tag, if present.
    pub fn hlo_path(&self, tag: &str) -> Option<PathBuf> {
        if self.executable.iter().any(|t| t == tag) {
            Some(self.dir.join(format!("{tag}.hlo.txt")))
        } else {
            None
        }
    }

    /// Path of the golden-IO JSON for a tag.
    pub fn io_path(&self, tag: &str) -> PathBuf {
        self.dir.join(format!("{tag}.io.json"))
    }

    /// Does `dir` hold a loadable catalog (`index.json` present)?
    pub fn is_present(dir: &Path) -> bool {
        dir.join("index.json").exists()
    }

    /// Load the artifact catalog from `dir`, falling back to
    /// [`Catalog::synthetic`] when no artifacts exist there — the one
    /// place that knows the on-disk marker, shared by the CLI and the
    /// examples.
    pub fn load_or_synthetic(dir: &Path) -> Result<Catalog> {
        if Catalog::is_present(dir) {
            Catalog::load(dir)
        } else {
            Ok(Catalog::synthetic())
        }
    }

    /// An in-memory catalog of miniature stand-in manifests for all six
    /// networks — no `make artifacts` required.
    ///
    /// Input/output shapes match the real sensor streams and decision
    /// logic (so the surrogate executor path works end to end), layer
    /// structure and counts are scaled-down stand-ins (so the analytic
    /// simulators produce *plausible*, not paper-accurate, timings).
    /// DPU models carry both fp32 and int8 variants; MMS/ESPERTA models
    /// are fp32-only, exactly like the deployed matrix.  Used by the
    /// dispatcher tests, the policy examples, and any artifact-less run.
    ///
    /// ```
    /// use spaceinfer::model::{Catalog, Precision};
    /// let c = Catalog::synthetic();
    /// assert!(c.manifest("vae", Precision::Int8).unwrap().dpu_compatible());
    /// assert!(c.manifest("baseline", Precision::Int8).is_err()); // HLS-only
    /// ```
    pub fn synthetic() -> Catalog {
        SYNTHETIC_BUILDS.with(|c| c.set(c.get() + 1));
        let mut manifests = BTreeMap::new();
        for prec in [Precision::Fp32, Precision::Int8] {
            for man in [synthetic_vae(prec), synthetic_cnet(prec)] {
                manifests.insert(format!("{}.{}", man.name, prec.as_str()), man);
            }
        }
        for man in [
            synthetic_esperta(),
            synthetic_logistic(),
            synthetic_reduced(),
            synthetic_baseline(),
        ] {
            manifests.insert(format!("{}.fp32", man.name), man);
        }
        Catalog {
            dir: PathBuf::from("<synthetic>"),
            manifests,
            executable: Vec::new(),
        }
    }
}

thread_local! {
    /// How many times [`Catalog::synthetic`] ran on this thread.
    /// Thread-local (not a global atomic) so parallel test threads
    /// cannot race the counter a sharing test reads.
    static SYNTHETIC_BUILDS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of [`Catalog::synthetic`] builds performed *on the calling
/// thread*.  The fleet layer shares one catalog across every craft; the
/// no-per-craft-rebuild test pins that by asserting this counter rises
/// by exactly one across a whole fleet run.
pub fn synthetic_builds_this_thread() -> u64 {
    SYNTHETIC_BUILDS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// synthetic stand-in manifests (Catalog::synthetic)
// ---------------------------------------------------------------------------

fn syn_layer(
    kind: LayerKind,
    in_shape: &[usize],
    out_shape: &[usize],
    macs: u64,
    ops: u64,
    params: u64,
    weight_bytes: u64,
    act: Activation,
) -> Layer {
    Layer {
        kind,
        in_shape: in_shape.to_vec(),
        out_shape: out_shape.to_vec(),
        macs,
        ops,
        params,
        weight_bytes,
        act_bytes: out_shape.iter().skip(1).product::<usize>() as u64 * 4,
        act,
    }
}

fn syn_manifest(
    name: &str,
    precision: Precision,
    inputs: Vec<(&str, Vec<usize>)>,
    output_shape: Vec<usize>,
    layers: Vec<Layer>,
) -> Manifest {
    Manifest {
        name: name.to_string(),
        precision,
        inputs: inputs
            .into_iter()
            .map(|(n, s)| (n.to_string(), s))
            .collect(),
        output_shape,
        total_macs: layers.iter().map(|l| l.macs).sum(),
        total_ops: layers.iter().map(|l| l.ops).sum(),
        total_params: layers.iter().map(|l| l.params).sum(),
        weight_bytes: layers.iter().map(|l| l.weight_bytes).sum(),
        layers,
    }
}

fn bytes_per_param(prec: Precision) -> u64 {
    match prec {
        Precision::Fp32 => 4,
        Precision::Int8 => 1,
    }
}

/// Miniature VAE encoder: conv2d + dense over the 128x256x3 magnetogram
/// tile; every operator DPU-mappable.
fn synthetic_vae(prec: Precision) -> Manifest {
    let bp = bytes_per_param(prec);
    let conv_out = (64 * 128 * 8) as u64;
    let conv_macs = conv_out * 27; // k=3, cin=3
    let dense_macs = 65_536u64 * 12;
    syn_manifest(
        "vae",
        prec,
        vec![("x", vec![1, 128, 256, 3])],
        vec![1, 12],
        vec![
            syn_layer(
                LayerKind::Conv2d,
                &[1, 128, 256, 3],
                &[1, 64, 128, 8],
                conv_macs,
                2 * conv_macs + 2 * conv_out,
                8 * 28,
                8 * 28 * bp,
                Activation::Relu,
            ),
            syn_layer(LayerKind::Flatten, &[1, 64, 128, 8], &[1, 65536], 0, 0, 0, 0, Activation::None),
            syn_layer(
                LayerKind::Dense,
                &[1, 65536],
                &[1, 12],
                dense_macs,
                2 * dense_macs + 12,
                12 * 65_537,
                12 * 65_537 * bp,
                Activation::None,
            ),
        ],
    )
}

/// Miniature CNetPlusScalar: conv2d + pool + flatten + scalar concat +
/// dense over the AIA/HMI pair; DPU-mappable.
fn synthetic_cnet(prec: Precision) -> Manifest {
    let bp = bytes_per_param(prec);
    let conv_out = (128 * 128 * 4) as u64;
    let conv_macs = conv_out * 18; // k=3, cin=2
    syn_manifest(
        "cnet",
        prec,
        vec![("img", vec![1, 256, 256, 2]), ("flux", vec![1, 1])],
        vec![1, 1],
        vec![
            syn_layer(
                LayerKind::Conv2d,
                &[1, 256, 256, 2],
                &[1, 128, 128, 4],
                conv_macs,
                2 * conv_macs + 2 * conv_out,
                4 * 19,
                4 * 19 * bp,
                Activation::Relu,
            ),
            syn_layer(
                LayerKind::MaxPool2d,
                &[1, 128, 128, 4],
                &[1, 64, 64, 4],
                0,
                16_384 * 3,
                0,
                0,
                Activation::None,
            ),
            syn_layer(LayerKind::Flatten, &[1, 64, 64, 4], &[1, 16384], 0, 0, 0, 0, Activation::None),
            syn_layer(
                LayerKind::ConcatScalar,
                &[1, 16384],
                &[1, 16385],
                0,
                0,
                0,
                0,
                Activation::None,
            ),
            syn_layer(
                LayerKind::Dense,
                &[1, 16385],
                &[1, 1],
                16_385,
                2 * 16_385 + 1,
                16_386,
                16_386 * bp,
                Activation::None,
            ),
        ],
    )
}

/// Multi-ESPERTA bank: six single-MAC sigmoid models over the 3-feature
/// flare descriptor (sigmoid + comparator keep it off the DPU).
fn synthetic_esperta() -> Manifest {
    syn_manifest(
        "esperta",
        Precision::Fp32,
        vec![("x", vec![1, 3])],
        vec![1, 12],
        vec![syn_layer(
            LayerKind::EspertaBank,
            &[1, 3],
            &[1, 12],
            18,
            2 * 18 + 3 * 6,
            24,
            96,
            Activation::Sigmoid,
        )],
    )
}

/// MMS LogisticNet stand-in: one dense layer over the flattened ion
/// distribution.
fn synthetic_logistic() -> Manifest {
    let macs = 16_384u64 * 4;
    syn_manifest(
        "logistic",
        Precision::Fp32,
        vec![("x", vec![1, 32, 16, 32, 1])],
        vec![1, 4],
        vec![
            syn_layer(
                LayerKind::Flatten,
                &[1, 32, 16, 32, 1],
                &[1, 16384],
                0,
                0,
                0,
                0,
                Activation::None,
            ),
            syn_layer(
                LayerKind::Dense,
                &[1, 16384],
                &[1, 4],
                macs,
                2 * macs + 4,
                4 * 16_385,
                4 * 16_385 * 4,
                Activation::None,
            ),
        ],
    )
}

/// MMS ReducedNet stand-in: one 3-D conv + dense (conv3d keeps it off
/// the DPU, like the real network).
fn synthetic_reduced() -> Manifest {
    let conv_out = (16 * 8 * 16 * 2) as u64;
    let conv_macs = conv_out * 27;
    let dense_macs = 4_096u64 * 4;
    syn_manifest(
        "reduced",
        Precision::Fp32,
        vec![("x", vec![1, 32, 16, 32, 1])],
        vec![1, 4],
        vec![
            syn_layer(
                LayerKind::Conv3d,
                &[1, 32, 16, 32, 1],
                &[1, 16, 8, 16, 2],
                conv_macs,
                2 * conv_macs + 2 * conv_out,
                2 * 28,
                2 * 28 * 4,
                Activation::Relu,
            ),
            syn_layer(LayerKind::Flatten, &[1, 16, 8, 16, 2], &[1, 4096], 0, 0, 0, 0, Activation::None),
            syn_layer(
                LayerKind::Dense,
                &[1, 4096],
                &[1, 4],
                dense_macs,
                2 * dense_macs + 4,
                4 * 4_097,
                4 * 4_097 * 4,
                Activation::None,
            ),
        ],
    )
}

/// MMS BaselineNet stand-in: 3-D conv + pool + a wide hidden dense +
/// the region head.  The hidden layer's fp32 weights (~1 MB) exceed the
/// HLS BRAM budget and spill to DRAM — reproducing, at synthetic scale,
/// the word-by-word fetch collapse behind the real BaselineNet's 0.01×
/// row, so artifact-less runs exhibit the paper's shallow-vs-deep
/// crossover.
fn synthetic_baseline() -> Manifest {
    let conv_out = (16 * 8 * 16 * 4) as u64;
    let conv_macs = conv_out * 27;
    let hidden_macs = 1_024u64 * 256;
    let head_macs = 256u64 * 4;
    syn_manifest(
        "baseline",
        Precision::Fp32,
        vec![("x", vec![1, 32, 16, 32, 1])],
        vec![1, 4],
        vec![
            syn_layer(
                LayerKind::Conv3d,
                &[1, 32, 16, 32, 1],
                &[1, 16, 8, 16, 4],
                conv_macs,
                2 * conv_macs + 2 * conv_out,
                4 * 28,
                4 * 28 * 4,
                Activation::Relu,
            ),
            syn_layer(
                LayerKind::MaxPool3d,
                &[1, 16, 8, 16, 4],
                &[1, 8, 4, 8, 4],
                0,
                1_024 * 7,
                0,
                0,
                Activation::None,
            ),
            syn_layer(LayerKind::Flatten, &[1, 8, 4, 8, 4], &[1, 1024], 0, 0, 0, 0, Activation::None),
            syn_layer(
                LayerKind::Dense,
                &[1, 1024],
                &[1, 256],
                hidden_macs,
                2 * hidden_macs + 256,
                256 * 1_025,
                256 * 1_025 * 4,
                Activation::Relu,
            ),
            syn_layer(
                LayerKind::Dense,
                &[1, 256],
                &[1, 4],
                head_macs,
                2 * head_macs + 4,
                4 * 257,
                4 * 257 * 4,
                Activation::None,
            ),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_models_with_paper_rows() {
        assert_eq!(MODELS.len(), 6);
        for m in MODELS {
            assert!(m.paper.cpu_fps > 0.0);
            assert!(m.paper.accel_fps > 0.0);
            // E = P * t must hold for the published rows within rounding
            let t_cpu_ms = 1000.0 / m.paper.cpu_fps;
            let e = m.paper.cpu_p_mpsoc * t_cpu_ms;
            // 5% slack: the paper's FPS column is rounded (42 FPS x
            // 2.75 W gives 65.5 mJ vs the printed 63.45)
            let rel = (e - m.paper.cpu_energy_mj).abs() / m.paper.cpu_energy_mj;
            assert!(rel < 0.05, "{}: E=P*t violated ({e} vs {})",
                    m.name, m.paper.cpu_energy_mj);
        }
    }

    #[test]
    fn speedups_consistent_with_fps() {
        for m in MODELS {
            let s = m.paper.accel_fps / m.paper.cpu_fps;
            // BaselineNet: the paper prints 0.01x for a 0.005 fps ratio
            // (one significant digit); allow that rounding.
            let rel = (s - m.paper.speedup).abs() / m.paper.speedup;
            assert!(rel < 0.55, "{}: speedup {} vs fps ratio {s}",
                    m.name, m.paper.speedup);
        }
    }

    #[test]
    fn synthetic_catalog_is_internally_consistent() {
        let c = Catalog::synthetic();
        // vae + cnet in both precisions, four HLS models fp32-only
        assert_eq!(c.manifests.len(), 8);
        for man in c.manifests.values() {
            man.validate().unwrap();
        }
        assert!(c.manifest("vae", Precision::Int8).unwrap().dpu_compatible());
        assert!(c.manifest("cnet", Precision::Int8).unwrap().dpu_compatible());
        assert!(!c.manifest("baseline", Precision::Fp32).unwrap().dpu_compatible());
        assert!(c.manifest("baseline", Precision::Int8).is_err());
        assert!(c.executable.is_empty());
        // output shapes match what the decision logic asserts per use case
        assert_eq!(c.manifest("vae", Precision::Fp32).unwrap().output_elems(), 12);
        assert_eq!(c.manifest("cnet", Precision::Fp32).unwrap().output_elems(), 1);
        assert_eq!(c.manifest("esperta", Precision::Fp32).unwrap().output_elems(), 12);
        assert_eq!(c.manifest("logistic", Precision::Fp32).unwrap().output_elems(), 4);
    }

    #[test]
    fn use_case_parse_roundtrip() {
        for uc in UseCase::ALL {
            assert_eq!(UseCase::parse(uc.as_str()).unwrap(), uc);
            assert_eq!(format!("{uc}"), uc.as_str());
        }
        assert!(UseCase::parse("lidar").is_err());
    }

    #[test]
    fn synthetic_baseline_spills_hls_bram() {
        // the stand-in must reproduce the paper's qualitative collapse:
        // BaselineNet's dense weights exceed the HLS BRAM budget
        let c = Catalog::synthetic();
        let man = c.manifest("baseline", Precision::Fp32).unwrap();
        let z = crate::board::Zcu104::default();
        let plan = crate::hls::BramAllocator::new(&z.pl).allocate(man);
        assert!(plan.spills(), "hidden dense must exceed the BRAM budget");
    }

    #[test]
    fn lookup() {
        assert!(model_info("vae").is_ok());
        assert!(model_info("nope").is_err());
        assert_eq!(model_info("cnet").unwrap().target, Target::Dpu);
        assert_eq!(model_info("baseline").unwrap().target, Target::Hls);
    }
}
