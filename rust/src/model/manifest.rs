//! Model manifests — the hw-codesign interchange format.
//!
//! The python compile path (`python -m compile.aot`) emits one manifest
//! JSON per model variant: per-layer kind, shapes, MAC/op/param counts and
//! byte footprints.  Every analytic simulator (A53, DPU, HLS) and the
//! resource estimator consume this structure; the PJRT runtime pairs it
//! with the matching `.hlo.txt` executable.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Numeric precision of a deployed variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// IEEE-754 binary32 — the CPU baseline and Vitis-HLS path.
    Fp32,
    /// INT8 post-training quantization — the Vitis-AI DPU path.
    Int8,
}

impl Precision {
    /// Parse the manifest spelling ("fp32" | "int8").
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "fp32" => Ok(Precision::Fp32),
            "int8" => Ok(Precision::Int8),
            _ => bail!("unknown precision {s:?}"),
        }
    }

    /// Artifact-tag spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
        }
    }
}

/// Activation function applied after a layer's linear part — typed, so
/// operator gates compare enum variants instead of raw manifest strings
/// (a typo like `"sigmiod"` is now a parse error at the manifest
/// boundary, not a silent pass through the DPU gate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// No activation (the layer is purely linear / data movement).
    None,
    /// Rectified linear unit.
    Relu,
    /// Leaky ReLU (the paper's Vitis-AI inspector rejects it).
    LeakyRelu,
    /// Logistic sigmoid (HLS-only; the DPU has no sigmoid core).
    Sigmoid,
}

impl Activation {
    /// Parse the manifest spelling ("none" | "relu" | "leaky_relu" |
    /// "sigmoid") — the exact set `python/compile/models/graph.py`
    /// emits.
    ///
    /// ```
    /// use spaceinfer::model::Activation;
    /// assert_eq!(Activation::parse("relu").unwrap(), Activation::Relu);
    /// assert!(Activation::parse("sigmiod").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Activation> {
        Ok(match s {
            "none" => Activation::None,
            "relu" => Activation::Relu,
            "leaky_relu" => Activation::LeakyRelu,
            "sigmoid" => Activation::Sigmoid,
            _ => bail!("unknown activation {s:?} (none | relu | leaky_relu | sigmoid)"),
        })
    }

    /// The manifest spelling of this activation.
    pub fn as_str(&self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "relu",
            Activation::LeakyRelu => "leaky_relu",
            Activation::Sigmoid => "sigmoid",
        }
    }

    /// Can the Vitis-AI DPU fuse this activation? (paper §III-B: no
    /// sigmoid, and the inspector also rejects leaky ReLU.)
    pub fn dpu_supported(&self) -> bool {
        matches!(self, Activation::None | Activation::Relu)
    }
}

/// Layer taxonomy shared with `python/compile/models/graph.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution (DPU-mappable).
    Conv2d,
    /// 3-D convolution (HLS-only; the DPU has no 3-D operators).
    Conv3d,
    /// 2-D max pooling.
    MaxPool2d,
    /// 3-D max pooling (HLS-only).
    MaxPool3d,
    /// 3-D average pooling (HLS-only).
    AvgPool3d,
    /// Reshape to a vector (pure data movement).
    Flatten,
    /// Append a scalar input to a feature vector (CNet's flux input).
    ConcatScalar,
    /// Fully-connected layer.
    Dense,
    /// Parallel dense heads sharing one input (multi-output).
    DenseHeads,
    /// Six single-MAC sigmoid+comparator models (multi-ESPERTA).
    EspertaBank,
}

impl LayerKind {
    /// Parse the manifest spelling ("conv2d", "dense", ...).
    pub fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv2d" => LayerKind::Conv2d,
            "conv3d" => LayerKind::Conv3d,
            "maxpool2d" => LayerKind::MaxPool2d,
            "maxpool3d" => LayerKind::MaxPool3d,
            "avgpool3d" => LayerKind::AvgPool3d,
            "flatten" => LayerKind::Flatten,
            "concat_scalar" => LayerKind::ConcatScalar,
            "dense" => LayerKind::Dense,
            "dense_heads" => LayerKind::DenseHeads,
            "esperta_bank" => LayerKind::EspertaBank,
            _ => bail!("unknown layer kind {s:?}"),
        })
    }

    /// Does this layer run MACs (vs pure data movement / reduction)?
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d
                | LayerKind::Conv3d
                | LayerKind::Dense
                | LayerKind::DenseHeads
                | LayerKind::EspertaBank
        )
    }

    /// Operators the Vitis-AI DPU supports (paper §III-B: no sigmoid /
    /// comparators / 3-D convolution / 3-D pooling).
    pub fn dpu_supported(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d
                | LayerKind::MaxPool2d
                | LayerKind::Flatten
                | LayerKind::ConcatScalar
                | LayerKind::Dense
                | LayerKind::DenseHeads
        )
    }
}

/// One layer of a model manifest.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Operator kind.
    pub kind: LayerKind,
    /// Input activation shape (leading batch dim of 1).
    pub in_shape: Vec<usize>,
    /// Output activation shape.
    pub out_shape: Vec<usize>,
    /// Multiply-accumulates per inference.
    pub macs: u64,
    /// Total operations per inference (DESIGN §8 convention).
    pub ops: u64,
    /// Learnable parameters.
    pub params: u64,
    /// Bytes of weights at the manifest's precision.
    pub weight_bytes: u64,
    /// Bytes of the output activation.
    pub act_bytes: u64,
    /// Activation function applied after the layer.
    pub act: Activation,
}

impl Layer {
    fn from_json(j: &Json) -> Result<Layer> {
        Ok(Layer {
            kind: LayerKind::parse(j.req("kind")?.as_str()?)?,
            in_shape: j.req("in_shape")?.as_shape()?,
            out_shape: j.req("out_shape")?.as_shape()?,
            macs: j.req("macs")?.as_i64()? as u64,
            ops: j.req("ops")?.as_i64()? as u64,
            params: j.req("params")?.as_i64()? as u64,
            weight_bytes: j.req("weight_bytes")?.as_i64()? as u64,
            act_bytes: j.req("act_bytes")?.as_i64()? as u64,
            act: Activation::parse(j.req("act")?.as_str()?)?,
        })
    }

    /// Elements in the output activation.
    pub fn out_elems(&self) -> u64 {
        self.out_shape.iter().skip(1).product::<usize>() as u64
    }

    /// Is this layer executable by the Vitis-AI DPU — operator *and*
    /// activation both inside the §III-B set?  The per-layer form of
    /// [`Manifest::dpu_compatible`]; the partitioner
    /// (`crate::plan`) uses it to find the maximal DPU-runnable
    /// subgraphs of an otherwise-incompatible model.
    pub fn dpu_mappable(&self) -> bool {
        self.kind.dpu_supported() && self.act.dpu_supported()
    }
}

/// A parsed model manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name (catalog key).
    pub name: String,
    /// Numeric precision of this variant.
    pub precision: Precision,
    /// Input name -> shape, in HLO parameter order.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Output tensor shape.
    pub output_shape: Vec<usize>,
    /// Per-layer descriptions, execution order.
    pub layers: Vec<Layer>,
    /// Sum of layer MACs (validated).
    pub total_macs: u64,
    /// Sum of layer ops (validated).
    pub total_ops: u64,
    /// Sum of layer params (validated).
    pub total_params: u64,
    /// Total weight bytes at this precision.
    pub weight_bytes: u64,
}

impl Manifest {
    /// Parse a manifest from its JSON document (validates totals and
    /// the layer shape chain).
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let order: Vec<String> = j
            .req("input_order")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let shapes = j.req("inputs")?.as_obj()?;
        let inputs = order
            .iter()
            .map(|n| {
                let shape = shapes
                    .get(n)
                    .with_context(|| format!("input {n} missing from shapes"))?
                    .as_shape()?;
                Ok((n.clone(), shape))
            })
            .collect::<Result<Vec<_>>>()?;
        let layers = j
            .req("layers")?
            .as_arr()?
            .iter()
            .map(Layer::from_json)
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            name: j.req("name")?.as_str()?.to_string(),
            precision: Precision::parse(j.req("precision")?.as_str()?)?,
            inputs,
            output_shape: j.req("output_shape")?.as_shape()?,
            layers,
            total_macs: j.req("total_macs")?.as_i64()? as u64,
            total_ops: j.req("total_ops")?.as_i64()? as u64,
            total_params: j.req("total_params")?.as_i64()? as u64,
            weight_bytes: j.req("weight_bytes")?.as_i64()? as u64,
        };
        m.validate()?;
        Ok(m)
    }

    /// Load and parse a `<tag>.manifest.json` file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Manifest::from_json(&Json::parse(&text)?)
    }

    /// Internal consistency: totals match layer sums, shapes chain.
    pub fn validate(&self) -> Result<()> {
        let macs: u64 = self.layers.iter().map(|l| l.macs).sum();
        let ops: u64 = self.layers.iter().map(|l| l.ops).sum();
        let params: u64 = self.layers.iter().map(|l| l.params).sum();
        if macs != self.total_macs || ops != self.total_ops || params != self.total_params {
            bail!(
                "manifest {:?}: totals disagree with layer sums \
                 (macs {} vs {}, ops {} vs {}, params {} vs {})",
                self.name, self.total_macs, macs, self.total_ops, ops,
                self.total_params, params
            );
        }
        for (a, b) in self.layers.iter().zip(self.layers.iter().skip(1)) {
            if a.out_shape != b.in_shape {
                bail!(
                    "manifest {:?}: layer shape chain broken ({:?} -> {:?})",
                    self.name, a.out_shape, b.in_shape
                );
            }
        }
        Ok(())
    }

    /// Total input elements (all inputs).
    pub fn input_elems(&self) -> u64 {
        self.inputs
            .iter()
            .map(|(_, s)| s.iter().product::<usize>() as u64)
            .sum()
    }

    /// Input bytes at fp32 (what the sensor DMA stages).
    pub fn input_bytes(&self) -> u64 {
        self.input_elems() * 4
    }

    /// Output elements.
    pub fn output_elems(&self) -> u64 {
        self.output_shape.iter().product::<usize>() as u64
    }

    /// Is every layer DPU-mappable? (paper §III-B gate for Vitis AI)
    pub fn dpu_compatible(&self) -> bool {
        self.layers.iter().all(Layer::dpu_mappable)
    }

    /// Sub-manifest over `layers[start..end)`: totals recomputed from
    /// the slice, input/output shapes taken from the boundary layers.
    /// The execution-plan partitioner evaluates the existing simulators
    /// on these to price each segment of a hybrid deployment.
    ///
    /// Panics when the range is empty or out of bounds (plan-layer
    /// callers partition `0..layers.len()` exactly).
    pub fn slice(&self, start: usize, end: usize) -> Manifest {
        assert!(start < end && end <= self.layers.len(), "bad slice {start}..{end}");
        let layers: Vec<Layer> = self.layers[start..end].to_vec();
        let inputs = if start == 0 {
            self.inputs.clone()
        } else {
            // interior boundary: the segment consumes the previous
            // segment's output activation as its sole input
            vec![("seg_in".to_string(), layers[0].in_shape.clone())]
        };
        Manifest {
            name: format!("{}[{start}..{end})", self.name),
            precision: self.precision,
            inputs,
            output_shape: layers.last().unwrap().out_shape.clone(),
            total_macs: layers.iter().map(|l| l.macs).sum(),
            total_ops: layers.iter().map(|l| l.ops).sum(),
            total_params: layers.iter().map(|l| l.params).sum(),
            weight_bytes: layers.iter().map(|l| l.weight_bytes).sum(),
            layers,
        }
    }

    /// Borrowed view of `layers[start..end)` — the allocation-free
    /// front door to [`Manifest::slice`].  Same bounds contract.
    pub fn view(&self, start: usize, end: usize) -> ManifestView<'_> {
        ManifestView::new(self, start, end)
    }
}

/// A borrowed layer range over a [`Manifest`].
///
/// The plan partitioner prices every candidate segment of every
/// candidate partition; materializing a fresh sub-manifest clone per
/// candidate (layer vectors, shape vectors, a formatted name) dominated
/// the planning hot path.  A view carries only `(&Manifest, start,
/// end)`: range queries read the parent in place, and
/// [`ManifestView::materialize`] returns `Cow::Borrowed` for the
/// full-range view — the common single-segment case prices with **zero
/// clones** — deferring the [`Manifest::slice`] allocation to proper
/// sub-ranges that genuinely need a standalone manifest.
#[derive(Debug, Clone, Copy)]
pub struct ManifestView<'a> {
    man: &'a Manifest,
    start: usize,
    end: usize,
}

impl<'a> ManifestView<'a> {
    /// View of `man.layers[start..end)`.  Panics on an empty or
    /// out-of-bounds range, exactly like [`Manifest::slice`].
    pub fn new(man: &'a Manifest, start: usize, end: usize) -> ManifestView<'a> {
        assert!(start < end && end <= man.layers.len(), "bad view {start}..{end}");
        ManifestView { man, start, end }
    }

    /// The viewed layers, borrowed from the parent manifest.
    pub fn layers(&self) -> &'a [Layer] {
        &self.man.layers[self.start..self.end]
    }

    /// Number of layers in view.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Always false (construction rejects empty ranges); present for
    /// clippy's `len`/`is_empty` pairing convention.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Does the view cover the whole parent manifest?
    pub fn is_full(&self) -> bool {
        self.start == 0 && self.end == self.man.layers.len()
    }

    /// A manifest for the viewed range: the parent itself (borrowed, no
    /// allocation) when the view is full, a [`Manifest::slice`] clone
    /// otherwise.
    pub fn materialize(&self) -> std::borrow::Cow<'a, Manifest> {
        if self.is_full() {
            std::borrow::Cow::Borrowed(self.man)
        } else {
            std::borrow::Cow::Owned(self.man.slice(self.start, self.end))
        }
    }
}

/// Shared test fixture (used by several modules' unit tests).
#[cfg(test)]
pub(crate) mod testdata {
    pub(crate) const MINI: &str = r#"{
      "name":"mini","precision":"fp32",
      "inputs":{"x":[1,4,4,1]},
      "input_order":["x"],
      "output_shape":[1,2],
      "layers":[
        {"kind":"conv2d","in_shape":[1,4,4,1],"out_shape":[1,4,4,2],
         "macs":288,"ops":640,"params":20,"weight_bytes":80,
         "act_bytes":128,"act":"relu"},
        {"kind":"flatten","in_shape":[1,4,4,2],"out_shape":[1,32],
         "macs":0,"ops":0,"params":0,"weight_bytes":0,
         "act_bytes":128,"act":"none"},
        {"kind":"dense","in_shape":[1,32],"out_shape":[1,2],
         "macs":64,"ops":130,"params":66,"weight_bytes":264,
         "act_bytes":8,"act":"none"}],
      "total_macs":352,"total_ops":770,"total_params":86,
      "weight_bytes":344}"#;
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "name":"mini","precision":"fp32",
      "inputs":{"x":[1,4,4,1]},
      "input_order":["x"],
      "output_shape":[1,2],
      "layers":[
        {"kind":"conv2d","in_shape":[1,4,4,1],"out_shape":[1,4,4,2],
         "macs":288,"ops":640,"params":20,"weight_bytes":80,
         "act_bytes":128,"act":"relu"},
        {"kind":"flatten","in_shape":[1,4,4,2],"out_shape":[1,32],
         "macs":0,"ops":0,"params":0,"weight_bytes":0,
         "act_bytes":128,"act":"none"},
        {"kind":"dense","in_shape":[1,32],"out_shape":[1,2],
         "macs":64,"ops":130,"params":66,"weight_bytes":264,
         "act_bytes":8,"act":"none"}],
      "total_macs":352,"total_ops":770,"total_params":86,
      "weight_bytes":344}"#;

    #[test]
    fn parses_mini() {
        let m = Manifest::from_json(&Json::parse(MINI).unwrap()).unwrap();
        assert_eq!(m.name, "mini");
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.total_params, 86);
        assert!(m.dpu_compatible());
        assert_eq!(m.input_bytes(), 64);
        assert_eq!(m.output_elems(), 2);
    }

    #[test]
    fn full_view_materializes_without_cloning() {
        let m = Manifest::from_json(&Json::parse(MINI).unwrap()).unwrap();
        let v = m.view(0, m.layers.len());
        assert!(v.is_full());
        assert_eq!(v.len(), 3);
        let cow = v.materialize();
        assert!(
            matches!(cow, std::borrow::Cow::Borrowed(_)),
            "full-range view must borrow, not clone"
        );
        assert!(std::ptr::eq(&*cow, &m), "borrowed manifest is the parent itself");
    }

    #[test]
    fn partial_view_matches_slice_bit_for_bit() {
        let m = Manifest::from_json(&Json::parse(MINI).unwrap()).unwrap();
        let v = m.view(1, 3);
        assert!(!v.is_full());
        assert_eq!(v.layers().len(), 2);
        let cow = v.materialize();
        assert!(matches!(cow, std::borrow::Cow::Owned(_)));
        let sliced = m.slice(1, 3);
        assert_eq!(cow.name, sliced.name);
        assert_eq!(cow.total_macs, sliced.total_macs);
        assert_eq!(cow.weight_bytes, sliced.weight_bytes);
        assert_eq!(cow.layers.len(), sliced.layers.len());
    }

    #[test]
    fn rejects_bad_totals() {
        let bad = MINI.replace("\"total_macs\":352", "\"total_macs\":999");
        assert!(Manifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_broken_chain() {
        let bad = MINI.replace(
            "\"kind\":\"flatten\",\"in_shape\":[1,4,4,2]",
            "\"kind\":\"flatten\",\"in_shape\":[1,9,9,2]",
        );
        assert!(Manifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn sigmoid_blocks_dpu() {
        let s = MINI.replace("\"act\":\"relu\"", "\"act\":\"sigmoid\"");
        let m = Manifest::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert!(!m.dpu_compatible());
    }

    #[test]
    fn conv3d_blocks_dpu() {
        assert!(!LayerKind::Conv3d.dpu_supported());
        assert!(!LayerKind::MaxPool3d.dpu_supported());
        assert!(LayerKind::Conv2d.dpu_supported());
    }

    #[test]
    fn precision_roundtrip() {
        assert_eq!(Precision::parse("fp32").unwrap(), Precision::Fp32);
        assert_eq!(Precision::Int8.as_str(), "int8");
        assert!(Precision::parse("fp16").is_err());
    }

    #[test]
    fn activation_roundtrip_and_gate() {
        for a in [
            Activation::None,
            Activation::Relu,
            Activation::LeakyRelu,
            Activation::Sigmoid,
        ] {
            assert_eq!(Activation::parse(a.as_str()).unwrap(), a);
        }
        // the typo that used to slip through the stringly gate is now
        // rejected at parse time
        assert!(Activation::parse("sigmiod").is_err());
        let bad = MINI.replace("\"act\":\"relu\"", "\"act\":\"sigmiod\"");
        assert!(Manifest::from_json(&Json::parse(&bad).unwrap()).is_err());
        assert!(Activation::Relu.dpu_supported());
        assert!(!Activation::Sigmoid.dpu_supported());
        assert!(!Activation::LeakyRelu.dpu_supported());
    }

    #[test]
    fn layer_level_gate_matches_model_level() {
        let m = Manifest::from_json(&Json::parse(MINI).unwrap()).unwrap();
        assert!(m.layers.iter().all(Layer::dpu_mappable));
        let s = MINI.replace("\"act\":\"relu\"", "\"act\":\"sigmoid\"");
        let m = Manifest::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert!(!m.layers[0].dpu_mappable(), "sigmoid conv is off the DPU");
        assert!(m.layers[2].dpu_mappable(), "the dense tail stays mappable");
        assert_eq!(m.dpu_compatible(), m.layers.iter().all(Layer::dpu_mappable));
    }

    #[test]
    fn slice_recomputes_totals_and_boundaries() {
        let m = Manifest::from_json(&Json::parse(MINI).unwrap()).unwrap();
        let head = m.slice(0, 1);
        assert_eq!(head.layers.len(), 1);
        assert_eq!(head.total_macs, 288);
        assert_eq!(head.inputs, m.inputs, "prefix keeps the sensor inputs");
        assert_eq!(head.output_shape, vec![1, 4, 4, 2]);
        let tail = m.slice(1, 3);
        assert_eq!(tail.total_macs, 64);
        assert_eq!(tail.total_params, 66);
        assert_eq!(tail.inputs[0].1, vec![1, 4, 4, 2], "boundary activation in");
        assert_eq!(tail.output_shape, m.output_shape);
        tail.validate().unwrap();
        // the whole-model slice is the manifest itself, totals included
        let all = m.slice(0, 3);
        assert_eq!(all.total_ops, m.total_ops);
        assert_eq!(all.weight_bytes, m.weight_bytes);
    }
}
