//! Model manifests — the hw-codesign interchange format.
//!
//! The python compile path (`python -m compile.aot`) emits one manifest
//! JSON per model variant: per-layer kind, shapes, MAC/op/param counts and
//! byte footprints.  Every analytic simulator (A53, DPU, HLS) and the
//! resource estimator consume this structure; the PJRT runtime pairs it
//! with the matching `.hlo.txt` executable.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Numeric precision of a deployed variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// IEEE-754 binary32 — the CPU baseline and Vitis-HLS path.
    Fp32,
    /// INT8 post-training quantization — the Vitis-AI DPU path.
    Int8,
}

impl Precision {
    /// Parse the manifest spelling ("fp32" | "int8").
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "fp32" => Ok(Precision::Fp32),
            "int8" => Ok(Precision::Int8),
            _ => bail!("unknown precision {s:?}"),
        }
    }

    /// Artifact-tag spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
        }
    }
}

/// Layer taxonomy shared with `python/compile/models/graph.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution (DPU-mappable).
    Conv2d,
    /// 3-D convolution (HLS-only; the DPU has no 3-D operators).
    Conv3d,
    /// 2-D max pooling.
    MaxPool2d,
    /// 3-D max pooling (HLS-only).
    MaxPool3d,
    /// 3-D average pooling (HLS-only).
    AvgPool3d,
    /// Reshape to a vector (pure data movement).
    Flatten,
    /// Append a scalar input to a feature vector (CNet's flux input).
    ConcatScalar,
    /// Fully-connected layer.
    Dense,
    /// Parallel dense heads sharing one input (multi-output).
    DenseHeads,
    /// Six single-MAC sigmoid+comparator models (multi-ESPERTA).
    EspertaBank,
}

impl LayerKind {
    /// Parse the manifest spelling ("conv2d", "dense", ...).
    pub fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv2d" => LayerKind::Conv2d,
            "conv3d" => LayerKind::Conv3d,
            "maxpool2d" => LayerKind::MaxPool2d,
            "maxpool3d" => LayerKind::MaxPool3d,
            "avgpool3d" => LayerKind::AvgPool3d,
            "flatten" => LayerKind::Flatten,
            "concat_scalar" => LayerKind::ConcatScalar,
            "dense" => LayerKind::Dense,
            "dense_heads" => LayerKind::DenseHeads,
            "esperta_bank" => LayerKind::EspertaBank,
            _ => bail!("unknown layer kind {s:?}"),
        })
    }

    /// Does this layer run MACs (vs pure data movement / reduction)?
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d
                | LayerKind::Conv3d
                | LayerKind::Dense
                | LayerKind::DenseHeads
                | LayerKind::EspertaBank
        )
    }

    /// Operators the Vitis-AI DPU supports (paper §III-B: no sigmoid /
    /// comparators / 3-D convolution / 3-D pooling).
    pub fn dpu_supported(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv2d
                | LayerKind::MaxPool2d
                | LayerKind::Flatten
                | LayerKind::ConcatScalar
                | LayerKind::Dense
                | LayerKind::DenseHeads
        )
    }
}

/// One layer of a model manifest.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Operator kind.
    pub kind: LayerKind,
    /// Input activation shape (leading batch dim of 1).
    pub in_shape: Vec<usize>,
    /// Output activation shape.
    pub out_shape: Vec<usize>,
    /// Multiply-accumulates per inference.
    pub macs: u64,
    /// Total operations per inference (DESIGN §8 convention).
    pub ops: u64,
    /// Learnable parameters.
    pub params: u64,
    /// Bytes of weights at the manifest's precision.
    pub weight_bytes: u64,
    /// Bytes of the output activation.
    pub act_bytes: u64,
    /// Activation function name ("none" | "relu" | "leaky_relu" | "sigmoid").
    pub act: String,
}

impl Layer {
    fn from_json(j: &Json) -> Result<Layer> {
        Ok(Layer {
            kind: LayerKind::parse(j.req("kind")?.as_str()?)?,
            in_shape: j.req("in_shape")?.as_shape()?,
            out_shape: j.req("out_shape")?.as_shape()?,
            macs: j.req("macs")?.as_i64()? as u64,
            ops: j.req("ops")?.as_i64()? as u64,
            params: j.req("params")?.as_i64()? as u64,
            weight_bytes: j.req("weight_bytes")?.as_i64()? as u64,
            act_bytes: j.req("act_bytes")?.as_i64()? as u64,
            act: j.req("act")?.as_str()?.to_string(),
        })
    }

    /// Elements in the output activation.
    pub fn out_elems(&self) -> u64 {
        self.out_shape.iter().skip(1).product::<usize>() as u64
    }
}

/// A parsed model manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name (catalog key).
    pub name: String,
    /// Numeric precision of this variant.
    pub precision: Precision,
    /// Input name -> shape, in HLO parameter order.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// Output tensor shape.
    pub output_shape: Vec<usize>,
    /// Per-layer descriptions, execution order.
    pub layers: Vec<Layer>,
    /// Sum of layer MACs (validated).
    pub total_macs: u64,
    /// Sum of layer ops (validated).
    pub total_ops: u64,
    /// Sum of layer params (validated).
    pub total_params: u64,
    /// Total weight bytes at this precision.
    pub weight_bytes: u64,
}

impl Manifest {
    /// Parse a manifest from its JSON document (validates totals and
    /// the layer shape chain).
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let order: Vec<String> = j
            .req("input_order")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let shapes = j.req("inputs")?.as_obj()?;
        let inputs = order
            .iter()
            .map(|n| {
                let shape = shapes
                    .get(n)
                    .with_context(|| format!("input {n} missing from shapes"))?
                    .as_shape()?;
                Ok((n.clone(), shape))
            })
            .collect::<Result<Vec<_>>>()?;
        let layers = j
            .req("layers")?
            .as_arr()?
            .iter()
            .map(Layer::from_json)
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            name: j.req("name")?.as_str()?.to_string(),
            precision: Precision::parse(j.req("precision")?.as_str()?)?,
            inputs,
            output_shape: j.req("output_shape")?.as_shape()?,
            layers,
            total_macs: j.req("total_macs")?.as_i64()? as u64,
            total_ops: j.req("total_ops")?.as_i64()? as u64,
            total_params: j.req("total_params")?.as_i64()? as u64,
            weight_bytes: j.req("weight_bytes")?.as_i64()? as u64,
        };
        m.validate()?;
        Ok(m)
    }

    /// Load and parse a `<tag>.manifest.json` file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Manifest::from_json(&Json::parse(&text)?)
    }

    /// Internal consistency: totals match layer sums, shapes chain.
    pub fn validate(&self) -> Result<()> {
        let macs: u64 = self.layers.iter().map(|l| l.macs).sum();
        let ops: u64 = self.layers.iter().map(|l| l.ops).sum();
        let params: u64 = self.layers.iter().map(|l| l.params).sum();
        if macs != self.total_macs || ops != self.total_ops || params != self.total_params {
            bail!(
                "manifest {:?}: totals disagree with layer sums \
                 (macs {} vs {}, ops {} vs {}, params {} vs {})",
                self.name, self.total_macs, macs, self.total_ops, ops,
                self.total_params, params
            );
        }
        for (a, b) in self.layers.iter().zip(self.layers.iter().skip(1)) {
            if a.out_shape != b.in_shape {
                bail!(
                    "manifest {:?}: layer shape chain broken ({:?} -> {:?})",
                    self.name, a.out_shape, b.in_shape
                );
            }
        }
        Ok(())
    }

    /// Total input elements (all inputs).
    pub fn input_elems(&self) -> u64 {
        self.inputs
            .iter()
            .map(|(_, s)| s.iter().product::<usize>() as u64)
            .sum()
    }

    /// Input bytes at fp32 (what the sensor DMA stages).
    pub fn input_bytes(&self) -> u64 {
        self.input_elems() * 4
    }

    /// Output elements.
    pub fn output_elems(&self) -> u64 {
        self.output_shape.iter().product::<usize>() as u64
    }

    /// Is every layer DPU-mappable? (paper §III-B gate for Vitis AI)
    pub fn dpu_compatible(&self) -> bool {
        self.layers.iter().all(|l| l.kind.dpu_supported())
            && !self.layers.iter().any(|l| l.act == "sigmoid" || l.act == "leaky_relu")
    }
}

/// Shared test fixture (used by several modules' unit tests).
#[cfg(test)]
pub(crate) mod testdata {
    pub(crate) const MINI: &str = r#"{
      "name":"mini","precision":"fp32",
      "inputs":{"x":[1,4,4,1]},
      "input_order":["x"],
      "output_shape":[1,2],
      "layers":[
        {"kind":"conv2d","in_shape":[1,4,4,1],"out_shape":[1,4,4,2],
         "macs":288,"ops":640,"params":20,"weight_bytes":80,
         "act_bytes":128,"act":"relu"},
        {"kind":"flatten","in_shape":[1,4,4,2],"out_shape":[1,32],
         "macs":0,"ops":0,"params":0,"weight_bytes":0,
         "act_bytes":128,"act":"none"},
        {"kind":"dense","in_shape":[1,32],"out_shape":[1,2],
         "macs":64,"ops":130,"params":66,"weight_bytes":264,
         "act_bytes":8,"act":"none"}],
      "total_macs":352,"total_ops":770,"total_params":86,
      "weight_bytes":344}"#;
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "name":"mini","precision":"fp32",
      "inputs":{"x":[1,4,4,1]},
      "input_order":["x"],
      "output_shape":[1,2],
      "layers":[
        {"kind":"conv2d","in_shape":[1,4,4,1],"out_shape":[1,4,4,2],
         "macs":288,"ops":640,"params":20,"weight_bytes":80,
         "act_bytes":128,"act":"relu"},
        {"kind":"flatten","in_shape":[1,4,4,2],"out_shape":[1,32],
         "macs":0,"ops":0,"params":0,"weight_bytes":0,
         "act_bytes":128,"act":"none"},
        {"kind":"dense","in_shape":[1,32],"out_shape":[1,2],
         "macs":64,"ops":130,"params":66,"weight_bytes":264,
         "act_bytes":8,"act":"none"}],
      "total_macs":352,"total_ops":770,"total_params":86,
      "weight_bytes":344}"#;

    #[test]
    fn parses_mini() {
        let m = Manifest::from_json(&Json::parse(MINI).unwrap()).unwrap();
        assert_eq!(m.name, "mini");
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.total_params, 86);
        assert!(m.dpu_compatible());
        assert_eq!(m.input_bytes(), 64);
        assert_eq!(m.output_elems(), 2);
    }

    #[test]
    fn rejects_bad_totals() {
        let bad = MINI.replace("\"total_macs\":352", "\"total_macs\":999");
        assert!(Manifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_broken_chain() {
        let bad = MINI.replace(
            "\"kind\":\"flatten\",\"in_shape\":[1,4,4,2]",
            "\"kind\":\"flatten\",\"in_shape\":[1,9,9,2]",
        );
        assert!(Manifest::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn sigmoid_blocks_dpu() {
        let s = MINI.replace("\"act\":\"relu\"", "\"act\":\"sigmoid\"");
        let m = Manifest::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert!(!m.dpu_compatible());
    }

    #[test]
    fn conv3d_blocks_dpu() {
        assert!(!LayerKind::Conv3d.dpu_supported());
        assert!(!LayerKind::MaxPool3d.dpu_supported());
        assert!(LayerKind::Conv2d.dpu_supported());
    }

    #[test]
    fn precision_roundtrip() {
        assert_eq!(Precision::parse("fp32").unwrap(), Precision::Fp32);
        assert_eq!(Precision::Int8.as_str(), "int8");
        assert!(Precision::parse("fp16").is_err());
    }
}
