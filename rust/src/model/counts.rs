//! Cross-language count validation.
//!
//! Recomputes MAC/op/param counts from layer dimensions alone, under the
//! DESIGN.md §8 convention, and checks them against what the python side
//! wrote into the manifest.  Any drift between the two implementations of
//! the convention fails loudly (used by integration tests and `inspect`).

use anyhow::{bail, Result};

use super::manifest::{Activation, Layer, LayerKind, Manifest};

/// Recomputed counts for one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Counts {
    /// Multiply-accumulates.
    pub macs: u64,
    /// Total operations (DESIGN §8 convention).
    pub ops: u64,
    /// Learnable parameters.
    pub params: u64,
}

/// Recompute counts for a layer from its shapes (DESIGN §8 convention).
pub fn recount(layer: &Layer) -> Result<Counts> {
    let out_elems = layer.out_elems();
    let has_act = layer.act != Activation::None;
    let counts = match layer.kind {
        LayerKind::Conv2d | LayerKind::Conv3d => {
            let cin = *layer.in_shape.last().unwrap() as u64;
            let cout = *layer.out_shape.last().unwrap() as u64;
            // kernel volume from params: params = cout*(k^d*cin + 1)
            if layer.params == 0 || layer.params % cout != 0 {
                bail!("conv params {} not divisible by cout {cout}", layer.params);
            }
            let kvol = layer.params / cout - 1;
            if kvol % cin != 0 {
                bail!("conv kernel volume {kvol} not divisible by cin {cin}");
            }
            let macs = out_elems * kvol;
            let mut ops = 2 * macs + out_elems;
            if has_act {
                ops += out_elems;
            }
            Counts { macs, ops, params: cout * (kvol + 1) }
        }
        LayerKind::Dense => {
            let din = layer.in_shape[1] as u64;
            let dout = layer.out_shape[1] as u64;
            let macs = din * dout;
            let mut ops = 2 * macs + dout;
            if has_act {
                ops += dout;
            }
            Counts { macs, ops, params: dout * (din + 1) }
        }
        LayerKind::DenseHeads => {
            let din = layer.in_shape[1] as u64;
            let width = layer.out_shape[1] as u64; // heads * dout
            let macs = din * width;
            let ops = 2 * macs + width;
            Counts { macs, ops, params: width * (din + 1) }
        }
        LayerKind::EspertaBank => {
            let din = layer.in_shape[1] as u64;
            let n = layer.out_shape[1] as u64 / 2;
            let macs = n * din;
            Counts { macs, ops: 2 * macs + 3 * n, params: n * (din + 1) }
        }
        LayerKind::MaxPool2d | LayerKind::MaxPool3d => {
            let in_elems: u64 = layer.in_shape.iter().skip(1).product::<usize>() as u64;
            let win = in_elems / out_elems;
            Counts { macs: 0, ops: out_elems * (win - 1), params: 0 }
        }
        LayerKind::AvgPool3d => {
            let in_elems: u64 = layer.in_shape.iter().skip(1).product::<usize>() as u64;
            let win = in_elems / out_elems;
            Counts { macs: 0, ops: out_elems * win, params: 0 }
        }
        LayerKind::Flatten | LayerKind::ConcatScalar => {
            Counts { macs: 0, ops: 0, params: 0 }
        }
    };
    Ok(counts)
}

/// Validate every layer of a manifest against the recomputation.
pub fn validate_manifest(man: &Manifest) -> Result<()> {
    for (i, layer) in man.layers.iter().enumerate() {
        let c = recount(layer)?;
        if c.macs != layer.macs || c.ops != layer.ops || c.params != layer.params {
            bail!(
                "manifest {:?} layer {i} ({:?}): python says \
                 macs={} ops={} params={}, rust recount says \
                 macs={} ops={} params={}",
                man.name, layer.kind, layer.macs, layer.ops, layer.params,
                c.macs, c.ops, c.params
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn mini() -> Manifest {
        let src = r#"{
          "name":"mini","precision":"fp32",
          "inputs":{"x":[1,4,4,1]},
          "input_order":["x"],
          "output_shape":[1,2],
          "layers":[
            {"kind":"conv2d","in_shape":[1,4,4,1],"out_shape":[1,4,4,2],
             "macs":288,"ops":640,"params":20,"weight_bytes":80,
             "act_bytes":128,"act":"relu"},
            {"kind":"flatten","in_shape":[1,4,4,2],"out_shape":[1,32],
             "macs":0,"ops":0,"params":0,"weight_bytes":0,
             "act_bytes":128,"act":"none"},
            {"kind":"dense","in_shape":[1,32],"out_shape":[1,2],
             "macs":64,"ops":130,"params":66,"weight_bytes":264,
             "act_bytes":8,"act":"none"}],
          "total_macs":352,"total_ops":770,"total_params":86,
          "weight_bytes":344}"#;
        Manifest::from_json(&Json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn conv_recount_matches() {
        let m = mini();
        // conv2d 1->2 k3 on 4x4: macs = 32 out * 9 = 288
        let c = recount(&m.layers[0]).unwrap();
        assert_eq!(c, Counts { macs: 288, ops: 640, params: 20 });
        validate_manifest(&m).unwrap();
    }

    #[test]
    fn detects_drift() {
        let mut m = mini();
        m.layers[2].macs = 63;
        assert!(validate_manifest(&m).is_err());
    }

    #[test]
    fn dense_recount() {
        let m = mini();
        let c = recount(&m.layers[2]).unwrap();
        assert_eq!(c.macs, 64);
        assert_eq!(c.params, 66);
    }
}
