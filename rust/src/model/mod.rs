//! Model metadata: manifests (the python->rust interchange), the model
//! catalog, and count cross-checks.

pub mod catalog;
pub mod counts;
pub mod manifest;

pub use catalog::{Catalog, ModelInfo, UseCase};
pub use manifest::{Activation, Layer, LayerKind, Manifest, ManifestView, Precision};
