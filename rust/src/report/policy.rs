//! Policy comparison: the same workload run under every dispatch
//! policy, side by side — the report artifact for the trade-space the
//! paper measures row by row (Table III) and the dispatcher exploits at
//! runtime.  Timing-only runs (deterministic surrogate numerics), so
//! the table regenerates without artifacts or PJRT.

use anyhow::Result;

use crate::backend::TargetSet;
use crate::board::Calibration;
use crate::coordinator::{Pipeline, PipelineConfig, Policy};
use crate::model::catalog::Catalog;
use crate::model::UseCase;
use crate::util::table::{eng, Table};

/// Knobs for one policy-comparison run.
#[derive(Debug, Clone)]
pub struct PolicyRun {
    /// Which paper use case the comparison runs.
    pub use_case: UseCase,
    /// Events per run.
    pub n_events: usize,
    /// Sensor cadence (s).
    pub cadence_s: f64,
    /// Batcher flush threshold (events).
    pub max_batch: usize,
    /// Batcher wait budget (s) — must sit below the deadline for the
    /// `deadline` row to be meetable (the batch spends this long
    /// waiting before dispatch even starts).
    pub max_wait_s: f64,
    /// Mission power budget (W), applied to every dynamic policy.
    pub power_budget_w: Option<f64>,
    /// Deadline override (s); `None` = per-use-case default.
    pub deadline_s: Option<f64>,
    /// MMS sub-model selector.
    pub mms_model: String,
    /// RNG seed (sensors + decisions).
    pub seed: u64,
    /// Which backend targets every policy row dispatches over.
    pub targets: TargetSet,
    /// Bounded sensor-ingress queue capacity; `None` (default) admits
    /// every event.  When set, the Drops column shows the decimation
    /// each policy's backlog forces.
    pub ingress_cap: Option<usize>,
}

impl Default for PolicyRun {
    fn default() -> Self {
        PolicyRun {
            use_case: UseCase::Mms,
            n_events: 200,
            cadence_s: 0.15,
            max_batch: 8,
            max_wait_s: 0.5,
            power_budget_w: None,
            deadline_s: None,
            // match `spaceinfer pipeline`'s default MMS sub-model so the
            // two subcommands evaluate the same workload
            mms_model: "baseline".into(),
            seed: 7,
            targets: TargetSet::Default,
            ingress_cap: None,
        }
    }
}

/// Run the configured workload under all four policies and tabulate
/// target mix, latency, energy, deadline misses, and power sheds.
pub fn policy_comparison(
    catalog: &Catalog,
    calib: &Calibration,
    run: &PolicyRun,
) -> Result<Table> {
    let mut t = Table::new(
        &format!(
            "Dispatch policy comparison [{}] ({} events @ {} ev/s{})",
            run.use_case,
            run.n_events,
            eng(1.0 / run.cadence_s.max(1e-12)),
            match run.power_budget_w {
                Some(b) => format!(", budget {b} W"),
                None => String::new(),
            },
        ),
        &[
            "Policy",
            "Target mix (batches)",
            "Mean lat (s)",
            "p95 (s)",
            "p99 (s)",
            "Energy (J)",
            "Deadline misses",
            "Power sheds",
            "Drops",
        ],
    );
    for policy in [
        Policy::Static,
        Policy::MinLatency,
        Policy::MinEnergy,
        Policy::Deadline,
    ] {
        let cfg = PipelineConfig {
            use_case: run.use_case,
            n_events: run.n_events,
            cadence_s: run.cadence_s,
            max_batch: run.max_batch,
            max_wait_s: run.max_wait_s,
            mms_model: run.mms_model.clone(),
            seed: run.seed,
            targets: run.targets.clone(),
            policy,
            deadline_s: run.deadline_s,
            power_budget_w: run.power_budget_w,
            ingress_cap: run.ingress_cap,
            ..Default::default()
        };
        let report = Pipeline::new(cfg, catalog, calib)?.run(None)?;
        t.row(vec![
            policy.as_str().to_string(),
            report.target_mix_str(),
            format!("{:.4}", report.mean_latency_s),
            format!("{:.4}", report.p95_latency_s),
            format!("{:.4}", report.p99_latency_s),
            format!("{:.3}", report.energy_j),
            report.deadline_misses.to_string(),
            report.power_sheds.to_string(),
            report.ingress_dropped.to_string(),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_runs_on_synthetic_catalog() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let run = PolicyRun { use_case: UseCase::Vae, n_events: 64, ..Default::default() };
        let t = policy_comparison(&catalog, &calib, &run).unwrap();
        assert_eq!(t.rows.len(), 4);
        let rendered = t.render();
        assert!(rendered.contains("static"));
        assert!(rendered.contains("min-energy"));
    }

    #[test]
    fn ingress_cap_surfaces_drops_column() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        // BaselineNet saturates at survey cadence: with a bounded
        // ingress the decimation must be visible, not silent
        let t = policy_comparison(
            &catalog,
            &calib,
            &PolicyRun {
                use_case: UseCase::Mms,
                n_events: 100,
                ingress_cap: Some(8),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(t.header.last().map(String::as_str), Some("Drops"));
        let static_drops: u64 = t.rows[0].last().unwrap().parse().unwrap();
        assert!(static_drops > 0, "saturated static row must show drops");
        // without a queue every policy's Drops column reads 0
        let free = policy_comparison(
            &catalog,
            &calib,
            &PolicyRun { use_case: UseCase::Mms, n_events: 100, ..Default::default() },
        )
        .unwrap();
        assert!(free.rows.iter().all(|r| r.last().unwrap() == "0"));
    }

    #[test]
    fn budget_changes_the_energy_row() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let free = policy_comparison(
            &catalog,
            &calib,
            &PolicyRun { use_case: UseCase::Vae, n_events: 64, ..Default::default() },
        )
        .unwrap();
        let capped = policy_comparison(
            &catalog,
            &calib,
            &PolicyRun {
                use_case: UseCase::Vae,
                n_events: 64,
                power_budget_w: Some(4.0),
                ..Default::default()
            },
        )
        .unwrap();
        // row 2 = min-energy: 4 W excludes the DPU, so the mix differs
        assert_ne!(free.rows[2][1], capped.rows[2][1]);
    }
}
