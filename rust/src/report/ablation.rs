//! A1/A2 ablations (paper §IV):
//!
//! * **A1 — CNet modifications**: pooling removed, parameters reduced to
//!   VAE-like levels, scalar input removed — the paper observes the CPU
//!   benefits more than the DPU from the shrink, so the *speedup*
//!   shrinks.
//! * **HLS what-if**: burst-capable AXI (the pragma the naive flow
//!   omits) against BaselineNet's DRAM-bound collapse.

use anyhow::Result;

use crate::board::{Calibration, Zcu104};
use crate::cpu::A53Model;
use crate::dpu::{DpuArch, DpuSchedule};
use crate::hls::{AxiMaster, BramAllocator, HlsDesign};
use crate::model::catalog::{model_info, Catalog};
use crate::model::Precision;
use crate::util::table::{commas, eng, Table};

/// A1: CNet variants on CPU + DPU.
///
/// The CPU baseline for the variants scales the calibrated full-CNet
/// efficiency (same framework, same kernel mix); the DPU numbers come
/// from the mechanism model directly.
pub fn cnet_ablation(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let board = Zcu104::default();
    let info = model_info("cnet")?;
    let full_cpu_man = catalog.manifest("cnet", Precision::Fp32)?;
    let anchored = A53Model::calibrated(full_cpu_man, calib, info.paper.cpu_fps);

    let mut t = Table::new(
        "A1: CNetPlusScalar ablations (paper §IV)",
        &["Variant", "Params", "Ops", "CPU FPS", "DPU FPS", "Speedup"],
    );
    for (tag, label) in [
        ("cnet.int8", "full (deployed)"),
        ("cnet_nopool.int8", "(i) pooling removed"),
        ("cnet_small.int8", "(ii) VAE-sized"),
        ("cnet_noscalar.int8", "(iii) scalar removed"),
    ] {
        let man = catalog
            .manifests
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("missing manifest {tag}"))?;
        let cpu = A53Model::with_util(man, calib, anchored.util);
        let sched = DpuSchedule::new(
            man,
            DpuArch::b4096(calib, board.dpu_clock_hz),
            calib,
            board.axi_bandwidth,
        )?;
        t.row(vec![
            label.to_string(),
            commas(man.total_params),
            commas(man.total_ops),
            eng(cpu.fps()),
            eng(sched.fps()),
            format!("{}x", eng(sched.fps() / cpu.fps())),
        ]);
    }
    Ok(t)
}

/// ESPERTA packing ablation: sequential six single models vs the fused
/// parallel multi-ESPERTA (paper §III-A.3: "reduces control overhead").
pub fn esperta_packing(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let board = Zcu104::default();
    let multi = catalog.manifest("esperta", Precision::Fp32)?;
    let single = catalog.manifest("esperta_single", Precision::Fp32)?;
    let d_multi = HlsDesign::synthesize(multi, &board, calib);
    let d_single = HlsDesign::synthesize(single, &board, calib);
    let t_multi = d_multi.latency_s();
    let t_seq = 6.0 * d_single.latency_s(); // six sequential invocations
    let mut t = Table::new(
        "ESPERTA packing: parallel multi-model vs 6x sequential",
        &["Configuration", "Latency (us)", "FPS(all six)", "vs sequential"],
    );
    t.row(vec![
        "6x sequential single".into(),
        eng(1e6 * t_seq),
        eng(1.0 / t_seq),
        "1x".into(),
    ]);
    t.row(vec![
        "multi-ESPERTA (fused)".into(),
        eng(1e6 * t_multi),
        eng(1.0 / t_multi),
        format!("{}x", eng(t_seq / t_multi)),
    ]);
    Ok(t)
}

/// HLS what-if: AXI burst inference against the naive single-beat master
/// (what one pragma would have bought BaselineNet).
pub fn axi_burst_whatif(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let board = Zcu104::default();
    let man = catalog.manifest("baseline", Precision::Fp32)?;
    let design = HlsDesign::synthesize(man, &board, calib);
    let plan = BramAllocator::new(&board.pl).allocate(man);
    let spilled = plan.dram_weight_bytes;
    let mut t = Table::new(
        "What-if: AXI burst length vs BaselineNet weight-fetch stall",
        &["Burst", "Fetch cycles", "Total latency (s)", "FPS"],
    );
    for burst in [1u64, 4, 16, 64, 256] {
        let axi = AxiMaster::bursting(board.ddr_word_cycles, burst);
        let fetch = axi.fetch_cycles(spilled);
        let base_cycles = design.total_cycles()
            - design.fetch_cycles.iter().sum::<f64>();
        let total = base_cycles + fetch;
        let lat = total / board.hls_clock_hz;
        t.row(vec![
            format!("{burst}"),
            eng(fetch),
            eng(lat),
            eng(1.0 / lat),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    // exercised end-to-end by tests/integration.rs (requires artifacts/)
}
