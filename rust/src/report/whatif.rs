//! Extension what-ifs from the paper's §VI future-work list:
//! frequency scaling, pruning/sparsity, and scrubbing/TMR hardening.

use anyhow::Result;

use crate::board::{Calibration, Zcu104};
use crate::hls::{BramAllocator, HlsDesign};
use crate::model::catalog::{model_info, Catalog, Target, MODELS};
use crate::model::{Manifest, Precision};
use crate::power::{energy_mj, Implementation, PowerModel};
use crate::rad::scrub::ScrubPolicy;
use crate::rad::seu::{essential_bits, Orbit, SeuEnvironment};
use crate::rad::tmr::{apply_tmr, residual_p_fault};
use crate::resources::estimate_hls;
use crate::util::table::{eng, Table};

/// Frequency-scaling what-if for the HLS designs (paper §VI: "headroom
/// for further power optimization through frequency scaling").
///
/// Naive HLS latency is cycle-bound, so latency scales 1/f while the PL
/// dynamic power term scales ~f (and a small voltage co-scaling term
/// below nominal); energy per inference therefore has a shallow optimum.
pub fn frequency_scaling(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let board = Zcu104::default();
    let mut t = Table::new(
        "What-if: HLS clock scaling (LogisticNet)",
        &["Clock (MHz)", "FPS", "P_MPSoC (W)", "E/inf (mJ)", "vs 100 MHz"],
    );
    let man = catalog.manifest("logistic", Precision::Fp32)?;
    let base_design = HlsDesign::synthesize(man, &board, calib);
    let util = estimate_hls(man, &base_design.plan);
    let pm = PowerModel::new(calib.clone());
    let base_p = pm.mpsoc_w(&Implementation::Hls {
        kiloluts: util.luts as f64 / 1000.0,
        brams: base_design.plan.brams(),
        duty: 1.0,
    });
    // split static vs frequency-scaled part of the design's power
    let p_static = calib.p_hls_base;
    let p_dyn_100 = base_p - p_static;
    let e_100 = energy_mj(base_p, base_design.total_cycles() / 100.0e6);
    for mhz in [25.0, 50.0, 100.0, 150.0, 200.0] {
        let latency = base_design.total_cycles() / (mhz * 1e6);
        // dynamic power ~ f * V(f)^2; below nominal Vmin limits savings
        let v = (0.72 + 0.0014 * mhz) / (0.72 + 0.14);
        let p = p_static + p_dyn_100 * (mhz / 100.0) * v * v;
        let e = energy_mj(p, latency);
        t.row(vec![
            format!("{mhz:.0}"),
            eng(1.0 / latency),
            format!("{p:.2}"),
            format!("{e:.3}"),
            format!("{:.2}x", e / e_100),
        ]);
    }
    Ok(t)
}

/// Pruning / sparsity what-if (paper §VI: "sparse computation, pruning").
///
/// Structured pruning removes a fraction of MACs.  The CPU and a
/// sparsity-aware HLS datapath skip pruned MACs (time ~ (1-s)); the dense
/// DPU array does not (its time is shape-padded, so pruning buys nothing
/// until channels are physically removed) — the architectural contrast
/// the paper hints at.
pub fn pruning_sweep(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let board = Zcu104::default();
    let mut t = Table::new(
        "What-if: structured pruning (BaselineNet on HLS, CNet on DPU)",
        &["Sparsity", "BaselineNet HLS FPS", "speedup vs CPU",
          "CNet DPU FPS (dense array)"],
    );
    let base_info = model_info("baseline")?;
    let base_man = catalog.manifest("baseline", Precision::Fp32)?;
    let cnet_man = catalog.manifest("cnet", Precision::Int8)?;
    let cnet_sched = crate::dpu::DpuSchedule::new(
        cnet_man,
        crate::dpu::DpuArch::b4096(calib, board.dpu_clock_hz),
        calib,
        board.axi_bandwidth,
    )?;
    for sparsity in [0.0, 0.5, 0.75, 0.9, 0.95] {
        let pruned = prune_manifest(base_man, sparsity);
        let design = HlsDesign::synthesize(&pruned, &board, calib);
        let cpu = crate::cpu::A53Model::calibrated(
            base_man, calib, base_info.paper.cpu_fps);
        // CPU also skips structurally-pruned MACs
        let cpu_latency = cpu.latency_s() * (1.0 - sparsity).max(0.05);
        t.row(vec![
            format!("{:.0}%", 100.0 * sparsity),
            eng(design.fps()),
            format!("{:.3}x", design.fps() * cpu_latency),
            eng(cnet_sched.fps()), // dense array: unchanged
        ]);
    }
    Ok(t)
}

fn prune_manifest(man: &Manifest, sparsity: f64) -> Manifest {
    let keep = 1.0 - sparsity;
    let mut m = man.clone();
    for l in &mut m.layers {
        if l.kind.is_compute() {
            l.macs = (l.macs as f64 * keep) as u64;
            l.ops = (l.ops as f64 * keep) as u64;
            l.weight_bytes = (l.weight_bytes as f64 * keep) as u64;
        }
    }
    m.total_macs = m.layers.iter().map(|l| l.macs).sum();
    m.total_ops = m.layers.iter().map(|l| l.ops).sum();
    m.weight_bytes = m.layers.iter().map(|l| l.weight_bytes).sum();
    m
}

/// Scrubbing / TMR hardening report (paper §IV Fig 13 discussion + §VI).
pub fn hardening(catalog: &Catalog, calib: &Calibration, orbit: Orbit) -> Result<Table> {
    let board = Zcu104::default();
    let env = SeuEnvironment::new(orbit);
    let mut t = Table::new(
        &format!("Radiation hardening on {orbit:?}: scrub period for p_fault<=1e-3, TMR cost"),
        &["Design", "Essential bits", "Scrub period (s)", "Scrub J/day",
          "TMR fits?", "TMR residual p"],
    );
    for info in MODELS.iter().filter(|m| m.target == Target::Hls) {
        let man = catalog.manifest(info.name, Precision::Fp32)?;
        let plan = BramAllocator::new(&board.pl).allocate(man);
        let util = estimate_hls(man, &plan);
        let bits = essential_bits(util.luts, util.ffs, util.dsps, util.brams);
        let period = ScrubPolicy::period_for_target(&env, bits, 1e-3);
        let plan_eval = ScrubPolicy { period_s: period }
            .evaluate(&env, bits, calib);
        let tmr = apply_tmr(util, &board.pl);
        let p_single = env.p_fault(bits, period);
        t.row(vec![
            info.display.to_string(),
            eng(bits as f64),
            eng(period),
            eng(plan_eval.energy_per_day_j),
            format!("{}", tmr.fits),
            format!("{:.2e}", residual_p_fault(p_single)),
        ]);
    }
    // the DPU for contrast
    let dpu = crate::dpu::DpuArch::b4096(calib, board.dpu_clock_hz).resources();
    let bits = essential_bits(dpu.luts, dpu.ffs, dpu.dsps, dpu.brams);
    let period = ScrubPolicy::period_for_target(&env, bits, 1e-3);
    let plan_eval = ScrubPolicy { period_s: period }.evaluate(&env, bits, calib);
    let tmr_fits = apply_tmr(
        crate::resources::Utilization {
            luts: dpu.luts, ffs: dpu.ffs, dsps: dpu.dsps, brams: dpu.brams,
            urams: dpu.urams,
        },
        &board.pl,
    )
    .fits;
    t.row(vec![
        "B4096 DPU".into(),
        eng(bits as f64),
        eng(period),
        eng(plan_eval.energy_per_day_j),
        format!("{tmr_fits}"),
        "-".into(),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    // exercised end-to-end via tests/integration.rs (requires artifacts/)
    use super::prune_manifest;
    use crate::model::manifest::Manifest;
    use crate::util::json::Json;

    #[test]
    fn pruning_scales_compute_layers_only() {
        let man = Manifest::from_json(
            &Json::parse(crate::model::manifest::testdata::MINI).unwrap(),
        )
        .unwrap();
        let p = prune_manifest(&man, 0.5);
        assert_eq!(p.layers[0].macs, man.layers[0].macs / 2);
        assert_eq!(p.layers[1].macs, 0); // flatten untouched
        assert!(p.total_ops < man.total_ops);
        p.validate().unwrap();
    }
}
