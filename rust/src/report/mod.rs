//! Report harness: regenerates every table and figure of the paper's
//! evaluation section from the simulators + runtime.

pub mod ablation;
pub mod evaluate;
pub mod figures;
pub mod related;
pub mod whatif;
pub mod tables;

pub use evaluate::{evaluate_model, Evaluation};
