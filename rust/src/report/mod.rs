//! Report harness: regenerates every table and figure of the paper's
//! evaluation section from the simulators + runtime.

pub mod ablation;
pub mod evaluate;
pub mod figures;
pub mod plan;
pub mod policy;
pub mod related;
pub mod targets;
pub mod whatif;
pub mod tables;

pub use evaluate::{evaluate_model, Evaluation};
pub use plan::plan_report;
pub use policy::{policy_comparison, PolicyRun};
pub use targets::target_matrix;
