//! Model evaluation: one place that produces the CPU-vs-accelerator
//! numbers every table/figure cites (Table III's columns).

use anyhow::Result;

use crate::board::{Calibration, Zcu104};
use crate::cpu::A53Model;
use crate::dpu::{DpuArch, DpuSchedule};
use crate::hls::HlsDesign;
use crate::model::catalog::{ModelInfo, Target};
use crate::model::Manifest;
use crate::power::{energy_mj, Implementation, PowerModel};
use crate::resources::{estimate_hls, Utilization};

/// Everything Table III reports for one model, CPU + accelerator.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Catalog name.
    pub name: String,
    /// Paper display name.
    pub display: String,
    /// Deployed accelerator (DPU or HLS).
    pub target: Target,
    // CPU baseline (calibrated to the paper's CPU rows)
    /// CPU inferences/s.
    pub cpu_fps: f64,
    /// CPU achieved MOP/s (the paper's Throughput column).
    pub cpu_mops: f64,
    /// CPU board (12 V rail) power, W.
    pub cpu_p_board: f64,
    /// CPU MPSoC (INT rail) power, W.
    pub cpu_p_mpsoc: f64,
    /// CPU energy per inference, mJ.
    pub cpu_energy_mj: f64,
    // Accelerator (predicted by the mechanism models)
    /// Accelerator inferences/s.
    pub accel_fps: f64,
    /// Accelerator achieved MOP/s.
    pub accel_mops: f64,
    /// Accelerator board power, W.
    pub accel_p_board: f64,
    /// Accelerator MPSoC power, W.
    pub accel_p_mpsoc: f64,
    /// Accelerator energy per inference, mJ.
    pub accel_energy_mj: f64,
    /// Accelerator FPS over CPU FPS (Table III's Speedup column).
    pub speedup: f64,
    /// Accelerator resource estimate (None for the DPU — fixed IP row).
    pub hls_util: Option<Utilization>,
    /// DPU MAC-array duty (drives its dynamic power), if DPU.
    pub dpu_duty: Option<f64>,
    /// Input staging time (s) — the Fig 11 effect.
    pub input_stage_s: f64,
    /// Accelerator per-inference latency, s.
    pub accel_latency_s: f64,
    /// CPU per-inference latency, s.
    pub cpu_latency_s: f64,
}

/// Evaluate one model on CPU + its deployed accelerator.
///
/// `man` must be the *deployed* variant's manifest (int8 for DPU models,
/// fp32 for HLS models); `cpu_man` the fp32 manifest for the CPU baseline
/// (op counts are identical, weight bytes differ).
pub fn evaluate_model(
    info: &ModelInfo,
    man: &Manifest,
    cpu_man: &Manifest,
    calib: &Calibration,
) -> Result<Evaluation> {
    let board = Zcu104::default();
    let power = PowerModel::new(calib.clone());

    // --- CPU baseline (anchored on the paper's CPU rows) ---
    let a53 = A53Model::calibrated(cpu_man, calib, info.paper.cpu_fps);
    let cpu_latency = a53.latency_s();
    let cpu_imp = Implementation::Cpu { p_mpsoc_paper: info.paper.cpu_p_mpsoc };
    let cpu_p_mpsoc = power.mpsoc_w(&cpu_imp);
    let cpu_p_board = power.board_w(&cpu_imp);

    // --- accelerator (predicted) ---
    let (accel_latency, accel_p_mpsoc, accel_p_board, hls_util, dpu_duty, stage) =
        match info.target {
            Target::Dpu => {
                let sched = DpuSchedule::new(
                    man,
                    DpuArch::b4096(calib, board.dpu_clock_hz),
                    calib,
                    board.axi_bandwidth,
                )?;
                let imp = PowerModel::dpu_impl(&sched);
                (
                    sched.latency_s(),
                    power.mpsoc_w(&imp),
                    power.board_w(&imp),
                    None,
                    Some(sched.mac_duty()),
                    sched.input_dma_s,
                )
            }
            Target::Hls => {
                let design = HlsDesign::synthesize(man, &board, calib);
                let util = estimate_hls(man, &design.plan);
                let imp = Implementation::Hls {
                    kiloluts: util.luts as f64 / 1000.0,
                    brams: design.plan.brams(),
                    duty: 1.0,
                };
                (
                    design.latency_s(),
                    power.mpsoc_w(&imp),
                    power.board_w(&imp),
                    Some(util),
                    None,
                    design.input_stage_s,
                )
            }
        };

    let cpu_fps = 1.0 / cpu_latency;
    let accel_fps = 1.0 / accel_latency;
    Ok(Evaluation {
        name: info.name.to_string(),
        display: info.display.to_string(),
        target: info.target,
        cpu_fps,
        cpu_mops: cpu_man.total_ops as f64 * cpu_fps / 1e6,
        cpu_p_board,
        cpu_p_mpsoc,
        cpu_energy_mj: energy_mj(cpu_p_mpsoc, cpu_latency),
        accel_fps,
        accel_mops: man.total_ops as f64 * accel_fps / 1e6,
        accel_p_board,
        accel_p_mpsoc,
        accel_energy_mj: energy_mj(accel_p_mpsoc, accel_latency),
        speedup: accel_fps / cpu_fps,
        hls_util,
        dpu_duty,
        input_stage_s: stage,
        accel_latency_s: accel_latency,
        cpu_latency_s: cpu_latency,
    })
}
