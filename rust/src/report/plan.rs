//! `spaceinfer plan <model>` — render the candidate execution plans for
//! one model and the partition each dispatch policy would choose.
//!
//! Two tables: the candidate set (every plan the partitioner grew, with
//! predicted latency / energy / peak power / boundary-transfer toll at
//! the chosen batch size), then the per-policy verdict on idle queues —
//! which plan static / min-latency / min-energy / deadline would
//! dispatch, and why hybrid plans earn their keep (or don't).

use anyhow::Result;

use crate::backend::{TargetRegistry, TargetSet};
use crate::board::Calibration;
use crate::coordinator::scheduler::AccelTimeline;
use crate::coordinator::{default_deadline_s, Dispatcher, Policy};
use crate::model::catalog::{model_info, Catalog};
use crate::model::UseCase;
use crate::plan::Planner;
use crate::util::table::Table;

/// Use case a catalog model serves (the MMS sub-models all serve the
/// MMS stream).
fn use_case_of(model: &str) -> UseCase {
    match model {
        "vae" => UseCase::Vae,
        "cnet" => UseCase::Cnet,
        "esperta" => UseCase::Esperta,
        _ => UseCase::Mms,
    }
}

/// Fresh idle lane timelines for one planner (registry lanes first,
/// then derived lanes — `Planner::flat` order).
fn idle_timelines(d: &Dispatcher, planner: &Planner) -> Vec<AccelTimeline> {
    let mut tls = d.timelines();
    for name in planner.derived_lane_names() {
        tls.push(AccelTimeline::new(name));
    }
    tls
}

/// Render the candidate-plan table and the per-policy choices for
/// `model` at batch size `batch`.  Artifact-free (synthetic catalog
/// works); `deadline_s` / `power_budget_w` default like the pipeline.
pub fn plan_report(
    catalog: &Catalog,
    calib: &Calibration,
    model: &str,
    set: &TargetSet,
    batch: u64,
    deadline_s: Option<f64>,
    power_budget_w: Option<f64>,
) -> Result<String> {
    model_info(model)?; // reject unknown models with the catalog error
    let use_case = use_case_of(model);
    let deadline_s = deadline_s.unwrap_or_else(|| default_deadline_s(use_case));
    let registry = TargetRegistry::build(model, catalog, calib, set)?;
    let planner = Planner::build(model, catalog, calib, &registry, set)?;
    let mut d = Dispatcher { policy: Policy::MinLatency, registry, deadline_s, power_budget_w };

    let mut out = String::new();
    let mut candidates = Table::new(
        &format!(
            "Candidate execution plans [{model}] batch={batch} ({} lanes, {} plans)",
            planner.lane_count(),
            planner.plans().len(),
        ),
        &[
            "Preferred",
            "Partition",
            "Segs",
            "Latency (ms)",
            "Energy (mJ)",
            "Peak W",
            "Transfer (us/inf)",
        ],
    );
    for plan in planner.plans() {
        candidates.row(vec![
            plan.preferred.clone(),
            plan.describe(),
            plan.segments.len().to_string(),
            format!("{:.3}", plan.batch_latency_s(batch) * 1e3),
            format!("{:.3}", plan.batch_energy_j(batch) * 1e3),
            format!("{:.2}", plan.peak_power_w()),
            format!("{:.2}", plan.transfer_per_item_s * 1e6),
        ]);
    }
    out.push_str(&candidates.render());
    out.push('\n');

    let mut chosen = Table::new(
        &format!(
            "Chosen partition per policy [{model}] (deadline {:.0} ms{})",
            deadline_s * 1e3,
            match power_budget_w {
                Some(w) => format!(", power budget {w:.1} W"),
                None => String::new(),
            },
        ),
        &["Policy", "Partition", "Hybrid", "Latency (ms)", "Energy (mJ)", "Meets deadline"],
    );
    let mut policies = Vec::new();
    if d.registry.primary_index().is_some() {
        policies.push(Policy::Static);
    }
    policies.extend([Policy::MinLatency, Policy::MinEnergy, Policy::Deadline]);
    for policy in policies {
        d.policy = policy;
        let tls = idle_timelines(&d, &planner);
        let pc = d.choose_plan(&planner, &tls, 0.0, 0.0, batch);
        let plan = &planner.plans()[pc.index];
        chosen.row(vec![
            policy.as_str().to_string(),
            plan.describe(),
            (if plan.is_hybrid() { "yes" } else { "no" }).to_string(),
            format!("{:.3}", pc.cost.latency_s * 1e3),
            format!("{:.3}", pc.cost.energy_j * 1e3),
            (if pc.cost.meets_deadline { "yes" } else { "no" }).to_string(),
        ]);
    }
    out.push_str(&chosen.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_report_shows_a_hybrid_partition() {
        let out = plan_report(
            &Catalog::synthetic(),
            &Calibration::default(),
            "baseline",
            &TargetSet::Default,
            8,
            None,
            None,
        )
        .unwrap();
        assert!(out.contains("dpu["), "a DPU segment must appear:\n{out}");
        assert!(out.contains("->"), "a multi-segment partition must appear");
        assert!(out.contains("min-latency"));
    }

    #[test]
    fn vae_report_is_single_segment_only() {
        let out = plan_report(
            &Catalog::synthetic(),
            &Calibration::default(),
            "vae",
            &TargetSet::Default,
            8,
            None,
            None,
        )
        .unwrap();
        assert!(!out.contains("->"), "no hybrid exists for vae:\n{out}");
        assert!(out.contains("static"));
    }

    #[test]
    fn unknown_model_is_rejected() {
        assert!(plan_report(
            &Catalog::synthetic(),
            &Calibration::default(),
            "warp-net",
            &TargetSet::Default,
            8,
            None,
            None,
        )
        .is_err());
    }
}
