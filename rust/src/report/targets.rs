//! `spaceinfer targets` — the target-matrix comparison table: every
//! backend the registry can instantiate for a use case, with its
//! predicted latency, energy, active power, PL footprint, and SEU
//! exposure side by side.  The design space the paper's three rows
//! sample, enumerated.

use anyhow::Result;

use crate::backend::{AccelModel, TargetRegistry, TargetSet};
use crate::board::Calibration;
use crate::coordinator::Router;
use crate::model::catalog::Catalog;
use crate::model::UseCase;
use crate::rad::seu::essential_bits_of;
use crate::util::table::{eng, Table};

/// Tabulate the full target family ([`TargetSet::All`]) for one use
/// case's deployed model.  DPU rows appear only when the model passes
/// the operator gate, so ESPERTA/MMS tables are CPU + the HLS pair.
pub fn target_matrix(
    catalog: &Catalog,
    calib: &Calibration,
    use_case: UseCase,
    mms_model: &str,
    batch: u64,
) -> Result<Table> {
    let mut router = Router::default();
    router.mms_model = mms_model.to_string();
    let route = router.route(use_case, 0)?;
    let registry = TargetRegistry::build(&route.model, catalog, calib, &TargetSet::All)?;
    let batch_col = format!("Batch-{batch} (ms)");
    let mut t = Table::new(
        &format!(
            "Registered targets [{use_case}] model={} ({} of {} registrable)",
            route.model,
            registry.len(),
            TargetSet::KNOWN.len(),
        ),
        &[
            "Target",
            "Slot",
            "Prec",
            "Setup (ms)",
            "Per-inf (ms)",
            batch_col.as_str(),
            "mJ/inf",
            "Power (W)",
            "kLUT",
            "DSP",
            "BRAM",
            "Ess. bits",
        ],
    );
    for target in registry.targets() {
        let r = target.resources();
        t.row(vec![
            target.name().to_string(),
            target.slot().name().to_string(),
            target.precision().as_str().to_string(),
            format!("{:.3}", target.setup_s() * 1e3),
            format!("{:.4}", target.per_item_s() * 1e3),
            format!("{:.3}", target.batch_latency_s(batch) * 1e3),
            format!("{:.3}", target.batch_energy_j(1) * 1e3),
            format!("{:.2}", target.active_power_w()),
            format!("{:.1}", r.luts as f64 / 1000.0),
            r.dsps.to_string(),
            format!("{:.1}", r.brams),
            eng(essential_bits_of(&r) as f64),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vae_matrix_lists_the_whole_family() {
        let t = target_matrix(
            &Catalog::synthetic(),
            &Calibration::default(),
            UseCase::Vae,
            "baseline",
            8,
        )
        .unwrap();
        assert!(t.rows.len() >= 6, "acceptance: >= 6 targets, got {}", t.rows.len());
        let names: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
        for expect in ["cpu", "dpu-b512", "dpu-b1024", "dpu-b2304", "dpu", "hls", "hls-pipe"]
        {
            assert!(names.contains(&expect), "{expect} missing from {names:?}");
        }
    }

    #[test]
    fn mms_matrix_has_no_dpu_rows() {
        let t = target_matrix(
            &Catalog::synthetic(),
            &Calibration::default(),
            UseCase::Mms,
            "baseline",
            8,
        )
        .unwrap();
        assert!(t.rows.iter().all(|r| !r[0].starts_with("dpu")));
        assert_eq!(t.rows.len(), 3); // cpu + hls + hls-pipe
    }
}
