//! Tables I–III regeneration (paper-vs-measured side by side).

use anyhow::Result;

use crate::board::{Calibration, Zcu104};
use crate::dpu::DpuArch;
use crate::hls::{BramAllocator, HlsDesign};
use crate::model::catalog::{Catalog, Target, MODELS};
use crate::model::Precision;
use crate::resources::estimate_hls;
use crate::util::table::{commas, eng, Table};

use super::evaluate::evaluate_model;

/// Table I: parameters and operations per model.
pub fn table1(catalog: &Catalog) -> Result<Table> {
    let mut t = Table::new(
        "Table I: Summary of parameters and operations",
        &["Model", "# Params (paper)", "# Params (ours)", "match",
          "# Ops (paper)", "# Ops (ours, DESIGN §8)"],
    );
    for info in MODELS {
        let man = catalog.manifest(info.name, Precision::Fp32)?;
        t.row(vec![
            info.display.to_string(),
            commas(info.table1_params),
            commas(man.total_params),
            if man.total_params == info.table1_params { "EXACT" } else { "DIFF" }
                .to_string(),
            commas(info.table1_ops),
            commas(man.total_ops),
        ]);
    }
    Ok(t)
}

/// Table II: resource utilization and clock frequency.
pub fn table2(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let board = Zcu104::default();
    let pl = board.pl;
    let mut t = Table::new(
        "Table II: Resource Utilization and Clock Frequency (ZCU104)",
        &["Design", "LUTs", "FFs", "DSPs", "BRAMs", "URAMs", "Clock"],
    );
    t.row(vec![
        "Available".into(),
        commas(pl.luts),
        commas(pl.ffs),
        commas(pl.dsps),
        format!("{}", pl.brams),
        commas(pl.urams),
        "-".into(),
    ]);
    let dpu = DpuArch::b4096(calib, board.dpu_clock_hz).resources();
    t.row(vec![
        "B4096 DPU (Vitis AI)".into(),
        format!("{} ({:.0}%)", commas(dpu.luts), 100.0 * dpu.luts as f64 / pl.luts as f64),
        format!("{} ({:.0}%)", commas(dpu.ffs), 100.0 * dpu.ffs as f64 / pl.ffs as f64),
        format!("{} ({:.0}%)", commas(dpu.dsps), 100.0 * dpu.dsps as f64 / pl.dsps as f64),
        format!("{} ({:.0}%)", dpu.brams, 100.0 * dpu.brams / pl.brams),
        format!("{} ({:.0}%)", dpu.urams, 100.0 * dpu.urams as f64 / pl.urams as f64),
        "300/600 MHz".into(),
    ]);
    for info in MODELS.iter().filter(|m| m.target == Target::Hls) {
        let man = catalog.manifest(info.name, Precision::Fp32)?;
        let plan = BramAllocator::new(&pl).allocate(man);
        let u = estimate_hls(man, &plan);
        let (l, f, d, b, _) = u.percent(&pl);
        t.row(vec![
            format!("{} HLS", info.display),
            format!("{} ({:.0}%)", commas(u.luts), l),
            format!("{} ({:.0}%)", commas(u.ffs), f),
            format!("{} ({:.1}%)", u.dsps, d),
            format!("{} ({:.0}%)", u.brams, b),
            "-".into(),
            "100 MHz".into(),
        ]);
    }
    Ok(t)
}

/// Table III: performance metrics, ours vs paper.
pub fn table3(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let mut t = Table::new(
        "Table III: Performance metrics (ours | paper)",
        &["Implementation", "Speedup", "FPS", "MOP/s", "P_Board (W)",
          "P_MPSoC (W)", "E/inf (mJ)"],
    );
    for info in MODELS {
        let man = catalog.deployed(info)?;
        let cpu_man = catalog.manifest(info.name, Precision::Fp32)?;
        let e = evaluate_model(info, man, cpu_man, calib)?;
        t.row(vec![
            format!("{} - CPU", e.display),
            "1x | 1x".into(),
            format!("{} | {}", eng(e.cpu_fps), eng(info.paper.cpu_fps)),
            eng(e.cpu_mops),
            format!("{} | {}", eng(e.cpu_p_board), eng(info.paper.cpu_p_board)),
            format!("{} | {}", eng(e.cpu_p_mpsoc), eng(info.paper.cpu_p_mpsoc)),
            format!("{} | {}", eng(e.cpu_energy_mj), eng(info.paper.cpu_energy_mj)),
        ]);
        let accel = match e.target {
            Target::Dpu => "Vitis AI",
            Target::Hls => "HLS",
        };
        t.row(vec![
            format!("{} - {}", e.display, accel),
            format!("{}x | {}x", eng(e.speedup), eng(info.paper.speedup)),
            format!("{} | {}", eng(e.accel_fps), eng(info.paper.accel_fps)),
            eng(e.accel_mops),
            format!("{} | {}", eng(e.accel_p_board), eng(info.paper.accel_p_board)),
            format!("{} | {}", eng(e.accel_p_mpsoc), eng(info.paper.accel_p_mpsoc)),
            format!("{} | {}", eng(e.accel_energy_mj), eng(info.paper.accel_energy_mj)),
        ]);
    }
    Ok(t)
}

/// Sanity harness for EXPERIMENTS.md: per-row relative error + the shape
/// criteria (who wins, crossovers).
pub fn table3_shape_check(catalog: &Catalog, calib: &Calibration) -> Result<String> {
    let mut out = String::new();
    let mut ok = true;
    for info in MODELS {
        let man = catalog.deployed(info)?;
        let cpu_man = catalog.manifest(info.name, Precision::Fp32)?;
        let e = evaluate_model(info, man, cpu_man, calib)?;
        let same_side = (e.speedup > 1.0) == (info.paper.speedup > 1.0);
        let factor = e.speedup / info.paper.speedup;
        let energy_side = (e.accel_energy_mj < e.cpu_energy_mj)
            == (info.paper.accel_energy_mj < info.paper.cpu_energy_mj);
        ok &= same_side && energy_side;
        out.push_str(&format!(
            "{:<16} speedup ours {:>8.3}x paper {:>7.2}x (ratio {:>5.2}) \
             winner-match={} energy-match={}\n",
            info.name, e.speedup, info.paper.speedup, factor, same_side,
            energy_side
        ));
    }
    out.push_str(if ok {
        "SHAPE OK: every accelerator wins/loses on the same side as the paper\n"
    } else {
        "SHAPE MISMATCH — see rows above\n"
    });
    Ok(out)
}

/// DPU utilization context (paper discusses why CNet > VAE speedup).
pub fn dpu_utilization_note(catalog: &Catalog, calib: &Calibration) -> Result<String> {
    let board = Zcu104::default();
    let mut out = String::new();
    for name in ["vae", "cnet"] {
        let man = catalog.manifest(name, Precision::Int8)?;
        let sched = crate::dpu::DpuSchedule::new(
            man,
            DpuArch::b4096(calib, board.dpu_clock_hz),
            calib,
            board.axi_bandwidth,
        )?;
        out.push_str(&format!(
            "{name}: DPU MAC utilization {:.1}%  duty {:.1}%  latency {:.3} ms\n",
            100.0 * sched.mac_utilization(),
            100.0 * sched.mac_duty(),
            1e3 * sched.latency_s()
        ));
    }
    Ok(out)
}

/// HLS spill context (paper attributes BaselineNet's collapse to DRAM).
pub fn hls_spill_note(catalog: &Catalog, calib: &Calibration) -> Result<String> {
    let board = Zcu104::default();
    let mut out = String::new();
    for info in MODELS.iter().filter(|m| m.target == Target::Hls) {
        let man = catalog.manifest(info.name, Precision::Fp32)?;
        let d = HlsDesign::synthesize(man, &board, calib);
        out.push_str(&format!(
            "{:<10} brams {:>6.1}  spill {:>9} B  fetch-stall {:>5.1}%  \
             latency {:.4} s\n",
            info.name,
            d.plan.brams(),
            d.plan.dram_weight_bytes,
            100.0 * d.fetch_stall_fraction(),
            d.latency_s()
        ));
    }
    Ok(out)
}
