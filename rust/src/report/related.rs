//! Tables IV & V: comparison with related work.  Literature rows are
//! published constants from the cited papers; our rows come from the
//! evaluation harness.

use anyhow::Result;

use crate::board::Calibration;
use crate::model::catalog::{model_info, Catalog};
use crate::model::Precision;
use crate::util::table::{commas, eng, Table};

use super::evaluate::evaluate_model;

struct LitRow {
    network: &'static str,
    board: &'static str,
    params: Option<u64>,
    fps: f64,
    power_w: Option<f64>,
}

const TABLE4_LIT: &[LitRow] = &[
    LitRow { network: "LD-UNet [13]", board: "ZCU104", params: Some(5_652), fps: 632.0, power_w: Some(14.1) },
    LitRow { network: "CAE [11]", board: "ZCU104", params: Some(2_950_000), fps: 250.0, power_w: Some(5.3) },
    LitRow { network: "ResNet-50 [28]", board: "ZCU102", params: None, fps: 68.0, power_w: Some(30.0) },
    LitRow { network: "mod. YOLOv4 [27]", board: "KV260", params: None, fps: 3.8, power_w: None },
    LitRow { network: "YOLOv4-Mobv3 [26]", board: "KV260", params: Some(5_690_000), fps: 48.0, power_w: Some(7.2) },
    LitRow { network: "Pixel-Net [25]", board: "Ultra96-V2", params: Some(17_430), fps: 0.051, power_w: Some(2.4) },
    LitRow { network: "Patch-Net [25]", board: "Ultra96-V2", params: Some(13_000), fps: 0.049, power_w: Some(2.5) },
    LitRow { network: "Scene-Net [25]", board: "Ultra96-V2", params: Some(3_320_000), fps: 57.0, power_w: Some(2.5) },
    LitRow { network: "U-Net [25]", board: "Ultra96-V2", params: Some(26_620), fps: 37.0, power_w: Some(2.4) },
];

const TABLE5_LIT: &[LitRow] = &[
    LitRow { network: "CNN [12]", board: "ZCU104", params: Some(245_000), fps: 3_676.0, power_w: Some(9.493) },
    LitRow { network: "TCN+U-Net [29]", board: "Z-7020", params: Some(2_000), fps: 0.98, power_w: Some(0.196) },
];

fn lit_cells(r: &LitRow) -> Vec<String> {
    vec![
        r.network.to_string(),
        r.board.to_string(),
        r.params.map(commas).unwrap_or_else(|| "-".into()),
        eng(r.fps),
        r.power_w.map(|p| format!("{p} W")).unwrap_or_else(|| "-".into()),
    ]
}

/// Table IV: Vitis-AI implementations vs related work.
pub fn table4(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let mut t = Table::new(
        "Table IV: Vitis AI performance vs related work",
        &["Network", "Board", "# Param.", "FPS", "Power"],
    );
    for name in ["vae", "cnet"] {
        let info = model_info(name)?;
        let man = catalog.deployed(info)?;
        let cpu_man = catalog.manifest(name, Precision::Fp32)?;
        let e = evaluate_model(info, man, cpu_man, calib)?;
        t.row(vec![
            format!("{} (ours)", info.display),
            "ZCU104 (sim)".into(),
            commas(man.total_params),
            eng(e.accel_fps),
            format!("{:.2} W", e.accel_p_mpsoc),
        ]);
    }
    for r in TABLE4_LIT {
        t.row(lit_cells(r));
    }
    Ok(t)
}

/// Table V: HLS implementations vs related work.
pub fn table5(catalog: &Catalog, calib: &Calibration) -> Result<Table> {
    let mut t = Table::new(
        "Table V: HLS performance vs related work",
        &["Network", "Board", "# Param.", "FPS", "Power"],
    );
    for name in ["esperta", "logistic"] {
        let info = model_info(name)?;
        let man = catalog.deployed(info)?;
        let cpu_man = catalog.manifest(name, Precision::Fp32)?;
        let e = evaluate_model(info, man, cpu_man, calib)?;
        let display = if name == "esperta" { "multi-ESPERTA" } else { "LogisticNet" };
        t.row(vec![
            format!("{display} (ours)"),
            "ZCU104 (sim)".into(),
            commas(man.total_params),
            eng(e.accel_fps),
            format!("{:.2} W", e.accel_p_mpsoc),
        ]);
    }
    for r in TABLE5_LIT {
        t.row(lit_cells(r));
    }
    Ok(t)
}
