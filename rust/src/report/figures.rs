//! Figures 9–13: power-vs-time traces.
//!
//! Each figure function regenerates the paper's trace for the matching
//! run (same input counts: 1000 for Figs 9/10/12, 10^6 for Fig 11,
//! BaselineNet limited to 10, single inference for Fig 13), returning
//! (CSV, ASCII art) so the CLI can print and persist both.

use anyhow::Result;

use crate::board::Calibration;
use crate::model::catalog::{model_info, Catalog};
use crate::model::Precision;
use crate::power::trace::{to_ascii, to_csv, Phase, TraceBuilder, TracePoint};
use crate::power::{Implementation, PowerModel};

use super::evaluate::evaluate_model;

fn eval(catalog: &Catalog, calib: &Calibration, name: &str)
        -> Result<super::evaluate::Evaluation> {
    let info = model_info(name)?;
    let man = catalog.deployed(info)?;
    let cpu_man = catalog.manifest(name, Precision::Fp32)?;
    evaluate_model(info, man, cpu_man, calib)
}

fn implementation(e: &super::evaluate::Evaluation) -> Implementation {
    match (e.dpu_duty, &e.hls_util) {
        (Some(duty), _) => Implementation::Dpu { mac_duty: duty },
        (None, Some(u)) => Implementation::Hls {
            kiloluts: u.luts as f64 / 1000.0,
            brams: u.brams,
            duty: 1.0,
        },
        _ => unreachable!("evaluation must be DPU or HLS"),
    }
}

fn run_trace(
    catalog: &Catalog,
    calib: &Calibration,
    name: &str,
    n_inputs: u64,
    seed: u64,
) -> Result<Vec<TracePoint>> {
    let e = eval(catalog, calib, name)?;
    let b = TraceBuilder::new(PowerModel::new(calib.clone()), seed);
    Ok(b.standard_run(
        &implementation(&e),
        e.cpu_p_mpsoc,
        n_inputs,
        e.cpu_latency_s,
        e.input_stage_s,
        e.accel_latency_s,
    ))
}

/// Fig 9: VAE encoder, 1000 inputs.
pub fn fig9(catalog: &Catalog, calib: &Calibration) -> Result<(String, String)> {
    let tr = run_trace(catalog, calib, "vae", 1000, 9)?;
    Ok((to_csv(&tr), to_ascii(&tr, 100, 18)))
}

/// Fig 10: CNetPlusScalar, 1000 inputs.
pub fn fig10(catalog: &Catalog, calib: &Calibration) -> Result<(String, String)> {
    let tr = run_trace(catalog, calib, "cnet", 1000, 10)?;
    Ok((to_csv(&tr), to_ascii(&tr, 100, 18)))
}

/// Fig 11: multi-ESPERTA, 10^6 inputs (input staging dominates).
pub fn fig11(catalog: &Catalog, calib: &Calibration) -> Result<(String, String)> {
    let tr = run_trace(catalog, calib, "esperta", 1_000_000, 11)?;
    Ok((to_csv(&tr), to_ascii(&tr, 100, 18)))
}

/// Fig 12: the three MMS networks back to back (1000/1000/10 inputs).
pub fn fig12(catalog: &Catalog, calib: &Calibration) -> Result<(String, String)> {
    let mut all: Vec<TracePoint> = Vec::new();
    let mut t_off = 0.0;
    for (name, n) in [("logistic", 1000u64), ("reduced", 1000), ("baseline", 10)] {
        let tr = run_trace(catalog, calib, name, n, 12)?;
        let end = tr.last().map(|p| p.t_s).unwrap_or(0.0);
        all.extend(tr.into_iter().map(|mut p| {
            p.t_s += t_off;
            p
        }));
        t_off += end;
    }
    Ok((to_csv(&all), to_ascii(&all, 120, 18)))
}

/// Fig 13: board-power phase decomposition, one BaselineNet inference.
pub fn fig13(catalog: &Catalog, calib: &Calibration) -> Result<(String, String)> {
    let e = eval(catalog, calib, "baseline")?;
    let pm = PowerModel::new(calib.clone());
    let imp = implementation(&e);
    let periph = calib.p_periph;
    let mut b = TraceBuilder::new(PowerModel::new(calib.clone()), 13);
    // board-level trace: add the peripheral floor to every phase
    b.phase(Phase::Idle, pm.mpsoc_idle_w() + periph, 2.0);
    b.phase(Phase::BitstreamLoad, pm.config_spike_w() + periph + 0.4,
            calib.t_config);
    b.phase(Phase::Idle, pm.mpsoc_idle_w() + periph, 1.0);
    b.phase(Phase::InputStaging, pm.mpsoc_idle_w() + periph + 0.35,
            e.input_stage_s.max(0.2));
    // CPU waits for the accelerator: the paper's lowest draw
    b.phase(Phase::FpgaInference, pm.mpsoc_w(&imp) + periph - 0.25,
            e.accel_latency_s.min(10.0));
    b.phase(Phase::Readback, pm.mpsoc_idle_w() + periph + 0.15, 0.3);
    b.phase(Phase::Idle, pm.mpsoc_idle_w() + periph, 1.0);
    let tr = b.build();
    Ok((to_csv(&tr), to_ascii(&tr, 100, 18)))
}

/// Every figure, for the bench harness: (name, csv, ascii).
pub fn all_figures(
    catalog: &Catalog,
    calib: &Calibration,
) -> Result<Vec<(&'static str, String, String)>> {
    let mut out = Vec::new();
    for (name, f) in [
        ("fig9", fig9 as fn(&Catalog, &Calibration) -> Result<(String, String)>),
        ("fig10", fig10),
        ("fig11", fig11),
        ("fig12", fig12),
        ("fig13", fig13),
    ] {
        let (csv, ascii) = f(catalog, calib)?;
        out.push((name, csv, ascii));
    }
    Ok(out)
}
