//! Power-vs-time trace generation (Figures 9–13).
//!
//! The paper's figures plot the MPSoC INT-rail (Figs 9–12) or total board
//! power (Fig 13) sampled over a run: reboot → CPU inference window →
//! bitstream configuration spike → input staging → FPGA inference window.
//! `TraceBuilder` composes those phases from the power model and the
//! timing simulators; the report harness renders them as CSV + ASCII.

use crate::power::model::{Implementation, PowerModel};
use crate::util::prng::Prng;

/// Phases of a measurement run (the grey/blue/orange bands of Figs 9–12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Nothing computing; PS idle draw.
    Idle,
    /// PyTorch-equivalent inference on the A53 (the blue band).
    CpuInference,
    /// Bitstream configuration (the grey spike).
    BitstreamLoad,
    /// Input staging over AXI / MMIO.
    InputStaging,
    /// Accelerator inference window (the orange band).
    FpgaInference,
    /// Output readback to the PS.
    Readback,
}

impl Phase {
    /// Short label used in CSV and plot legends.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Idle => "idle",
            Phase::CpuInference => "cpu",
            Phase::BitstreamLoad => "bitstream",
            Phase::InputStaging => "staging",
            Phase::FpgaInference => "fpga",
            Phase::Readback => "readback",
        }
    }
}

/// One sample of the trace.
#[derive(Debug, Clone)]
pub struct TracePoint {
    /// Sample time (s).
    pub t_s: f64,
    /// Sampled power (W).
    pub power_w: f64,
    /// Which run phase the sample belongs to.
    pub phase: Phase,
}

/// Builds phase-structured traces with measurement-like jitter.
pub struct TraceBuilder {
    /// Power model the phases draw from.
    pub model: PowerModel,
    /// Sampling rate (Hz).
    pub sample_hz: f64,
    /// Gaussian measurement noise (W, 1σ) — the INA226-style ripple
    /// visible in the paper's figures.
    pub noise_w: f64,
    points: Vec<TracePoint>,
    t: f64,
    rng: Prng,
}

impl TraceBuilder {
    /// Builder with the figures' default sample rate and noise floor.
    pub fn new(model: PowerModel, seed: u64) -> TraceBuilder {
        TraceBuilder {
            model,
            sample_hz: 100.0,
            noise_w: 0.045,
            points: Vec::new(),
            t: 0.0,
            rng: Prng::new(seed),
        }
    }

    /// Append a constant-power phase of `dur_s`.
    pub fn phase(&mut self, phase: Phase, power_w: f64, dur_s: f64) -> &mut Self {
        let n = ((dur_s * self.sample_hz).ceil() as usize).max(1);
        for _ in 0..n {
            let noise = self.rng.normal() * self.noise_w;
            self.points.push(TracePoint {
                t_s: self.t,
                power_w: (power_w + noise).max(0.0),
                phase,
            });
            self.t += 1.0 / self.sample_hz;
        }
        self
    }

    /// Append an inference window: `n` inferences of `t_inf` seconds at
    /// `p_active`, with the dynamic component visibly toggling (the
    /// min/max swing the paper reads dynamic power from).
    pub fn inference_window(
        &mut self,
        phase: Phase,
        p_active: f64,
        p_swing: f64,
        n: u64,
        t_inf_s: f64,
    ) -> &mut Self {
        let total = n as f64 * t_inf_s;
        let samples = ((total * self.sample_hz).ceil() as usize).max(2);
        for i in 0..samples {
            let toggle = if i % 2 == 0 { 0.0 } else { -p_swing };
            let noise = self.rng.normal() * self.noise_w;
            self.points.push(TracePoint {
                t_s: self.t,
                power_w: (p_active + toggle + noise).max(0.0),
                phase,
            });
            self.t += total / samples as f64;
        }
        self
    }

    /// Take the accumulated samples.
    pub fn build(&mut self) -> Vec<TracePoint> {
        std::mem::take(&mut self.points)
    }

    /// Standard Fig 9–12 run: reboot-idle, CPU window (blue), idle,
    /// bitstream (grey spike), staging, FPGA window (orange).
    pub fn standard_run(
        mut self,
        imp: &Implementation,
        cpu_p_mpsoc: f64,
        n_inputs: u64,
        t_cpu_s: f64,
        t_stage_s: f64,
        t_fpga_s: f64,
    ) -> Vec<TracePoint> {
        let idle = self.model.mpsoc_idle_w();
        let p_fpga = self.model.mpsoc_w(imp);
        let spike = self.model.config_spike_w();
        let t_config = self.model.calib.t_config;
        // compress long windows so every figure renders at a useful scale
        let window = |t: f64| (t * n_inputs as f64).clamp(2.0, 40.0);
        self.phase(Phase::Idle, idle, 2.0);
        self.inference_window(Phase::CpuInference, cpu_p_mpsoc, 0.25, 1,
                              window(t_cpu_s));
        self.phase(Phase::Idle, idle, 2.0);
        self.phase(Phase::BitstreamLoad, spike, t_config);
        self.phase(Phase::Idle, idle, 1.0);
        self.inference_window(Phase::InputStaging, idle + 0.35, 0.1, 1,
                              (t_stage_s * n_inputs as f64).clamp(0.5, 20.0));
        self.inference_window(Phase::FpgaInference, p_fpga, 0.3, 1,
                              window(t_fpga_s));
        self.phase(Phase::Idle, idle, 2.0);
        self.build()
    }
}

/// The highest-power sample of a trace, `None` for an empty trace —
/// the panic-free peak lookup the renderers and reports share.
pub fn peak(points: &[TracePoint]) -> Option<&TracePoint> {
    points.iter().max_by(|a, b| a.power_w.total_cmp(&b.power_w))
}

/// Render a trace as CSV (t_s, power_w, phase).  An empty trace
/// renders as the bare header — never a panic.
pub fn to_csv(points: &[TracePoint]) -> String {
    let mut out = String::from("t_s,power_w,phase\n");
    for p in points {
        out.push_str(&format!("{:.4},{:.4},{}\n", p.t_s, p.power_w, p.phase.label()));
    }
    out
}

/// Render a coarse ASCII plot (for terminal inspection of the figure).
/// An empty trace renders as an empty plot (no samples, no footer) —
/// never a panic.
pub fn to_ascii(points: &[TracePoint], width: usize, height: usize) -> String {
    let Some(last) = points.last() else {
        return String::new();
    };
    let t_max = last.t_s.max(1e-9);
    // 1e-9 floor: an all-zero trace plots flat instead of dividing by 0
    let p_max = (peak(points).map(|p| p.power_w).unwrap_or(0.0) * 1.05).max(1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    for p in points {
        let x = ((p.t_s / t_max) * (width - 1) as f64) as usize;
        let y = ((p.power_w / p_max) * (height - 1) as f64) as usize;
        let row = height - 1 - y.min(height - 1);
        let ch = match p.phase {
            Phase::CpuInference => b'b',
            Phase::FpgaInference => b'o',
            Phase::BitstreamLoad => b'#',
            Phase::InputStaging => b's',
            _ => b'.',
        };
        grid[row][x.min(width - 1)] = ch;
    }
    let mut out = String::new();
    for row in grid {
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!(
        "0 .. {:.1}s   peak {:.2} W   (b=cpu o=fpga #=bitstream s=staging)\n",
        t_max, p_max / 1.05
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::Calibration;

    fn builder() -> TraceBuilder {
        TraceBuilder::new(PowerModel::new(Calibration::default()), 7)
    }

    #[test]
    fn phases_are_ordered_in_time() {
        let tr = builder().standard_run(
            &Implementation::Dpu { mac_duty: 0.3 }, 2.75, 1000, 0.040,
            0.0001, 0.0016,
        );
        assert!(!tr.is_empty());
        for w in tr.windows(2) {
            assert!(w[1].t_s >= w[0].t_s);
        }
    }

    #[test]
    fn bitstream_spike_is_peak_mpsoc() {
        let tr = builder().standard_run(
            &Implementation::Hls { kiloluts: 6.5, brams: 150.5, duty: 1.0 },
            2.75, 10, 0.024, 0.001, 4.76,
        );
        let top = peak(&tr).expect("non-empty trace has a peak");
        assert_eq!(top.phase, Phase::BitstreamLoad);
    }

    #[test]
    fn empty_trace_renders_empty_not_panics() {
        let none: Vec<TracePoint> = Vec::new();
        assert_eq!(to_csv(&none), "t_s,power_w,phase\n");
        assert_eq!(to_ascii(&none, 80, 16), "");
        assert!(peak(&none).is_none());
        // a zero-power trace must also render without dividing by zero
        let flat = vec![TracePoint { t_s: 0.0, power_w: 0.0, phase: Phase::Idle }];
        let art = to_ascii(&flat, 10, 4);
        assert!(art.contains("peak 0.00 W"));
    }

    #[test]
    fn hls_window_below_cpu_window() {
        let tr = builder().standard_run(
            &Implementation::Hls { kiloluts: 8.1, brams: 1.5, duty: 1.0 },
            2.0, 1_000_000, 0.000144, 0.00002, 0.0000269,
        );
        let avg = |ph: Phase| {
            let v: Vec<f64> = tr.iter().filter(|p| p.phase == ph)
                .map(|p| p.power_w).collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(avg(Phase::FpgaInference) < avg(Phase::CpuInference));
    }

    #[test]
    fn dpu_window_above_cpu_window() {
        let tr = builder().standard_run(
            &Implementation::Dpu { mac_duty: 0.85 }, 2.75, 1000, 0.2087,
            0.0002, 0.0061,
        );
        let avg = |ph: Phase| {
            let v: Vec<f64> = tr.iter().filter(|p| p.phase == ph)
                .map(|p| p.power_w).collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(avg(Phase::FpgaInference) > avg(Phase::CpuInference));
    }

    #[test]
    fn csv_and_ascii_render() {
        let tr = builder().standard_run(
            &Implementation::Dpu { mac_duty: 0.3 }, 2.75, 100, 0.04, 0.0001,
            0.0016,
        );
        let csv = to_csv(&tr);
        assert!(csv.starts_with("t_s,power_w,phase\n"));
        assert_eq!(csv.lines().count(), tr.len() + 1);
        let art = to_ascii(&tr, 80, 16);
        assert!(art.contains('#'));
        assert!(art.contains('o'));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = builder().standard_run(&Implementation::Dpu { mac_duty: 0.3 },
                                       2.75, 10, 0.04, 1e-4, 1.6e-3);
        let b = builder().standard_run(&Implementation::Dpu { mac_duty: 0.3 },
                                       2.75, 10, 0.04, 1e-4, 1.6e-3);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.power_w == y.power_w));
    }
}
