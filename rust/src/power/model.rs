//! Steady-state power model per implementation (Table III's P columns).
//!
//! `P_MPSoC = P_PS + P_PL_static(design) + P_PL_dyn(design, activity)`;
//! `P_board = P_MPSoC + peripheral floor (+ DDR activity when the PS is
//! the one computing)`.
//!
//! Calibration scope (DESIGN.md §4): CPU-row MPSoC power comes straight
//! from the paper (baseline anchoring); the DPU *static* base is anchored
//! on the single VAE row; every other accelerator figure — CNet DPU power,
//! all HLS rows, all board rows, all energies — is predicted.

use crate::board::Calibration;
use crate::dpu::DpuSchedule;
use crate::hls::HlsDesign;

/// What is executing on the MPSoC.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Implementation {
    /// PS runs the network (PyTorch-equivalent); PL unconfigured.
    Cpu { p_mpsoc_paper: f64 },
    /// DPU configured and running; PS polls.
    Dpu { mac_duty: f64 },
    /// HLS IP configured and running; PS polls.
    Hls { kiloluts: f64, brams: f64, duty: f64 },
}

/// Power model bound to a calibration.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Calibration constants the rail terms come from.
    pub calib: Calibration,
}

impl PowerModel {
    /// Bind a calibration.
    pub fn new(calib: Calibration) -> PowerModel {
        PowerModel { calib }
    }

    /// MPSoC (INT-rail) power during inference.
    pub fn mpsoc_w(&self, imp: &Implementation) -> f64 {
        let c = &self.calib;
        match imp {
            Implementation::Cpu { p_mpsoc_paper } => *p_mpsoc_paper,
            Implementation::Dpu { mac_duty } => c.p_dpu_base + c.p_dpu_dyn * mac_duty,
            Implementation::Hls { kiloluts, brams, duty } => {
                c.p_hls_base
                    + c.p_hls_per_kilolut * kiloluts
                    + c.p_hls_per_bram * brams
                    + 0.05 * duty // datapath toggle, small by construction
            }
        }
    }

    /// Active MPSoC draw for a DPU-family member, scaled from the
    /// calibrated B4096 anchor.  `frac` is the member's MAC-array
    /// capacity relative to B4096 (`dpu::DpuSize::frac`): the static
    /// base splits into a fixed share (`dpu_static_fixed_frac` —
    /// scheduler / fetch / interconnect) plus an array-proportional
    /// share, and the dynamic swing scales with the array.  For
    /// `frac = 1` this routes through the exact B4096 formula, so the
    /// default target set stays bit-identical to the seed dispatcher.
    pub fn dpu_family_w(&self, frac: f64, mac_duty: f64) -> f64 {
        if frac >= 1.0 {
            return self.mpsoc_w(&Implementation::Dpu { mac_duty });
        }
        let c = &self.calib;
        let f = c.dpu_static_fixed_frac;
        c.p_dpu_base * (f + (1.0 - f) * frac) + c.p_dpu_dyn * frac * mac_duty
    }

    /// MPSoC power when idle (after reboot, before any bitstream).
    pub fn mpsoc_idle_w(&self) -> f64 {
        self.calib.p_ps_idle
    }

    /// Board (12 V rail) power during inference.
    pub fn board_w(&self, imp: &Implementation) -> f64 {
        let ddr = match imp {
            Implementation::Cpu { .. } => self.calib.p_ddr_cpu,
            _ => 0.15, // accelerator DMA keeps DDR mildly active
        };
        self.mpsoc_w(imp) + self.calib.p_periph + ddr
    }

    /// MPSoC power during bitstream configuration (the Fig 13 spike).
    pub fn config_spike_w(&self) -> f64 {
        self.calib.p_ps_idle + self.calib.p_config_spike
    }

    /// Convenience constructors from scheduled designs.
    pub fn dpu_impl(sched: &DpuSchedule) -> Implementation {
        Implementation::Dpu { mac_duty: sched.mac_duty() }
    }

    /// `Implementation::Hls` from a synthesized design + LUT estimate.
    pub fn hls_impl(design: &HlsDesign, luts: u64, duty: f64) -> Implementation {
        Implementation::Hls {
            kiloluts: luts as f64 / 1000.0,
            brams: design.plan.brams(),
            duty,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pm() -> PowerModel {
        PowerModel::new(Calibration::default())
    }

    #[test]
    fn cpu_rows_are_anchored() {
        let p = pm().mpsoc_w(&Implementation::Cpu { p_mpsoc_paper: 2.75 });
        assert_eq!(p, 2.75);
    }

    #[test]
    fn dpu_power_scales_with_duty() {
        let lo = pm().mpsoc_w(&Implementation::Dpu { mac_duty: 0.26 });
        let hi = pm().mpsoc_w(&Implementation::Dpu { mac_duty: 0.85 });
        assert!(hi > lo);
        // paper range: 5.75 (VAE) .. 6.75 (CNet)
        assert!((5.2..6.2).contains(&lo), "{lo}");
        assert!((6.2..7.2).contains(&hi), "{hi}");
    }

    #[test]
    fn dpu_family_power_anchored_and_monotone() {
        let m = pm();
        // frac = 1 is bit-identical to the B4096 formula
        let anchor = m.mpsoc_w(&Implementation::Dpu { mac_duty: 0.42 });
        assert_eq!(m.dpu_family_w(1.0, 0.42).to_bits(), anchor.to_bits());
        // smaller arrays draw strictly less, but keep the fixed floor
        let fracs = [0.125, 0.25, 0.5625, 1.0];
        for pair in fracs.windows(2) {
            assert!(m.dpu_family_w(pair[0], 0.5) < m.dpu_family_w(pair[1], 0.5));
        }
        let floor = m.calib.p_dpu_base * m.calib.dpu_static_fixed_frac;
        assert!(m.dpu_family_w(0.125, 0.0) > floor * 0.99);
    }

    #[test]
    fn hls_power_in_paper_band() {
        // ESPERTA-like: 8.1 kLUT, 1.5 BRAM
        let p = pm().mpsoc_w(&Implementation::Hls {
            kiloluts: 8.1, brams: 1.5, duty: 1.0,
        });
        assert!((1.3..2.0).contains(&p), "{p}");
        // all HLS designs must draw less than any CPU row (>= 2.0 W)
        assert!(p < 2.0);
    }

    #[test]
    fn board_exceeds_mpsoc_by_peripheral_floor() {
        let m = pm();
        let imp = Implementation::Dpu { mac_duty: 0.5 };
        assert!(m.board_w(&imp) - m.mpsoc_w(&imp) > 8.5);
    }

    #[test]
    fn config_spike_above_idle() {
        let m = pm();
        assert!(m.config_spike_w() > m.mpsoc_idle_w() + 2.0);
    }
}
