//! Power and energy models: rails, per-implementation draw, time-series
//! traces (Figures 9–13), and energy-per-inference accounting.

pub mod energy;
pub mod model;
pub mod trace;

pub use energy::energy_mj;
pub use model::{Implementation, PowerModel};
pub use trace::{Phase, TracePoint, TraceBuilder};
