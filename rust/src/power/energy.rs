//! Energy-per-inference accounting (paper convention: E = P_MPSoC × t).

/// Millijoules for one inference: MPSoC watts × latency seconds × 1000.
pub fn energy_mj(p_mpsoc_w: f64, latency_s: f64) -> f64 {
    p_mpsoc_w * latency_s * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rows_reproduce() {
        // VAE CPU: 2.75 W at 25.21 FPS -> 109.08 mJ (Table III)
        let e = energy_mj(2.75, 1.0 / 25.21);
        assert!((e - 109.08).abs() < 0.05, "{e}");
        // ESPERTA HLS: 1.5 W at 37231 FPS -> 0.04 mJ
        let e = energy_mj(1.5, 1.0 / 37231.0);
        assert!((e - 0.04).abs() < 0.001, "{e}");
    }

    #[test]
    fn linear_in_both_factors() {
        assert_eq!(energy_mj(2.0, 0.5), 2.0 * energy_mj(1.0, 0.5));
        assert_eq!(energy_mj(2.0, 0.5), 2.0 * energy_mj(2.0, 0.25));
    }
}
