//! Synthetic on-board sensor sources (the flight-data substitution,
//! DESIGN.md §2): magnetogram tiles (VAE), AIA/HMI image pairs + GOES
//! background flux (CNet), flare feature vectors (ESPERTA), and FPI ion
//! energy distributions (MMS nets).  Mirrors `python/compile/data.py` so
//! both layers exercise the same input structure.

pub mod generators;
pub mod pool;
pub mod stream;

pub use generators::{aia_hmi_pair, flare_features, ion_distribution,
                     magnetogram_tile, Region};
pub use pool::{Frame, FramePool, PoolStats};
pub use stream::{SensorEvent, SensorStream};
