//! Sensor event streams feeding the coordinator.
//!
//! Each use case is a stream of timestamped inputs with a ground-truth
//! annotation where one exists (MMS region, SEP event) so decision-logic
//! accuracy can be scored downstream.

use std::sync::Arc;

use crate::model::UseCase;
use crate::util::prng::Prng;

use super::generators;
use super::generators::Region;
use super::pool::{Frame, FramePool};

/// One sensor reading, routed by `use_case`.
#[derive(Debug, Clone)]
pub struct SensorEvent {
    /// Simulated onboard time (s).
    pub t_s: f64,
    /// Use case this event belongs to.
    pub use_case: UseCase,
    /// Flat input tensors (manifest input order of the target model),
    /// `Arc`-shared so the batcher -> executor path never copies the
    /// buffers (cloning an event or building an `ExecRequest` is a
    /// refcount bump).
    pub inputs: Arc<Vec<Vec<f32>>>,
    /// Ground truth: MMS region index or SEP-event flag.
    pub truth: Option<usize>,
    /// Monotonic sequence number within the stream.
    pub seq: u64,
}

/// Deterministic generator of interleaved sensor events.
pub struct SensorStream {
    rng: Prng,
    /// Virtual-clock frontier (s): the timestamp the next generated
    /// event will carry.  Read-only outside the stream — advance it by
    /// generating events, retune it via `set_cadence`.
    pub t_s: f64,
    seq: u64,
    /// Cadence per use case (s between samples).
    pub cadence_s: f64,
    /// Use case this stream generates for.
    pub use_case: UseCase,
    /// Probability an ESPERTA sample is a real SEP precursor.
    pub sep_rate: f64,
}

impl SensorStream {
    /// Deterministic stream for one use case.
    pub fn new(use_case: UseCase, seed: u64, cadence_s: f64) -> SensorStream {
        SensorStream {
            rng: Prng::new(seed),
            t_s: 0.0,
            seq: 0,
            cadence_s,
            use_case,
            sep_rate: 0.15,
        }
    }

    /// Fill `bufs` in place with the next event's input tensors and
    /// return its ground-truth label.  One shared body for the
    /// allocating and pooled paths: identical RNG draw order, identical
    /// per-element arithmetic, so both produce bit-identical events.
    fn fill_inputs(&mut self, bufs: &mut Vec<Vec<f32>>) -> Option<usize> {
        match self.use_case {
            UseCase::Vae => {
                bufs.resize_with(1, Vec::new);
                generators::magnetogram_tile_into(&mut self.rng, &mut bufs[0]);
                None
            }
            UseCase::Cnet => {
                bufs.resize_with(2, Vec::new);
                generators::aia_hmi_pair_into(&mut self.rng, &mut bufs[0]);
                let flux = generators::background_flux(&mut self.rng);
                bufs[1].clear();
                bufs[1].push(flux);
                None
            }
            UseCase::Esperta => {
                bufs.resize_with(1, Vec::new);
                let sep = self.rng.chance(self.sep_rate);
                generators::flare_features_into(&mut self.rng, sep, &mut bufs[0]);
                Some(sep as usize)
            }
            UseCase::Mms => {
                bufs.resize_with(1, Vec::new);
                let region = Region::ALL[self.rng.below(4)];
                generators::ion_distribution_into(&mut self.rng, region, &mut bufs[0]);
                Some(region.index())
            }
        }
    }

    /// Stamp `inputs`/`truth` into an event and advance the clock.
    fn wrap(&mut self, inputs: Frame, truth: Option<usize>) -> SensorEvent {
        let ev = SensorEvent {
            t_s: self.t_s,
            use_case: self.use_case,
            inputs,
            truth,
            seq: self.seq,
        };
        self.t_s += self.cadence_s;
        self.seq += 1;
        ev
    }

    /// Produce the next event (fresh allocation per event).
    pub fn next_event(&mut self) -> SensorEvent {
        let mut inputs = Vec::new();
        let truth = self.fill_inputs(&mut inputs);
        self.wrap(Arc::new(inputs), truth)
    }

    /// Produce the next event into a frame from `pool` — bit-identical
    /// to [`next_event`], allocation-free once the pool has warmed up.
    pub fn next_event_pooled(&mut self, pool: &mut FramePool) -> SensorEvent {
        let mut frame = pool.acquire();
        let bufs = Arc::get_mut(&mut frame).expect("pool frames are uniquely owned");
        let truth = self.fill_inputs(bufs);
        self.wrap(frame, truth)
    }

    /// Does every RNG draw of this stream land in the pixel values of
    /// its input tensors?  True for the truth-free image streams (VAE
    /// magnetograms, CNet image pairs): no ground-truth label, no
    /// branch on a drawn value — so a consumer that never reads the
    /// pixels can skip synthesis entirely without perturbing anything
    /// it *does* read.
    pub fn synthesis_is_pixels_only(&self) -> bool {
        matches!(self.use_case, UseCase::Vae | UseCase::Cnet)
    }

    /// Produce the next event as a pixel-free husk: the timestamp,
    /// sequence number, and (absent) truth label of the real event,
    /// sharing one caller-owned empty frame.  Only meaningful on
    /// streams where [`Self::synthesis_is_pixels_only`] holds *and*
    /// the consumer never reads `inputs` — the timing-only pipeline,
    /// which prices batches from the model manifest, not the pixels.
    /// The sensor RNG is left untouched; the skipped draws could only
    /// have changed pixel values nobody reads.
    pub fn next_event_husk(&mut self, shared: &Frame) -> SensorEvent {
        debug_assert!(self.synthesis_is_pixels_only());
        self.wrap(shared.clone(), None)
    }

    /// Produce `n` events.
    pub fn take(&mut self, n: usize) -> Vec<SensorEvent> {
        (0..n).map(|_| self.next_event()).collect()
    }

    /// Change the inter-event cadence mid-stream (s) — the instrument
    /// switching survey modes, or a burst raising the sample rate.
    /// Takes effect from the *next* inter-event gap; the timestamp the
    /// upcoming event carries is already committed.  Panics on a
    /// non-positive cadence (the virtual clock must advance).
    pub fn set_cadence(&mut self, cadence_s: f64) {
        assert!(
            cadence_s > 0.0 && cadence_s.is_finite(),
            "cadence must be positive and finite"
        );
        self.cadence_s = cadence_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mms_stream_has_truth_labels() {
        let mut s = SensorStream::new(UseCase::Mms, 1, 0.15);
        let evs = s.take(8);
        assert_eq!(evs.len(), 8);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert!(e.truth.unwrap() < 4);
            assert_eq!(e.inputs[0].len(), 32 * 16 * 32);
        }
        // timestamps advance at cadence
        assert!((evs[1].t_s - evs[0].t_s - 0.15).abs() < 1e-12);
    }

    #[test]
    fn cnet_stream_two_inputs() {
        let mut s = SensorStream::new(UseCase::Cnet, 2, 60.0);
        let e = s.next_event();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].len(), 256 * 256 * 2);
        assert_eq!(e.inputs[1].len(), 1);
    }

    #[test]
    fn cadence_change_applies_to_subsequent_gaps() {
        let mut s = SensorStream::new(UseCase::Mms, 1, 0.15);
        let a = s.next_event();
        s.set_cadence(0.015); // 10x burst
        let b = s.next_event();
        let c = s.next_event();
        // the gap *before* b was already committed at the old cadence
        assert!((b.t_s - a.t_s - 0.15).abs() < 1e-12);
        assert!((c.t_s - b.t_s - 0.015).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "cadence")]
    fn zero_cadence_rejected() {
        SensorStream::new(UseCase::Mms, 1, 0.15).set_cadence(0.0);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = SensorStream::new(UseCase::Esperta, 9, 1.0);
        let mut b = SensorStream::new(UseCase::Esperta, 9, 1.0);
        let (x, y) = (a.next_event(), b.next_event());
        assert_eq!(x.inputs[0], y.inputs[0]);
        assert_eq!(x.truth, y.truth);
    }

    #[test]
    fn pooled_events_bit_identical_to_allocating_events() {
        for uc in crate::model::UseCase::ALL {
            let mut fresh = SensorStream::new(uc, 5, 0.25);
            let mut pooled = SensorStream::new(uc, 5, 0.25);
            let mut pool = super::FramePool::new(4);
            for _ in 0..12 {
                let a = fresh.next_event();
                let b = pooled.next_event_pooled(&mut pool);
                assert_eq!(a.inputs, b.inputs, "{uc:?} pooled inputs diverged");
                assert_eq!(a.truth, b.truth);
                assert_eq!(a.seq, b.seq);
                assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
                // hand the frame back like a reaped batch would
                pool.reclaim(b.inputs);
            }
            assert!(
                pool.stats().recycled > 0,
                "{uc:?} never recycled a frame"
            );
        }
    }

    #[test]
    fn husk_events_carry_clock_and_seq_without_touching_the_rng() {
        let mut real = SensorStream::new(UseCase::Vae, 3, 0.5);
        let mut lazy = SensorStream::new(UseCase::Vae, 3, 0.5);
        assert!(lazy.synthesis_is_pixels_only());
        let shared: super::Frame = Arc::new(Vec::new());
        for _ in 0..4 {
            let a = real.next_event();
            let b = lazy.next_event_husk(&shared);
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
            assert_eq!(a.truth, b.truth);
            assert!(b.inputs.is_empty(), "husk carries no pixels");
            assert!(Arc::ptr_eq(&b.inputs, &shared));
        }
        assert!(!SensorStream::new(UseCase::Mms, 3, 0.5).synthesis_is_pixels_only());
        assert!(!SensorStream::new(UseCase::Esperta, 3, 0.5).synthesis_is_pixels_only());
    }
}
