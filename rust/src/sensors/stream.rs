//! Sensor event streams feeding the coordinator.
//!
//! Each use case is a stream of timestamped inputs with a ground-truth
//! annotation where one exists (MMS region, SEP event) so decision-logic
//! accuracy can be scored downstream.

use std::sync::Arc;

use crate::model::UseCase;
use crate::util::prng::Prng;

use super::generators;
use super::generators::Region;

/// One sensor reading, routed by `use_case`.
#[derive(Debug, Clone)]
pub struct SensorEvent {
    /// Simulated onboard time (s).
    pub t_s: f64,
    /// Use case this event belongs to.
    pub use_case: UseCase,
    /// Flat input tensors (manifest input order of the target model),
    /// `Arc`-shared so the batcher -> executor path never copies the
    /// buffers (cloning an event or building an `ExecRequest` is a
    /// refcount bump).
    pub inputs: Arc<Vec<Vec<f32>>>,
    /// Ground truth: MMS region index or SEP-event flag.
    pub truth: Option<usize>,
    /// Monotonic sequence number within the stream.
    pub seq: u64,
}

/// Deterministic generator of interleaved sensor events.
pub struct SensorStream {
    rng: Prng,
    /// Virtual-clock frontier (s): the timestamp the next generated
    /// event will carry.  Read-only outside the stream — advance it by
    /// generating events, retune it via `set_cadence`.
    pub t_s: f64,
    seq: u64,
    /// Cadence per use case (s between samples).
    pub cadence_s: f64,
    /// Use case this stream generates for.
    pub use_case: UseCase,
    /// Probability an ESPERTA sample is a real SEP precursor.
    pub sep_rate: f64,
}

impl SensorStream {
    /// Deterministic stream for one use case.
    pub fn new(use_case: UseCase, seed: u64, cadence_s: f64) -> SensorStream {
        SensorStream {
            rng: Prng::new(seed),
            t_s: 0.0,
            seq: 0,
            cadence_s,
            use_case,
            sep_rate: 0.15,
        }
    }

    /// Produce the next event.
    pub fn next_event(&mut self) -> SensorEvent {
        let (inputs, truth) = match self.use_case {
            UseCase::Vae => (vec![generators::magnetogram_tile(&mut self.rng)], None),
            UseCase::Cnet => (
                vec![
                    generators::aia_hmi_pair(&mut self.rng),
                    vec![generators::background_flux(&mut self.rng)],
                ],
                None,
            ),
            UseCase::Esperta => {
                let sep = self.rng.chance(self.sep_rate);
                (
                    vec![generators::flare_features(&mut self.rng, sep)],
                    Some(sep as usize),
                )
            }
            UseCase::Mms => {
                let region = Region::ALL[self.rng.below(4)];
                (
                    vec![generators::ion_distribution(&mut self.rng, region)],
                    Some(region.index()),
                )
            }
        };
        let ev = SensorEvent {
            t_s: self.t_s,
            use_case: self.use_case,
            inputs: Arc::new(inputs),
            truth,
            seq: self.seq,
        };
        self.t_s += self.cadence_s;
        self.seq += 1;
        ev
    }

    /// Produce `n` events.
    pub fn take(&mut self, n: usize) -> Vec<SensorEvent> {
        (0..n).map(|_| self.next_event()).collect()
    }

    /// Change the inter-event cadence mid-stream (s) — the instrument
    /// switching survey modes, or a burst raising the sample rate.
    /// Takes effect from the *next* inter-event gap; the timestamp the
    /// upcoming event carries is already committed.  Panics on a
    /// non-positive cadence (the virtual clock must advance).
    pub fn set_cadence(&mut self, cadence_s: f64) {
        assert!(
            cadence_s > 0.0 && cadence_s.is_finite(),
            "cadence must be positive and finite"
        );
        self.cadence_s = cadence_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mms_stream_has_truth_labels() {
        let mut s = SensorStream::new(UseCase::Mms, 1, 0.15);
        let evs = s.take(8);
        assert_eq!(evs.len(), 8);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
            assert!(e.truth.unwrap() < 4);
            assert_eq!(e.inputs[0].len(), 32 * 16 * 32);
        }
        // timestamps advance at cadence
        assert!((evs[1].t_s - evs[0].t_s - 0.15).abs() < 1e-12);
    }

    #[test]
    fn cnet_stream_two_inputs() {
        let mut s = SensorStream::new(UseCase::Cnet, 2, 60.0);
        let e = s.next_event();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].len(), 256 * 256 * 2);
        assert_eq!(e.inputs[1].len(), 1);
    }

    #[test]
    fn cadence_change_applies_to_subsequent_gaps() {
        let mut s = SensorStream::new(UseCase::Mms, 1, 0.15);
        let a = s.next_event();
        s.set_cadence(0.015); // 10x burst
        let b = s.next_event();
        let c = s.next_event();
        // the gap *before* b was already committed at the old cadence
        assert!((b.t_s - a.t_s - 0.15).abs() < 1e-12);
        assert!((c.t_s - b.t_s - 0.015).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "cadence")]
    fn zero_cadence_rejected() {
        SensorStream::new(UseCase::Mms, 1, 0.15).set_cadence(0.0);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = SensorStream::new(UseCase::Esperta, 9, 1.0);
        let mut b = SensorStream::new(UseCase::Esperta, 9, 1.0);
        let (x, y) = (a.next_event(), b.next_event());
        assert_eq!(x.inputs[0], y.inputs[0]);
        assert_eq!(x.truth, y.truth);
    }
}
