//! Recycled sensor-frame buffers — the allocation half of the tick
//! hot path.
//!
//! Every sensor event carries its input tensors as an
//! `Arc<Vec<Vec<f32>>>` *frame*.  Without a pool each event heap-
//! allocates fresh tensors (~393 KB per magnetogram tile, ~524 KB per
//! AIA/HMI pair); with one, frames drained from a finished batch are
//! handed back and the next event fills the same capacity in place.
//!
//! Determinism contract: the pool recycles *capacity*, never values —
//! a recycled frame is only handed out once its refcount is back to 1,
//! and every generator `_into` fill clears the buffer before writing.
//! The pool is owned per run (per craft in a fleet), so recycling is
//! invisible to the PRNG streams and thread-count bit-identity holds.

use std::sync::Arc;

/// One input frame: the flat tensors of a single sensor event.
pub type Frame = Arc<Vec<Vec<f32>>>;

/// Effectiveness counters for one [`FramePool`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Frames handed out (fresh + recycled).
    pub acquired: u64,
    /// Acquisitions served from the free list (no allocation).
    pub recycled: u64,
    /// Frames handed back and kept for reuse.
    pub returned: u64,
    /// Frames handed back but dropped: still shared elsewhere, pool
    /// at capacity, or pool disabled.
    pub rejected: u64,
}

/// Pool of recycled input-frame buffers, owned by one pipeline run.
#[derive(Debug)]
pub struct FramePool {
    free: Vec<Frame>,
    cap: usize,
    enabled: bool,
    stats: PoolStats,
}

impl FramePool {
    /// Pool holding at most `cap` free frames.
    pub fn new(cap: usize) -> FramePool {
        FramePool { free: Vec::with_capacity(cap), cap, enabled: true, stats: PoolStats::default() }
    }

    /// A pool that never recycles — the `--no-frame-pool` escape hatch.
    /// `acquire` still works (always fresh), `reclaim` always drops.
    pub fn disabled() -> FramePool {
        FramePool { free: Vec::new(), cap: 0, enabled: false, stats: PoolStats::default() }
    }

    /// Is recycling armed?
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Frames currently on the free list.
    pub fn free_len(&self) -> usize {
        self.free.len()
    }

    /// Effectiveness counters so far.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Hand out a frame: recycled capacity when available, else a
    /// fresh empty frame.  The result is always uniquely owned
    /// (`Arc::get_mut` succeeds).
    pub fn acquire(&mut self) -> Frame {
        self.stats.acquired += 1;
        match self.free.pop() {
            Some(f) => {
                self.stats.recycled += 1;
                f
            }
            None => Arc::new(Vec::new()),
        }
    }

    /// Hand a frame back.  It is kept for reuse only when this was the
    /// last reference (recycling a shared frame would let a later event
    /// overwrite buffers someone still reads) and the free list has
    /// room; otherwise it is dropped.  When one frame is reclaimed via
    /// two clones (the batch event and the executor's input set), the
    /// first call drops its clone and the second recycles — order
    /// between the two does not matter.
    pub fn reclaim(&mut self, frame: Frame) {
        if self.enabled && self.free.len() < self.cap && Arc::strong_count(&frame) == 1 {
            self.stats.returned += 1;
            self.free.push(frame);
        } else {
            self.stats.rejected += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_prefers_recycled_capacity() {
        let mut pool = FramePool::new(4);
        let mut f = pool.acquire();
        Arc::get_mut(&mut f).unwrap().push(vec![1.0; 64]);
        pool.reclaim(f);
        assert_eq!(pool.free_len(), 1);
        let f = pool.acquire();
        assert_eq!(pool.free_len(), 0);
        assert_eq!(f[0].len(), 64, "recycled frame keeps its buffers");
        let s = pool.stats();
        assert_eq!((s.acquired, s.recycled, s.returned), (2, 1, 1));
    }

    #[test]
    fn shared_frames_are_rejected_until_last_reference() {
        let mut pool = FramePool::new(4);
        let a = pool.acquire();
        let b = a.clone();
        pool.reclaim(a); // still shared via b -> dropped
        assert_eq!(pool.free_len(), 0);
        pool.reclaim(b); // last reference -> kept
        assert_eq!(pool.free_len(), 1);
        let s = pool.stats();
        assert_eq!((s.returned, s.rejected), (1, 1));
    }

    #[test]
    fn reclaim_order_of_two_clones_is_irrelevant() {
        for flip in [false, true] {
            let mut pool = FramePool::new(4);
            let a = pool.acquire();
            let b = a.clone();
            let (first, second) = if flip { (a, b) } else { (b, a) };
            pool.reclaim(first);
            pool.reclaim(second);
            assert_eq!(pool.free_len(), 1);
            assert_eq!(pool.stats().returned, 1);
            assert_eq!(pool.stats().rejected, 1);
        }
    }

    #[test]
    fn capacity_cap_and_disabled_pool_drop_frames() {
        let mut pool = FramePool::new(1);
        let (a, b) = (pool.acquire(), pool.acquire());
        pool.reclaim(a);
        pool.reclaim(b); // over cap -> dropped
        assert_eq!(pool.free_len(), 1);

        let mut off = FramePool::disabled();
        assert!(!off.is_enabled());
        let f = off.acquire();
        off.reclaim(f);
        assert_eq!(off.free_len(), 0);
        assert_eq!(off.stats().rejected, 1);
    }
}
