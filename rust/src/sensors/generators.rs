//! Structured synthetic inputs, rust side (mirrors python/compile/data.py).

use crate::util::prng::Prng;

/// Earth's dayside plasma regions (MMS classification targets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    /// Solar wind: cold narrow beam.
    Sw,
    /// Ion foreshock: beam + diffuse suprathermal.
    If,
    /// Magnetosheath: hot broad Maxwellian.
    Msh,
    /// Magnetosphere: tenuous, very hot.
    Msp,
}

impl Region {
    /// All four regions, index order matching the classifier logits.
    pub const ALL: [Region; 4] = [Region::Sw, Region::If, Region::Msh, Region::Msp];

    /// Short display label ("SW", "IF", ...).
    pub fn label(&self) -> &'static str {
        match self {
            Region::Sw => "SW",
            Region::If => "IF",
            Region::Msh => "MSH",
            Region::Msp => "MSP",
        }
    }

    /// Position in `Region::ALL` (the classifier's logit index).
    pub fn index(&self) -> usize {
        Region::ALL.iter().position(|r| r == self).unwrap()
    }
}

/// Bipolar active-region magnetogram tile, 128x256x3 (flattened NHWC).
pub fn magnetogram_tile(rng: &mut Prng) -> Vec<f32> {
    let (h, w) = (128usize, 256usize);
    let cx = rng.range_f64(-0.4, 0.4);
    let cy = rng.range_f64(-0.4, 0.4);
    let mut out = Vec::with_capacity(h * w * 3);
    for i in 0..h {
        let y = -1.0 + 2.0 * i as f64 / (h - 1) as f64;
        for j in 0..w {
            let x = -1.0 + 2.0 * j as f64 / (w - 1) as f64;
            let r2p = (x - cx).powi(2) + (y - cy).powi(2);
            let r2n = (x - cx - 0.25).powi(2) + (y - cy + 0.1).powi(2);
            let spot = (-r2p / 0.02).exp() - 0.7 * (-r2n / 0.04).exp();
            let v = (spot + 0.08 * fast_normal(rng)).clamp(-1.0, 1.0) as f32;
            out.extend_from_slice(&[v, v, v]);
        }
    }
    out
}

/// CNet image input: [AIA 193 | HMI] pair, 256x256x2 (flattened NHWC).
pub fn aia_hmi_pair(rng: &mut Prng) -> Vec<f32> {
    let n = 256usize;
    let loops: Vec<(f64, f64)> = (0..3)
        .map(|_| (rng.range_f64(-0.5, 0.5), rng.range_f64(-0.5, 0.5)))
        .collect();
    let cx = rng.range_f64(-0.4, 0.4);
    let cy = rng.range_f64(-0.4, 0.4);
    let mut out = Vec::with_capacity(n * n * 2);
    for i in 0..n {
        let y = -1.0 + 2.0 * i as f64 / (n - 1) as f64;
        for j in 0..n {
            let x = -1.0 + 2.0 * j as f64 / (n - 1) as f64;
            let r = (x * x + y * y).sqrt();
            let disk = if r < 0.95 { 1.0 } else { 0.0 };
            let mu = (1.0 - (r / 0.95).powi(2)).clamp(1e-3, 1.0).sqrt();
            let mut aia = 0.3 * disk / mu.sqrt();
            for (lx, ly) in &loops {
                aia += (-((x - lx).powi(2) + (y - ly).powi(2)) / 0.01).exp();
            }
            let aia = (aia.clamp(0.0, 4.0) / 4.0) as f32;
            let r2p = (x - cx).powi(2) + (y - cy).powi(2);
            let hmi = ((-r2p / 0.02).exp() + 0.05 * fast_normal(rng)).clamp(-1.0, 1.0) as f32;
            out.push(aia);
            out.push(hmi);
        }
    }
    out
}

/// log10 GOES background flux over the preceding 30 min.
pub fn background_flux(rng: &mut Prng) -> f32 {
    rng.range_f64(-8.0, -5.0) as f32
}

/// ESPERTA features: (heliolongitude/90, log SXR fluence, log radio
/// fluence).  `sep_event` biases toward a large well-connected flare.
pub fn flare_features(rng: &mut Prng, sep_event: bool) -> Vec<f32> {
    if sep_event {
        vec![
            rng.range_f64(0.3, 1.0) as f32,
            rng.range_f64(1.2, 2.0) as f32,
            rng.range_f64(1.2, 2.0) as f32,
        ]
    } else {
        vec![
            rng.range_f64(-1.0, 1.0) as f32,
            rng.range_f64(0.0, 0.8) as f32,
            rng.range_f64(0.0, 0.8) as f32,
        ]
    }
}

/// Fast approximately-normal noise: Irwin-Hall with two 32-bit uniforms
/// drawn from a single xorshift step (var 1/6, scaled to unit variance).
/// ~10x cheaper than Box-Muller on the per-voxel hot path; the sensors
/// only need qualitative noise (§Perf L3 iteration log in EXPERIMENTS.md).
#[inline]
fn fast_normal(rng: &mut Prng) -> f64 {
    let bits = rng.next_u64();
    let u1 = (bits >> 32) as f64 / 4294967296.0;
    let u2 = (bits & 0xFFFF_FFFF) as f64 / 4294967296.0;
    (u1 + u2 - 1.0) * 2.449_489_743 // sqrt(6): unit variance
}

/// FPI-like ion energy distribution, 32x16x32 (flattened NDHWC, C=1).
///
/// The region structure is separable (energy profile x angular profile),
/// so the deterministic part is built from per-axis tables — the per-voxel
/// work is one multiply + noise + the log intensity mapping (§Perf L3:
/// 2.0 ms -> ~0.5 ms per distribution).
pub fn ion_distribution(rng: &mut Prng, region: Region) -> Vec<f32> {
    let (e_n, t_n, p_n) = (32usize, 16usize, 32usize);
    let ln101 = 101.0f64.ln();
    // per-axis tables
    let mut ge = [0.0f64; 32]; // energy profile
    let mut ge2 = [0.0f64; 32]; // secondary population (IF suprathermal)
    for (ei, g) in ge.iter_mut().enumerate() {
        let e = ei as f64 / (e_n - 1) as f64;
        *g = match region {
            Region::Sw | Region::If => (-(e - 0.25).powi(2) / 0.003).exp(),
            Region::Msh => (-(e - 0.4).powi(2) / 0.04).exp(),
            Region::Msp => 0.3 * (-(e - 0.7).powi(2) / 0.08).exp(),
        };
        if region == Region::If {
            let e = ei as f64 / (e_n - 1) as f64;
            ge2[ei] = 0.25 * (-(e - 0.55).powi(2) / 0.05).exp();
        }
    }
    let mut htp = [0.0f32; 16 * 32]; // angular profile
    for ti in 0..t_n {
        let t = -1.0 + 2.0 * ti as f64 / (t_n - 1) as f64;
        for pi in 0..p_n {
            let p = -1.0 + 2.0 * pi as f64 / (p_n - 1) as f64;
            htp[ti * p_n + pi] = (match region {
                Region::Sw | Region::If => (-(t * t + p * p) / 0.08).exp(),
                Region::Msh => 1.0 + 0.2 * t,
                Region::Msp => 1.0,
            }) as f32;
        }
    }
    let mut out = Vec::with_capacity(e_n * t_n * p_n);
    let inv_ln101 = (1.0 / ln101) as f32;
    for ei in 0..e_n {
        let (g, g2) = (ge[ei] as f32, ge2[ei] as f32);
        for &tp in htp.iter() {
            let f = g * tp + g2;
            let f = (f + 0.03 * fast_normal(rng) as f32).clamp(0.0, 1.0);
            out.push((100.0 * f).ln_1p() * inv_ln101);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magnetogram_shape_and_range() {
        let mut rng = Prng::new(1);
        let img = magnetogram_tile(&mut rng);
        assert_eq!(img.len(), 128 * 256 * 3);
        let max = img.iter().cloned().fold(f32::MIN, f32::max);
        let min = img.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max > 0.3 && min < -0.1 && max <= 1.0 && min >= -1.0);
    }

    #[test]
    fn aia_pair_shape() {
        let mut rng = Prng::new(2);
        assert_eq!(aia_hmi_pair(&mut rng).len(), 256 * 256 * 2);
    }

    #[test]
    fn ion_regions_statistically_distinct() {
        let mut rng = Prng::new(3);
        let means: Vec<f64> = Region::ALL
            .iter()
            .map(|&r| {
                let d = ion_distribution(&mut rng, r);
                d.iter().map(|&v| v as f64).sum::<f64>() / d.len() as f64
            })
            .collect();
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert!(
                    (means[i] - means[j]).abs() > 1e-3,
                    "regions {i},{j} indistinguishable: {means:?}"
                );
            }
        }
    }

    #[test]
    fn sep_flares_are_stronger() {
        let mut rng = Prng::new(4);
        let sep = flare_features(&mut rng, true);
        assert!(sep[1] >= 1.2 && sep[2] >= 1.2);
        assert_eq!(sep.len(), 3);
    }

    #[test]
    fn region_index_roundtrip() {
        for r in Region::ALL {
            assert_eq!(Region::ALL[r.index()], r);
        }
    }
}
