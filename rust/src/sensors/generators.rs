//! Structured synthetic inputs, rust side (mirrors python/compile/data.py).
//!
//! Every generator comes in two forms: the original allocating function
//! and a `_into` variant that fills a caller-owned buffer — the frame
//! pool's hot path.  Both produce bit-identical values: the `_into`
//! bodies hoist loop-invariant coordinate grids but evaluate every
//! per-element expression exactly as the inline versions did.

use std::sync::OnceLock;

use crate::util::prng::Prng;

/// Earth's dayside plasma regions (MMS classification targets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    /// Solar wind: cold narrow beam.
    Sw,
    /// Ion foreshock: beam + diffuse suprathermal.
    If,
    /// Magnetosheath: hot broad Maxwellian.
    Msh,
    /// Magnetosphere: tenuous, very hot.
    Msp,
}

impl Region {
    /// All four regions, index order matching the classifier logits.
    pub const ALL: [Region; 4] = [Region::Sw, Region::If, Region::Msh, Region::Msp];

    /// Short display label ("SW", "IF", ...).
    pub fn label(&self) -> &'static str {
        match self {
            Region::Sw => "SW",
            Region::If => "IF",
            Region::Msh => "MSH",
            Region::Msp => "MSP",
        }
    }

    /// Position in `Region::ALL` (the classifier's logit index).
    /// Constant-time: this runs once per classified event.
    pub fn index(&self) -> usize {
        match self {
            Region::Sw => 0,
            Region::If => 1,
            Region::Msh => 2,
            Region::Msp => 3,
        }
    }
}

/// Bipolar active-region magnetogram tile, 128x256x3 (flattened NHWC).
pub fn magnetogram_tile(rng: &mut Prng) -> Vec<f32> {
    let mut out = Vec::new();
    magnetogram_tile_into(rng, &mut out);
    out
}

/// [`magnetogram_tile`] into a caller-owned buffer (cleared first) —
/// allocation-free once the buffer has capacity.  The x grid is hoisted
/// out of the row loop but built with the exact inline expression, so
/// every output element is bit-identical to the allocating version.
pub fn magnetogram_tile_into(rng: &mut Prng, out: &mut Vec<f32>) {
    let (h, w) = (128usize, 256usize);
    let cx = rng.range_f64(-0.4, 0.4);
    let cy = rng.range_f64(-0.4, 0.4);
    let mut xs = [0.0f64; 256];
    for (j, x) in xs.iter_mut().enumerate() {
        *x = -1.0 + 2.0 * j as f64 / (w - 1) as f64;
    }
    out.clear();
    out.reserve(h * w * 3);
    for i in 0..h {
        let y = -1.0 + 2.0 * i as f64 / (h - 1) as f64;
        for &x in &xs {
            let r2p = (x - cx).powi(2) + (y - cy).powi(2);
            let r2n = (x - cx - 0.25).powi(2) + (y - cy + 0.1).powi(2);
            let spot = (-r2p / 0.02).exp() - 0.7 * (-r2n / 0.04).exp();
            let v = (spot + 0.08 * fast_normal(rng)).clamp(-1.0, 1.0) as f32;
            out.extend_from_slice(&[v, v, v]);
        }
    }
}

/// CNet image input: [AIA 193 | HMI] pair, 256x256x2 (flattened NHWC).
pub fn aia_hmi_pair(rng: &mut Prng) -> Vec<f32> {
    let mut out = Vec::new();
    aia_hmi_pair_into(rng, &mut out);
    out
}

/// The RNG-independent AIA term per pixel — the limb-darkened solar
/// disk `0.3 * disk / mu.sqrt()` — built once per process with the
/// exact per-pixel expressions the inline version used.
fn aia_base() -> &'static [f64] {
    static AIA_BASE: OnceLock<Vec<f64>> = OnceLock::new();
    AIA_BASE.get_or_init(|| {
        let n = 256usize;
        let mut base = Vec::with_capacity(n * n);
        for i in 0..n {
            let y = -1.0 + 2.0 * i as f64 / (n - 1) as f64;
            for j in 0..n {
                let x = -1.0 + 2.0 * j as f64 / (n - 1) as f64;
                let r = (x * x + y * y).sqrt();
                let disk = if r < 0.95 { 1.0 } else { 0.0 };
                let mu = (1.0 - (r / 0.95).powi(2)).clamp(1e-3, 1.0).sqrt();
                base.push(0.3 * disk / mu.sqrt());
            }
        }
        base
    })
}

/// [`aia_hmi_pair`] into a caller-owned buffer (cleared first).  The
/// solar-disk term depends only on pixel coordinates and comes from a
/// process-wide table; the flare-loop and sunspot terms keep the
/// original expressions and RNG draw order, so the output is
/// bit-identical to the allocating version.
pub fn aia_hmi_pair_into(rng: &mut Prng, out: &mut Vec<f32>) {
    let n = 256usize;
    let mut loops = [(0.0f64, 0.0f64); 3];
    for l in loops.iter_mut() {
        *l = (rng.range_f64(-0.5, 0.5), rng.range_f64(-0.5, 0.5));
    }
    let cx = rng.range_f64(-0.4, 0.4);
    let cy = rng.range_f64(-0.4, 0.4);
    let base = aia_base();
    let mut xs = [0.0f64; 256];
    for (j, x) in xs.iter_mut().enumerate() {
        *x = -1.0 + 2.0 * j as f64 / (n - 1) as f64;
    }
    out.clear();
    out.reserve(n * n * 2);
    for i in 0..n {
        let y = -1.0 + 2.0 * i as f64 / (n - 1) as f64;
        let row = &base[i * n..(i + 1) * n];
        for (j, &x) in xs.iter().enumerate() {
            let mut aia = row[j];
            for (lx, ly) in &loops {
                aia += (-((x - lx).powi(2) + (y - ly).powi(2)) / 0.01).exp();
            }
            let aia = (aia.clamp(0.0, 4.0) / 4.0) as f32;
            let r2p = (x - cx).powi(2) + (y - cy).powi(2);
            let hmi = ((-r2p / 0.02).exp() + 0.05 * fast_normal(rng)).clamp(-1.0, 1.0) as f32;
            out.push(aia);
            out.push(hmi);
        }
    }
}

/// log10 GOES background flux over the preceding 30 min.
pub fn background_flux(rng: &mut Prng) -> f32 {
    rng.range_f64(-8.0, -5.0) as f32
}

/// ESPERTA features: (heliolongitude/90, log SXR fluence, log radio
/// fluence).  `sep_event` biases toward a large well-connected flare.
pub fn flare_features(rng: &mut Prng, sep_event: bool) -> Vec<f32> {
    let mut out = Vec::new();
    flare_features_into(rng, sep_event, &mut out);
    out
}

/// [`flare_features`] into a caller-owned buffer (cleared first);
/// identical draw order, so identical values.
pub fn flare_features_into(rng: &mut Prng, sep_event: bool, out: &mut Vec<f32>) {
    out.clear();
    if sep_event {
        out.push(rng.range_f64(0.3, 1.0) as f32);
        out.push(rng.range_f64(1.2, 2.0) as f32);
        out.push(rng.range_f64(1.2, 2.0) as f32);
    } else {
        out.push(rng.range_f64(-1.0, 1.0) as f32);
        out.push(rng.range_f64(0.0, 0.8) as f32);
        out.push(rng.range_f64(0.0, 0.8) as f32);
    }
}

/// Fast approximately-normal noise: Irwin-Hall with two 32-bit uniforms
/// drawn from a single xorshift step (var 1/6, scaled to unit variance).
/// ~10x cheaper than Box-Muller on the per-voxel hot path; the sensors
/// only need qualitative noise (§Perf L3 iteration log in EXPERIMENTS.md).
#[inline]
fn fast_normal(rng: &mut Prng) -> f64 {
    let bits = rng.next_u64();
    let u1 = (bits >> 32) as f64 / 4294967296.0;
    let u2 = (bits & 0xFFFF_FFFF) as f64 / 4294967296.0;
    (u1 + u2 - 1.0) * 2.449_489_743 // sqrt(6): unit variance
}

/// FPI-like ion energy distribution, 32x16x32 (flattened NDHWC, C=1).
///
/// The region structure is separable (energy profile x angular profile),
/// so the deterministic part is built from per-axis tables — the per-voxel
/// work is one multiply + noise + the log intensity mapping (§Perf L3:
/// 2.0 ms -> ~0.5 ms per distribution).
pub fn ion_distribution(rng: &mut Prng, region: Region) -> Vec<f32> {
    let mut out = Vec::new();
    ion_distribution_into(rng, region, &mut out);
    out
}

/// [`ion_distribution`] into a caller-owned buffer (cleared first);
/// same per-axis tables and per-voxel arithmetic, so identical values.
pub fn ion_distribution_into(rng: &mut Prng, region: Region, out: &mut Vec<f32>) {
    let (e_n, t_n, p_n) = (32usize, 16usize, 32usize);
    let ln101 = 101.0f64.ln();
    // per-axis tables
    let mut ge = [0.0f64; 32]; // energy profile
    let mut ge2 = [0.0f64; 32]; // secondary population (IF suprathermal)
    for (ei, g) in ge.iter_mut().enumerate() {
        let e = ei as f64 / (e_n - 1) as f64;
        *g = match region {
            Region::Sw | Region::If => (-(e - 0.25).powi(2) / 0.003).exp(),
            Region::Msh => (-(e - 0.4).powi(2) / 0.04).exp(),
            Region::Msp => 0.3 * (-(e - 0.7).powi(2) / 0.08).exp(),
        };
        if region == Region::If {
            let e = ei as f64 / (e_n - 1) as f64;
            ge2[ei] = 0.25 * (-(e - 0.55).powi(2) / 0.05).exp();
        }
    }
    let mut htp = [0.0f32; 16 * 32]; // angular profile
    for ti in 0..t_n {
        let t = -1.0 + 2.0 * ti as f64 / (t_n - 1) as f64;
        for pi in 0..p_n {
            let p = -1.0 + 2.0 * pi as f64 / (p_n - 1) as f64;
            htp[ti * p_n + pi] = (match region {
                Region::Sw | Region::If => (-(t * t + p * p) / 0.08).exp(),
                Region::Msh => 1.0 + 0.2 * t,
                Region::Msp => 1.0,
            }) as f32;
        }
    }
    out.clear();
    out.reserve(e_n * t_n * p_n);
    let inv_ln101 = (1.0 / ln101) as f32;
    for ei in 0..e_n {
        let (g, g2) = (ge[ei] as f32, ge2[ei] as f32);
        for &tp in htp.iter() {
            let f = g * tp + g2;
            let f = (f + 0.03 * fast_normal(rng) as f32).clamp(0.0, 1.0);
            out.push((100.0 * f).ln_1p() * inv_ln101);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magnetogram_shape_and_range() {
        let mut rng = Prng::new(1);
        let img = magnetogram_tile(&mut rng);
        assert_eq!(img.len(), 128 * 256 * 3);
        let max = img.iter().cloned().fold(f32::MIN, f32::max);
        let min = img.iter().cloned().fold(f32::MAX, f32::min);
        assert!(max > 0.3 && min < -0.1 && max <= 1.0 && min >= -1.0);
    }

    #[test]
    fn aia_pair_shape() {
        let mut rng = Prng::new(2);
        assert_eq!(aia_hmi_pair(&mut rng).len(), 256 * 256 * 2);
    }

    #[test]
    fn ion_regions_statistically_distinct() {
        let mut rng = Prng::new(3);
        let means: Vec<f64> = Region::ALL
            .iter()
            .map(|&r| {
                let d = ion_distribution(&mut rng, r);
                d.iter().map(|&v| v as f64).sum::<f64>() / d.len() as f64
            })
            .collect();
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert!(
                    (means[i] - means[j]).abs() > 1e-3,
                    "regions {i},{j} indistinguishable: {means:?}"
                );
            }
        }
    }

    #[test]
    fn sep_flares_are_stronger() {
        let mut rng = Prng::new(4);
        let sep = flare_features(&mut rng, true);
        assert!(sep[1] >= 1.2 && sep[2] >= 1.2);
        assert_eq!(sep.len(), 3);
    }

    #[test]
    fn region_index_roundtrip() {
        for r in Region::ALL {
            assert_eq!(Region::ALL[r.index()], r);
        }
    }

    #[test]
    fn into_variants_reuse_dirty_buffers_bit_identically() {
        let (mut a, mut b) = (Prng::new(11), Prng::new(11));
        // one shared buffer, reused dirty across shapes: every fill must
        // clear it and reproduce the allocating output exactly
        let mut buf = vec![9.0f32; 7];
        magnetogram_tile_into(&mut b, &mut buf);
        assert_eq!(magnetogram_tile(&mut a), buf);
        aia_hmi_pair_into(&mut b, &mut buf);
        assert_eq!(aia_hmi_pair(&mut a), buf);
        flare_features_into(&mut b, true, &mut buf);
        assert_eq!(flare_features(&mut a, true), buf);
        ion_distribution_into(&mut b, Region::If, &mut buf);
        assert_eq!(ion_distribution(&mut a, Region::If), buf);
    }
}
