//! ARM Cortex-A53 baseline timing model.

pub mod a53;

pub use a53::A53Model;
