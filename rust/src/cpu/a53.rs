//! ARM Cortex-A53 (PS) inference-time model — the paper's CPU baseline.
//!
//! Two regimes, both visible in Table III:
//!
//! * **throughput-bound** (VAE, CNet, BaselineNet): time ≈ ops divided by
//!   an effective NEON throughput well below peak;
//! * **dispatch-bound** (ESPERTA at 6,932 FPS = 144 µs, LogisticNet,
//!   ReducedNet): time ≈ per-layer PyTorch kernel-launch overhead.
//!
//! The model is `t = Σ_l ops_l / (peak · util) + Σ_l dispatch(kind_l)`.
//! `util` (the per-model NEON efficiency) is the one quantity calibrated
//! from the paper's CPU rows — PyTorch's per-model efficiency on an
//! in-order A53 is an empirical artifact of their testbed that cannot be
//! derived from first principles.  Accelerator rows are *not* calibrated.

use crate::board::Calibration;
use crate::model::Manifest;

/// Calibrated A53 model for one network.
#[derive(Debug, Clone)]
pub struct A53Model {
    /// NEON efficiency in (0, 1]: fraction of peak ops/s achieved.
    pub util: f64,
    /// Total per-inference dispatch overhead (s).
    pub dispatch_s: f64,
    /// Total ops per inference.
    pub ops: u64,
    peak_ops: f64,
}

impl A53Model {
    /// Build with an explicit efficiency (used by tests and sweeps).
    pub fn with_util(man: &Manifest, calib: &Calibration, util: f64) -> A53Model {
        let dispatch_s = man
            .layers
            .iter()
            .map(|l| calib.dispatch_for(l.kind))
            .sum();
        A53Model {
            util: util.clamp(1e-9, 0.95),
            dispatch_s,
            ops: man.total_ops,
            peak_ops: calib.cpu_peak_ops,
        }
    }

    /// Calibrate the efficiency so the predicted time equals the paper's
    /// measured CPU time for this network (Table III anchoring).
    pub fn calibrated(man: &Manifest, calib: &Calibration, paper_cpu_fps: f64) -> A53Model {
        let mut m = A53Model::with_util(man, calib, 0.5);
        let t_target = 1.0 / paper_cpu_fps;
        let t_compute = (t_target - m.dispatch_s).max(1e-9);
        m.util = (m.ops as f64 / (m.peak_ops * t_compute)).clamp(1e-9, 0.95);
        m
    }

    /// Predicted per-inference latency (s).
    pub fn latency_s(&self) -> f64 {
        self.ops as f64 / (self.peak_ops * self.util) + self.dispatch_s
    }

    /// Predicted FPS.
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }

    /// Effective throughput (op/s) — the paper's "Throughput" column.
    pub fn achieved_ops_per_s(&self) -> f64 {
        self.ops as f64 * self.fps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::util::json::Json;

    fn mini(ops_scale: u64) -> Manifest {
        // dense-only manifest with adjustable op count
        let macs = 32 * ops_scale;
        let ops = 2 * macs + 2;
        let src = format!(
            r#"{{"name":"m","precision":"fp32",
              "inputs":{{"x":[1,{k}]}},"input_order":["x"],
              "output_shape":[1,2],
              "layers":[{{"kind":"dense","in_shape":[1,{k}],
                "out_shape":[1,2],"macs":{macs},"ops":{ops},
                "params":{p},"weight_bytes":{wb},"act_bytes":8,
                "act":"none"}}],
              "total_macs":{macs},"total_ops":{ops},"total_params":{p},
              "weight_bytes":{wb}}}"#,
            k = 16 * ops_scale,
            macs = macs,
            ops = ops,
            p = 2 * (16 * ops_scale + 1),
            wb = 8 * (16 * ops_scale + 1),
        );
        Manifest::from_json(&Json::parse(&src).unwrap()).unwrap()
    }

    #[test]
    fn calibration_reproduces_target_fps() {
        let c = Calibration::default();
        let man = mini(1_000_000);
        let m = A53Model::calibrated(&man, &c, 25.21);
        assert!((m.fps() - 25.21).abs() / 25.21 < 1e-6);
    }

    #[test]
    fn dispatch_bound_regime() {
        let c = Calibration::default();
        let man = mini(1); // 66 ops: dispatch dominates
        let m = A53Model::with_util(&man, &c, 0.5);
        assert!(m.dispatch_s > 0.9 * m.latency_s());
    }

    #[test]
    fn throughput_bound_regime() {
        let c = Calibration::default();
        let man = mini(10_000_000); // 640M ops
        let m = A53Model::with_util(&man, &c, 0.3);
        assert!(m.dispatch_s < 0.01 * m.latency_s());
    }

    #[test]
    fn util_clamped() {
        let c = Calibration::default();
        let man = mini(100_000_000);
        // impossible target -> util hits the clamp, no panic/negative
        let m = A53Model::calibrated(&man, &c, 1.0e9);
        assert!(m.util <= 0.95);
        assert!(m.latency_s() > 0.0);
    }

    #[test]
    fn more_ops_is_slower() {
        let c = Calibration::default();
        let a = A53Model::with_util(&mini(1000), &c, 0.3);
        let b = A53Model::with_util(&mini(2000), &c, 0.3);
        assert!(b.latency_s() > a.latency_s());
    }
}
