//! Seeded, deterministic fault injection — the typed fault vocabulary.
//!
//! The injector owns its own xorshift stream (salted so it never aliases
//! the decision jitter RNG) and draws a **fixed number of variates per
//! query**: two per batch attempt, two per pipeline tick.  Fixing the
//! draw count is what makes the fault timeline reproducible — a fault
//! that fires (or doesn't) never shifts the stream position of the next
//! roll, so the same seed replays the same campaign bit for bit.
//!
//! Per-target SEU susceptibility is scaled by the target's essential
//! configuration bits (`rad::seu::essential_bits_of`): the A53 software
//! path exposes zero CRAM and therefore never draws a corruption fault,
//! while the DPU's large footprint makes it the most SEU-prone slot.

use crate::util::prng::Prng;

/// Salt XORed into the fault seed so the injector's stream is decoupled
/// from the pipeline's decision RNG even when both use the same seed.
const FAULT_RNG_SALT: u64 = 0xFA17_5EED;

/// One injected fault drawn against a batch attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The batch execution fails outright (worker fault, bus error).
    ExecFail,
    /// The batch completes but far over budget (hung DMA, retried bus).
    ExecTimeout,
    /// SEU configuration/weight corruption — output untrustworthy.
    SeuCorrupt,
}

impl FaultKind {
    /// Stable metric/report label for the fault kind.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::ExecFail => "exec_fail",
            FaultKind::ExecTimeout => "exec_timeout",
            FaultKind::SeuCorrupt => "seu_corrupt",
        }
    }
}

/// Tick-granularity environment faults rolled once per pipeline tick.
#[derive(Debug, Clone, Copy, Default)]
pub struct TickFaults {
    /// A brownout power sag begins this tick.
    pub brownout: bool,
    /// A downlink dropout begins this tick.
    pub dropout: bool,
}

/// Per-fault-class probabilities and severities.
///
/// Probabilities are per *attempt* (batch-level faults) or per *tick*
/// (environment faults); severities parameterize the injected effect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProfile {
    /// P(transient execution failure) per batch attempt, per target.
    pub exec_fail_p: f64,
    /// P(execution timeout) per batch attempt, per target.
    pub timeout_p: f64,
    /// Latency multiplier applied to a timed-out attempt.
    pub timeout_factor_x: f64,
    /// Base P(SEU corruption) per attempt — scaled by the target's
    /// essential-bit exposure (0 for the CPU, ~1 for the largest slot).
    pub seu_corrupt_p: f64,
    /// P(thermal throttle trips) per batch attempt, per target.
    pub thermal_p: f64,
    /// Latency derate applied while a throttle window is open.
    pub thermal_derate_x: f64,
    /// Duration of one thermal throttle window (virtual seconds).
    pub thermal_duration_s: f64,
    /// P(brownout power sag begins) per pipeline tick.
    pub brownout_p: f64,
    /// Power budget enforced while a brownout window is open (W).
    pub brownout_budget_w: f64,
    /// Duration of one brownout window (virtual seconds).
    pub brownout_duration_s: f64,
    /// P(downlink dropout begins) per pipeline tick.
    pub dropout_p: f64,
    /// Duration of one downlink dropout window (virtual seconds).
    pub dropout_duration_s: f64,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile {
            exec_fail_p: 0.02,
            timeout_p: 0.01,
            timeout_factor_x: 4.0,
            seu_corrupt_p: 0.02,
            thermal_p: 0.01,
            thermal_derate_x: 2.0,
            thermal_duration_s: 4.0,
            brownout_p: 0.002,
            brownout_budget_w: 2.5,
            brownout_duration_s: 5.0,
            dropout_p: 0.003,
            dropout_duration_s: 8.0,
        }
    }
}

/// Deterministic fault source: a salted PRNG plus the profile and the
/// per-target SEU exposure weights (essential bits, normalized).
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rng: Prng,
    profile: FaultProfile,
    exposure: Vec<f64>,
}

impl FaultInjector {
    /// Build an injector for `exposure.len()` targets.  `exposure[i]`
    /// scales target `i`'s SEU corruption probability and should be in
    /// [0, 1] (essential bits over the fleet maximum).
    pub fn new(seed: u64, profile: FaultProfile, exposure: Vec<f64>) -> Self {
        FaultInjector { rng: Prng::new(seed ^ FAULT_RNG_SALT), profile, exposure }
    }

    /// The profile this injector draws from.
    pub fn profile(&self) -> &FaultProfile {
        &self.profile
    }

    /// Roll the batch-attempt faults for `target`.  Always consumes
    /// exactly two variates: one for the mutually-exclusive batch fault
    /// (fail | timeout | corrupt), one for the thermal trip.
    pub fn roll_attempt(&mut self, target: usize) -> (Option<FaultKind>, bool) {
        let expo = self.exposure.get(target).copied().unwrap_or(0.0);
        let u = self.rng.f64();
        let fail_edge = self.profile.exec_fail_p;
        let timeout_edge = fail_edge + self.profile.timeout_p;
        let corrupt_edge = timeout_edge + self.profile.seu_corrupt_p * expo;
        let fault = if u < fail_edge {
            Some(FaultKind::ExecFail)
        } else if u < timeout_edge {
            Some(FaultKind::ExecTimeout)
        } else if u < corrupt_edge {
            Some(FaultKind::SeuCorrupt)
        } else {
            None
        };
        let thermal = self.rng.chance(self.profile.thermal_p);
        (fault, thermal)
    }

    /// Roll the tick-granularity environment faults.  Always consumes
    /// exactly two variates (brownout, dropout).
    pub fn roll_tick(&mut self) -> TickFaults {
        let brownout = self.rng.chance(self.profile.brownout_p);
        let dropout = self.rng.chance(self.profile.dropout_p);
        TickFaults { brownout, dropout }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_timeline() {
        let profile = FaultProfile { exec_fail_p: 0.3, ..Default::default() };
        let mut a = FaultInjector::new(9, profile, vec![1.0, 0.0]);
        let mut b = FaultInjector::new(9, profile, vec![1.0, 0.0]);
        for i in 0..200 {
            assert_eq!(a.roll_attempt(i % 2), b.roll_attempt(i % 2));
            let (ta, tb) = (a.roll_tick(), b.roll_tick());
            assert_eq!(ta.brownout, tb.brownout);
            assert_eq!(ta.dropout, tb.dropout);
        }
    }

    #[test]
    fn zero_exposure_never_corrupts() {
        let profile = FaultProfile {
            exec_fail_p: 0.0,
            timeout_p: 0.0,
            seu_corrupt_p: 1.0,
            ..Default::default()
        };
        let mut inj = FaultInjector::new(3, profile, vec![0.0]);
        for _ in 0..500 {
            assert_eq!(inj.roll_attempt(0).0, None);
        }
    }

    #[test]
    fn full_exposure_always_corrupts_at_p1() {
        let profile = FaultProfile {
            exec_fail_p: 0.0,
            timeout_p: 0.0,
            seu_corrupt_p: 1.0,
            ..Default::default()
        };
        let mut inj = FaultInjector::new(3, profile, vec![1.0]);
        for _ in 0..100 {
            assert_eq!(inj.roll_attempt(0).0, Some(FaultKind::SeuCorrupt));
        }
    }

    #[test]
    fn draw_count_is_fixed() {
        // a fault firing must not shift the stream vs. one not firing
        let quiet = FaultProfile {
            exec_fail_p: 0.0,
            timeout_p: 0.0,
            seu_corrupt_p: 0.0,
            thermal_p: 0.0,
            ..Default::default()
        };
        let noisy = FaultProfile {
            exec_fail_p: 1.0,
            thermal_p: 1.0,
            ..quiet
        };
        let mut a = FaultInjector::new(77, quiet, vec![1.0]);
        let mut b = FaultInjector::new(77, noisy, vec![1.0]);
        for _ in 0..50 {
            a.roll_attempt(0);
            b.roll_attempt(0);
        }
        // after equal draw counts the raw streams realign
        assert_eq!(a.rng.next_u64(), b.rng.next_u64());
    }
}
