//! Recovery policy knobs and the TMR cost model for hardened dispatch.
//!
//! The policy is deliberately small: bounded same-target retries with
//! exponential virtual-clock backoff, escalation to the next-best
//! covering target, consecutive-fault quarantine healed by the scrub
//! schedule, and optional TMR voting.  TMR costing reuses `rad::tmr`:
//! a PL target whose triplicated footprint still fits the ZU7EV pays
//! the spatial power factor at unchanged latency; anything else (the
//! A53, or a design too large to triplicate) votes temporally by
//! running the batch three times.

use crate::backend::AccelModel;
use crate::board::zcu104::PlResources;
use crate::rad::seu::essential_bits_of;
use crate::rad::tmr::apply_tmr;

/// Bounded-retry / quarantine / TMR recovery configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPolicy {
    /// Same-target retries before escalating to the next-best target.
    pub max_retries_per_target: u32,
    /// Hard cap on attempts per batch; the final attempt is forced to
    /// complete (no fault rolls) so every admitted batch finishes.
    pub max_attempts: u32,
    /// First backoff delay; doubles per attempt (virtual seconds).
    pub backoff_base_s: f64,
    /// Consecutive faults on one target before it is quarantined.
    pub quarantine_threshold: u32,
    /// Scrub cadence used to schedule quarantine reinstatement (s).
    pub quarantine_scrub_period_s: f64,
    /// Run every batch under triple-modular-redundancy voting.
    pub tmr: bool,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            max_retries_per_target: 1,
            max_attempts: 5,
            backoff_base_s: 0.005,
            quarantine_threshold: 3,
            quarantine_scrub_period_s: 30.0,
            tmr: false,
        }
    }
}

/// How a target pays for TMR voting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TmrCost {
    /// Triplicated fabric fits: power multiplies, latency unchanged.
    Spatial(f64),
    /// No fabric to triplicate (or it would not fit): the batch runs
    /// three times back-to-back at unchanged power.
    Temporal,
}

/// Derive the TMR cost mode for one target on the given device pool.
pub fn tmr_cost_of(target: &dyn AccelModel, pl: &PlResources) -> TmrCost {
    let util = target.resources();
    if essential_bits_of(&util) == 0 {
        // pure software path — nothing to triplicate spatially
        return TmrCost::Temporal;
    }
    let overhead = apply_tmr(util, pl);
    if overhead.fits {
        TmrCost::Spatial(overhead.power_factor)
    } else {
        TmrCost::Temporal
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Slot;
    use crate::board::Zcu104;
    use crate::model::{Manifest, Precision};
    use crate::resources::Utilization;

    #[derive(Debug)]
    struct Stub {
        util: Utilization,
    }

    impl AccelModel for Stub {
        fn name(&self) -> &'static str {
            "stub"
        }
        fn slot(&self) -> Slot {
            Slot::Hls
        }
        fn precision(&self) -> Precision {
            Precision::Fp32
        }
        fn supports(&self, _man: &Manifest) -> anyhow::Result<()> {
            Ok(())
        }
        fn setup_s(&self) -> f64 {
            0.001
        }
        fn per_item_s(&self) -> f64 {
            0.001
        }
        fn active_power_w(&self) -> f64 {
            1.0
        }
        fn resources(&self) -> Utilization {
            self.util
        }
    }

    #[test]
    fn defaults_are_bounded() {
        let p = RecoveryPolicy::default();
        assert!(p.max_attempts > p.max_retries_per_target);
        assert!(p.backoff_base_s > 0.0);
        assert!(!p.tmr);
    }

    #[test]
    fn spatial_power_factor_exceeds_one() {
        let pl = Zcu104::default().pl;
        // a tiny fabric design triplicated on the ZU7EV still fits
        let tiny = Stub {
            util: Utilization { luts: 5_000, ffs: 4_000, dsps: 10, brams: 4.0, urams: 0 },
        };
        match tmr_cost_of(&tiny, &pl) {
            TmrCost::Spatial(f) => assert!(f > 1.0, "factor {f}"),
            TmrCost::Temporal => panic!("a tiny design must triplicate spatially"),
        }
    }

    #[test]
    fn zero_fabric_votes_temporally() {
        let pl = Zcu104::default().pl;
        let soft = Stub { util: Utilization::none() };
        assert_eq!(tmr_cost_of(&soft, &pl), TmrCost::Temporal);
    }
}
