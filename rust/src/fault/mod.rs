//! Deterministic fault injection + recovery policies for the pipeline.
//!
//! The space survey literature (and the paper's §VI future work) treats
//! radiation upsets, power sags, and link dropouts as the *operating
//! norm* of on-board inference, not exceptional conditions.  This layer
//! makes them first-class and reproducible:
//!
//! * [`FaultInjector`] — a seeded, salted PRNG stream drawing from a
//!   typed fault vocabulary ([`FaultKind`] per batch attempt, brownout
//!   and downlink dropout per tick, thermal throttling), with SEU
//!   corruption scaled by each target's essential configuration bits;
//! * [`RecoveryPolicy`] — bounded same-target retries with exponential
//!   virtual-clock backoff, escalation to the next-best covering
//!   target, consecutive-fault quarantine healed on the scrub cadence,
//!   and optional TMR voting costed through `rad::tmr` ([`TmrCost`]);
//! * [`FaultState`] — the per-run working state the coordinator
//!   threads through dispatch: open fault windows, forced one-shot
//!   faults (for tests and mission events), quarantine bookkeeping,
//!   and the [`FaultStats`] accounting surfaced in `PipelineReport`.
//!
//! Determinism contract: the injector draws a **fixed** number of
//! variates per query, so the same `--faults <seed>` replays the same
//! campaign bit for bit; with no injector and no fault mission events,
//! [`FaultState::active`] stays `false` and the coordinator's dispatch
//! path is byte-identical to the fault-free build.

pub mod injector;
pub mod recovery;

pub use injector::{FaultInjector, FaultKind, FaultProfile, TickFaults};
pub use recovery::{tmr_cost_of, RecoveryPolicy, TmrCost};

/// Fault / recovery accounting for one pipeline run (and, mirrored
/// field-by-field, per phase).  All counters are exact event counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Faults drawn or forced against batch attempts (incl. masked
    /// TMR replica faults) plus opened environment fault windows.
    pub faults_injected: u64,
    /// Same-target retry attempts scheduled after a fault.
    pub retries: u64,
    /// Escalations to the next-best target after retries ran out.
    pub redispatches: u64,
    /// Targets quarantined for repeated consecutive faults.
    pub quarantines: u64,
    /// Quarantined targets reinstated after a scrub window.
    pub reinstates: u64,
    /// Batch attempts executed under TMR voting.
    pub tmr_batches: u64,
    /// Single-replica faults masked (outvoted) by TMR.
    pub tmr_masked: u64,
    /// Batches dispatched under a brownout-degraded power budget.
    pub degraded_batches: u64,
    /// Decisions dropped because the downlink was in a dropout window.
    pub link_dropped: u64,
    /// Batches forced to complete at the attempt cap.
    pub forced_completions: u64,
    /// Real executor batches whose results were lost to a typed
    /// execution error (panic audit path) rather than aborting the run.
    pub exec_failed_batches: u64,
}

impl FaultStats {
    /// Any fault/recovery activity at all?  Gates report rendering.
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }
}

/// Per-run fault working state the coordinator owns: the (optional)
/// injector, the recovery policy, open fault windows, forced one-shot
/// faults, quarantine bookkeeping, and the running [`FaultStats`].
#[derive(Debug)]
pub struct FaultState {
    /// Seeded injector; `None` runs fault-free unless a mission event
    /// or test knob forces a fault.
    pub injector: Option<FaultInjector>,
    /// The recovery policy in force for this run.
    pub recovery: RecoveryPolicy,
    /// Running fault/recovery counters (aggregate; phases keep their
    /// own slices).
    pub stats: FaultStats,
    /// True once any fault source exists — gates the recovery dispatch
    /// path so fault-free runs stay byte-identical to the legacy path.
    touched: bool,
    /// Per-target thermal throttle window: (open until, latency derate).
    throttle: Vec<(f64, f64)>,
    /// Open brownout window: (until, budget W).  Re-opening overwrites.
    brownout: Option<(f64, f64)>,
    /// Downlink dropout window end; re-opening extends (max).
    link_down_until: f64,
    /// Pending forced transient execution failures per target.
    forced_fail: Vec<u32>,
    /// Pending forced SEU corruptions per target.
    forced_corrupt: Vec<u32>,
    /// Consecutive-fault streak per target (quarantine trigger).
    consecutive_faults: Vec<u32>,
    /// Is the target currently quarantined by the recovery layer?
    quarantined: Vec<bool>,
    /// Scheduled reinstatements: (target index, ready-at virtual time).
    reinstates: Vec<(usize, f64)>,
}

impl FaultState {
    /// Fault-state for `n_targets` registry entries.
    pub fn new(
        n_targets: usize,
        injector: Option<FaultInjector>,
        recovery: RecoveryPolicy,
    ) -> Self {
        let touched = injector.is_some();
        FaultState {
            injector,
            recovery,
            stats: FaultStats::default(),
            touched,
            throttle: vec![(f64::NEG_INFINITY, 1.0); n_targets],
            brownout: None,
            link_down_until: f64::NEG_INFINITY,
            forced_fail: vec![0; n_targets],
            forced_corrupt: vec![0; n_targets],
            consecutive_faults: vec![0; n_targets],
            quarantined: vec![false; n_targets],
            reinstates: Vec::new(),
        }
    }

    /// Has any fault source ever been armed?  While `false`, dispatch
    /// takes the legacy byte-identical path.
    pub fn active(&self) -> bool {
        self.touched
    }

    /// Is the downlink inside a dropout window at virtual time `t_s`?
    pub fn link_down(&self, t_s: f64) -> bool {
        t_s < self.link_down_until
    }

    /// Latency derate for `target` at virtual time `t_s` (1.0 = none).
    pub fn throttle_factor(&self, target: usize, t_s: f64) -> f64 {
        let (until, derate) = self.throttle[target];
        if t_s < until {
            derate
        } else {
            1.0
        }
    }

    /// Brownout power budget in force at virtual time `t_s`, if any.
    pub fn brownout_budget(&self, t_s: f64) -> Option<f64> {
        match self.brownout {
            Some((until, budget)) if t_s < until => Some(budget),
            _ => None,
        }
    }

    /// Open (or overwrite) a thermal throttle window on `target`.
    pub fn open_throttle(&mut self, target: usize, derate_x: f64, until_s: f64) {
        self.touched = true;
        self.throttle[target] = (until_s, derate_x);
    }

    /// Open (or overwrite) a brownout power-sag window.
    pub fn open_brownout(&mut self, until_s: f64, budget_w: f64) {
        self.touched = true;
        self.brownout = Some((until_s, budget_w));
    }

    /// Open (or extend) a downlink dropout window.
    pub fn open_link_dropout(&mut self, until_s: f64) {
        self.touched = true;
        self.link_down_until = self.link_down_until.max(until_s);
    }

    /// Queue one forced transient execution failure against `target` —
    /// consumed (and counted) by the next attempt dispatched there.
    pub fn force_exec_fail(&mut self, target: usize) {
        self.touched = true;
        self.forced_fail[target] += 1;
    }

    /// Queue one forced SEU corruption against `target`.
    pub fn force_corrupt(&mut self, target: usize) {
        self.touched = true;
        self.forced_corrupt[target] += 1;
    }

    /// Roll the batch-attempt faults for `target`: forced one-shots
    /// first (no RNG), then the injector (exactly two variates), else
    /// nothing.  Returns `(fault, thermal trip)`.
    pub fn roll_attempt(&mut self, target: usize) -> (Option<FaultKind>, bool) {
        if self.forced_fail[target] > 0 {
            self.forced_fail[target] -= 1;
            return (Some(FaultKind::ExecFail), false);
        }
        if self.forced_corrupt[target] > 0 {
            self.forced_corrupt[target] -= 1;
            return (Some(FaultKind::SeuCorrupt), false);
        }
        match self.injector.as_mut() {
            Some(inj) => inj.roll_attempt(target),
            None => (None, false),
        }
    }

    /// Roll the tick-granularity environment faults; `None` without an
    /// injector.  Returns the rolls plus a copy of the profile so the
    /// caller can size the windows it opens.
    pub fn roll_tick(&mut self) -> Option<(TickFaults, FaultProfile)> {
        let inj = self.injector.as_mut()?;
        let ticks = inj.roll_tick();
        let profile = *inj.profile();
        Some((ticks, profile))
    }

    /// Latency multiplier for a timed-out attempt.
    pub fn timeout_factor(&self) -> f64 {
        match &self.injector {
            Some(inj) => inj.profile().timeout_factor_x,
            None => FaultProfile::default().timeout_factor_x,
        }
    }

    /// Thermal window parameters `(derate, duration s)` when an
    /// injector is armed.
    pub fn thermal_params(&self) -> Option<(f64, f64)> {
        let inj = self.injector.as_ref()?;
        Some((inj.profile().thermal_derate_x, inj.profile().thermal_duration_s))
    }

    /// Is `target` currently quarantined by the recovery layer?
    pub fn is_quarantined(&self, target: usize) -> bool {
        self.quarantined[target]
    }

    /// Consecutive-fault streak on `target`.
    pub fn streak(&self, target: usize) -> u32 {
        self.consecutive_faults[target]
    }

    /// Record a fault on `target`; returns the new streak length.
    pub fn note_fault(&mut self, target: usize) -> u32 {
        self.consecutive_faults[target] += 1;
        self.consecutive_faults[target]
    }

    /// Record a successful completion on `target` (resets the streak).
    pub fn note_success(&mut self, target: usize) {
        self.consecutive_faults[target] = 0;
    }

    /// Quarantine `target` and schedule its reinstatement.
    pub fn quarantine(&mut self, target: usize, ready_at_s: f64) {
        self.touched = true;
        self.quarantined[target] = true;
        self.reinstates.push((target, ready_at_s));
    }

    /// Drain the reinstatements due by `now_s`, clearing their
    /// quarantine marks and fault streaks.  Returned in schedule order.
    pub fn take_due_reinstates(&mut self, now_s: f64) -> Vec<usize> {
        let mut due = Vec::new();
        self.reinstates.retain(|&(target, ready_at)| {
            if ready_at <= now_s {
                due.push(target);
                false
            } else {
                true
            }
        });
        for &target in &due {
            self.quarantined[target] = false;
            self.consecutive_faults[target] = 0;
        }
        due
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untouched_until_armed() {
        let mut fs = FaultState::new(2, None, RecoveryPolicy::default());
        assert!(!fs.active());
        assert_eq!(fs.roll_attempt(0), (None, false));
        assert!(fs.roll_tick().is_none());
        assert!(!fs.active(), "rolling without a source must not arm");
        fs.open_link_dropout(5.0);
        assert!(fs.active());
        assert!(fs.link_down(4.0));
        assert!(!fs.link_down(5.0));
    }

    #[test]
    fn forced_faults_consume_once() {
        let mut fs = FaultState::new(1, None, RecoveryPolicy::default());
        fs.force_exec_fail(0);
        assert_eq!(fs.roll_attempt(0).0, Some(FaultKind::ExecFail));
        assert_eq!(fs.roll_attempt(0).0, None);
        fs.force_corrupt(0);
        assert_eq!(fs.roll_attempt(0).0, Some(FaultKind::SeuCorrupt));
        assert_eq!(fs.roll_attempt(0).0, None);
    }

    #[test]
    fn quarantine_reinstates_on_schedule() {
        let mut fs = FaultState::new(2, None, RecoveryPolicy::default());
        fs.quarantine(1, 10.0);
        assert!(fs.is_quarantined(1));
        assert!(fs.take_due_reinstates(9.9).is_empty());
        assert_eq!(fs.take_due_reinstates(10.0), vec![1]);
        assert!(!fs.is_quarantined(1));
        assert!(fs.take_due_reinstates(11.0).is_empty());
    }

    #[test]
    fn fault_windows_expire() {
        let mut fs = FaultState::new(1, None, RecoveryPolicy::default());
        assert_eq!(fs.throttle_factor(0, 0.0), 1.0);
        fs.open_throttle(0, 2.5, 3.0);
        assert_eq!(fs.throttle_factor(0, 2.9), 2.5);
        assert_eq!(fs.throttle_factor(0, 3.0), 1.0);
        assert_eq!(fs.brownout_budget(0.0), None);
        fs.open_brownout(4.0, 2.0);
        assert_eq!(fs.brownout_budget(3.9), Some(2.0));
        assert_eq!(fs.brownout_budget(4.0), None);
    }
}
