//! `spaceinfer` CLI — leader entrypoint of the Layer-3 coordinator.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts
//! (DESIGN.md §5) plus the serving pipeline:
//!
//! ```text
//! spaceinfer table1|table2|table3|table4|table5   paper tables
//! spaceinfer shape                                Table III shape check
//! spaceinfer fig9..fig13 [--out reports/]         power traces (CSV+ASCII)
//! spaceinfer ablation                             A1 CNet + ESPERTA + AXI
//! spaceinfer quantization                         A2 PTQ error (real PJRT)
//! spaceinfer selfcheck                            golden-IO over PJRT
//! spaceinfer pipeline --use-case mms [--real]     end-to-end coordinator
//!     [--policy static|min-latency|min-energy|deadline]
//!     [--power-budget W] [--deadline-ms MS] [--targets default|all|...]
//!     [--plan] [--faults SEED] [--tmr] [--no-dispatch-cache]
//!     [--no-frame-pool]
//! spaceinfer plan <model>                         execution-plan table
//! spaceinfer policies [--use-case vae] [--json]   policy comparison table
//! spaceinfer scenario <name> | --list             mission scenario engine
//! spaceinfer fleet <name> [--crafts N] [--threads T]  constellation shards
//! spaceinfer fuzz [--seeds N] [--base-seed S]     scenario fuzzer
//! spaceinfer serve [--port P] [--workers N]       multi-tenant HTTP serving
//! spaceinfer targets [--use-case vae] [--json]    target-matrix table
//! spaceinfer inspect --model vae                  manifests, DPU program
//! spaceinfer calibrate [--save calib.json]        dump calibration
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use spaceinfer::backend::TargetSet;
use spaceinfer::board::Calibration;
use spaceinfer::coordinator::{OverflowPolicy, Pipeline, PipelineConfig, Policy};
use spaceinfer::fault::RecoveryPolicy;
use spaceinfer::model::catalog::{model_info, Catalog};
use spaceinfer::model::{Precision, UseCase};
use spaceinfer::report::{ablation, figures, policy, related, tables, targets, whatif};
use spaceinfer::runtime::{Backend, Engine, ExecutorPool, GoldenIo, PoolConfig};
use spaceinfer::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn load_calib(args: &Args) -> Result<Calibration> {
    match args.flags.get("calib") {
        Some(path) => Calibration::load(Path::new(path)),
        None => Ok(Calibration::default()),
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let dir = artifacts_dir(&args);
    let calib = load_calib(&args)?;
    match args.command.as_str() {
        "" | "help" => {
            println!("{}", HELP);
            Ok(())
        }
        "table1" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", tables::table1(&catalog)?.render());
            Ok(())
        }
        "table2" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", tables::table2(&catalog, &calib)?.render());
            Ok(())
        }
        "table3" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", tables::table3(&catalog, &calib)?.render());
            println!("{}", tables::dpu_utilization_note(&catalog, &calib)?);
            println!("{}", tables::hls_spill_note(&catalog, &calib)?);
            Ok(())
        }
        "shape" => {
            let catalog = Catalog::load(&dir)?;
            print!("{}", tables::table3_shape_check(&catalog, &calib)?);
            Ok(())
        }
        "table4" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", related::table4(&catalog, &calib)?.render());
            Ok(())
        }
        "table5" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", related::table5(&catalog, &calib)?.render());
            Ok(())
        }
        cmd @ ("fig9" | "fig10" | "fig11" | "fig12" | "fig13" | "figs") => {
            let catalog = Catalog::load(&dir)?;
            let out_dir = PathBuf::from(args.get("out", "reports"));
            std::fs::create_dir_all(&out_dir)?;
            let all = figures::all_figures(&catalog, &calib)?;
            for (name, csv, ascii) in all {
                if cmd != "figs" && cmd != name {
                    continue;
                }
                let path = out_dir.join(format!("{name}.csv"));
                std::fs::write(&path, &csv)?;
                println!("== {name} ==  (csv: {})", path.display());
                println!("{ascii}");
            }
            Ok(())
        }
        "ablation" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", ablation::cnet_ablation(&catalog, &calib)?.render());
            println!("{}", ablation::esperta_packing(&catalog, &calib)?.render());
            println!("{}", ablation::axi_burst_whatif(&catalog, &calib)?.render());
            Ok(())
        }
        "whatif" => {
            let catalog = Catalog::load(&dir)?;
            println!("{}", whatif::frequency_scaling(&catalog, &calib)?.render());
            println!("{}", whatif::pruning_sweep(&catalog, &calib)?.render());
            let orbit = match args.get("orbit", "gto") {
                "leo" => spaceinfer::rad::Orbit::Leo,
                "deep" => spaceinfer::rad::Orbit::DeepSpace,
                _ => spaceinfer::rad::Orbit::Gto,
            };
            println!("{}", whatif::hardening(&catalog, &calib, orbit)?.render());
            Ok(())
        }
        "quantization" => quantization(&dir),
        "selfcheck" => selfcheck(&dir),
        "pipeline" => pipeline_cmd(&args, &dir, calib),
        "plan" => plan_cmd(&args, &dir, calib),
        "policies" => policies_cmd(&args, &dir, calib),
        "scenario" => scenario_cmd(&args, &dir, calib),
        "fleet" => fleet_cmd(&args, &dir, calib),
        "fuzz" => fuzz_cmd(&args, &dir, calib),
        "serve" => serve_cmd(&args, &dir, calib),
        "targets" => targets_cmd(&args, &dir, calib),
        "inspect" => inspect(&args, &dir, &calib),
        "calibrate" => {
            if let Some(path) = args.flags.get("save") {
                calib.save(Path::new(path))?;
                println!("wrote calibration to {path}");
            } else {
                println!("{}", calib.to_json());
            }
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `spaceinfer help`)"),
    }
}

/// A2: PTQ degradation measured on the real HLO (fp32 vs int8 variants on
/// the identical input) plus the fp32 fidelity check (HLS ≡ CPU claim).
fn quantization(dir: &Path) -> Result<()> {
    let engine = Engine::new(dir)?;
    println!("platform: {}", engine.platform());
    for name in ["vae", "cnet"] {
        let f32m = engine.load(name, Precision::Fp32)?;
        let i8m = engine.load(name, Precision::Int8)?;
        let io = GoldenIo::load(&dir.join(format!("{name}.fp32.io.json")))?;
        let inputs = io.input_slices();
        let out_f32 = f32m.run(&inputs)?;
        let out_i8 = i8m.run(&inputs)?;
        let max_abs: f64 = out_f32
            .iter()
            .zip(&out_i8)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        let denom: f64 = out_f32.iter().map(|v| v.abs() as f64).sum::<f64>()
            / out_f32.len() as f64;
        println!(
            "{name}: fp32 vs int8-PTQ max|err| {max_abs:.6}  \
             mean|fp32| {denom:.6}  rel {:.3}",
            max_abs / denom.max(1e-12)
        );
    }
    // fp32 fidelity: rust-PJRT output vs python-jax output (<= 1e-10
    // would be bitwise on identical HLO; allow tiny cross-run noise)
    for name in ["esperta", "logistic", "reduced", "baseline"] {
        let m = engine.load(name, Precision::Fp32)?;
        let io = GoldenIo::load(&dir.join(format!("{name}.fp32.io.json")))?;
        let out = m.run(&io.input_slices())?;
        println!(
            "{name}: fp32 HLS-path max|err| vs python oracle = {:.3e}",
            io.max_abs_err(&out)
        );
    }
    Ok(())
}

/// Golden-IO self-check over every executable artifact.
fn selfcheck(dir: &Path) -> Result<()> {
    let catalog = Catalog::load(dir)?;
    let engine = Engine::new(dir)?;
    let mut worst: f64 = 0.0;
    for tag in &catalog.executable {
        let (name, prec) = tag
            .rsplit_once('.')
            .context("artifact tag must be name.precision")?;
        let model = engine.load(name, Precision::parse(prec)?)?;
        let io = GoldenIo::load(&catalog.io_path(tag))?;
        let out = model.run(&io.input_slices())?;
        let err = io.max_abs_err(&out);
        worst = worst.max(err);
        println!("{tag:<22} max|err| = {err:.3e}  ({} outputs)", out.len());
    }
    println!("worst artifact error: {worst:.3e}");
    if worst > 1e-3 {
        bail!("selfcheck failed: artifact disagreed with golden IO");
    }
    Ok(())
}

/// `--deadline-ms N` -> seconds; absent -> per-use-case default.
fn parse_deadline_s(args: &Args) -> Result<Option<f64>> {
    Ok(match args.flags.get("deadline-ms") {
        Some(_) => Some(args.get_f64("deadline-ms", 0.0)? / 1000.0),
        None => None,
    })
}

/// `--power-budget W` -> active MPSoC power cap; absent -> off.
fn parse_power_budget_w(args: &Args) -> Result<Option<f64>> {
    Ok(match args.flags.get("power-budget") {
        Some(_) => Some(args.get_f64("power-budget", 0.0)?),
        None => None,
    })
}

/// `--ingress-cap N` -> bounded sensor-ingress queue; absent -> off
/// (every event admitted unconditionally, the legacy behavior).
fn parse_ingress_cap(args: &Args) -> Result<Option<usize>> {
    Ok(match args.flags.get("ingress-cap") {
        Some(_) => {
            let cap = args.get_usize("ingress-cap", 0)?;
            if cap == 0 {
                bail!("--ingress-cap must be >= 1 (omit the flag to disable the queue)");
            }
            Some(cap)
        }
        None => None,
    })
}

/// `--faults SEED` -> arm the deterministic fault injector; absent ->
/// fault-free (bit-identical to a build without the fault layer).
fn parse_fault_seed(args: &Args) -> Result<Option<u64>> {
    Ok(match args.flags.get("faults") {
        Some(_) => Some(args.get_usize("faults", 0)? as u64),
        None => None,
    })
}

/// Catalog from `--artifacts`, or the synthetic stand-in catalog when
/// the artifacts directory does not exist (policy exploration works
/// without `make artifacts`; simulated numbers are stand-ins then).
fn catalog_or_synthetic(dir: &Path) -> Result<Catalog> {
    if !Catalog::is_present(dir) {
        eprintln!(
            "note: no artifacts at {} — using the synthetic stand-in catalog",
            dir.display()
        );
    }
    Catalog::load_or_synthetic(dir)
}

fn pipeline_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    let catalog = catalog_or_synthetic(dir)?;
    let use_case = UseCase::parse(args.get("use-case", "mms"))?;
    let cfg = PipelineConfig {
        use_case,
        n_events: args.get_usize("n", 200)?,
        cadence_s: args.get_f64("cadence", 0.15)?,
        max_batch: args.get_usize("batch", 8)?,
        max_wait_s: args.get_f64("max-wait", 0.5)?,
        downlink_budget: args.get_usize("budget", 64 * 1024)? as u64,
        mms_model: args.get("mms-model", "baseline").to_string(),
        seed: args.get_usize("seed", 7)? as u64,
        policy: Policy::parse(args.get("policy", "static"))?,
        deadline_s: parse_deadline_s(args)?,
        power_budget_w: parse_power_budget_w(args)?,
        targets: TargetSet::parse(args.get("targets", "default"))?,
        ingress_cap: parse_ingress_cap(args)?,
        plan_mode: args.has("plan"),
        fault_seed: parse_fault_seed(args)?,
        recovery: RecoveryPolicy { tmr: args.has("tmr"), ..Default::default() },
        dispatch_cache: !args.has("no-dispatch-cache"),
        frame_pool: !args.has("no-frame-pool"),
        ..Default::default()
    };
    if args.has("tmr") && cfg.fault_seed.is_none() {
        bail!("--tmr votes against injected faults; arm the injector with --faults SEED");
    }
    if cfg.policy == Policy::Static && cfg.power_budget_w.is_some() {
        bail!(
            "--power-budget only applies to dynamic policies (static \
             reproduces the paper's fixed mapping; try --policy min-energy \
             or deadline)"
        );
    }
    let mut pipeline = Pipeline::new(cfg, &catalog, &calib)?;
    if !args.has("real") {
        for flag in ["workers", "exec-backend"] {
            if args.flags.contains_key(flag) {
                bail!("--{flag} only applies with --real (timing-only runs have no executor)");
            }
        }
    } else if !Catalog::is_present(dir) {
        bail!("--real needs `make artifacts` output in {}", dir.display());
    }
    let executor;
    let exec_ref = if args.has("real") {
        let backend = match args.get("exec-backend", "default") {
            "pjrt" => Backend::Pjrt,
            "surrogate" => Backend::Surrogate,
            "default" => Backend::default(),
            other => bail!("unknown executor backend {other:?}"),
        };
        let pool_cfg = PoolConfig {
            workers: args.get_usize("workers", ExecutorPool::default_workers())?,
            backend,
            preload: vec![(
                pipeline.route.model.clone(),
                pipeline.route.precision,
            )],
        };
        executor = ExecutorPool::with_config(dir.to_path_buf(), pool_cfg)?;
        println!(
            "executor: {} worker(s), backend {}, model {} -> shard {}",
            executor.worker_count(),
            executor.engine().backend().as_str(),
            pipeline.route.model,
            executor.shard_of(&pipeline.route.model, pipeline.route.precision),
        );
        Some(&executor)
    } else {
        None
    };
    let report = pipeline.run(exec_ref)?;
    print!("{}", report.render());
    if let Some(pool) = exec_ref {
        println!(
            "executor: {} batch(es) dispatched ({} inferences)",
            pool.batches_submitted(),
            report.metrics.counter("inferences"),
        );
    }
    println!("--- telemetry ---\n{}", report.metrics.report());
    Ok(())
}

/// `spaceinfer plan <model>` — the candidate execution plans for one
/// model (single-target and hybrid partitions) and the partition each
/// dispatch policy would choose.  Artifact-free.
fn plan_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    let catalog = catalog_or_synthetic(dir)?;
    let model = match args.positional.first() {
        Some(m) => m.as_str(),
        None => bail!(
            "usage: spaceinfer plan <model>  (vae | cnet | esperta | \
             logistic | reduced | baseline)"
        ),
    };
    let set = TargetSet::parse(args.get("targets", "default"))?;
    let batch = args.get_usize("batch", 8)? as u64;
    let report = spaceinfer::report::plan_report(
        &catalog,
        &calib,
        model,
        &set,
        batch,
        parse_deadline_s(args)?,
        parse_power_budget_w(args)?,
    )?;
    println!("{report}");
    Ok(())
}

/// `spaceinfer policies` — the dispatch-policy comparison table: the
/// same workload under static / min-latency / min-energy / deadline.
fn policies_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    let catalog = catalog_or_synthetic(dir)?;
    let run = policy::PolicyRun {
        use_case: UseCase::parse(args.get("use-case", "mms"))?,
        n_events: args.get_usize("n", 200)?,
        cadence_s: args.get_f64("cadence", 0.15)?,
        max_batch: args.get_usize("batch", 8)?,
        max_wait_s: args.get_f64("max-wait", 0.5)?,
        power_budget_w: parse_power_budget_w(args)?,
        deadline_s: parse_deadline_s(args)?,
        mms_model: args.get("mms-model", "baseline").to_string(),
        seed: args.get_usize("seed", 7)? as u64,
        targets: TargetSet::parse(args.get("targets", "default"))?,
        ingress_cap: parse_ingress_cap(args)?,
    };
    let table = policy::policy_comparison(&catalog, &calib, &run)?;
    if args.has("json") {
        println!("{}", table.to_json());
    } else {
        println!("{}", table.render());
    }
    Ok(())
}

/// `spaceinfer serve` — the multi-tenant serving front-end: an HTTP/
/// JSON endpoint over the timing-only pipeline with per-tenant bounded
/// admission and continuous cross-tenant batching.  Blocks until
/// `POST /shutdown` drains the server, then prints the final counters
/// and exits 0.
fn serve_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    use spaceinfer::serve::{ServeConfig, Server};
    let catalog = catalog_or_synthetic(dir)?;
    let mut cfg = ServeConfig {
        host: args.get("host", "127.0.0.1").to_string(),
        ..Default::default()
    };
    cfg.port = u16::try_from(args.get_usize("port", 0)?)
        .map_err(|_| anyhow::anyhow!("--port must fit in 16 bits"))?;
    if args.flags.contains_key("workers") {
        cfg.workers = args.get_usize("workers", cfg.workers)?;
        if cfg.workers == 0 {
            bail!("--workers must be >= 1");
        }
    }
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
    if cfg.max_batch == 0 {
        bail!("--max-batch must be >= 1");
    }
    cfg.tenant_cap = args.get_usize("tenant-cap", cfg.tenant_cap)?;
    if cfg.tenant_cap == 0 {
        bail!("--tenant-cap must be >= 1");
    }
    cfg.overflow = match args.get("drop", "newest") {
        "newest" => OverflowPolicy::DropNewest,
        "oldest" => OverflowPolicy::DropOldest,
        other => bail!("unknown --drop {other:?} (newest | oldest)"),
    };
    cfg.service_delay_ms = args.get_usize("service-delay-ms", 0)? as u64;
    let server = Server::bind(cfg, &catalog, &calib)?;
    let addr = server.local_addr();
    println!(
        "serving on http://{addr}  (POST /infer /shutdown, GET /healthz /stats)"
    );
    println!(
        "  e.g. curl -s http://{addr}/infer -d \
         '{{\"tenant\":\"ops\",\"use_case\":\"vae\",\"seed\":1}}'"
    );
    let stats = server.run()?;
    println!("{}", stats.render());
    if !stats.conserved() {
        bail!("serve accounting violated conservation at drain");
    }
    Ok(())
}

/// `spaceinfer scenario <name>` — run a built-in mission scenario on
/// the steppable pipeline (timing-only, artifact-free) and print the
/// phase-segmented report; `--list` tabulates the library.
fn scenario_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    use spaceinfer::scenario;
    use spaceinfer::util::table::Table;
    let name = args.positional.first().map(String::as_str);
    if args.has("list") || name.is_none() {
        let mut t = Table::new(
            "Built-in mission scenarios (spaceinfer scenario <name>)",
            &["Name", "Use case", "Events", "Phases", "Mission"],
        );
        for sc in scenario::all_builtins() {
            t.row(vec![
                sc.name.clone(),
                sc.config.use_case.to_string(),
                sc.total_events().to_string(),
                sc.phase_chain(),
                sc.summary.clone(),
            ]);
        }
        println!("{}", t.render());
        return Ok(());
    }
    let mut sc = scenario::builtin(name.unwrap_or_default())?;
    if args.flags.contains_key("seed") {
        sc.config.seed = args.get_usize("seed", 7)? as u64;
    }
    let catalog = catalog_or_synthetic(dir)?;
    println!(
        "scenario [{}] — {}\n  phases: {}\n",
        sc.name,
        sc.summary,
        sc.phase_chain()
    );
    let report = scenario::run_scenario(&sc, &catalog, &calib, None)?;
    print!("{}", report.render());
    println!("--- telemetry ---\n{}", report.metrics.report());
    Ok(())
}

/// `spaceinfer fleet <scenario>` — constellation-scale simulation: N
/// spacecraft fly the scenario in parallel shards (stream-split seeds,
/// work-stealing pool) with ground-station passes arbitrated
/// deterministically at epoch barriers.  The printed `FleetReport` is
/// bit-identical for `--threads 1` and any `--threads T`; only the
/// trailing wall-clock line varies.
fn fleet_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    use spaceinfer::fleet::{self, FleetConfig};
    use spaceinfer::scenario;
    let name = match args.positional.first() {
        Some(n) => n.as_str(),
        None => bail!(
            "usage: spaceinfer fleet <scenario> [--crafts N] [--threads T] \
             — see `spaceinfer scenario --list` for scenario names"
        ),
    };
    let sc = scenario::builtin(name)?;
    let crafts = args.get_usize("crafts", 8)?;
    let requested = if args.flags.contains_key("threads") {
        Some(args.get_usize("threads", 1)?)
    } else {
        None
    };
    let threads = fleet::resolve_threads(requested, crafts)?;
    let cfg = FleetConfig {
        crafts,
        threads,
        master_seed: args.get_usize("seed", 7)? as u64,
        pass_budget_bytes: args.get_usize("pass-budget", 0)? as u64,
        pass_link_bytes_per_s: args.get_f64("link-rate", 125_000.0)?,
        relay: args.has("relay"),
        planes: args.get_usize("planes", 1)?,
        stagger_events: args.get_usize("stagger", 0)?,
    };
    let catalog = catalog_or_synthetic(dir)?;
    println!(
        "fleet [{} x {}] — {}\n  threads: {}  pass budget: {} B  relay: {}\n",
        cfg.crafts, sc.name, sc.summary, threads, cfg.pass_budget_bytes, cfg.relay,
    );
    let t0 = std::time::Instant::now();
    let report = fleet::run_fleet(&sc, &catalog, &calib, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    print!("{}", report.render());
    // wall-clock note stays outside the deterministic report surface
    println!(
        "wall: {:.2} s on {} thread(s) — {:.1} crafts/s",
        wall,
        threads,
        crafts as f64 / wall.max(1e-9),
    );
    Ok(())
}

/// `spaceinfer fuzz` — seeded scenario fuzzer: each seed expands into
/// a random fault-campaign scenario, runs twice, and must replay
/// bit-for-bit while the global accounting invariants hold.
fn fuzz_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    use spaceinfer::scenario::fuzz;
    use spaceinfer::util::table::Table;
    let catalog = catalog_or_synthetic(dir)?;
    // --exact-seed replays one derived case verbatim: `fuzz_many`
    // stream-splits the base seed, so the seed a failure names is the
    // derived value, not something `--base-seed` can reach directly
    if args.flags.contains_key("exact-seed") {
        let seed = args.get_usize("exact-seed", 0)? as u64;
        let o = fuzz::fuzz_one(seed, &catalog, &calib)?;
        println!(
            "seed {} ({}, {}): {} events, {} dropped, {} fault(s) — \
             bit-identical replay, invariants hold",
            o.seed,
            o.use_case,
            o.policy,
            o.events,
            o.dropped,
            o.faults.faults_injected,
        );
        return Ok(());
    }
    let seeds = args.get_usize("seeds", 25)?;
    if seeds == 0 {
        bail!("--seeds must be >= 1");
    }
    let base = args.get_usize("base-seed", 1)? as u64;
    let outcomes = fuzz::fuzz_many(base, seeds, &catalog, &calib)?;
    let mut t = Table::new(
        "Scenario fuzz (deterministic replay + invariant checks)",
        &[
            "Seed", "Use case", "Policy", "Phases", "Events", "Dropped",
            "Faults", "Retries", "Quar",
        ],
    );
    for o in &outcomes {
        t.row(vec![
            o.seed.to_string(),
            o.use_case.to_string(),
            o.policy.clone(),
            o.phases.to_string(),
            o.events.to_string(),
            o.dropped.to_string(),
            o.faults.faults_injected.to_string(),
            o.faults.retries.to_string(),
            o.faults.quarantines.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "{} seed(s) passed: bit-identical replay, conservation and \
         partition invariants hold",
        outcomes.len()
    );
    Ok(())
}

/// `spaceinfer targets` — enumerate every registrable backend for one
/// (or every) use case: the design-space table behind `--targets all`.
fn targets_cmd(args: &Args, dir: &Path, calib: Calibration) -> Result<()> {
    use spaceinfer::util::json::Json;
    let catalog = catalog_or_synthetic(dir)?;
    let mms_model = args.get("mms-model", "baseline");
    let batch = args.get_usize("batch", 8)? as u64;
    match args.flags.get("use-case") {
        Some(uc) => {
            let table = targets::target_matrix(
                &catalog, &calib, UseCase::parse(uc)?, mms_model, batch,
            )?;
            if args.has("json") {
                println!("{}", table.to_json());
            } else {
                println!("{}", table.render());
            }
        }
        None if args.has("json") => {
            let mut docs = Vec::new();
            for uc in UseCase::ALL {
                let table =
                    targets::target_matrix(&catalog, &calib, uc, mms_model, batch)?;
                docs.push(table.to_json());
            }
            println!("{}", Json::Arr(docs));
        }
        None => {
            for uc in UseCase::ALL {
                let table =
                    targets::target_matrix(&catalog, &calib, uc, mms_model, batch)?;
                println!("{}", table.render());
            }
        }
    }
    Ok(())
}

fn inspect(args: &Args, dir: &Path, calib: &Calibration) -> Result<()> {
    let catalog = Catalog::load(dir)?;
    let name = args.get("model", "vae");
    let info = model_info(name)?;
    let man = catalog.deployed(info)?;
    println!(
        "{} ({}) target={} precision={} params={} macs={} ops={}",
        info.display, man.name, info.target.as_str(),
        man.precision.as_str(), man.total_params, man.total_macs,
        man.total_ops
    );
    spaceinfer::model::counts::validate_manifest(man)?;
    println!("manifest counts cross-validated against rust recount: OK");
    for (i, l) in man.layers.iter().enumerate() {
        println!(
            "  layer {i:2} {:<14} {:?} -> {:?}  macs={} params={}",
            format!("{:?}", l.kind), l.in_shape, l.out_shape, l.macs,
            l.params
        );
    }
    if man.dpu_compatible() {
        let board = spaceinfer::board::Zcu104::default();
        let arch = spaceinfer::dpu::DpuArch::b4096(calib, board.dpu_clock_hz);
        let sched = spaceinfer::dpu::DpuSchedule::new(man, arch, calib,
                                                      board.axi_bandwidth)?;
        let prog = spaceinfer::dpu::DpuProgram::compile(man, &sched)?;
        println!("{}", prog.listing());
    }
    Ok(())
}

const HELP: &str = "\
spaceinfer — on-board NN inference coordinator (MCSoC'25 reproduction)

usage: spaceinfer <subcommand> [--artifacts DIR] [--calib FILE]

  table1..table5      regenerate the paper's tables (ours | paper)
  shape               Table III shape check (who wins, by what factor)
  fig9..fig13 | figs  regenerate power traces  [--out reports/]
  ablation            CNet ablations, ESPERTA packing, AXI what-if
  whatif              extensions: clock scaling, pruning, scrubbing/TMR
                      [--orbit leo|gto|deep]
  quantization        A2: PTQ error on real HLO outputs
  selfcheck           golden-IO check of every artifact over PJRT
  pipeline            end-to-end coordinator run
                      [--use-case mms|vae|cnet|esperta] [--n N] [--real]
                      [--batch B] [--budget BYTES] [--mms-model NAME]
                      [--workers N] [--exec-backend pjrt|surrogate]
                      [--policy static|min-latency|min-energy|deadline]
                      [--power-budget W] [--deadline-ms MS]
                      [--targets default|all|cpu,dpu-b1024,hls-pipe,...]
                      [--ingress-cap N] [--plan]
                      [--faults SEED] [--tmr]  (deterministic fault
                      injection + recovery: retries, escalation,
                      quarantine, TMR voting, degraded dispatch)
                      [--no-dispatch-cache]  (disable decision
                      memoization; bit-identical output, slower)
                      [--no-frame-pool]  (disable sensor-frame
                      recycling; bit-identical output, slower)
  plan                execution-plan table for one model: candidate
                      partitions (hybrid DPU-subgraph + fallback plans
                      next to whole-model deployments) and the choice
                      per policy; artifact-free
                      plan <model> [--batch B] [--targets ...]
                      [--deadline-ms MS] [--power-budget W]
  policies            dispatch-policy comparison table (all policies)
                      [--use-case ...] [--n N] [--cadence S]
                      [--batch B] [--max-wait S]
                      [--power-budget W] [--deadline-ms MS]
                      [--targets default|all|NAMES] [--ingress-cap N]
                      [--json]  (machine-readable table)
  scenario            run a built-in mission scenario (steppable
                      pipeline + declarative timeline; artifact-free,
                      phase-segmented report)
                      scenario --list | scenario <name> [--seed N]
  fleet               constellation-scale run of one scenario: N craft
                      shards (per-craft stream-split seeds) on a
                      work-stealing pool, shared ground-station passes
                      arbitrated deterministically at epoch barriers;
                      the report is bit-identical at any --threads
                      fleet <name> [--crafts N] [--seed S]
                      [--threads T]  (default: available parallelism;
                      0 rejected; capped at the craft count)
                      [--pass-budget BYTES] [--link-rate B/S] [--relay]
                      [--planes P] [--stagger EVENTS]
  fuzz                seeded scenario fuzzer: random fault campaigns,
                      each replayed bit-for-bit and checked against the
                      accounting invariants
                      [--seeds N] [--base-seed S] [--exact-seed S]
  serve               multi-tenant HTTP/JSON serving front-end:
                      POST /infer runs one request through the solo
                      pipeline path (bit-identical results) with
                      per-tenant bounded admission and continuous
                      cross-tenant batching; POST /shutdown drains and
                      exits 0 with conserved counters
                      [--host H] [--port P]  (0 = ephemeral)
                      [--workers N] [--max-batch B] [--tenant-cap N]
                      [--drop newest|oldest] [--service-delay-ms MS]
  targets             registered-target comparison matrix (latency,
                      energy, power, footprint, essential bits)
                      [--use-case ...] [--mms-model NAME] [--batch B]
                      [--json]  (single table, or an array without
                      --use-case)
  inspect             model + DPU program listing  [--model NAME]
  calibrate           print or save calibration    [--save FILE]
";
