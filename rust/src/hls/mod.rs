//! Vitis-HLS custom-IP simulator (the paper's flexibility path: fp32,
//! sigmoid/comparator/3-D operators).  Two design points: the paper's
//! naive sequential dataflow and the pipelined II=1 variant (§V's
//! acknowledged pragma headroom) exposed through the backend registry.

pub mod axi;
pub mod bram;
pub mod dataflow;

pub use axi::AxiMaster;
pub use bram::{BramAllocator, BramPlan, WeightPlacement};
pub use dataflow::HlsDesign;
