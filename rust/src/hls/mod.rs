//! Vitis-HLS custom-IP simulator (the paper's flexibility path: fp32,
//! sigmoid/comparator/3-D operators, naive sequential dataflow).

pub mod axi;
pub mod bram;
pub mod dataflow;

pub use axi::AxiMaster;
pub use bram::{BramAllocator, BramPlan, WeightPlacement};
pub use dataflow::HlsDesign;
