//! AXI master model for DRAM-resident weights.
//!
//! The paper's HLS designs expose an AXI4 master that fetches spilled
//! weights word-by-word — un-pipelined in the naive (no-pragma) mapping,
//! so every 32-bit read pays the full DDR round trip.  This is the
//! mechanism behind BaselineNet's collapse (paper §IV: "Fetching these
//! parameters from external memory can further increase inference time").

/// AXI4 master with un-pipelined single-beat reads.
#[derive(Debug, Clone, Copy)]
pub struct AxiMaster {
    /// PL clock cycles per 32-bit read (address phase + DDR latency).
    pub cycles_per_word: f64,
    /// Burst length the design achieves (1 = naive, no burst inference).
    pub burst_len: u64,
}

impl AxiMaster {
    /// The naive no-pragma configuration.
    pub fn naive(cycles_per_word: f64) -> AxiMaster {
        AxiMaster { cycles_per_word, burst_len: 1 }
    }

    /// An optimized configuration with burst inference (used by the
    /// ablation bench to show what pragmas would buy).
    pub fn bursting(cycles_per_word: f64, burst_len: u64) -> AxiMaster {
        AxiMaster { cycles_per_word, burst_len: burst_len.max(1) }
    }

    /// Cycles to stream `bytes` of weights from DRAM.
    pub fn fetch_cycles(&self, bytes: u64) -> f64 {
        let words = bytes.div_ceil(4);
        // a burst amortizes the address/latency cost over burst_len beats
        let bursts = words.div_ceil(self.burst_len);
        bursts as f64 * self.cycles_per_word
            + (words.saturating_sub(bursts)) as f64 // 1 cycle/extra beat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_pays_full_latency_per_word() {
        let axi = AxiMaster::naive(12.0);
        assert_eq!(axi.fetch_cycles(4000), 12.0 * 1000.0);
    }

    #[test]
    fn bursts_amortize() {
        let naive = AxiMaster::naive(12.0);
        let burst = AxiMaster::bursting(12.0, 16);
        let n = naive.fetch_cycles(64 * 1024);
        let b = burst.fetch_cycles(64 * 1024);
        assert!(b < n / 5.0, "burst {b} vs naive {n}");
    }

    #[test]
    fn zero_bytes_zero_cycles() {
        assert_eq!(AxiMaster::naive(12.0).fetch_cycles(0), 0.0);
    }

    #[test]
    fn rounds_partial_words_up() {
        let axi = AxiMaster::naive(10.0);
        assert_eq!(axi.fetch_cycles(5), 2.0 * 10.0);
    }
}
