//! Naive HLS dataflow timing model (paper §III-B.2 / §IV).
//!
//! The paper's HLS designs are deliberately *unoptimized*: ONNX2C output
//! compiled with no performance pragmas, so "the tool defaults to a safe
//! method for mapping the C code to RTL ... executing tasks sequentially".
//! The resulting datapath retires roughly one floating-point operation per
//! initiation interval (~5 cycles: the un-pipelined fp32 add/mul latency)
//! at 100 MHz, layer after layer, plus:
//!
//! * AXI-Lite setup / start / done-poll cycles per inference (dominates
//!   ESPERTA: 2,686 total cycles for a 60-op network);
//! * pipeline fill per layer;
//! * DRAM fetch cycles for weights the BRAM allocator spilled
//!   (BaselineNet's collapse).
//!
//! This is why shallow nets win (ESPERTA 5.33x, LogisticNet 2.03x) and
//! deep 3-D CNNs lose (ReducedNet 0.16x, BaselineNet 0.01x) — the
//! crossover emerges from the mechanism, not from fitting each row.

use super::axi::AxiMaster;
use super::bram::{BramAllocator, BramPlan, WeightPlacement};
use crate::board::{Calibration, Zcu104};
use crate::model::Manifest;

/// One synthesized HLS accelerator.
#[derive(Debug, Clone)]
pub struct HlsDesign {
    /// Synthesized model name.
    pub model: String,
    /// Memory allocation (weight placement, buffers, spill).
    pub plan: BramPlan,
    /// Compute cycles per layer (ops x II + fill).
    pub layer_cycles: Vec<f64>,
    /// DRAM weight-fetch cycles per layer (0 if on-chip).
    pub fetch_cycles: Vec<f64>,
    /// AXI-Lite setup/start/poll cycles per inference.
    pub axi_setup_cycles: f64,
    /// PL clock of the design (Hz) — paper: 100 MHz.
    pub clock_hz: f64,
    /// Input staging time over AXI (s) — *excluded* from inference time,
    /// like the paper's Fig 11 treatment, but shown in power traces.
    pub input_stage_s: f64,
}

impl HlsDesign {
    /// Synthesize (i.e., model) a manifest as a naive HLS accelerator.
    pub fn synthesize(man: &Manifest, board: &Zcu104, calib: &Calibration) -> HlsDesign {
        Self::synthesize_with(
            man,
            board,
            calib,
            calib.hls_ii,
            calib.hls_layer_fill_cycles,
            1.0,
        )
    }

    /// Synthesize the pipelined (II=1) dataflow variant — the pragma
    /// headroom the paper's §V leaves on the table.  The datapath
    /// retires one op per cycle after a deeper pipeline fill, at the
    /// cost of BRAM partitioning pressure (`hls_pipe_bram_factor`
    /// bytes of budget per stored byte), so large models spill to DRAM
    /// sooner — pipelining does not rescue BaselineNet.
    pub fn synthesize_pipelined(
        man: &Manifest,
        board: &Zcu104,
        calib: &Calibration,
    ) -> HlsDesign {
        Self::synthesize_with(
            man,
            board,
            calib,
            calib.hls_pipe_ii,
            calib.hls_pipe_fill_cycles,
            calib.hls_pipe_bram_factor,
        )
    }

    fn synthesize_with(
        man: &Manifest,
        board: &Zcu104,
        calib: &Calibration,
        ii: f64,
        fill_cycles: f64,
        bram_factor: f64,
    ) -> HlsDesign {
        let plan = BramAllocator::new(&board.pl).allocate_scaled(man, bram_factor);
        let axi = AxiMaster::naive(board.ddr_word_cycles);
        let mut layer_cycles = Vec::with_capacity(man.layers.len());
        let mut fetch_cycles = Vec::with_capacity(man.layers.len());
        for (l, place) in man.layers.iter().zip(&plan.placement) {
            let compute =
                l.ops as f64 * ii + if l.ops > 0 { fill_cycles } else { 0.0 };
            layer_cycles.push(compute);
            fetch_cycles.push(match place {
                WeightPlacement::Dram => axi.fetch_cycles(l.weight_bytes),
                WeightPlacement::OnChip => 0.0,
            });
        }
        // feature maps that exceeded the BRAM budget round-trip DRAM
        // (write + read) once per inference
        let act_spill = axi.fetch_cycles(2 * plan.dram_act_bytes);
        if act_spill > 0.0 {
            if let Some(last) = fetch_cycles.last_mut() {
                *last += act_spill;
            }
        }
        HlsDesign {
            model: man.name.clone(),
            plan,
            layer_cycles,
            fetch_cycles,
            axi_setup_cycles: calib.hls_axi_setup_cycles,
            clock_hz: board.hls_clock_hz,
            input_stage_s: man.input_bytes() as f64 / board.axi_bandwidth
                // MMIO staging from a PYNQ notebook is much slower than
                // raw AXI: per-word driver overhead dominates (Fig 11
                // shows input loading exceeding ESPERTA inference time).
                + man.input_elems() as f64 * 0.4e-6,
        }
    }

    /// Total cycles per inference.
    pub fn total_cycles(&self) -> f64 {
        self.axi_setup_cycles
            + self.layer_cycles.iter().sum::<f64>()
            + self.fetch_cycles.iter().sum::<f64>()
    }

    /// Inference latency (s), input staging excluded (paper convention).
    pub fn latency_s(&self) -> f64 {
        self.total_cycles() / self.clock_hz
    }

    /// Inferences per second (input staging excluded, like the paper).
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }

    /// Fraction of time stalled on DRAM weight fetches.
    pub fn fetch_stall_fraction(&self) -> f64 {
        self.fetch_cycles.iter().sum::<f64>() / self.total_cycles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::util::json::Json;

    fn mini() -> Manifest {
        Manifest::from_json(
            &Json::parse(crate::model::manifest::testdata::MINI).unwrap(),
        )
        .unwrap()
    }

    fn design(man: &Manifest) -> HlsDesign {
        HlsDesign::synthesize(man, &Zcu104::default(), &Calibration::default())
    }

    #[test]
    fn cycle_model_components() {
        let man = mini();
        let d = design(&man);
        let c = Calibration::default();
        // layer 0: 608 ops * 5 + 64 fill; layer 1: flatten 0 ops -> 0;
        // layer 2: 130 * 5 + 64
        assert_eq!(d.layer_cycles[0], 640.0 * c.hls_ii + 64.0);
        assert_eq!(d.layer_cycles[1], 0.0);
        assert!(!d.plan.spills());
        assert_eq!(d.fetch_cycles.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn tiny_model_is_setup_dominated() {
        let mut man = mini();
        // strip to just the dense layer: ESPERTA-like
        man.layers[0].ops = 0;
        man.layers[0].macs = 0;
        man.layers[0].params = 0;
        man.layers[0].weight_bytes = 0;
        man.total_ops = 130;
        man.total_macs = 64;
        man.total_params = 66;
        man.weight_bytes = 264;
        let d = design(&man);
        let setup_frac = d.axi_setup_cycles / d.total_cycles();
        assert!(setup_frac > 0.7, "setup fraction {setup_frac}");
    }

    #[test]
    fn spill_adds_fetch_stall() {
        let mut man = mini();
        man.layers[2].weight_bytes = 4 * 1024 * 1024;
        let d = design(&man);
        assert!(d.plan.spills());
        assert!(d.fetch_stall_fraction() > 0.9);
    }

    #[test]
    fn latency_at_100mhz() {
        let d = design(&mini());
        let expected = d.total_cycles() / 100.0e6;
        assert!((d.latency_s() - expected).abs() < 1e-12);
    }

    #[test]
    fn pipelined_variant_cuts_initiation_interval() {
        let man = mini();
        let c = Calibration::default();
        let naive = design(&man);
        let pipe =
            HlsDesign::synthesize_pipelined(&man, &Zcu104::default(), &c);
        // II=1 with a deeper fill, same AXI shell
        assert_eq!(
            pipe.layer_cycles[0],
            640.0 * c.hls_pipe_ii + c.hls_pipe_fill_cycles
        );
        assert_eq!(pipe.axi_setup_cycles, naive.axi_setup_cycles);
        assert!(pipe.latency_s() < naive.latency_s());
        // partitioning charges more BRAM for the same weights
        assert!(pipe.plan.onchip_weight_bytes >= naive.plan.onchip_weight_bytes);
    }

    #[test]
    fn input_staging_excluded_from_latency() {
        let d = design(&mini());
        assert!(d.input_stage_s > 0.0);
        // latency doesn't include staging
        assert!((d.latency_s() - d.total_cycles() / d.clock_hz).abs() < 1e-15);
    }
}
