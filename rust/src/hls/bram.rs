//! BRAM allocation for HLS designs — the paper's on-chip-first weight
//! residency policy (§III-B.2).
//!
//! Policy reproduced from the paper: *"By default, we instantiated all
//! weights on-chip; weights that did not fit in BRAM were placed in
//! DRAM"*, plus ping-pong buffers for the inter-layer feature maps (the
//! paper infers LogisticNet's extra BRAM is "used between layers for
//! intermediate feature maps").  BaselineNet's dense-layer weights blow
//! the budget and spill — the mechanism behind its 0.01x collapse.

use crate::board::zcu104::{PlResources, BRAM36_BYTES};
use crate::model::{LayerKind, Manifest};

/// Where one layer's weights live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightPlacement {
    /// Weights resident in BRAM (streamed at datapath speed).
    OnChip,
    /// Spilled to DRAM (word-by-word AXI fetches — the slow path).
    Dram,
}

/// Allocation result for one design.
#[derive(Debug, Clone)]
pub struct BramPlan {
    /// Per-layer placement (indexed like the manifest's layers).
    pub placement: Vec<WeightPlacement>,
    /// On-chip weight bytes charged against the BRAM budget (equals the
    /// manifest's weight bytes for naive designs; includes partitioning
    /// padding under a pipelined allocation factor).
    pub onchip_weight_bytes: u64,
    /// Weight bytes spilled to DRAM.
    pub dram_weight_bytes: u64,
    /// On-chip ping-pong activation buffer bytes.
    pub act_buffer_bytes: u64,
    /// Activation bytes that exceeded the budget and stream via DRAM.
    pub dram_act_bytes: u64,
    /// I/O staging buffer bytes (output regs + small-input FIFO; large
    /// inputs stream from a DRAM address per the paper's AXI-master
    /// design).
    pub io_buffer_bytes: u64,
    /// Does the design fetch its input via the AXI master (DRAM pointer)?
    pub input_from_dram: bool,
}

/// Allocator with a budget expressed in BRAM36 blocks.
#[derive(Debug, Clone, Copy)]
pub struct BramAllocator {
    /// Budget in BRAM36 blocks available to one accelerator (the tool
    /// will not route a design that consumes every block on the device;
    /// paper designs stay below ~50%).
    pub budget_brams: f64,
}

impl BramAllocator {
    /// Allocator with the routable fraction of the device's BRAM.
    pub fn new(pl: &PlResources) -> BramAllocator {
        // Vitis keeps utilization routable; paper's biggest HLS design
        // sits at 48% of device BRAM.
        BramAllocator { budget_brams: pl.brams * 0.5 }
    }

    /// Inputs above this stay in DRAM and stream over the AXI master
    /// (paper §III-B.2: "For large inputs, we instead exposed a register
    /// holding a DRAM address").
    pub const ONCHIP_INPUT_LIMIT: u64 = 16 * 1024;

    /// Allocate a manifest's memories: I/O first, then weights greedily
    /// in layer order, then activation ping-pong buffers capped at
    /// whatever budget remains (overflow streams via DRAM).
    pub fn allocate(&self, man: &Manifest) -> BramPlan {
        self.allocate_scaled(man, 1.0)
    }

    /// Allocate under a storage-pressure factor: pipelined (II=1)
    /// designs partition weight arrays across BRAM banks and
    /// double-buffer inter-layer feature maps, so every on-chip
    /// weight/activation byte costs `factor` bytes of BRAM budget.
    /// The I/O staging memories are deliberately exempt: the output
    /// registers and the small input FIFO (or the 1 KB DRAM-pointer
    /// stage) sit on the AXI shell, which the dataflow pragmas do not
    /// partition.  Spilled traffic (what the AXI master actually
    /// fetches) stays at the manifest's true byte counts.
    /// `factor = 1.0` is the naive allocation, bit-identical to
    /// [`BramAllocator::allocate`].
    pub fn allocate_scaled(&self, man: &Manifest, factor: f64) -> BramPlan {
        let budget_bytes = (self.budget_brams * BRAM36_BYTES as f64) as u64;
        let cost = |bytes: u64| -> u64 {
            if factor == 1.0 {
                bytes
            } else {
                (bytes as f64 * factor).ceil() as u64
            }
        };

        let input_bytes = man.input_bytes();
        let input_from_dram = input_bytes > Self::ONCHIP_INPUT_LIMIT;
        let io_buffer_bytes = man.output_elems() * 4
            + if input_from_dram { 1024 } else { input_bytes };

        let mut remaining = budget_bytes.saturating_sub(io_buffer_bytes);
        let mut placement = Vec::with_capacity(man.layers.len());
        let mut onchip = 0u64;
        let mut dram = 0u64;
        // Greedy in layer order (the tool allocates as it elaborates).
        for l in &man.layers {
            if l.weight_bytes == 0 {
                placement.push(WeightPlacement::OnChip);
                continue;
            }
            let charged = cost(l.weight_bytes);
            if charged <= remaining {
                remaining -= charged;
                onchip += charged;
                placement.push(WeightPlacement::OnChip);
            } else {
                dram += l.weight_bytes;
                placement.push(WeightPlacement::Dram);
            }
        }
        // Ping-pong activation buffers: two largest consecutive
        // activations, capped at the remaining budget.
        let act_needed = man
            .layers
            .iter()
            .map(|l| l.act_bytes)
            .fold((0u64, 0u64), |(best, prev), cur| (best.max(prev + cur), cur))
            .0;
        let (act_buffer_bytes, dram_act_bytes) = if cost(act_needed) <= remaining {
            (cost(act_needed), 0)
        } else {
            // whatever the remaining budget covers (at `factor` bytes of
            // BRAM per activation byte) stays on chip; the rest streams
            (remaining, act_needed.saturating_sub((remaining as f64 / factor) as u64))
        };
        BramPlan {
            placement,
            onchip_weight_bytes: onchip,
            dram_weight_bytes: dram,
            act_buffer_bytes,
            dram_act_bytes,
            io_buffer_bytes,
            input_from_dram,
        }
    }
}

impl BramPlan {
    /// Total BRAM36 blocks consumed (half-block granularity like the
    /// paper's "1.5 BRAMs" for ESPERTA).
    pub fn brams(&self) -> f64 {
        let bytes =
            self.onchip_weight_bytes + self.act_buffer_bytes + self.io_buffer_bytes;
        // round up to half blocks (an RAMB18 is half an RAMB36)
        let half_blocks = (bytes as f64 / (BRAM36_BYTES as f64 / 2.0)).ceil();
        (half_blocks / 2.0).max(0.5)
    }

    /// Did anything spill?
    pub fn spills(&self) -> bool {
        self.dram_weight_bytes > 0
    }
}

/// True for layers whose weights a dataflow design streams exactly once
/// per inference (all of ours).
pub fn weight_reads_per_inference(kind: LayerKind) -> u64 {
    match kind {
        k if k.is_compute() => 1,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zcu104::Zcu104;
    use crate::model::manifest::Manifest;
    use crate::util::json::Json;

    fn mini() -> Manifest {
        Manifest::from_json(
            &Json::parse(crate::model::manifest::testdata::MINI).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn small_model_fits_onchip() {
        let z = Zcu104::default();
        let plan = BramAllocator::new(&z.pl).allocate(&mini());
        assert!(!plan.spills());
        assert_eq!(plan.onchip_weight_bytes, 344);
        assert!(plan.brams() >= 0.5);
    }

    #[test]
    fn huge_layer_spills() {
        let mut man = mini();
        man.layers[2].weight_bytes = 10 * 1024 * 1024; // 10 MB dense
        let z = Zcu104::default();
        let plan = BramAllocator::new(&z.pl).allocate(&man);
        assert!(plan.spills());
        assert_eq!(plan.dram_weight_bytes, 10 * 1024 * 1024);
        assert_eq!(plan.placement[2], WeightPlacement::Dram);
        // earlier small conv stays on chip
        assert_eq!(plan.placement[0], WeightPlacement::OnChip);
    }

    #[test]
    fn brams_half_block_granularity() {
        let z = Zcu104::default();
        let plan = BramAllocator::new(&z.pl).allocate(&mini());
        let b = plan.brams();
        assert_eq!(b * 2.0, (b * 2.0).round());
    }

    #[test]
    fn scaled_allocation_raises_pressure() {
        let z = Zcu104::default();
        let alloc = BramAllocator::new(&z.pl);
        // factor 1.0 is bit-identical to the naive path
        let man = mini();
        let naive = alloc.allocate(&man);
        let same = alloc.allocate_scaled(&man, 1.0);
        assert_eq!(naive.onchip_weight_bytes, same.onchip_weight_bytes);
        assert_eq!(naive.act_buffer_bytes, same.act_buffer_bytes);
        assert_eq!(naive.dram_act_bytes, same.dram_act_bytes);
        // factor 2.0 doubles the charge, so a layer that just fits
        // under the naive budget spills under partitioning pressure
        let mut big = mini();
        big.layers[2].weight_bytes = 500 * 1024; // < budget, > budget/2
        assert!(!alloc.allocate(&big).spills());
        let pressured = alloc.allocate_scaled(&big, 2.0);
        assert!(pressured.spills(), "partitioned weights must spill");
        // spilled traffic is the true byte count, not the charged one
        assert_eq!(pressured.dram_weight_bytes, 500 * 1024);
    }

    #[test]
    fn budget_respected() {
        let z = Zcu104::default();
        let alloc = BramAllocator::new(&z.pl);
        let mut man = mini();
        man.layers[2].weight_bytes = 600 * 1024; // just under 0.5*312 blocks
        let plan = alloc.allocate(&man);
        let used_bytes =
            plan.onchip_weight_bytes + plan.act_buffer_bytes + plan.io_buffer_bytes;
        assert!(used_bytes as f64 <= alloc.budget_brams * BRAM36_BYTES as f64);
    }
}
