//! Triple-modular-redundancy what-if for the HLS designs.
//!
//! TMR triplicates the datapath and votes: ~3.2x logic (voters included),
//! ~3x dynamic power, and masks any single-module configuration fault
//! between scrubs.  Combined with the scrub model this answers the
//! paper's future-work question quantitatively: what does
//! radiation-hardening a lightweight HLS accelerator actually cost on
//! the ZCU104's resource and power budget?

use crate::board::zcu104::PlResources;
use crate::resources::Utilization;

/// TMR overhead factors (logic triplication + majority voters).
const LOGIC_FACTOR: f64 = 3.2;
const DSP_FACTOR: f64 = 3.0;
const BRAM_FACTOR: f64 = 3.0;
const POWER_FACTOR: f64 = 3.05;

/// A TMR'd design evaluation.
#[derive(Debug, Clone, Copy)]
pub struct TmrOverhead {
    /// Un-hardened design footprint.
    pub base: Utilization,
    /// Triplicated footprint (voters included).
    pub tmr: Utilization,
    /// Power multiplier to apply to the design's PL power term.
    pub power_factor: f64,
    /// Does the TMR'd design still fit the device?
    pub fits: bool,
    /// Residual fault probability factor: TMR masks single faults, so the
    /// unmasked probability goes from p to ~3p^2 (two modules hit within
    /// one scrub period).
    pub residual_fault_exponent: u32,
}

/// Apply TMR to a utilization estimate.
pub fn apply_tmr(base: Utilization, pl: &PlResources) -> TmrOverhead {
    let tmr = Utilization {
        luts: (base.luts as f64 * LOGIC_FACTOR) as u64,
        ffs: (base.ffs as f64 * LOGIC_FACTOR) as u64,
        dsps: (base.dsps as f64 * DSP_FACTOR) as u64,
        brams: base.brams * BRAM_FACTOR,
        urams: base.urams * 3,
    };
    TmrOverhead {
        base,
        fits: tmr.fits(pl),
        tmr,
        power_factor: POWER_FACTOR,
        residual_fault_exponent: 2,
    }
}

/// Residual (unmasked) fault probability under TMR given the single-module
/// fault probability `p` within one scrub period.
pub fn residual_p_fault(p: f64) -> f64 {
    // any 2-of-3 modules faulted
    3.0 * p * p * (1.0 - p) + p * p * p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zcu104::Zcu104;

    fn esperta_util() -> Utilization {
        Utilization { luts: 9_240, ffs: 10_440, dsps: 35, brams: 0.5, urams: 0 }
    }

    #[test]
    fn small_designs_fit_tmr() {
        let z = Zcu104::default();
        let t = apply_tmr(esperta_util(), &z.pl);
        assert!(t.fits, "TMR'd ESPERTA must fit the ZU7EV");
        assert!(t.tmr.luts > 3 * t.base.luts);
    }

    #[test]
    fn dpu_class_design_does_not_fit_tmr() {
        let z = Zcu104::default();
        let dpu = Utilization {
            luts: 102_154, ffs: 199_192, dsps: 1_420, brams: 165.0, urams: 92,
        };
        let t = apply_tmr(dpu, &z.pl);
        assert!(!t.fits, "triplicated B4096 cannot fit — HLS-class designs \
                          are the TMR candidates");
    }

    #[test]
    fn residual_fault_is_quadratic() {
        let p = 1e-3;
        let r = residual_p_fault(p);
        assert!(r < 3.1e-6 && r > 2.9e-6, "{r}");
        assert_eq!(residual_p_fault(0.0), 0.0);
        assert!((residual_p_fault(1.0) - 1.0).abs() < 1e-12);
    }
}
