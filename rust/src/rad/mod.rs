//! Radiation effects & mitigation — the paper's future-work axis made
//! concrete (§VI: "evaluating the impact of ... radiation-induced fault
//! mitigation techniques on performance and reliability"; §IV/Fig 13:
//! "particularly relevant when FPGA scrubbing is used to periodically
//! reprogram the device").
//!
//! * `seu`   — single-event-upset environment model: orbit class ->
//!   configuration-memory upset rate for the ZU7EV's CRAM.
//! * `scrub` — scrubbing scheduler: periodic bitstream reload, its energy
//!   cost (the Fig 13 spike, repeated), duty lost to reconfiguration, and
//!   the resulting probability an inference runs on corrupted
//!   configuration.
//! * `tmr`   — triple-modular-redundancy what-if: area/power overhead vs
//!   masked-fault coverage for the HLS designs.

pub mod scrub;
pub mod seu;
pub mod tmr;

pub use scrub::{ScrubPlan, ScrubPolicy};
pub use seu::{Orbit, SeuEnvironment};
pub use tmr::TmrOverhead;
