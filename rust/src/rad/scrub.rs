//! Scrubbing scheduler: periodic reconfiguration against CRAM upsets.
//!
//! The paper flags the bitstream-load power spike (Fig 13) as "an
//! important factor in space mission planning ... particularly relevant
//! when FPGA scrubbing is used".  This module quantifies the trade:
//! shorter scrub periods cut the probability an inference runs on
//! corrupted configuration but cost reconfiguration energy and duty.

use super::seu::SeuEnvironment;
use crate::board::Calibration;

/// A scrubbing policy.
#[derive(Debug, Clone, Copy)]
pub struct ScrubPolicy {
    /// Seconds between scrubs (full reconfiguration).
    pub period_s: f64,
}

/// Evaluated scrub plan for one design in one environment.
#[derive(Debug, Clone, Copy)]
pub struct ScrubPlan {
    /// Seconds between scrubs (copied from the policy).
    pub period_s: f64,
    /// Fraction of wall time lost to reconfiguration.
    pub duty_lost: f64,
    /// Mean scrub power overhead (W), amortized.
    pub power_overhead_w: f64,
    /// Scrub energy per day (J).
    pub energy_per_day_j: f64,
    /// Probability an inference at the end of a period sees a faulted
    /// configuration (worst case within the period).
    pub p_fault_end_of_period: f64,
    /// Mean fault probability over the period.
    pub p_fault_mean: f64,
}

impl ScrubPolicy {
    /// Evaluate against an environment + design essential bits.
    pub fn evaluate(
        &self,
        env: &SeuEnvironment,
        essential_bits: u64,
        calib: &Calibration,
    ) -> ScrubPlan {
        assert!(self.period_s > 0.0, "scrub period must be positive");
        let t_cfg = calib.t_config;
        let cycle = self.period_s + t_cfg;
        let duty_lost = t_cfg / cycle;
        let spike_w = calib.p_config_spike;
        let power_overhead_w = spike_w * duty_lost;
        let scrubs_per_day = 86_400.0 / cycle;
        let energy_per_day_j = scrubs_per_day * spike_w * t_cfg;
        let p_end = env.p_fault(essential_bits, self.period_s);
        // mean of 1-exp(-lambda t) over the period
        let lam = env.design_upsets(essential_bits, self.period_s)
            / self.period_s.max(1e-12);
        let p_mean = if lam * self.period_s < 1e-12 {
            0.0
        } else {
            1.0 - (1.0 - (-lam * self.period_s).exp()) / (lam * self.period_s)
        };
        ScrubPlan {
            period_s: self.period_s,
            duty_lost,
            power_overhead_w,
            energy_per_day_j,
            p_fault_end_of_period: p_end,
            p_fault_mean: p_mean,
        }
    }

    /// Smallest period whose worst-case fault probability stays below
    /// `target` (bisection over [1 s, 1 day]).
    pub fn period_for_target(
        env: &SeuEnvironment,
        essential_bits: u64,
        target: f64,
    ) -> f64 {
        let (mut lo, mut hi) = (1.0f64, 86_400.0f64);
        if env.p_fault(essential_bits, hi) <= target {
            return hi;
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if env.p_fault(essential_bits, mid) <= target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rad::seu::{essential_bits, Orbit};

    fn env() -> SeuEnvironment {
        SeuEnvironment::new(Orbit::Gto)
    }

    fn bits() -> u64 {
        essential_bits(102_154, 199_192, 1_420, 165.0) // DPU design
    }

    #[test]
    fn shorter_period_less_fault_more_energy() {
        let c = Calibration::default();
        let fast = ScrubPolicy { period_s: 60.0 }.evaluate(&env(), bits(), &c);
        let slow = ScrubPolicy { period_s: 3600.0 }.evaluate(&env(), bits(), &c);
        assert!(fast.p_fault_end_of_period < slow.p_fault_end_of_period);
        assert!(fast.energy_per_day_j > slow.energy_per_day_j);
        assert!(fast.duty_lost > slow.duty_lost);
    }

    #[test]
    fn duty_and_power_consistent() {
        let c = Calibration::default();
        let p = ScrubPolicy { period_s: 600.0 }.evaluate(&env(), bits(), &c);
        assert!(p.duty_lost > 0.0 && p.duty_lost < 0.01);
        // amortized overhead = spike * duty
        assert!((p.power_overhead_w - c.p_config_spike * p.duty_lost).abs()
                < 1e-12);
        // mean fault probability below end-of-period worst case
        assert!(p.p_fault_mean <= p.p_fault_end_of_period);
    }

    #[test]
    fn period_solver_meets_target() {
        let target = 1e-3;
        let period = ScrubPolicy::period_for_target(&env(), bits(), target);
        assert!(env().p_fault(bits(), period) <= target * 1.001);
        // and the next factor-2 longer period violates it (solver is tight)
        assert!(env().p_fault(bits(), period * 2.0) > target);
    }

    #[test]
    fn benign_environment_allows_daily_scrub() {
        // LEO LogisticNet: ~0.12 essential-bit upsets/day, so a relaxed
        // 15% fault budget is met by daily scrubbing...
        let quiet = SeuEnvironment::new(Orbit::Leo);
        let small = essential_bits(5_420, 6_880, 5, 11.0); // LogisticNet
        let period = ScrubPolicy::period_for_target(&quiet, small, 0.15);
        assert_eq!(period, 86_400.0);
        // ...while a tight 1% budget demands intra-day scrubs
        let tight = ScrubPolicy::period_for_target(&quiet, small, 0.01);
        assert!(tight < 86_400.0 && tight > 3_600.0, "{tight}");
    }

    #[test]
    #[should_panic(expected = "scrub period")]
    fn zero_period_rejected() {
        let c = Calibration::default();
        ScrubPolicy { period_s: 0.0 }.evaluate(&env(), bits(), &c);
    }
}
