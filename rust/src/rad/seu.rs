//! Single-event-upset environment model.
//!
//! Configuration-memory (CRAM) upset rates for a 16-nm UltraScale+ part,
//! scaled by orbit environment.  Rates are order-of-magnitude figures from
//! the radiation-test literature for this device class (Xilinx XCZU
//! proton/heavy-ion data): LEO ~1e-7 upsets/bit/day quiet-sun, rising
//! ~30x through GTO belts, ~3x for deep space GCR background, with a
//! solar-event multiplier on top.

use crate::resources::Utilization;

/// Mission orbit regimes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Orbit {
    /// Low Earth orbit (ISS-like, partly shielded by the magnetosphere).
    Leo,
    /// Geostationary transfer / outer-belt crossing.
    Gto,
    /// Interplanetary cruise (GCR-dominated).
    DeepSpace,
}

/// SEU environment bound to an orbit and solar condition.
#[derive(Debug, Clone, Copy)]
pub struct SeuEnvironment {
    /// Orbit regime setting the baseline upset rate.
    pub orbit: Orbit,
    /// Multiplier for solar energetic particle events (1.0 = quiet sun).
    pub solar_activity: f64,
}

/// ZU7EV configuration-memory size (bits) — the scrub target.
pub const ZU7EV_CRAM_BITS: u64 = 205_000_000;

impl SeuEnvironment {
    /// Quiet-sun environment for an orbit.
    pub fn new(orbit: Orbit) -> SeuEnvironment {
        SeuEnvironment { orbit, solar_activity: 1.0 }
    }

    /// Upsets per bit per day in CRAM.
    pub fn upsets_per_bit_day(&self) -> f64 {
        let base = match self.orbit {
            Orbit::Leo => 1.0e-7,
            Orbit::Gto => 3.0e-6,
            Orbit::DeepSpace => 3.0e-7,
        };
        base * self.solar_activity.max(0.0)
    }

    /// Expected device CRAM upsets per day.
    pub fn device_upsets_per_day(&self) -> f64 {
        self.upsets_per_bit_day() * ZU7EV_CRAM_BITS as f64
    }

    /// Expected upsets in the *essential* bits of one design during an
    /// interval.  `essential_bits` is the design-sensitive fraction of
    /// CRAM (typically 5–25% for these accelerator footprints).
    pub fn design_upsets(&self, essential_bits: u64, interval_s: f64) -> f64 {
        self.upsets_per_bit_day() * essential_bits as f64 * interval_s / 86_400.0
    }

    /// Probability >= 1 upset hits the essential bits within an interval
    /// (Poisson).
    pub fn p_fault(&self, essential_bits: u64, interval_s: f64) -> f64 {
        1.0 - (-self.design_upsets(essential_bits, interval_s)).exp()
    }
}

/// Essential-bit estimate for a design from its PL footprint: each LUT
/// configures ~200 CRAM bits, each FF ~10, each DSP ~1,200, each BRAM36
/// ~2,000 control bits (contents are ECC-protected separately).
pub fn essential_bits(luts: u64, ffs: u64, dsps: u64, brams: f64) -> u64 {
    luts * 200 + ffs * 10 + dsps * 1_200 + (brams * 2_000.0) as u64
}

/// Essential bits of an execution target from its estimated
/// [`Utilization`] — the seam SEU / scrub reporting shares with the
/// backend registry: every `backend::AccelModel::resources()` feeds
/// here, so upset rates scale with DPU array size and pipelined-HLS
/// BRAM growth automatically, and the A53 (empty footprint) contributes
/// zero CRAM exposure.
pub fn essential_bits_of(u: &Utilization) -> u64 {
    essential_bits(u.luts, u.ffs, u.dsps, u.brams)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gto_is_harshest() {
        let leo = SeuEnvironment::new(Orbit::Leo);
        let gto = SeuEnvironment::new(Orbit::Gto);
        let deep = SeuEnvironment::new(Orbit::DeepSpace);
        assert!(gto.device_upsets_per_day() > deep.device_upsets_per_day());
        assert!(deep.device_upsets_per_day() > leo.device_upsets_per_day());
        // LEO quiet sun: O(10) CRAM upsets/day for a 205 Mbit device
        let u = leo.device_upsets_per_day();
        assert!((5.0..100.0).contains(&u), "{u}");
    }

    #[test]
    fn solar_event_scales_linearly() {
        let mut env = SeuEnvironment::new(Orbit::DeepSpace);
        let quiet = env.device_upsets_per_day();
        env.solar_activity = 100.0; // large SEP event
        assert!((env.device_upsets_per_day() / quiet - 100.0).abs() < 1e-9);
    }

    #[test]
    fn p_fault_poisson_properties() {
        let env = SeuEnvironment::new(Orbit::Gto);
        let bits = 10_000_000;
        let p1 = env.p_fault(bits, 600.0);
        let p2 = env.p_fault(bits, 6_000.0);
        assert!(p1 > 0.0 && p1 < p2 && p2 < 1.0);
        assert_eq!(env.p_fault(0, 600.0), 0.0);
    }

    #[test]
    fn essential_bits_scale_with_footprint() {
        // ESPERTA-ish vs DPU-ish designs
        let small = essential_bits(9_240, 10_440, 35, 0.5);
        let dpu = essential_bits(102_154, 199_192, 1_420, 165.0);
        assert!(dpu > 10 * small);
        assert!(small > 1_000_000); // ~2 Mbit
    }

    // ---- per-registry-target essential bits: SEU exposure must track
    // each backend's resources() ----

    use crate::backend::{AccelModel, TargetRegistry, TargetSet};
    use crate::board::Calibration;
    use crate::model::Catalog;

    fn bits_of(model: &str, target: &str) -> u64 {
        let reg = TargetRegistry::build(
            model,
            &Catalog::synthetic(),
            &Calibration::default(),
            &TargetSet::All,
        )
        .unwrap();
        let t = reg
            .targets()
            .iter()
            .find(|t| t.name() == target)
            .unwrap_or_else(|| panic!("no target {target} for {model}"));
        essential_bits_of(&t.resources())
    }

    #[test]
    fn target_cpu_has_zero_cram_exposure() {
        assert_eq!(bits_of("vae", "cpu"), 0);
    }

    #[test]
    fn target_dpu_b512_exposure() {
        let b = bits_of("vae", "dpu-b512");
        // scaled footprint: well above an HLS design, well below B4096
        assert!(b > 5_000_000, "{b}");
        assert!(b < bits_of("vae", "dpu"));
    }

    #[test]
    fn target_dpu_b1024_exposure() {
        assert!(bits_of("vae", "dpu-b1024") > bits_of("vae", "dpu-b512"));
    }

    #[test]
    fn target_dpu_b2304_exposure() {
        assert!(bits_of("vae", "dpu-b2304") > bits_of("vae", "dpu-b1024"));
    }

    #[test]
    fn target_dpu_b4096_matches_table2_footprint() {
        assert_eq!(
            bits_of("vae", "dpu"),
            essential_bits(102_154, 199_192, 1_420, 165.0)
        );
        assert!(bits_of("vae", "dpu") > bits_of("vae", "dpu-b2304"));
    }

    #[test]
    fn target_hls_naive_exposure() {
        let b = bits_of("esperta", "hls");
        assert!(b > 1_000_000, "{b}"); // sigmoid cores cost real LUTs
        // even the smallest DPU member dwarfs a naive HLS shell
        assert!(b < bits_of("vae", "dpu-b512"));
    }

    #[test]
    fn target_hls_pipelined_exposure_grows() {
        // unrolled datapath + partitioned BRAM -> more essential bits
        assert!(bits_of("esperta", "hls-pipe") > bits_of("esperta", "hls"));
        assert!(bits_of("baseline", "hls-pipe") > bits_of("baseline", "hls"));
    }

    #[test]
    fn scrub_period_scales_with_target_exposure() {
        use crate::rad::scrub::ScrubPolicy;
        let env = SeuEnvironment::new(Orbit::Gto);
        let small = ScrubPolicy::period_for_target(&env, bits_of("vae", "dpu-b512"), 1e-3);
        let big = ScrubPolicy::period_for_target(&env, bits_of("vae", "dpu"), 1e-3);
        assert!(
            big < small,
            "the bigger array must scrub more often ({big} vs {small})"
        );
    }
}
