//! Constellation-scale fleet simulation: N spacecraft fly the same
//! mission scenario in parallel shards, contending for shared
//! ground-station passes.
//!
//! # Sharding
//!
//! Each craft is one [`OwnedPipelineRun`] (its own pipeline, sensor
//! stream, and RNG streams) driven by its own
//! [`ScenarioCursor`], seeded with
//! [`stream_seed`]`(master, craft)` so craft *i* is bit-identical
//! regardless of fleet size or thread count.  Crafts advance in
//! *epochs*: one scenario phase per epoch, fanned across the
//! work-stealing pool in [`shard`], with a barrier after every epoch.
//!
//! # Barrier arbitration
//!
//! Each epoch barrier is one ground-station pass.  A shared byte
//! budget ([`FleetConfig::pass_budget_bytes`]) is granted to crafts
//! *in craft-id order* against their accumulated downlink demand
//! (bytes their own manager shed), so contention deterministically
//! starves late claimants; unmet demand stalls the craft
//! (demand / link rate) and, with [`FleetConfig::relay`], routes to
//! the next craft's following pass.  Arbitration runs on the calling
//! thread between epochs — never inside the pool.
//!
//! # Determinism argument
//!
//! Workers only ever mutate *their claimed craft*; every cross-craft
//! byte flows through the sequential barrier.  Per-craft seeds are a
//! pure function of `(master, craft)`.  Hence the [`FleetReport`] is
//! bit-identical for `--threads 1` and any `--threads T` — parallelism
//! is pure speedup, which the determinism suite pins at 256 crafts and
//! `benches/runtime.rs` prices.

pub mod report;
pub mod shard;

use anyhow::{bail, Result};
use std::sync::Mutex;

use crate::board::Calibration;
use crate::coordinator::{OwnedPipelineRun, Pipeline};
use crate::model::catalog::Catalog;
use crate::scenario::{Phase, Scenario, ScenarioCursor};
use crate::util::hash::fnv1a;
use crate::util::prng::stream_seed;

pub use report::{CraftSummary, Dispersion, FleetReport};
pub use shard::{resolve_threads, try_parallel_for};

/// Fleet-run configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of spacecraft.
    pub crafts: usize,
    /// Worker threads (clamped to `1..=crafts`); 1 runs inline on the
    /// caller.  Any value yields the same [`FleetReport`].
    pub threads: usize,
    /// Master seed; craft `i` flies under `stream_seed(master, i)`.
    pub master_seed: u64,
    /// Shared downlink budget granted per ground-station pass (one
    /// pass per epoch barrier).  0 disables pass arbitration entirely
    /// — every craft keeps exactly its solo behavior.
    pub pass_budget_bytes: u64,
    /// Pass link rate (bytes/s) converting unmet demand into
    /// contention-stall time.
    pub pass_link_bytes_per_s: f64,
    /// Route a craft's unmet demand through craft `(i+1) % n`'s next
    /// pass (needs `crafts >= 2` to have any effect).
    pub relay: bool,
    /// Orbital planes for phase staggering: craft `i` flies a silent
    /// prelude of `(i % planes) * stagger_events` events before the
    /// scenario proper, offsetting eclipse/storm phases across planes.
    pub planes: usize,
    /// Prelude events per plane step (0 disables staggering).
    pub stagger_events: usize,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            crafts: 8,
            threads: 1,
            master_seed: 7,
            pass_budget_bytes: 0,
            pass_link_bytes_per_s: 125_000.0,
            relay: false,
            planes: 1,
            stagger_events: 0,
        }
    }
}

/// The per-craft flavor of `base` that craft `i` flies: the same
/// mission with this craft's stream-split seeds and (when staggering
/// is configured) its plane's silent prelude phase prepended.
///
/// Pure function of `(base, cfg, i)` — the seam the single-craft
/// equivalence test uses to compare a fleet member against a plain
/// [`crate::scenario::run_scenario`] of the identical scenario.
pub fn craft_scenario(base: &Scenario, cfg: &FleetConfig, i: usize) -> Scenario {
    let mut sc = base.clone();
    sc.config.seed = stream_seed(cfg.master_seed, i as u64);
    if let Some(fs) = sc.config.fault_seed {
        // fault streams split per craft too, salted by the master so
        // fleet faults never alias the sensor/decision streams
        sc.config.fault_seed = Some(stream_seed(fs ^ cfg.master_seed, i as u64));
    }
    let offset = (i % cfg.planes.max(1)) * cfg.stagger_events;
    if offset > 0 {
        sc.phases.insert(0, Phase::new("stagger", offset, vec![]));
    }
    sc
}

/// One spacecraft shard plus its pass-arbitration ledger.
struct Craft {
    scenario: Scenario,
    cursor: ScenarioCursor,
    run: OwnedPipelineRun,
    seed: u64,
    /// Did the last epoch advance a phase?
    stepped: bool,
    /// Shed-bytes watermark at the last barrier.
    shed_seen: u64,
    /// Accumulated unmet downlink demand (bytes).
    demand_bytes: u64,
    /// Shared budget granted to this craft so far.
    granted_bytes: u64,
    /// Neighbor backlog this craft carried.
    relayed_bytes: u64,
    /// Neighbor backlog parked here awaiting this craft's next pass.
    relay_queue: u64,
    /// Contention-stall time (s).
    stall_s: f64,
}

/// Fly `scenario` across a fleet and aggregate the [`FleetReport`].
///
/// One shared `catalog`/`calib` serves every craft (no per-craft
/// catalog rebuild — pinned by a unit test below); craft pipelines are
/// built on the calling thread, stepped epoch-by-epoch across the
/// worker pool, arbitrated at each barrier, and finished in craft-id
/// order.
pub fn run_fleet(
    scenario: &Scenario,
    catalog: &Catalog,
    calib: &Calibration,
    cfg: &FleetConfig,
) -> Result<FleetReport> {
    if cfg.crafts == 0 {
        bail!("fleet needs at least one craft (--crafts >= 1)");
    }
    if !(cfg.pass_link_bytes_per_s > 0.0 && cfg.pass_link_bytes_per_s.is_finite()) {
        bail!(
            "pass link rate must be positive and finite, got {}",
            cfg.pass_link_bytes_per_s
        );
    }
    let n = cfg.crafts;
    let threads = cfg.threads.clamp(1, n);
    let mut slots: Vec<Mutex<Craft>> = Vec::with_capacity(n);
    for i in 0..n {
        let sc = craft_scenario(scenario, cfg, i);
        let seed = sc.config.seed;
        let run = Pipeline::new(sc.config.clone(), catalog, calib)?.begin_owned();
        slots.push(Mutex::new(Craft {
            scenario: sc,
            cursor: ScenarioCursor::new(),
            run,
            seed,
            stepped: false,
            shed_seen: 0,
            demand_bytes: 0,
            granted_bytes: 0,
            relayed_bytes: 0,
            relay_queue: 0,
            stall_s: 0.0,
        }));
    }
    loop {
        // epoch: every craft advances one scenario phase, in parallel
        try_parallel_for(n, threads, |i| {
            let mut slot = slots[i].lock().expect("craft slot");
            let craft = &mut *slot;
            let stepped = {
                let Craft { scenario, cursor, run, .. } = craft;
                run.with_run(|r| cursor.step_phase(scenario, calib, r))?
            };
            craft.stepped = stepped;
            Ok(())
        })?;
        let mut any = false;
        for slot in slots.iter_mut() {
            any |= slot.get_mut().expect("craft slot").stepped;
        }
        if !any {
            break;
        }
        // barrier: one ground-station pass, arbitrated sequentially
        if cfg.pass_budget_bytes > 0 {
            arbitrate_pass(&mut slots, cfg);
        }
    }
    let mut rows = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        let craft = slot.into_inner().expect("craft slot");
        let backlog_bytes = craft.demand_bytes + craft.relay_queue;
        let report = craft.run.finish()?;
        rows.push(CraftSummary {
            craft: i,
            seed: craft.seed,
            events: report.events,
            energy_j: report.energy_j,
            sent_bytes: report.downlink_sent_bytes,
            shed_bytes: report.downlink_shed_bytes,
            granted_bytes: craft.granted_bytes,
            relayed_bytes: craft.relayed_bytes,
            backlog_bytes,
            deadline_misses: report.deadline_misses,
            stall_s: craft.stall_s,
            report_digest: fnv1a(report.render().bytes()),
        });
    }
    Ok(FleetReport::assemble(&scenario.name, rows))
}

/// One ground-station pass: refresh per-craft demand from the shed
/// watermarks, grant the shared budget in craft-id order, drain relay
/// backlog parked at each craft, then stall (and optionally hand off)
/// whatever stayed unmet.  Sequential and craft-id ordered throughout
/// — the entire cross-craft surface of the fleet model.
fn arbitrate_pass(slots: &mut [Mutex<Craft>], cfg: &FleetConfig) {
    let n = slots.len();
    for slot in slots.iter_mut() {
        let craft = slot.get_mut().expect("craft slot");
        let shed_now = craft.run.with_run(|r| r.downlink_shed_bytes());
        craft.demand_bytes += shed_now - craft.shed_seen;
        craft.shed_seen = shed_now;
    }
    let mut budget = cfg.pass_budget_bytes;
    // own demand first, craft-id order: late claimants starve
    for slot in slots.iter_mut() {
        let craft = slot.get_mut().expect("craft slot");
        let grant = craft.demand_bytes.min(budget);
        if grant > 0 {
            budget -= grant;
            craft.demand_bytes -= grant;
            craft.granted_bytes += grant;
            // a zero grant must NOT touch the run: granting 0 bytes
            // would still create a metrics counter entry and break
            // bit-identity with the solo (non-fleet) run
            craft.run.with_run(|r| r.grant_downlink_bytes(grant));
        }
    }
    // relay backlog parked by earlier passes drains after own demand
    if cfg.relay {
        for slot in slots.iter_mut() {
            let craft = slot.get_mut().expect("craft slot");
            let grant = craft.relay_queue.min(budget);
            if grant > 0 {
                budget -= grant;
                craft.relay_queue -= grant;
                craft.relayed_bytes += grant;
            }
        }
    }
    // unmet demand stalls the craft until the next pass; with relay it
    // also re-parks at the neighbor, whose next pass may carry it
    for i in 0..n {
        let unmet = {
            let craft = slots[i].get_mut().expect("craft slot");
            let unmet = craft.demand_bytes;
            if unmet > 0 {
                craft.stall_s += unmet as f64 / cfg.pass_link_bytes_per_s;
                if cfg.relay && n > 1 {
                    craft.demand_bytes = 0;
                }
            }
            unmet
        };
        if cfg.relay && n > 1 && unmet > 0 {
            slots[(i + 1) % n].get_mut().expect("craft slot").relay_queue += unmet;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{PipelineConfig, Policy};
    use crate::model::catalog::synthetic_builds_this_thread;
    use crate::model::UseCase;
    use crate::rad::ScrubPolicy;
    use crate::scenario::MissionEvent;

    /// A small two-phase mission that sheds downlink: a tight budget
    /// plus steady traffic guarantees nonzero demand at every pass.
    fn tight_scenario() -> Scenario {
        Scenario {
            name: "fleet-test".into(),
            summary: "tight downlink for pass-contention tests".into(),
            config: PipelineConfig {
                use_case: UseCase::Esperta,
                cadence_s: 0.1,
                downlink_budget: 64,
                policy: Policy::Static,
                ..Default::default()
            },
            scrub: ScrubPolicy { period_s: 60.0 },
            phases: vec![
                Phase::new("cruise", 30, vec![]),
                Phase::new(
                    "storm",
                    30,
                    vec![MissionEvent::SepStorm { burst_x: 4.0, deadline_s: 0.5 }],
                ),
            ],
        }
    }

    fn fleet_cfg(crafts: usize, threads: usize) -> FleetConfig {
        FleetConfig {
            crafts,
            threads,
            master_seed: 11,
            pass_budget_bytes: 96,
            relay: true,
            planes: 2,
            stagger_events: 5,
            ..Default::default()
        }
    }

    #[test]
    fn one_catalog_serves_the_whole_fleet() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let before = synthetic_builds_this_thread();
        run_fleet(&tight_scenario(), &catalog, &calib, &fleet_cfg(6, 1))
            .unwrap();
        assert_eq!(
            synthetic_builds_this_thread(),
            before,
            "fleet must not rebuild Catalog::synthetic() per craft"
        );
    }

    #[test]
    fn craft_scenario_is_pure_and_seed_split() {
        let base = tight_scenario();
        let cfg = fleet_cfg(8, 1);
        let a = craft_scenario(&base, &cfg, 3);
        let b = craft_scenario(&base, &cfg, 3);
        assert_eq!(a.config.seed, b.config.seed);
        assert_eq!(a.phases.len(), b.phases.len());
        assert_ne!(
            craft_scenario(&base, &cfg, 0).config.seed,
            craft_scenario(&base, &cfg, 1).config.seed
        );
        // plane 0 crafts fly the base phase chain; plane 1 gets the
        // stagger prelude
        assert_eq!(craft_scenario(&base, &cfg, 0).phases.len(), 2);
        assert_eq!(craft_scenario(&base, &cfg, 1).phases.len(), 3);
        assert_eq!(craft_scenario(&base, &cfg, 1).phases[0].name, "stagger");
    }

    #[test]
    fn pass_contention_starves_late_claimants() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let mut cfg = fleet_cfg(4, 1);
        cfg.relay = false;
        cfg.planes = 1;
        cfg.stagger_events = 0;
        // budget far below fleet demand: craft 0 must be granted at
        // least as much as craft 3, and someone must stall
        cfg.pass_budget_bytes = 40;
        let r = run_fleet(&tight_scenario(), &catalog, &calib, &cfg).unwrap();
        assert!(
            r.per_craft[0].granted_bytes >= r.per_craft[3].granted_bytes,
            "craft-id order must favor early claimants: {:#?}",
            r.per_craft
        );
        assert!(r.total_stall_s > 0.0, "contention must stall someone");
        assert!(r.total_granted_bytes > 0, "someone must be granted");
    }

    #[test]
    fn relay_routes_unmet_demand_through_neighbors() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        // dense phase: demand far exceeds the pass budget, so unmet
        // bytes park at neighbors; quiet phase: almost no new demand,
        // so the next pass has headroom to drain the relay queues
        let mut sc = tight_scenario();
        sc.phases = vec![
            Phase::new("dense", 60, vec![]),
            Phase::new("quiet", 1, vec![]),
        ];
        let mut cfg = fleet_cfg(4, 1);
        cfg.planes = 1;
        cfg.stagger_events = 0;
        cfg.pass_budget_bytes = 100;
        let r = run_fleet(&sc, &catalog, &calib, &cfg).unwrap();
        assert!(
            r.total_shed_bytes > cfg.pass_budget_bytes,
            "dense phase must oversubscribe the pass: {:#?}",
            r.per_craft
        );
        assert!(
            r.total_relayed_bytes > 0,
            "quiet-pass headroom must drain neighbor backlog: {:#?}",
            r.per_craft
        );
    }

    #[test]
    fn thread_count_does_not_change_the_report() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let sc = tight_scenario();
        let r1 = run_fleet(&sc, &catalog, &calib, &fleet_cfg(12, 1)).unwrap();
        let r3 = run_fleet(&sc, &catalog, &calib, &fleet_cfg(12, 3)).unwrap();
        assert_eq!(r1, r3);
        assert_eq!(r1.digest(), r3.digest());
    }

    #[test]
    fn fleet_size_does_not_change_a_craft() {
        // craft 2 of a 4-fleet == craft 2 of an 8-fleet, bit for bit
        // (arbitration off: passes couple crafts by design)
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let sc = tight_scenario();
        let mut cfg = fleet_cfg(4, 1);
        cfg.pass_budget_bytes = 0;
        cfg.relay = false;
        let small = run_fleet(&sc, &catalog, &calib, &cfg).unwrap();
        cfg.crafts = 8;
        let big = run_fleet(&sc, &catalog, &calib, &cfg).unwrap();
        assert_eq!(small.per_craft[2], big.per_craft[2]);
    }

    #[test]
    fn zero_crafts_is_an_error() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let cfg = FleetConfig { crafts: 0, ..Default::default() };
        assert!(run_fleet(&tight_scenario(), &catalog, &calib, &cfg).is_err());
    }
}
