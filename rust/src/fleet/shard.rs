//! Zero-dependency work-stealing pool for fleet shards.
//!
//! Built on `std::thread::scope` plus one shared atomic work index:
//! each worker claims the next unclaimed craft index with a
//! `fetch_add`, so a worker that finishes a cheap craft immediately
//! steals the next one instead of idling behind a static partition.
//! The pool imposes *no* ordering of its own — callers get determinism
//! by making each index's work independent of every other index (one
//! spacecraft per index) and doing all cross-craft work on the calling
//! thread between pool invocations.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Run `f(i)` for every `i in 0..n` across up to `threads` scoped
/// workers, claiming indices from a shared atomic counter.
///
/// `threads <= 1` (or `n <= 1`) runs inline on the calling thread —
/// no spawn, no atomics — which is what lets thread-local assertions
/// (e.g. the catalog no-rebuild pin) observe a single-threaded fleet.
///
/// Errors are collected per index; the error for the *lowest* failing
/// index is returned, so the reported failure is deterministic no
/// matter which worker hit it first.  Remaining indices still run
/// (no cancellation) — a fleet epoch is cheap enough that draining
/// beats the non-determinism of a mid-epoch abort.
pub fn try_parallel_for<F>(n: usize, threads: usize, f: F) -> Result<()>
where
    F: Fn(usize) -> Result<()> + Sync,
{
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i).with_context(|| format!("craft {i}"))?;
        }
        return Ok(());
    }
    let next = AtomicUsize::new(0);
    let errors: Mutex<Vec<(usize, anyhow::Error)>> = Mutex::new(Vec::new());
    let workers = threads.min(n);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if let Err(e) = f(i) {
                    errors.lock().expect("error sink").push((i, e));
                }
            });
        }
    });
    let mut errors = errors.into_inner().expect("error sink");
    errors.sort_by_key(|(i, _)| *i);
    match errors.into_iter().next() {
        Some((i, e)) => Err(e).with_context(|| format!("craft {i}")),
        None => Ok(()),
    }
}

/// Resolve a `--threads` request against the fleet size.
///
/// `None` defaults to [`std::thread::available_parallelism`] (1 when
/// the runtime cannot tell); an explicit 0 is rejected; anything above
/// the craft count is capped there — extra workers could never claim
/// an index and would only pay spawn cost.
pub fn resolve_threads(requested: Option<usize>, crafts: usize) -> Result<usize> {
    let t = match requested {
        Some(0) => bail!("--threads must be >= 1 (omit the flag for auto)"),
        Some(t) => t,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    };
    Ok(t.min(crafts.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4] {
            let hits: Vec<AtomicU64> =
                (0..97).map(|_| AtomicU64::new(0)).collect();
            try_parallel_for(97, threads, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
                Ok(())
            })
            .unwrap();
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn lowest_failing_index_wins() {
        // run a few times: whichever worker errors first, the reported
        // craft must always be the lowest failing index
        for _ in 0..5 {
            let err = try_parallel_for(64, 4, |i| {
                if i % 2 == 1 {
                    bail!("odd craft {i}");
                }
                Ok(())
            })
            .unwrap_err();
            assert!(err.to_string().contains("craft 1"), "{err:#}");
        }
    }

    #[test]
    fn zero_items_is_a_no_op() {
        try_parallel_for(0, 4, |_| bail!("must not run")).unwrap();
    }

    #[test]
    fn threads_validation() {
        assert!(resolve_threads(Some(0), 8).is_err());
        assert_eq!(resolve_threads(Some(3), 8).unwrap(), 3);
        // capped at the craft count
        assert_eq!(resolve_threads(Some(64), 8).unwrap(), 8);
        // default is available_parallelism, still capped
        let auto = resolve_threads(None, 2).unwrap();
        assert!((1..=2).contains(&auto));
        // degenerate fleet still yields a worker
        assert_eq!(resolve_threads(Some(4), 0).unwrap(), 1);
    }
}
