//! Fleet-level aggregation: per-craft summaries rolled up into one
//! [`FleetReport`].
//!
//! Everything in the report is a pure function of the per-craft
//! [`crate::coordinator::PipelineReport`]s plus the barrier
//! arbitration's byte/stall ledgers — no wall-clock time, no thread
//! count — so `#[derive(PartialEq)]` equality *is* the fleet
//! determinism check: two runs that compare equal rendered the same
//! bytes from the same per-craft state.

use crate::util::hash::fnv1a;
use crate::util::table::Table;

/// One spacecraft's contribution to the fleet rollup.
#[derive(Debug, Clone, PartialEq)]
pub struct CraftSummary {
    /// Craft index (also its arbitration priority: lower goes first).
    pub craft: usize,
    /// The stream-split seed this craft ran under.
    pub seed: u64,
    /// Events processed end to end.
    pub events: u64,
    /// Energy spent (J, virtual ZCU104 clock).
    pub energy_j: f64,
    /// Science bytes downlinked.
    pub sent_bytes: u64,
    /// Bytes shed by the craft's own downlink manager.
    pub shed_bytes: u64,
    /// Shared pass budget granted to this craft across all barriers.
    pub granted_bytes: u64,
    /// Neighbor backlog this craft carried over its own passes.
    pub relayed_bytes: u64,
    /// Backlog still parked (unrecovered demand + undrained relay).
    pub backlog_bytes: u64,
    /// Deadline misses.
    pub deadline_misses: u64,
    /// Time spent waiting on pass contention (s).
    pub stall_s: f64,
    /// FNV-1a digest of the craft's full rendered `PipelineReport` —
    /// the bit-identity witness: per-craft reports agree if and only
    /// if these agree.
    pub report_digest: u64,
}

/// Min/mean/max spread of one per-craft statistic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dispersion {
    /// Smallest per-craft value.
    pub min: f64,
    /// Fleet mean.
    pub mean: f64,
    /// Largest per-craft value.
    pub max: f64,
}

impl Dispersion {
    /// Dispersion of a sample; all zeros for an empty fleet.
    pub fn of(values: &[f64]) -> Dispersion {
        if values.is_empty() {
            return Dispersion { min: 0.0, mean: 0.0, max: 0.0 };
        }
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for &v in values {
            min = min.min(v);
            max = max.max(v);
            sum += v;
        }
        Dispersion { min, mean: sum / values.len() as f64, max }
    }
}

/// The aggregate fleet report: per-craft rows plus rollups.
///
/// Bit-identical across `--threads 1` and any `--threads T` — the
/// headline invariant `spaceinfer fleet` and the determinism suite
/// assert with plain `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// Scenario every craft flew (with per-craft seeds/stagger).
    pub scenario: String,
    /// Fleet size.
    pub crafts: usize,
    /// Per-craft rows, in craft-id order.
    pub per_craft: Vec<CraftSummary>,
    /// Total events processed.
    pub total_events: u64,
    /// Total energy (J).
    pub total_energy_j: f64,
    /// Total science downlinked (bytes).
    pub total_sent_bytes: u64,
    /// Total bytes shed across the fleet.
    pub total_shed_bytes: u64,
    /// Total shared pass budget granted.
    pub total_granted_bytes: u64,
    /// Total bytes relayed through neighbors.
    pub total_relayed_bytes: u64,
    /// Total contention-stall time (s).
    pub total_stall_s: f64,
    /// Deadline-miss CDF: `(misses, fraction of crafts with <= misses)`
    /// over the distinct per-craft miss counts, ascending.
    pub miss_cdf: Vec<(u64, f64)>,
    /// Per-craft energy spread.
    pub energy_dispersion: Dispersion,
    /// Per-craft downlinked-bytes spread.
    pub sent_dispersion: Dispersion,
}

impl FleetReport {
    /// Assemble the rollup from per-craft rows (kept in craft order).
    pub fn assemble(scenario: &str, per_craft: Vec<CraftSummary>) -> FleetReport {
        let energies: Vec<f64> = per_craft.iter().map(|c| c.energy_j).collect();
        let sents: Vec<f64> =
            per_craft.iter().map(|c| c.sent_bytes as f64).collect();
        let mut misses: Vec<u64> =
            per_craft.iter().map(|c| c.deadline_misses).collect();
        misses.sort_unstable();
        let n = per_craft.len();
        let mut miss_cdf = Vec::new();
        for (rank, &m) in misses.iter().enumerate() {
            let frac = (rank + 1) as f64 / n as f64;
            // collapse ties: keep the highest fraction per miss value
            match miss_cdf.last_mut() {
                Some(entry) if entry.0 == m => entry.1 = frac,
                _ => miss_cdf.push((m, frac)),
            }
        }
        FleetReport {
            scenario: scenario.to_string(),
            crafts: n,
            total_events: per_craft.iter().map(|c| c.events).sum(),
            total_energy_j: energies.iter().sum(),
            total_sent_bytes: per_craft.iter().map(|c| c.sent_bytes).sum(),
            total_shed_bytes: per_craft.iter().map(|c| c.shed_bytes).sum(),
            total_granted_bytes: per_craft.iter().map(|c| c.granted_bytes).sum(),
            total_relayed_bytes: per_craft.iter().map(|c| c.relayed_bytes).sum(),
            total_stall_s: per_craft.iter().map(|c| c.stall_s).sum(),
            miss_cdf,
            energy_dispersion: Dispersion::of(&energies),
            sent_dispersion: Dispersion::of(&sents),
            per_craft,
        }
    }

    /// Digest of the whole rendered report — one u64 that changes if
    /// any craft's report, any ledger, or any rollup changes.
    pub fn digest(&self) -> u64 {
        fnv1a(self.render().bytes())
    }

    /// Render the fleet table plus rollup lines.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!("Fleet: {} x {}", self.crafts, self.scenario),
            &[
                "Craft", "Seed", "Events", "Energy J", "Sent B", "Shed B",
                "Grant B", "Relay B", "Backlog", "Miss", "Stall s", "Digest",
            ],
        );
        for c in &self.per_craft {
            t.row(vec![
                c.craft.to_string(),
                format!("{:016x}", c.seed),
                c.events.to_string(),
                format!("{:.3}", c.energy_j),
                c.sent_bytes.to_string(),
                c.shed_bytes.to_string(),
                c.granted_bytes.to_string(),
                c.relayed_bytes.to_string(),
                c.backlog_bytes.to_string(),
                c.deadline_misses.to_string(),
                format!("{:.3}", c.stall_s),
                format!("{:016x}", c.report_digest),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "fleet totals: {} events, {:.3} J, {} B sent, {} B shed, \
             {} B granted, {} B relayed, {:.3} s stalled\n",
            self.total_events,
            self.total_energy_j,
            self.total_sent_bytes,
            self.total_shed_bytes,
            self.total_granted_bytes,
            self.total_relayed_bytes,
            self.total_stall_s,
        ));
        out.push_str(&format!(
            "energy/craft: min {:.3} mean {:.3} max {:.3} J   \
             sent/craft: min {:.0} mean {:.1} max {:.0} B\n",
            self.energy_dispersion.min,
            self.energy_dispersion.mean,
            self.energy_dispersion.max,
            self.sent_dispersion.min,
            self.sent_dispersion.mean,
            self.sent_dispersion.max,
        ));
        out.push_str("deadline-miss CDF:");
        for (m, frac) in &self.miss_cdf {
            out.push_str(&format!("  <={m}: {:.1}%", frac * 100.0));
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(craft: usize, misses: u64, energy: f64) -> CraftSummary {
        CraftSummary {
            craft,
            seed: craft as u64,
            events: 10,
            energy_j: energy,
            sent_bytes: 100,
            shed_bytes: 5,
            granted_bytes: 0,
            relayed_bytes: 0,
            backlog_bytes: 0,
            deadline_misses: misses,
            stall_s: 0.0,
            report_digest: 0xABCD,
        }
    }

    #[test]
    fn cdf_is_monotone_and_collapses_ties() {
        let r = FleetReport::assemble(
            "t",
            vec![row(0, 0, 1.0), row(1, 0, 2.0), row(2, 3, 3.0), row(3, 7, 4.0)],
        );
        assert_eq!(
            r.miss_cdf,
            vec![(0, 0.5), (3, 0.75), (7, 1.0)],
            "{:?}",
            r.miss_cdf
        );
    }

    #[test]
    fn dispersion_of_sample() {
        let d = Dispersion::of(&[1.0, 2.0, 3.0]);
        assert_eq!((d.min, d.mean, d.max), (1.0, 2.0, 3.0));
        let empty = Dispersion::of(&[]);
        assert_eq!((empty.min, empty.mean, empty.max), (0.0, 0.0, 0.0));
    }

    #[test]
    fn equality_tracks_every_field() {
        let a = FleetReport::assemble("t", vec![row(0, 1, 2.0)]);
        let mut b = a.clone();
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        b.per_craft[0].report_digest ^= 1;
        assert_ne!(a, b);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn render_mentions_totals_and_cdf() {
        let r = FleetReport::assemble("eclipse", vec![row(0, 0, 1.5)]);
        let s = r.render();
        assert!(s.contains("fleet totals"), "{s}");
        assert!(s.contains("deadline-miss CDF"), "{s}");
        assert!(s.contains("eclipse"), "{s}");
    }
}
