//! AMD ZCU104 / ZU7EV MPSoC device model (paper §II-A, Table II).

/// Programmable-logic resource pool of the ZU7EV (Table II, "Available
/// Resources" row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlResources {
    /// Lookup tables.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// DSP slices.
    pub dsps: u64,
    /// BRAM36 blocks (half units allowed — the paper counts an 18 Kb block
    /// as 0.5, e.g. ESPERTA's 1.5).
    pub brams: f64,
    /// UltraRAM blocks.
    pub urams: u64,
}

/// Bytes in one BRAM36 block (36 Kbit).
pub const BRAM36_BYTES: u64 = 4608;
/// Bytes in one UltraRAM block (288 Kbit).
pub const URAM_BYTES: u64 = 36_864;

/// The ZCU104 board: PS (2x A53 cluster as used by PYNQ) + PL + DDR.
#[derive(Debug, Clone, Copy)]
pub struct Zcu104 {
    /// Programmable-logic resource pool.
    pub pl: PlResources,
    /// A53 clock (Hz).
    pub ps_clock_hz: f64,
    /// Default PL clock for naive HLS designs (Hz) — paper: 100 MHz.
    pub hls_clock_hz: f64,
    /// DPU clock (Hz) — paper Table II: 300 MHz MAC array (600 MHz DSP).
    pub dpu_clock_hz: f64,
    /// PS<->PL / DDR streaming bandwidth for input staging (bytes/s).
    pub axi_bandwidth: f64,
    /// Random-access DDR penalty for spilled weight words (PL clock
    /// cycles per 32-bit word, un-pipelined AXI master — the naive HLS
    /// access pattern).
    pub ddr_word_cycles: f64,
}

impl Default for Zcu104 {
    fn default() -> Self {
        Zcu104 {
            pl: PlResources {
                luts: 230_000,
                ffs: 461_000,
                dsps: 1_728,
                brams: 312.0,
                urams: 96,
            },
            ps_clock_hz: 1.2e9,
            hls_clock_hz: 100.0e6,
            dpu_clock_hz: 300.0e6,
            axi_bandwidth: 2.0e9,
            ddr_word_cycles: 12.0,
        }
    }
}

impl Zcu104 {
    /// Total on-chip PL memory in bytes (38 Mb: BRAM + URAM).
    pub fn onchip_bytes(&self) -> u64 {
        (self.pl.brams as u64) * BRAM36_BYTES + self.pl.urams * URAM_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onchip_is_about_38mbit() {
        let z = Zcu104::default();
        let bits = z.onchip_bytes() * 8;
        // paper §II-A: 38 Mb of on-chip SRAM (4.75 MB)
        assert!((bits as f64 - 38.0e6).abs() / 38.0e6 < 0.06, "{bits}");
    }

    #[test]
    fn table2_available_row() {
        let z = Zcu104::default();
        assert_eq!(z.pl.luts, 230_000);
        assert_eq!(z.pl.dsps, 1_728);
        assert_eq!(z.pl.brams, 312.0);
        assert_eq!(z.pl.urams, 96);
    }
}
