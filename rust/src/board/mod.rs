//! The simulated testbed: ZCU104 board description and the calibration
//! constants that translate the paper's physical testbed onto it.

pub mod calib;
pub mod zcu104;

pub use calib::Calibration;
pub use zcu104::Zcu104;
