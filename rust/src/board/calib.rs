//! Calibration constants — the knobs that map the paper's physical
//! testbed onto the analytic simulators.
//!
//! Calibration discipline (DESIGN.md §4): the **CPU baseline rows** of
//! Table III anchor the per-model A53 efficiency (the paper's PyTorch
//! numbers cannot be derived ab initio), and the **VAE DPU power row**
//! anchors the DPU static draw.  Everything else — all accelerator
//! latencies, the CNet DPU power, every HLS row, every energy figure — is
//! *predicted* by the mechanism models and compared against the paper in
//! EXPERIMENTS.md.

use std::path::Path;

use anyhow::Result;

use crate::util::json::{num, obj, Json};

/// All tunable constants, with physically-motivated defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    // ---- A53 CPU timing ----
    /// Peak single-core NEON fp32 throughput (ops/s): 1.2 GHz x 8.
    pub cpu_peak_ops: f64,
    /// PyTorch per-layer dispatch overhead: 2-D convolution (s).
    pub dispatch_conv2d: f64,
    /// Dispatch overhead: 3-D convolution (s).
    pub dispatch_conv3d: f64,
    /// Dispatch overhead: pooling layers (s).
    pub dispatch_pool: f64,
    /// Dispatch overhead: dense / dense-heads layers (s).
    pub dispatch_dense: f64,
    /// Dispatch overhead: reshape / concat / misc kernels (s).
    pub dispatch_misc: f64,

    // ---- DPU B4096 timing ----
    /// MAC-array pixel parallelism (output pixels per cycle).
    pub dpu_pp: u64,
    /// MAC-array input-channel parallelism.
    pub dpu_icp: u64,
    /// MAC-array output-channel parallelism.
    pub dpu_ocp: u64,
    /// Fixed runner-invocation overhead per inference (s) — the PYNQ/VART
    /// submit-wait path the paper measured through.
    pub dpu_invoke_s: f64,
    /// Per-layer instruction fetch/dispatch (s).
    pub dpu_layer_s: f64,
    /// Misc-engine elements per cycle (pooling / elementwise).
    pub dpu_misc_elems_per_cycle: f64,
    /// Feature-map DDR streaming bandwidth (bytes per MAC-array cycle):
    /// ~4 GB/s at 300 MHz.  Intermediate activations do not fit the DPU's
    /// on-chip store for the big CNNs and stream through DDR.
    pub dpu_ddr_bytes_per_cycle: f64,

    // ---- DPU family scaling ----
    /// Fraction of the B4096 static draw that does not scale with array
    /// size (scheduler, instruction fetch, AXI interconnect); the rest
    /// scales with MAC-array capacity.  Anchored so the B4096 member
    /// reproduces `p_dpu_base` exactly.
    pub dpu_static_fixed_frac: f64,

    // ---- HLS naive-dataflow timing ----
    /// AXI-Lite setup + start + done-poll cycles per inference.
    pub hls_axi_setup_cycles: f64,
    /// Initiation interval of the un-pipelined fp32 datapath (cycles/op).
    pub hls_ii: f64,
    /// Pipeline fill cycles per layer.
    pub hls_layer_fill_cycles: f64,

    // ---- HLS pipelined (II=1) variant ----
    /// Initiation interval with pipeline/unroll pragmas (cycles/op).
    pub hls_pipe_ii: f64,
    /// Deeper pipeline fill cycles per layer in the pipelined variant.
    pub hls_pipe_fill_cycles: f64,
    /// BRAM bytes charged per stored byte under array partitioning +
    /// double buffering (>= 1.0; the naive flow is 1.0).
    pub hls_pipe_bram_factor: f64,

    // ---- power (W) ----
    /// Board peripheral floor (fans, PHYs, VRM losses).
    pub p_periph: f64,
    /// Extra board draw while the PS hammers DDR (CPU inference).
    pub p_ddr_cpu: f64,
    /// PS idle draw.
    pub p_ps_idle: f64,
    /// PS draw while polling an accelerator.
    pub p_ps_poll: f64,
    /// DPU design static+poll base (calibrated on the VAE row).
    pub p_dpu_base: f64,
    /// DPU dynamic swing at 100% MAC duty.
    pub p_dpu_dyn: f64,
    /// HLS design power: static/poll base term (W).
    pub p_hls_base: f64,
    /// HLS design power per 1000 LUTs (W).
    pub p_hls_per_kilolut: f64,
    /// HLS design power per BRAM36 block (W).
    pub p_hls_per_bram: f64,
    /// MPSoC power spike during bitstream configuration.
    pub p_config_spike: f64,
    /// Bitstream configuration time (s).
    pub t_config: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            cpu_peak_ops: 9.6e9,
            dispatch_conv2d: 400e-6,
            dispatch_conv3d: 700e-6,
            dispatch_pool: 150e-6,
            dispatch_dense: 80e-6,
            dispatch_misc: 30e-6,

            dpu_pp: 8,
            dpu_icp: 16,
            dpu_ocp: 16,
            dpu_invoke_s: 1.0e-3,
            dpu_layer_s: 20e-6,
            dpu_misc_elems_per_cycle: 64.0,
            dpu_ddr_bytes_per_cycle: 13.0,

            dpu_static_fixed_frac: 0.35,

            hls_axi_setup_cycles: 2600.0,
            hls_ii: 5.0,
            hls_layer_fill_cycles: 64.0,

            hls_pipe_ii: 1.0,
            hls_pipe_fill_cycles: 256.0,
            hls_pipe_bram_factor: 2.0,

            p_periph: 8.95,
            p_ddr_cpu: 0.5,
            p_ps_idle: 1.30,
            p_ps_poll: 1.35,
            p_dpu_base: 5.31,
            p_dpu_dyn: 1.7,
            p_hls_base: 1.35,
            p_hls_per_kilolut: 0.019,
            p_hls_per_bram: 0.0028,
            p_config_spike: 2.5,
            t_config: 0.8,
        }
    }
}

macro_rules! calib_fields {
    ($($field:ident),* $(,)?) => {
        const FIELDS: &[&str] = &[$(stringify!($field)),*];

        impl Calibration {
            fn get_field(&self, name: &str) -> Option<f64> {
                match name {
                    $(stringify!($field) => Some(self.$field as f64),)*
                    _ => None,
                }
            }

            fn set_field(&mut self, name: &str, v: f64) -> bool {
                match name {
                    $(stringify!($field) => { self.$field = v as _; true },)*
                    _ => false,
                }
            }
        }
    };
}

calib_fields!(
    cpu_peak_ops, dispatch_conv2d, dispatch_conv3d, dispatch_pool,
    dispatch_dense, dispatch_misc, dpu_invoke_s, dpu_layer_s,
    dpu_misc_elems_per_cycle, dpu_ddr_bytes_per_cycle,
    dpu_static_fixed_frac, hls_axi_setup_cycles, hls_ii,
    hls_layer_fill_cycles, hls_pipe_ii, hls_pipe_fill_cycles,
    hls_pipe_bram_factor, p_periph, p_ddr_cpu, p_ps_idle, p_ps_poll,
    p_dpu_base, p_dpu_dyn, p_hls_base, p_hls_per_kilolut, p_hls_per_bram,
    p_config_spike, t_config,
);

impl Calibration {
    /// Serialize the float fields to JSON (integer parallelism constants
    /// are architectural, not calibration, and stay fixed).
    pub fn to_json(&self) -> Json {
        obj(FIELDS
            .iter()
            .map(|f| (*f, num(self.get_field(f).unwrap())))
            .collect())
    }

    /// Load from JSON, starting from defaults (missing keys keep default).
    pub fn from_json(j: &Json) -> Result<Calibration> {
        let mut c = Calibration::default();
        for (k, v) in j.as_obj()? {
            if !c.set_field(k, v.as_f64()?) {
                anyhow::bail!("unknown calibration key {k:?}");
            }
        }
        Ok(c)
    }

    /// Load a calibration JSON file (missing keys keep defaults).
    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path)?;
        Calibration::from_json(&Json::parse(&text)?)
    }

    /// Write the calibration as JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Per-layer dispatch overhead for the A53 model.
    pub fn dispatch_for(&self, kind: crate::model::LayerKind) -> f64 {
        use crate::model::LayerKind::*;
        match kind {
            Conv2d => self.dispatch_conv2d,
            Conv3d => self.dispatch_conv3d,
            MaxPool2d | MaxPool3d | AvgPool3d => self.dispatch_pool,
            Dense | DenseHeads => self.dispatch_dense,
            // bank = linear + sigmoid + compare + concat: 4 small kernels
            EspertaBank => 4.0 * self.dispatch_misc,
            Flatten | ConcatScalar => self.dispatch_misc,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip_json() {
        let c = Calibration::default();
        let j = c.to_json();
        let c2 = Calibration::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"hls_ii": 7.5}"#).unwrap();
        let c = Calibration::from_json(&j).unwrap();
        assert_eq!(c.hls_ii, 7.5);
        assert_eq!(c.p_periph, Calibration::default().p_periph);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"not_a_knob": 1}"#).unwrap();
        assert!(Calibration::from_json(&j).is_err());
    }

    #[test]
    fn dpu_array_is_b4096() {
        let c = Calibration::default();
        // B4096 = 4096 INT8 ops/cycle = 2048 MACs = PP x ICP x OCP
        assert_eq!(c.dpu_pp * c.dpu_icp * c.dpu_ocp, 2048);
    }
}
