//! Plain-text table rendering for the paper-table reports.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// A simple column-aligned table with a header row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    /// Rendered above the header as `== title ==` (empty = omitted).
    pub title: String,
    /// Column headers.
    pub header: Vec<String>,
    /// Data rows; each must match the header width.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    ///
    /// ```
    /// use spaceinfer::util::table::Table;
    /// let mut t = Table::new("T", &["model", "fps"]);
    /// t.row(vec!["vae".into(), "606.6".into()]);
    /// assert!(t.render().contains("== T =="));
    /// ```
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (panics on width mismatch — a bug in the caller).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Render with per-column width alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Machine-readable form: `{"title", "header", "rows"}` where each
    /// row is an object keyed by header name — what `spaceinfer
    /// policies --json` / `targets --json` emit so serve clients and CI
    /// consume the comparison tables without scraping the ASCII layout.
    /// Cells stay the formatted strings the text table shows, so both
    /// outputs agree character for character.
    ///
    /// ```
    /// use spaceinfer::util::table::Table;
    /// let mut t = Table::new("T", &["model", "fps"]);
    /// t.row(vec!["vae".into(), "606.6".into()]);
    /// let j = t.to_json().to_string();
    /// assert!(j.contains("\"fps\":\"606.6\""));
    /// ```
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                Json::Obj(
                    self.header
                        .iter()
                        .zip(row)
                        .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                        .collect(),
                )
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("title".to_string(), Json::Str(self.title.clone()));
        doc.insert(
            "header".to_string(),
            Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        doc.insert("rows".to_string(), Json::Arr(rows));
        Json::Obj(doc)
    }
}

/// Format a float with engineering-style precision (2 decimals under 100,
/// 1 decimal under 10k, integer above).
pub fn eng(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a >= 10_000.0 {
        format!("{:.0}", v)
    } else if a >= 100.0 {
        format!("{:.1}", v)
    } else {
        format!("{:.2}", v)
    }
}

/// Format a count with thousands separators (paper-style "3,061,966").
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        // all data lines equal width alignment for col 0
        assert!(lines[3].starts_with("x     "));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_rows_keyed_by_header() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["y".into(), "2".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str().unwrap(), "T");
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("b").unwrap().as_str().unwrap(), "2");
        // round-trips through the parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.to_string(), j.to_string());
    }

    #[test]
    fn comma_grouping() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(3_061_966), "3,061,966");
    }

    #[test]
    fn eng_scales() {
        assert_eq!(eng(3.14159), "3.14");
        assert_eq!(eng(606.65), "606.6"); // 606.65f64 rounds down (binary repr)
        assert_eq!(eng(37231.0), "37231");
    }
}
