//! xorshift64* PRNG — deterministic, seedable, dependency-free.
//!
//! Drives the synthetic sensors, the coordinator's jitter models, and the
//! hand-rolled property tests (`rand`/`proptest` are not in the offline
//! registry).  Failing property tests print the seed so any case replays.

/// xorshift64* generator (Vigna 2016); period 2^64 - 1.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Seeded constructor; seed 0 is remapped (xorshift state must be != 0).
    pub fn new(seed: u64) -> Self {
        Prng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Prng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Split off an independent stream (for per-source sensor streams).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut p = Prng::new(0);
        assert_ne!(p.next_u64(), 0);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut p = Prng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut p = Prng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[p.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn forks_diverge() {
        let mut p = Prng::new(5);
        let mut a = p.fork();
        let mut b = p.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
