//! xorshift64* PRNG — deterministic, seedable, dependency-free.
//!
//! Drives the synthetic sensors, the coordinator's jitter models, and the
//! hand-rolled property tests (`rand`/`proptest` are not in the offline
//! registry).  Failing property tests print the seed so any case replays.

/// SplitMix64 increment (golden-ratio constant) used by
/// [`stream_seed`] to place derived streams on a low-discrepancy walk.
const SPLITMIX_GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Derive the seed of an independent child stream from a master seed —
/// a SplitMix64-style stream split (Steele, Lea & Flood 2014).
///
/// Pure function of `(master, index)`: child `i` of a given master is
/// the same value no matter how many siblings exist or which thread
/// asks, which is what makes fleet craft `i` bit-identical regardless
/// of fleet size or thread count.  Two finalizer rounds decorrelate
/// even adjacent indices of adjacent masters, so no two derived
/// [`Prng`] streams share a 64-bit output prefix in practice (pinned
/// by the independence smoke test below).
///
/// ```
/// use spaceinfer::util::prng::stream_seed;
/// assert_eq!(stream_seed(7, 3), stream_seed(7, 3)); // pure
/// assert_ne!(stream_seed(7, 3), stream_seed(7, 4)); // split
/// ```
pub fn stream_seed(master: u64, index: u64) -> u64 {
    // SplitMix64 finalizer (Vigna's fmix-style avalanche), applied
    // twice over the golden-ratio walk from the master seed.
    let mut z = master
        .wrapping_add(index.wrapping_add(1).wrapping_mul(SPLITMIX_GOLDEN));
    for _ in 0..2 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
    }
    z
}

/// xorshift64* generator (Vigna 2016); period 2^64 - 1.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Seeded constructor; seed 0 is remapped (xorshift state must be != 0).
    pub fn new(seed: u64) -> Self {
        Prng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Prng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Split off an independent stream (for per-source sensor streams).
    pub fn fork(&mut self) -> Prng {
        Prng::new(self.next_u64() | 1)
    }

    /// Generator for child stream `index` of `master` — shorthand for
    /// `Prng::new(stream_seed(master, index))`.
    pub fn stream(master: u64, index: u64) -> Prng {
        Prng::new(stream_seed(master, index))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut p = Prng::new(0);
        assert_ne!(p.next_u64(), 0);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut p = Prng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut p = Prng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[p.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn stream_seed_is_pure_and_injective_on_a_grid() {
        let masters = [0u64, 1, 7, 42, 0xDEAD_BEEF, u64::MAX];
        let mut seen = std::collections::BTreeSet::new();
        for &m in &masters {
            for i in 0..64u64 {
                let s = stream_seed(m, i);
                assert_eq!(s, stream_seed(m, i), "must be pure");
                assert!(
                    seen.insert(s),
                    "seed collision at master {m} index {i}"
                );
            }
        }
    }

    #[test]
    fn derived_streams_share_no_64bit_prefix() {
        // statistical-independence smoke test: across masters AND
        // indices, no two derived streams may agree on their first
        // 64-bit output — a shared prefix means the split aliased.
        let masters = [0u64, 1, 7, 42, 0xDEAD_BEEF];
        let mut prefixes = std::collections::BTreeSet::new();
        let mut n = 0usize;
        for &m in &masters {
            for i in 0..64u64 {
                let mut p = Prng::stream(m, i);
                prefixes.insert(p.next_u64());
                n += 1;
            }
        }
        assert_eq!(prefixes.len(), n, "two derived streams share a prefix");
    }

    #[test]
    fn adjacent_indices_decorrelate() {
        // consecutive craft indices must not produce correlated walks:
        // compare the first 8 outputs pairwise
        let mut a = Prng::stream(7, 0);
        let mut b = Prng::stream(7, 1);
        let mut same = 0;
        for _ in 0..8 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0);
    }

    #[test]
    fn forks_diverge() {
        let mut p = Prng::new(5);
        let mut a = p.fork();
        let mut b = p.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
