//! Micro-benchmark kit (criterion is not in the offline registry).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! fixed sample count, median / p95 / mean reporting, and a trivial
//! throughput helper.  Deliberately simple — the paper's quantitative
//! claims come from the calibrated simulators, not from wall-clock on the
//! dev box; these benches guard the *coordinator's own* hot paths.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Case name (printed in reports).
    pub name: String,
    /// Raw timed samples.
    pub samples: Vec<Duration>,
}

impl Sample {
    fn sorted_nanos(&self) -> Vec<u128> {
        let mut v: Vec<u128> = self.samples.iter().map(|d| d.as_nanos()).collect();
        v.sort_unstable();
        v
    }

    /// Median sample.
    pub fn median(&self) -> Duration {
        let v = self.sorted_nanos();
        Duration::from_nanos(v[v.len() / 2] as u64)
    }

    /// 95th-percentile sample (nearest-rank, the same convention as the
    /// pipeline's latency p95 — truncating the rank understates the
    /// tail for small n).
    pub fn p95(&self) -> Duration {
        let v = self.sorted_nanos();
        let rank = ((v.len() as f64) * 0.95).ceil() as usize;
        Duration::from_nanos(v[rank.clamp(1, v.len()) - 1] as u64)
    }

    /// Arithmetic mean of the samples.
    pub fn mean(&self) -> Duration {
        let total: u128 = self.samples.iter().map(|d| d.as_nanos()).sum();
        Duration::from_nanos((total / self.samples.len() as u128) as u64)
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>12?}  p95 {:>12?}  mean {:>12?}  (n={})",
            self.name,
            self.median(),
            self.p95(),
            self.mean(),
            self.samples.len()
        )
    }
}

/// Run `f` with warmup and collect `n` timed samples.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, n: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    Sample { name: name.to_string(), samples }
}

/// Items/second from a duration and item count.
pub fn throughput(items: u64, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_n_samples() {
        let s = bench("noop", 2, 10, || {});
        assert_eq!(s.samples.len(), 10);
        assert!(s.median() <= s.p95());
    }

    #[test]
    fn throughput_math() {
        let t = throughput(1000, Duration::from_secs(2));
        assert!((t - 500.0).abs() < 1e-9);
    }
}
