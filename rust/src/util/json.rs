//! Minimal JSON parser + emitter (serde is not in the offline registry).
//!
//! Handles the full JSON grammar the artifact pipeline emits (objects,
//! arrays, strings with escapes, f64 numbers, bools, null) and is fast
//! enough for the multi-megabyte golden-IO files (single pass, byte
//! oriented, no backtracking).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// String (escapes resolved).
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys — deterministic emission).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON key {key:?}"))
    }

    /// Number, or error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// Integer-valued number, or error.
    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    /// Non-negative integer, or error.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        if n < 0 {
            bail!("expected unsigned, got {n}");
        }
        Ok(n as usize)
    }

    /// String, or error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got non-string"),
        }
    }

    /// Bool, or error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool"),
        }
    }

    /// Array slice, or error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array"),
        }
    }

    /// Object map, or error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    /// Array of numbers -> Vec<f64> (fast path for golden IO blobs).
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers -> Vec<usize> (shape vectors).
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at {}", self.pos),
                    }
                }
                _ => {
                    // copy a run of plain bytes in one go
                    let start = self.pos - 1;
                    while let Some(&c) = self.bytes.get(self.pos) {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit()
                || b == b'-'
                || b == b'+'
                || b == b'.'
                || b == b'e'
                || b == b'E'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

// ---------------------------------------------------------------------------
// emitter
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience: build `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: `Json::Num`.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Convenience: `Json::Str`.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j, Json::Str("é".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,true,null,"s\"q"],"z":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn big_float_array() {
        let src = format!("[{}]", (0..10_000).map(|i| format!("{}.5", i))
            .collect::<Vec<_>>().join(","));
        let j = Json::parse(&src).unwrap();
        let v = j.as_f64_vec().unwrap();
        assert_eq!(v.len(), 10_000);
        assert_eq!(v[9_999], 9999.5);
    }
}
