//! Tiny subcommand/flag parser (clap is not in the offline registry).
//!
//! Grammar: `spaceinfer <subcommand> [--flag value] [--switch] [positional]`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: String,
    /// `--key value` pairs.
    pub flags: BTreeMap<String, String>,
    /// Bare `--switch` tokens.
    pub switches: Vec<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = tok;
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process argv.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// String flag with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<&str> {
        match self.flags.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }

    /// Numeric flag with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Integer flag with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Is `--name` present as a switch?
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table3 --model vae --n 100 --verbose");
        assert_eq!(a.command, "table3");
        assert_eq!(a.get("model", ""), "vae");
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --model=cnet");
        assert_eq!(a.get("model", ""), "cnet");
    }

    #[test]
    fn positionals() {
        let a = parse("inspect one two");
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn required_missing() {
        assert!(parse("run").require("model").is_err());
    }

    #[test]
    fn default_values() {
        let a = parse("run");
        assert_eq!(a.get_f64("rate", 2.5).unwrap(), 2.5);
        assert_eq!(a.get("model", "vae"), "vae");
    }

    #[test]
    fn trailing_switch() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
        assert!(a.flags.is_empty());
    }
}
