//! Substrates the offline build image forces us to own: JSON, CLI parsing,
//! PRNG, table rendering, and a micro-benchmark kit (no serde / clap /
//! rand / criterion in the vendored registry).

pub mod benchkit;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prng;
pub mod table;
