//! FNV-1a 64-bit — the one non-cryptographic hash the crate needs
//! (executor shard routing, surrogate-engine seeding).  Streaming so
//! callers can fold strings, bytes, and raw f32 bits without
//! intermediate buffers.

/// FNV-1a offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Streaming FNV-1a hasher.
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(
    /// Current hash state (public so tests and seeding tricks can peek).
    pub u64,
);

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a(FNV_OFFSET)
    }
}

impl Fnv1a {
    /// Start from a custom state (e.g. a per-model seed).
    pub fn seeded(seed: u64) -> Fnv1a {
        Fnv1a(FNV_OFFSET ^ seed)
    }

    /// Fold one byte in.
    pub fn write_u8(&mut self, b: u8) -> &mut Self {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
        self
    }

    /// Fold a byte stream in.
    pub fn write_bytes(&mut self, bytes: impl IntoIterator<Item = u8>) -> &mut Self {
        for b in bytes {
            self.write_u8(b);
        }
        self
    }

    /// Fold a whole u64 in (one multiply per word — used for f32 bit
    /// patterns where byte granularity buys nothing).
    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
        self
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot convenience over a byte stream.
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    Fnv1a::default().write_bytes(bytes).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // canonical FNV-1a 64 test vectors
        assert_eq!(fnv1a("".bytes()), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a".bytes()), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a("foobar".bytes()), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let mut h = Fnv1a::default();
        h.write_bytes("foo".bytes()).write_bytes("bar".bytes());
        assert_eq!(h.finish(), fnv1a("foobar".bytes()));
    }

    #[test]
    fn seed_separates_streams() {
        assert_ne!(
            Fnv1a::seeded(1).write_u64(7).finish(),
            Fnv1a::seeded(2).write_u64(7).finish()
        );
    }
}
