//! PL resource estimation (Table II reproduction).

pub mod estimate;

pub use estimate::{estimate_hls, Utilization};
