//! PL resource estimation (Table II reproduction).

pub mod estimate;

pub use estimate::{estimate_hls, estimate_hls_pipelined, Utilization};
