//! LUT/FF/DSP/BRAM estimation for naive HLS designs (Table II).
//!
//! The estimator mirrors how Vitis maps un-pragma'd ONNX2C code:
//!
//! * a fixed control/AXI shell (state machine, AXI-Lite regs, AXI master);
//! * one shared fp32 datapath per layer *kind* present (the naive flow
//!   does not replicate MACs): multiplier 3 DSP + adder 2 DSP;
//! * sigmoid/exp from LUT-heavy polynomial cores (why ESPERTA's 8k LUTs
//!   top the HLS designs despite 24 parameters);
//! * BRAM from the allocator in `hls::bram` (weights + buffers).
//!
//! The DPU row of Table II is the IP's fixed footprint
//! (`dpu::arch::DpuArch::resources`).

use crate::board::zcu104::PlResources;
use crate::hls::BramPlan;
use crate::model::{Activation, LayerKind, Manifest};

/// Estimated utilization of one design.
#[derive(Debug, Clone, Copy)]
pub struct Utilization {
    /// Lookup tables.
    pub luts: u64,
    /// Flip-flops.
    pub ffs: u64,
    /// DSP slices.
    pub dsps: u64,
    /// BRAM36 blocks (half units allowed).
    pub brams: f64,
    /// UltraRAM blocks.
    pub urams: u64,
}

impl Utilization {
    /// The empty footprint — a target that configures no PL fabric at
    /// all (the A53 software path).
    pub fn none() -> Utilization {
        Utilization { luts: 0, ffs: 0, dsps: 0, brams: 0.0, urams: 0 }
    }

    /// Percentage strings against the device pool (Table II formatting).
    pub fn percent(&self, pl: &PlResources) -> (f64, f64, f64, f64, f64) {
        (
            100.0 * self.luts as f64 / pl.luts as f64,
            100.0 * self.ffs as f64 / pl.ffs as f64,
            100.0 * self.dsps as f64 / pl.dsps as f64,
            100.0 * self.brams / pl.brams,
            100.0 * self.urams as f64 / pl.urams as f64,
        )
    }

    /// Does the design fit the device?
    pub fn fits(&self, pl: &PlResources) -> bool {
        self.luts <= pl.luts
            && self.ffs <= pl.ffs
            && self.dsps <= pl.dsps
            && self.brams <= pl.brams
            && self.urams <= pl.urams
    }
}

// Shell: AXI-Lite slave + AXI master + FSM control.
const SHELL_LUTS: u64 = 3_900;
const SHELL_FFS: u64 = 5_200;

// One shared fp32 MAC datapath (mul 3 DSP + add 2 DSP).
const FP32_MAC_DSPS: u64 = 5;
const FP32_MAC_LUTS: u64 = 800;
const FP32_MAC_FFS: u64 = 900;

// Sigmoid/exp polynomial core (per parallel instance).
const SIGMOID_LUTS: u64 = 450;
const SIGMOID_FFS: u64 = 380;
const SIGMOID_DSPS: u64 = 5;

// Comparator bank + misc per layer.
const PER_LAYER_LUTS: u64 = 240;
const PER_LAYER_FFS: u64 = 260;

/// Estimate a naive HLS design's PL footprint from its manifest + BRAM
/// plan.
pub fn estimate_hls(man: &Manifest, plan: &BramPlan) -> Utilization {
    let mut luts = SHELL_LUTS;
    let mut ffs = SHELL_FFS;
    let mut dsps = 0u64;

    let mut mac_kinds = std::collections::BTreeSet::new();
    for l in &man.layers {
        luts += PER_LAYER_LUTS;
        ffs += PER_LAYER_FFS;
        match l.kind {
            LayerKind::Conv2d | LayerKind::Conv3d | LayerKind::Dense
            | LayerKind::DenseHeads => {
                mac_kinds.insert(format!("{:?}", l.kind));
            }
            LayerKind::EspertaBank => {
                // n parallel single-MAC models + sigmoid + comparator each
                let n = (l.out_shape[1] / 2) as u64;
                dsps += n * FP32_MAC_DSPS + n * SIGMOID_DSPS / 6;
                luts += n * (FP32_MAC_LUTS / 2 + SIGMOID_LUTS);
                ffs += n * (FP32_MAC_FFS / 2 + SIGMOID_FFS);
            }
            _ => {}
        }
        if l.act == Activation::Sigmoid {
            luts += SIGMOID_LUTS;
            ffs += SIGMOID_FFS;
            dsps += SIGMOID_DSPS;
        }
    }
    // one shared fp32 datapath per distinct compute-layer kind
    let k = mac_kinds.len() as u64;
    dsps += k * FP32_MAC_DSPS;
    luts += k * FP32_MAC_LUTS;
    ffs += k * FP32_MAC_FFS;
    // AXI master data staging logic when weights spill to DRAM
    if plan.spills() {
        luts += 900;
        ffs += 700;
    }

    Utilization { luts, ffs, dsps, brams: plan.brams(), urams: 0 }
}

/// Parallel fp32 MACs per compute layer in the pipelined (II=1)
/// variant — the unroll factor the dataflow pragmas buy.
pub const PIPE_UNROLL: u64 = 8;

/// Footprint of the pipelined (II=1) variant: instead of one shared
/// datapath per layer *kind*, every compute layer gets its own
/// [`PIPE_UNROLL`]-wide pipelined MAC datapath (what `#pragma HLS
/// pipeline` + `unroll` elaborate to), on top of the naive shell.  The
/// BRAM column comes from the partitioned plan, which already carries
/// the banking overhead.
pub fn estimate_hls_pipelined(man: &Manifest, plan: &BramPlan) -> Utilization {
    let base = estimate_hls(man, plan);
    let compute_layers =
        man.layers.iter().filter(|l| l.kind.is_compute()).count() as u64;
    let extra = compute_layers * (PIPE_UNROLL - 1);
    Utilization {
        luts: base.luts + extra * FP32_MAC_LUTS,
        ffs: base.ffs + extra * FP32_MAC_FFS,
        dsps: base.dsps + extra * FP32_MAC_DSPS,
        brams: plan.brams(),
        urams: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::zcu104::Zcu104;
    use crate::hls::BramAllocator;
    use crate::model::manifest::Manifest;
    use crate::util::json::Json;

    fn mini() -> Manifest {
        Manifest::from_json(
            &Json::parse(crate::model::manifest::testdata::MINI).unwrap(),
        )
        .unwrap()
    }

    fn util(man: &Manifest) -> Utilization {
        let z = Zcu104::default();
        let plan = BramAllocator::new(&z.pl).allocate(man);
        estimate_hls(man, &plan)
    }

    #[test]
    fn small_design_small_footprint() {
        let u = util(&mini());
        let z = Zcu104::default();
        assert!(u.fits(&z.pl));
        // naive designs sit in the paper's 2-4% LUT band
        let (lut_pct, ..) = u.percent(&z.pl);
        assert!(lut_pct < 5.0, "{lut_pct}");
        // conv2d + dense datapaths -> 10 DSPs
        assert_eq!(u.dsps, 10);
    }

    #[test]
    fn sigmoid_costs_luts_and_dsps() {
        let mut man = mini();
        man.layers[2].act = Activation::Sigmoid;
        let base = util(&mini());
        let sig = util(&man);
        assert!(sig.luts > base.luts);
        assert!(sig.dsps > base.dsps);
    }

    #[test]
    fn spill_adds_axi_logic() {
        let mut man = mini();
        man.layers[2].weight_bytes = 8 * 1024 * 1024;
        let spilled = util(&man);
        let base = util(&mini());
        assert!(spilled.luts > base.luts);
    }

    #[test]
    fn percent_math() {
        let z = Zcu104::default();
        let u = Utilization { luts: 23_000, ffs: 0, dsps: 864, brams: 156.0,
                              urams: 48 };
        let (l, _, d, b, ur) = u.percent(&z.pl);
        assert!((l - 10.0).abs() < 1e-9);
        assert!((d - 50.0).abs() < 1e-9);
        assert!((b - 50.0).abs() < 1e-9);
        assert!((ur - 50.0).abs() < 1e-9);
    }
}
