//! Admission control and continuous cross-tenant batch forming.
//!
//! Every tenant gets its own [`BoundedQueue`] — the same ingress
//! structure the pipeline uses for sensor decimation, here bounding
//! *request* backlog per tenant (`DropNewest` sheds the incoming
//! request with a 429; `DropOldest` evicts the tenant's stalest queued
//! request, whose closed reply channel the connection handler also
//! answers with a 429).  Compute workers call [`CoreState::take_batch`]
//! whenever they free up: it picks the lane (use case) of the oldest
//! queued request and drains up to `max_batch` matching requests
//! round-robin across *all* tenants — that is the continuous-batching
//! join point.  Requests never wait for a timer; they wait only for a
//! worker, and whoever is queued when one frees up shares the flush.

use std::collections::BTreeMap;
use std::sync::mpsc::Sender;

use crate::coordinator::{BoundedQueue, OverflowPolicy};
use crate::model::UseCase;
use crate::util::json::Json;

use super::protocol::InferRequest;

/// What the compute side sends back for one admitted request.
#[derive(Debug)]
pub enum Reply {
    /// The run completed; `result` is the solo-identical payload and
    /// `batch_size` how many requests shared the flush.
    Done {
        /// Solo-identical result object (the bit-identity surface).
        result: Json,
        /// Requests that joined this flush, this one included.
        batch_size: usize,
    },
    /// The run failed inside the pipeline — answered with a 500.
    Failed(String),
}

/// One admitted request waiting for a compute worker.
#[derive(Debug)]
pub struct Pending {
    /// The validated request.
    pub req: InferRequest,
    /// Global admission order — the batch former serves the oldest
    /// lane first, so no tenant can starve another.
    pub seq: u64,
    /// Reply channel back to the connection handler.  Dropping it
    /// unanswered (a `DropOldest` eviction) surfaces as a disconnect,
    /// which the handler answers with a 429.
    pub reply: Sender<Reply>,
}

/// Outcome of [`CoreState::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Queued for the next flush.
    Admitted,
    /// The incoming request was shed (`DropNewest` on a full queue).
    Shed,
}

/// The shared scheduling state behind the server mutex: per-tenant
/// admission queues plus the counters that make the conservation
/// invariant (`admitted == completed + evicted` at drain) checkable.
#[derive(Debug)]
pub struct CoreState {
    /// Per-tenant bounded request queues, created on first submit.
    pub tenants: BTreeMap<String, BoundedQueue<Pending>>,
    /// Per-tenant queue capacity.
    pub tenant_cap: usize,
    /// Overflow policy every tenant queue is created with.
    pub overflow: OverflowPolicy,
    /// Admission sequence counter (also total admitted requests).
    pub seq: u64,
    /// Requests currently queued across all tenants.
    pub pending: usize,
    /// Requests handed to a worker and not yet replied.
    pub in_flight: usize,
}

impl CoreState {
    /// Empty state with the given per-tenant cap and overflow policy.
    pub fn new(tenant_cap: usize, overflow: OverflowPolicy) -> CoreState {
        CoreState {
            tenants: BTreeMap::new(),
            tenant_cap,
            overflow,
            seq: 0,
            pending: 0,
            in_flight: 0,
        }
    }

    /// Admit one request into its tenant's queue.  A `DropOldest`
    /// eviction keeps `pending` unchanged (one in, one out) — the
    /// evicted entry's reply channel closes as the queue drops it.
    pub fn submit(&mut self, req: InferRequest, reply: Sender<Reply>) -> Admission {
        let (cap, overflow) = (self.tenant_cap, self.overflow);
        let queue = self
            .tenants
            .entry(req.tenant.clone())
            .or_insert_with(|| BoundedQueue::new(cap, overflow));
        let was_full = queue.len() == queue.capacity;
        let pending = Pending { req, seq: self.seq, reply };
        if !queue.push(pending) {
            return Admission::Shed;
        }
        self.seq += 1;
        if !was_full {
            self.pending += 1;
        }
        Admission::Admitted
    }

    /// Requests shed before admission across all tenants (`DropNewest`)
    /// plus requests evicted after admission (`DropOldest`) — the
    /// queues account both on the same counter.
    pub fn dropped(&self) -> u64 {
        self.tenants.values().map(|q| q.dropped).sum()
    }

    /// Requests admitted across all tenants.
    pub fn admitted(&self) -> u64 {
        self.tenants.values().map(|q| q.accepted).sum()
    }

    /// The lane (use case) of the oldest queued request, if any.
    fn oldest_lane(&self) -> Option<UseCase> {
        self.tenants
            .values()
            .filter_map(|q| q.peek().map(|p| (p.seq, p.req.use_case)))
            .min_by_key(|(seq, _)| *seq)
            .map(|(_, uc)| uc)
    }

    /// Form one cross-tenant batch: up to `max_batch` queued requests
    /// whose tenant-queue heads match the oldest request's lane,
    /// drained round-robin across tenants (one per tenant per sweep)
    /// so a chatty tenant cannot monopolize a flush.  Returns an empty
    /// vec when nothing is queued.
    pub fn take_batch(&mut self, max_batch: usize) -> Vec<Pending> {
        let Some(lane) = self.oldest_lane() else {
            return Vec::new();
        };
        let mut batch = Vec::new();
        loop {
            let before = batch.len();
            for queue in self.tenants.values_mut() {
                if batch.len() >= max_batch {
                    break;
                }
                if queue.peek().is_some_and(|p| p.req.use_case == lane) {
                    let p = queue.pop().expect("peeked entry must pop");
                    batch.push(p);
                }
            }
            if batch.len() == before || batch.len() >= max_batch {
                break;
            }
        }
        self.pending -= batch.len();
        self.in_flight += batch.len();
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;
    use std::sync::mpsc::channel;

    fn req(tenant: &str, uc: UseCase) -> InferRequest {
        InferRequest {
            tenant: tenant.into(),
            use_case: uc,
            seed: 7,
            count: 1,
            policy: Policy::Static,
            deadline_ms: None,
        }
    }

    #[test]
    fn batch_joins_across_tenants_on_one_lane() {
        let mut st = CoreState::new(8, OverflowPolicy::DropNewest);
        let (tx, _rx) = channel();
        st.submit(req("a", UseCase::Vae), tx.clone());
        st.submit(req("b", UseCase::Vae), tx.clone());
        st.submit(req("c", UseCase::Mms), tx.clone());
        st.submit(req("a", UseCase::Vae), tx);
        let batch = st.take_batch(8);
        // oldest request is vae; both tenants' vae requests join, the
        // mms request waits for the next flush
        assert_eq!(batch.len(), 3);
        assert!(batch.iter().all(|p| p.req.use_case == UseCase::Vae));
        let tenants: Vec<&str> =
            batch.iter().map(|p| p.req.tenant.as_str()).collect();
        assert_eq!(tenants, ["a", "b", "a"], "round-robin, one per sweep");
        assert_eq!(st.pending, 1);
        assert_eq!(st.take_batch(8).len(), 1);
        assert_eq!(st.pending, 0);
        assert!(st.take_batch(8).is_empty());
    }

    #[test]
    fn max_batch_caps_the_flush() {
        let mut st = CoreState::new(32, OverflowPolicy::DropNewest);
        let (tx, _rx) = channel();
        for i in 0..12 {
            st.submit(req(&format!("t{i}"), UseCase::Esperta), tx.clone());
        }
        assert_eq!(st.take_batch(8).len(), 8);
        assert_eq!(st.take_batch(8).len(), 4);
    }

    #[test]
    fn drop_newest_sheds_incoming_request() {
        let mut st = CoreState::new(1, OverflowPolicy::DropNewest);
        let (tx, _rx) = channel();
        assert_eq!(st.submit(req("t", UseCase::Vae), tx.clone()), Admission::Admitted);
        assert_eq!(st.submit(req("t", UseCase::Vae), tx), Admission::Shed);
        assert_eq!(st.pending, 1);
        assert_eq!(st.dropped(), 1);
        assert_eq!(st.admitted(), 1);
    }

    #[test]
    fn drop_oldest_evicts_and_closes_the_reply_channel() {
        let mut st = CoreState::new(1, OverflowPolicy::DropOldest);
        let (tx1, rx1) = channel();
        let (tx2, _rx2) = channel();
        st.submit(req("t", UseCase::Vae), tx1);
        assert_eq!(st.submit(req("t", UseCase::Vae), tx2), Admission::Admitted);
        // the evicted request's channel is closed, unanswered
        assert!(rx1.recv().is_err(), "evicted sender must be dropped");
        assert_eq!(st.pending, 1, "one in, one out");
        assert_eq!(st.dropped(), 1);
        assert_eq!(st.admitted(), 2);
    }
}
