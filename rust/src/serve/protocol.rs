//! The serve wire protocol: request validation and the per-request
//! result payload.
//!
//! A request names a tenant, a use case, and the knobs of one solo
//! pipeline run (`seed`, `count`, `policy`, `deadline_ms`).  The
//! response's `result` object is derived from the [`PipelineReport`]
//! of exactly that run — [`solo_config`] builds the config and
//! [`result_json`] the payload, and both are public so the loopback
//! suite can recompute a served response offline and compare it byte
//! for byte (`util::json` prints `f64`s shortest-roundtrip, so float
//! bit-identity survives serialization).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

use crate::coordinator::{PipelineConfig, PipelineReport, Policy};
use crate::model::UseCase;
use crate::util::json::{num, obj, s, Json};

/// Hard cap on the per-request event count: a serve request is one
/// interactive inference burst, not a batch import.
pub const MAX_COUNT: usize = 64;

/// Hard cap on tenant-name length (bytes).
pub const MAX_TENANT: usize = 64;

/// A validated `/infer` request — everything needed to reproduce the
/// run solo: the response is a pure function of this struct.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Admission-control key: each tenant gets its own bounded queue.
    pub tenant: String,
    /// Which paper use case to run.
    pub use_case: UseCase,
    /// RNG seed for the run (sensors + surrogate decisions).
    pub seed: u64,
    /// Events in the run (1..=[`MAX_COUNT`]).
    pub count: usize,
    /// Dispatch policy for the run.
    pub policy: Policy,
    /// Per-tenant deadline override (ms); `None` = use-case default.
    pub deadline_ms: Option<u64>,
}

/// Parse and validate an `/infer` body.  Any error here is answered
/// with a 400 *before* the request touches the admission queue or a
/// compute worker.
pub fn parse_infer(body: &[u8]) -> Result<InferRequest> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let j = Json::parse(text)?;
    let fields = j.as_obj().context("request must be a JSON object")?;
    for key in fields.keys() {
        match key.as_str() {
            "tenant" | "use_case" | "seed" | "count" | "policy" | "deadline_ms" => {}
            other => bail!("unknown field {other:?}"),
        }
    }
    let tenant = j.req("tenant")?.as_str()?.to_string();
    if tenant.is_empty() || tenant.len() > MAX_TENANT {
        bail!("tenant must be 1..={MAX_TENANT} bytes");
    }
    let use_case = UseCase::parse(j.req("use_case")?.as_str()?)?;
    let seed = match j.get("seed") {
        Some(v) => {
            let raw = v.as_i64().context("seed must be an integer")?;
            u64::try_from(raw).ok().context("seed must be >= 0")?
        }
        None => 7,
    };
    let count = match j.get("count") {
        Some(v) => v.as_usize().context("count must be a positive integer")?,
        None => 1,
    };
    if count == 0 || count > MAX_COUNT {
        bail!("count must be 1..={MAX_COUNT}");
    }
    let policy = match j.get("policy") {
        Some(v) => Policy::parse(v.as_str()?)?,
        None => Policy::Static,
    };
    let deadline_ms = match j.get("deadline_ms") {
        Some(v) => {
            let ms = v.as_i64().context("deadline_ms must be an integer")?;
            if ms <= 0 {
                bail!("deadline_ms must be > 0");
            }
            Some(ms as u64)
        }
        None => None,
    };
    Ok(InferRequest { tenant, use_case, seed, count, policy, deadline_ms })
}

/// The solo pipeline config this request reproduces: defaults
/// everywhere the request has no say, so a served run and a
/// `Pipeline::new(solo_config(req), ..).run(None)` run are the same
/// run.
pub fn solo_config(req: &InferRequest) -> PipelineConfig {
    PipelineConfig {
        use_case: req.use_case,
        n_events: req.count,
        seed: req.seed,
        policy: req.policy,
        deadline_s: req.deadline_ms.map(|ms| ms as f64 / 1000.0),
        ..PipelineConfig::default()
    }
}

/// The per-request telemetry payload: chosen target(s), predicted vs
/// measured latency/energy, deadline status, and the decisions the run
/// produced — everything a tenant needs to price its own traffic.
/// Keys are `BTreeMap`-ordered, so serialization is canonical.
pub fn result_json(report: &PipelineReport) -> Json {
    let decisions = Json::Obj(
        report
            .decisions
            .iter()
            .map(|(k, v)| (k.clone(), num(*v as f64)))
            .collect::<BTreeMap<_, _>>(),
    );
    obj(vec![
        ("use_case", s(report.use_case.as_str())),
        ("model", s(&report.model)),
        ("policy", s(&report.policy)),
        ("target_mix", s(&report.target_mix_str())),
        ("events", num(report.events as f64)),
        ("sim_elapsed_s", num(report.sim_elapsed_s)),
        ("mean_latency_s", num(report.mean_latency_s)),
        ("p95_latency_s", num(report.p95_latency_s)),
        ("p99_latency_s", num(report.p99_latency_s)),
        ("energy_j", num(report.energy_j)),
        ("predicted_energy_j", num(report.predicted_energy_j)),
        ("deadline_misses", num(report.deadline_misses as f64)),
        ("deadline_ok", Json::Bool(report.deadline_misses == 0)),
        ("power_sheds", num(report.power_sheds as f64)),
        (
            "accuracy",
            match report.accuracy {
                Some(a) => num(a),
                None => Json::Null,
            },
        ),
        ("decisions", decisions),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_request_parses() {
        let r = parse_infer(
            br#"{"tenant":"ops","use_case":"vae","seed":3,"count":4,
                "policy":"min-latency","deadline_ms":250}"#,
        )
        .unwrap();
        assert_eq!(r.tenant, "ops");
        assert_eq!(r.use_case, UseCase::Vae);
        assert_eq!(r.seed, 3);
        assert_eq!(r.count, 4);
        assert_eq!(r.policy, Policy::MinLatency);
        assert_eq!(r.deadline_ms, Some(250));
        let cfg = solo_config(&r);
        assert_eq!(cfg.n_events, 4);
        assert_eq!(cfg.deadline_s, Some(0.25));
    }

    #[test]
    fn defaults_match_pipeline_defaults() {
        let r = parse_infer(br#"{"tenant":"t","use_case":"esperta"}"#).unwrap();
        let base = PipelineConfig::default();
        assert_eq!(r.seed, base.seed);
        assert_eq!(r.policy, base.policy);
        assert_eq!(r.count, 1);
        assert!(r.deadline_ms.is_none());
    }

    #[test]
    fn malformed_shapes_rejected() {
        for bad in [
            &b"not json"[..],
            br#"[1,2,3]"#,
            br#"{"use_case":"vae"}"#,
            br#"{"tenant":"","use_case":"vae"}"#,
            br#"{"tenant":"t","use_case":"radar"}"#,
            br#"{"tenant":"t","use_case":"vae","count":0}"#,
            br#"{"tenant":"t","use_case":"vae","count":1000}"#,
            br#"{"tenant":"t","use_case":"vae","seed":-1}"#,
            br#"{"tenant":"t","use_case":"vae","policy":"fastest"}"#,
            br#"{"tenant":"t","use_case":"vae","deadline_ms":0}"#,
            br#"{"tenant":"t","use_case":"vae","surprise":1}"#,
        ] {
            assert!(parse_infer(bad).is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn result_json_is_canonical_and_roundtrips() {
        use crate::board::Calibration;
        use crate::coordinator::Pipeline;
        use crate::model::catalog::Catalog;
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let req = parse_infer(br#"{"tenant":"t","use_case":"esperta","count":8}"#).unwrap();
        let mut p = Pipeline::new(solo_config(&req), &catalog, &calib).unwrap();
        let a = result_json(&p.run(None).unwrap());
        let mut q = Pipeline::new(solo_config(&req), &catalog, &calib).unwrap();
        let b = result_json(&q.run(None).unwrap());
        assert_eq!(a.to_string(), b.to_string(), "same request, same bytes");
        let back = Json::parse(&a.to_string()).unwrap();
        assert_eq!(back.to_string(), a.to_string());
    }
}
