//! Multi-tenant serving front-end: `spaceinfer serve`.
//!
//! A zero-dependency HTTP/JSON server (std::net `TcpListener`, a
//! thread-per-connection acceptor, and a small compute-worker pool in
//! the same no-crates style as the fleet layer's work-stealing pool)
//! that turns the closed-loop simulation into a request-driven
//! service.  Concurrent clients POST `/infer`; admitted requests land
//! in per-tenant [`crate::coordinator::BoundedQueue`]s and
//! **continuous cross-tenant batching** drains them: whenever a compute worker frees up it takes
//! every queued request sharing the oldest request's lane (use case),
//! round-robin across tenants, up to `max_batch` — requests join the
//! next flush in flight instead of each client round-tripping a
//! private batch.
//!
//! Determinism: each admitted request runs the full solo pipeline path
//! ([`crate::coordinator::Pipeline::run_request`] on a per-lane cached
//! pipeline — construction amortized across the batch, the run itself
//! a pure function of the request), so the `result` payload is
//! bit-identical to running the same request alone through
//! [`crate::coordinator::Pipeline`].  `tests/serve_loopback.rs` pins
//! exactly that.
//!
//! Shutdown: `POST /shutdown` (or [`ServeHandle::shutdown`]) stops
//! admission (new `/infer`s get a 503), drains every queued request,
//! answers every in-flight reply, and returns the final [`ServeStats`]
//! whose conservation invariant — admitted == completed + evicted —
//! must hold at drain.

mod core;
mod http;
mod protocol;

pub use self::core::{Admission, CoreState, Pending, Reply};
pub use self::http::{HttpRequest, ReadOutcome};
pub use self::protocol::{
    parse_infer, result_json, solo_config, InferRequest, MAX_COUNT, MAX_TENANT,
};

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::board::Calibration;
use crate::coordinator::{OverflowPolicy, Pipeline};
use crate::model::catalog::Catalog;
use crate::model::UseCase;
use crate::util::json::{num, obj, s, Json};

use self::http::{read_request, write_response};

/// Knobs for one server instance.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind host (loopback by default).
    pub host: String,
    /// Bind port; 0 picks an ephemeral port (tests, benches).
    pub port: u16,
    /// Compute workers draining the admission queues.
    pub workers: usize,
    /// Most requests one flush may join.
    pub max_batch: usize,
    /// Per-tenant admission-queue capacity.
    pub tenant_cap: usize,
    /// What a full tenant queue does to overflow.
    pub overflow: OverflowPolicy,
    /// Most concurrent connections before the acceptor answers 503.
    pub max_conns: usize,
    /// Test/bench knob: artificial wall-clock delay per flush (ms) so
    /// suites can hold a backlog open deterministically.  0 in
    /// production.
    pub service_delay_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let workers = thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .clamp(2, 8);
        ServeConfig {
            host: "127.0.0.1".into(),
            port: 0,
            workers,
            max_batch: 8,
            tenant_cap: 32,
            overflow: OverflowPolicy::DropNewest,
            max_conns: 256,
            service_delay_ms: 0,
        }
    }
}

/// Final (or live, via `GET /stats`) serving counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests admitted into a tenant queue.
    pub admitted: u64,
    /// Admitted requests answered with a result (or a pipeline error).
    pub completed: u64,
    /// Admitted requests evicted by `DropOldest` before compute.
    pub evicted: u64,
    /// Requests shed at admission by `DropNewest` (answered 429).
    pub shed: u64,
    /// Requests answered without admission: malformed 4xx, 503s during
    /// drain, and the shed 429s.
    pub rejected: u64,
    /// Requests still queued (0 after a drain).
    pub pending: u64,
    /// Requests handed to a worker, reply outstanding (0 after drain).
    pub in_flight: u64,
}

impl ServeStats {
    /// The accounting invariant a drained server must satisfy: every
    /// admitted request was either completed or evicted — a
    /// killed-mid-batch server may not lose accepted requests.
    pub fn conserved(&self) -> bool {
        self.admitted == self.completed + self.evicted + self.pending + self.in_flight
    }

    /// JSON form (the `GET /stats` payload).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("admitted", num(self.admitted as f64)),
            ("completed", num(self.completed as f64)),
            ("evicted", num(self.evicted as f64)),
            ("shed", num(self.shed as f64)),
            ("rejected", num(self.rejected as f64)),
            ("pending", num(self.pending as f64)),
            ("in_flight", num(self.in_flight as f64)),
            ("conserved", Json::Bool(self.conserved())),
        ])
    }

    /// One-line text form for the CLI's shutdown summary.
    pub fn render(&self) -> String {
        format!(
            "serve: admitted {}  completed {}  evicted {}  shed {}  \
             rejected {}  conserved {}",
            self.admitted,
            self.completed,
            self.evicted,
            self.shed,
            self.rejected,
            self.conserved()
        )
    }
}

/// Shared server state: everything the acceptor, connection handlers,
/// compute workers, and [`ServeHandle`] touch.
struct Control {
    cfg: ServeConfig,
    state: Mutex<CoreState>,
    work: Condvar,
    shutdown: AtomicBool,
    addr: SocketAddr,
    completed: AtomicU64,
    rejected: AtomicU64,
    service_ns: AtomicU64,
    conns: AtomicUsize,
}

impl Control {
    fn stats(&self) -> ServeStats {
        let state = self.state.lock().expect("serve state poisoned");
        let dropped = state.dropped();
        let (evicted, shed) = match self.cfg.overflow {
            OverflowPolicy::DropOldest => (dropped, 0),
            OverflowPolicy::DropNewest => (0, dropped),
        };
        ServeStats {
            admitted: state.admitted(),
            completed: self.completed.load(Ordering::SeqCst),
            evicted,
            shed,
            rejected: self.rejected.load(Ordering::SeqCst),
            pending: state.pending as u64,
            in_flight: state.in_flight as u64,
        }
    }

    /// Backlog-derived retry hint (s): queue depth over the measured
    /// drain rate (completed requests per second of worker time),
    /// never below 1 s.
    fn retry_after_s(&self, pending: usize) -> u64 {
        let completed = self.completed.load(Ordering::SeqCst).max(1);
        let per_req_s =
            self.service_ns.load(Ordering::SeqCst) as f64 / 1e9 / completed as f64;
        let per_req_s = if per_req_s > 0.0 { per_req_s } else { 1e-3 };
        let workers = self.cfg.workers.max(1) as f64;
        ((pending as f64 + 1.0) * per_req_s / workers).ceil().max(1.0) as u64
    }

    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.work.notify_all();
        // unblock the acceptor with a throwaway connection
        let _ = TcpStream::connect(self.addr);
    }
}

/// Remote control for a running [`Server`]: trigger the same graceful
/// drain `POST /shutdown` does, from the embedding thread.
#[derive(Clone)]
pub struct ServeHandle {
    control: Arc<Control>,
}

impl ServeHandle {
    /// Stop admission, drain queued + in-flight requests, and make
    /// [`Server::run`] return.
    pub fn shutdown(&self) {
        self.control.begin_shutdown();
    }

    /// Live counters (same numbers as `GET /stats`).
    pub fn stats(&self) -> ServeStats {
        self.control.stats()
    }
}

/// A bound, not-yet-running server.  `bind` then `run`; `run` blocks
/// until a shutdown request drains the server, so tests and benches
/// run it on a scoped thread and drive it through [`ServeHandle`].
pub struct Server<'a> {
    listener: TcpListener,
    control: Arc<Control>,
    catalog: &'a Catalog,
    calib: &'a Calibration,
}

impl<'a> Server<'a> {
    /// Bind the listen socket and allocate shared state.
    pub fn bind(
        cfg: ServeConfig,
        catalog: &'a Catalog,
        calib: &'a Calibration,
    ) -> Result<Server<'a>> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let state = CoreState::new(cfg.tenant_cap, cfg.overflow);
        let control = Arc::new(Control {
            cfg,
            state: Mutex::new(state),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
            addr,
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            service_ns: AtomicU64::new(0),
            conns: AtomicUsize::new(0),
        });
        Ok(Server { listener, control, catalog, calib })
    }

    /// The bound address (the ephemeral port when `cfg.port == 0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.control.addr
    }

    /// A handle for shutting the server down from another thread.
    pub fn handle(&self) -> ServeHandle {
        ServeHandle { control: Arc::clone(&self.control) }
    }

    /// Serve until shutdown, then drain and return the final counters.
    /// The returned stats of a clean drain always satisfy
    /// [`ServeStats::conserved`] with `pending == in_flight == 0`.
    pub fn run(self) -> Result<ServeStats> {
        let control = &self.control;
        let catalog = self.catalog;
        let calib = self.calib;
        thread::scope(|scope| {
            for _ in 0..control.cfg.workers {
                let control = Arc::clone(control);
                scope.spawn(move || worker_loop(&control, catalog, calib));
            }
            loop {
                let stream = match self.listener.accept() {
                    Ok((stream, _)) => stream,
                    Err(_) if control.shutdown.load(Ordering::SeqCst) => break,
                    Err(_) => continue,
                };
                if control.shutdown.load(Ordering::SeqCst) {
                    break; // the wakeup connection itself
                }
                if control.conns.load(Ordering::SeqCst) >= control.cfg.max_conns {
                    let mut stream = stream;
                    let _ = write_response(
                        &mut stream,
                        503,
                        &[],
                        &err_body("connection limit reached"),
                        true,
                    );
                    control.rejected.fetch_add(1, Ordering::SeqCst);
                    continue;
                }
                control.conns.fetch_add(1, Ordering::SeqCst);
                let control = Arc::clone(control);
                scope.spawn(move || {
                    handle_connection(stream, &control);
                    control.conns.fetch_sub(1, Ordering::SeqCst);
                });
            }
            // belt and braces: make sure every worker sees the flag
            control.work.notify_all();
        });
        Ok(control.stats())
    }
}

/// Compute-worker loop: wait for pending requests, take a cross-tenant
/// batch, run each request through its lane's cached pipeline, reply.
/// Exits only once shutdown is flagged *and* the queues are drained.
fn worker_loop(control: &Control, catalog: &Catalog, calib: &Calibration) {
    // per-lane pipeline templates: construction (routing, registry,
    // simulators) amortized across every request sharing the lane
    let mut lanes: BTreeMap<LaneKey, Pipeline> = BTreeMap::new();
    loop {
        let batch = {
            let mut state = control.state.lock().expect("serve state poisoned");
            loop {
                if state.pending > 0 {
                    break state.take_batch(control.cfg.max_batch);
                }
                if control.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                state = control.work.wait(state).expect("serve state poisoned");
            }
        };
        if batch.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        if control.cfg.service_delay_ms > 0 {
            thread::sleep(Duration::from_millis(control.cfg.service_delay_ms));
        }
        let n = batch.len();
        for p in batch {
            let reply = run_one(&mut lanes, &p.req, catalog, calib, n);
            // a vanished receiver (client hung up) is not an error
            let _ = p.reply.send(reply);
            control.completed.fetch_add(1, Ordering::SeqCst);
        }
        control
            .service_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::SeqCst);
        let mut state = control.state.lock().expect("serve state poisoned");
        state.in_flight -= n;
    }
}

/// Pipelines are cached per lane: everything [`solo_config`] derives
/// from a request *except* the per-run knobs `run_request` rebinds.
type LaneKey = (UseCase, &'static str, Option<u64>);

/// Most cached lane pipelines per worker before the cache resets.
const MAX_LANES: usize = 64;

fn run_one(
    lanes: &mut BTreeMap<LaneKey, Pipeline>,
    req: &InferRequest,
    catalog: &Catalog,
    calib: &Calibration,
    batch_size: usize,
) -> Reply {
    let key: LaneKey = (req.use_case, req.policy.as_str(), req.deadline_ms);
    if !lanes.contains_key(&key) {
        if lanes.len() >= MAX_LANES {
            lanes.clear();
        }
        match Pipeline::new(solo_config(req), catalog, calib) {
            Ok(p) => {
                lanes.insert(key, p);
            }
            Err(e) => return Reply::Failed(format!("{e:#}")),
        }
    }
    let pipeline = lanes.get_mut(&key).expect("lane just inserted");
    match pipeline.run_request(req.seed, req.count) {
        Ok(report) => Reply::Done { result: result_json(&report), batch_size },
        Err(e) => Reply::Failed(format!("{e:#}")),
    }
}

fn err_body(msg: &str) -> String {
    obj(vec![("error", s(msg))]).to_string()
}

/// One keep-alive connection: read requests until EOF, error, or
/// shutdown; route each to a handler.  Read timeouts let an idle
/// connection observe the shutdown flag instead of pinning the scope
/// join forever.
fn handle_connection(mut stream: TcpStream, control: &Control) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let read_side = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(read_side);
    loop {
        match read_request(&mut reader) {
            Ok(ReadOutcome::Idle) => {
                if control.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Ok(ReadOutcome::Closed) => return,
            Ok(ReadOutcome::Request(req)) => {
                let draining = control.shutdown.load(Ordering::SeqCst);
                let keep = route(&mut stream, control, &req, draining);
                if !keep || draining {
                    return;
                }
            }
            Err(e) => {
                control.rejected.fetch_add(1, Ordering::SeqCst);
                let _ =
                    write_response(&mut stream, 400, &[], &err_body(&format!("{e:#}")), true);
                return;
            }
        }
    }
}

/// Dispatch one request to its endpoint.  Returns false when the
/// connection should close after the response.
fn route(stream: &mut TcpStream, control: &Control, req: &HttpRequest, close: bool) -> bool {
    let respond = |stream: &mut TcpStream, status: u16, extra: &[(&str, String)], body: &str| {
        write_response(stream, status, extra, body, close).is_ok() && !close
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            respond(stream, 200, &[], &obj(vec![("ok", Json::Bool(true))]).to_string())
        }
        ("GET", "/stats") => {
            respond(stream, 200, &[], &control.stats().to_json().to_string())
        }
        ("POST", "/shutdown") => {
            control.begin_shutdown();
            let _ = write_response(
                stream,
                200,
                &[],
                &obj(vec![("draining", Json::Bool(true))]).to_string(),
                true,
            );
            false
        }
        ("POST", "/infer") => infer(stream, control, req, close),
        (_, "/infer" | "/shutdown" | "/healthz" | "/stats") => {
            control.rejected.fetch_add(1, Ordering::SeqCst);
            respond(stream, 405, &[], &err_body("method not allowed"))
        }
        _ => {
            control.rejected.fetch_add(1, Ordering::SeqCst);
            respond(stream, 404, &[], &err_body("no such endpoint"))
        }
    }
}

/// The `/infer` endpoint: validate (400 before any compute), admit
/// (429/503 before any compute), then block on the reply channel the
/// compute worker answers.
fn infer(stream: &mut TcpStream, control: &Control, http: &HttpRequest, close: bool) -> bool {
    let respond = |stream: &mut TcpStream, status: u16, extra: &[(&str, String)], body: &str| {
        write_response(stream, status, extra, body, close).is_ok() && !close
    };
    let req = match parse_infer(&http.body) {
        Ok(r) => r,
        Err(e) => {
            control.rejected.fetch_add(1, Ordering::SeqCst);
            return respond(stream, 400, &[], &err_body(&format!("{e:#}")));
        }
    };
    let tenant = req.tenant.clone();
    let (tx, rx) = channel();
    let admission = {
        let mut state = control.state.lock().expect("serve state poisoned");
        if control.shutdown.load(Ordering::SeqCst) {
            None // draining: no new admissions
        } else {
            let a = state.submit(req, tx);
            if a == Admission::Admitted {
                control.work.notify_one();
            }
            Some((a, state.pending))
        }
    };
    match admission {
        None => {
            control.rejected.fetch_add(1, Ordering::SeqCst);
            respond(stream, 503, &[], &err_body("draining"))
        }
        Some((Admission::Shed, pending)) => {
            control.rejected.fetch_add(1, Ordering::SeqCst);
            let retry = control.retry_after_s(pending);
            respond(
                stream,
                429,
                &[("Retry-After", retry.to_string())],
                &obj(vec![
                    ("error", s("tenant backlog full")),
                    ("tenant", s(&tenant)),
                    ("retry_after_s", num(retry as f64)),
                ])
                .to_string(),
            )
        }
        Some((Admission::Admitted, _)) => match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Reply::Done { result, batch_size }) => {
                let body = obj(vec![
                    ("result", result),
                    (
                        "serve",
                        obj(vec![
                            ("tenant", s(&tenant)),
                            ("batch_size", num(batch_size as f64)),
                        ]),
                    ),
                ])
                .to_string();
                respond(stream, 200, &[], &body)
            }
            Ok(Reply::Failed(msg)) => respond(stream, 500, &[], &err_body(&msg)),
            Err(RecvTimeoutError::Disconnected) => {
                // the tenant queue evicted this request (DropOldest)
                let pending = control.state.lock().expect("serve state poisoned").pending;
                let retry = control.retry_after_s(pending);
                respond(
                    stream,
                    429,
                    &[("Retry-After", retry.to_string())],
                    &obj(vec![
                        ("error", s("evicted by newer request")),
                        ("tenant", s(&tenant)),
                        ("retry_after_s", num(retry as f64)),
                    ])
                    .to_string(),
                )
            }
            Err(RecvTimeoutError::Timeout) => {
                respond(stream, 500, &[], &err_body("compute worker timed out"))
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.workers >= 2);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.overflow, OverflowPolicy::DropNewest);
        assert_eq!(cfg.service_delay_ms, 0);
    }

    #[test]
    fn conservation_arithmetic() {
        let ok = ServeStats {
            admitted: 10,
            completed: 8,
            evicted: 2,
            shed: 3,
            rejected: 5,
            pending: 0,
            in_flight: 0,
        };
        assert!(ok.conserved());
        let lost = ServeStats { completed: 7, ..ok };
        assert!(!lost.conserved());
        assert!(ok.to_json().to_string().contains("\"conserved\":true"));
    }

    #[test]
    fn bind_and_drain_without_traffic() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let server =
            Server::bind(ServeConfig { workers: 2, ..Default::default() }, &catalog, &calib)
                .unwrap();
        let handle = server.handle();
        let stats = thread::scope(|s| {
            let run = s.spawn(|| server.run().unwrap());
            handle.shutdown();
            run.join().unwrap()
        });
        assert_eq!(stats.admitted, 0);
        assert!(stats.conserved());
    }
}
