//! Minimal HTTP/1.1 plumbing for the serving layer — request parsing
//! and response writing over a `TcpStream`, nothing more.
//!
//! Zero-dependency by design (the offline registry has no hyper/axum):
//! the server speaks exactly the subset the serve protocol needs —
//! `GET`/`POST`, `Content-Length` bodies, keep-alive — and rejects the
//! rest with a 4xx before any compute happens.  Read timeouts are set
//! by the connection handler so an idle keep-alive poll can observe
//! the shutdown flag between requests.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::TcpStream;

/// Upper bound on a request body (bytes) — serve requests are small
/// JSON objects; anything bigger is a client bug.
pub const MAX_BODY: usize = 1 << 20;
/// Upper bound on header count per request.
pub const MAX_HEADERS: usize = 64;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method (`GET`, `POST`, ...).
    pub method: String,
    /// Request path (`/infer`, `/healthz`, ...).
    pub path: String,
    /// Headers, names lowercased.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (empty without a `Content-Length`).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header with the given lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// What one read attempt on a keep-alive connection yielded.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete request arrived.
    Request(HttpRequest),
    /// Clean EOF before any request bytes — the client closed the
    /// keep-alive connection.
    Closed,
    /// The read timed out before any request bytes arrived — the
    /// caller may check the shutdown flag and poll again.
    Idle,
}

/// Read one request from a keep-alive connection.  Returns
/// [`ReadOutcome::Idle`] on a clean between-requests timeout (so the
/// handler can poll the shutdown flag) and errors on malformed or
/// oversized requests — the handler answers those with a 4xx.
pub fn read_request(reader: &mut BufReader<TcpStream>) -> Result<ReadOutcome> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Ok(ReadOutcome::Closed),
        Ok(_) => {}
        Err(e)
            if line.is_empty()
                && matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) =>
        {
            return Ok(ReadOutcome::Idle);
        }
        Err(e) => return Err(e).context("reading request line"),
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        bail!("malformed request line {line:?}");
    }
    let mut headers = Vec::new();
    loop {
        if headers.len() > MAX_HEADERS {
            bail!("too many headers");
        }
        let mut h = String::new();
        reader.read_line(&mut h).context("reading header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (name, value) = h.split_once(':').context("malformed header")?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let content_length = headers
        .iter()
        .find(|(n, _)| n == "content-length")
        .map(|(_, v)| v.parse::<usize>().context("bad Content-Length"))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY {
        bail!("body of {content_length} bytes exceeds the {MAX_BODY} byte cap");
    }
    if headers.iter().any(|(n, v)| n == "transfer-encoding" && v != "identity") {
        bail!("chunked transfer encoding is not supported");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading body")?;
    Ok(ReadOutcome::Request(HttpRequest { method, path, headers, body }))
}

/// Standard reason phrase for the status codes the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one `application/json` response.  `extra` carries per-response
/// headers (`Retry-After`, ...); `close` requests connection teardown.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    extra: &[(&str, String)],
    body: &str,
    close: bool,
) -> Result<()> {
    let mut out = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        status,
        reason(status),
        body.len()
    );
    for (name, value) in extra {
        out.push_str(&format!("{name}: {value}\r\n"));
    }
    out.push_str(if close { "Connection: close\r\n" } else { "Connection: keep-alive\r\n" });
    out.push_str("\r\n");
    out.push_str(body);
    stream.write_all(out.as_bytes()).context("writing response")?;
    stream.flush().context("flushing response")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip(raw: &[u8]) -> Result<ReadOutcome> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        client.write_all(raw).unwrap();
        drop(client);
        let (server_side, _) = listener.accept().unwrap();
        read_request(&mut BufReader::new(server_side))
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /infer HTTP/1.1\r\nContent-Length: 4\r\nX-Tenant: a\r\n\r\nbody";
        match roundtrip(raw).unwrap() {
            ReadOutcome::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/infer");
                assert_eq!(r.header("x-tenant"), Some("a"));
                assert_eq!(r.body, b"body");
            }
            other => panic!("expected a request, got {other:?}"),
        }
    }

    #[test]
    fn eof_reads_as_closed() {
        assert!(matches!(roundtrip(b"").unwrap(), ReadOutcome::Closed));
    }

    #[test]
    fn garbage_request_line_rejected() {
        assert!(roundtrip(b"not http at all\r\n\r\n").is_err());
    }

    #[test]
    fn oversized_body_rejected() {
        let raw = format!("POST /infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(roundtrip(raw.as_bytes()).is_err());
    }
}
