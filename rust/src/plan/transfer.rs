//! Host↔accelerator boundary-transfer cost model.
//!
//! Every boundary between two segments of a hybrid execution plan moves
//! the producing layer's output activation across the PS↔PL boundary:
//! the finishing target DMA-writes it to DDR and the next target reads
//! it back.  The cost is modeled over the same calibrated AXI/DDR path
//! the naive HLS designs pay for spilled weights ([`AxiMaster`] /
//! `board::Zcu104::ddr_word_cycles`), except that a segment handoff is
//! a streaming DMA, so burst inference amortizes the per-word DDR
//! round-trip — this is why the Vitis-AI CPU fallback is viable at all,
//! and why the partitioner still charges a real, nonzero toll per
//! boundary per inference.

use crate::board::Zcu104;
use crate::hls::AxiMaster;

/// Burst length a segment-handoff DMA achieves on the AXI HP ports
/// (streaming transfer, unlike the naive word-by-word weight fetch).
pub const HANDOFF_BURST_LEN: u64 = 16;

/// Calibrated boundary-transfer model for one board.
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    axi: AxiMaster,
    clock_hz: f64,
}

impl TransferModel {
    /// Build from the board description: DDR word latency from
    /// `ddr_word_cycles`, amortized over [`HANDOFF_BURST_LEN`]-beat
    /// bursts, clocked at the PL (HLS) clock the DMA shares.
    pub fn new(board: &Zcu104) -> TransferModel {
        TransferModel {
            axi: AxiMaster::bursting(board.ddr_word_cycles, HANDOFF_BURST_LEN),
            clock_hz: board.hls_clock_hz,
        }
    }

    /// Seconds to hand `bytes` of boundary activation from one segment
    /// to the next, per inference: a DDR write by the producer plus a
    /// DDR read by the consumer.  Exactly zero for an empty boundary
    /// (and therefore for every single-segment plan).
    pub fn boundary_s(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        2.0 * self.axi.fetch_cycles(bytes) / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TransferModel {
        TransferModel::new(&Zcu104::default())
    }

    #[test]
    fn zero_bytes_cost_exactly_zero() {
        assert_eq!(model().boundary_s(0).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn cost_is_positive_and_monotone() {
        let t = model();
        let small = t.boundary_s(1024);
        let big = t.boundary_s(1024 * 1024);
        assert!(small > 0.0);
        assert!(big > small);
    }

    #[test]
    fn bursting_beats_the_naive_weight_path() {
        // the handoff DMA must be far cheaper than word-by-word fetch
        let board = Zcu104::default();
        let naive = AxiMaster::naive(board.ddr_word_cycles);
        let t = model();
        let bytes = 64 * 1024;
        let naive_s = 2.0 * naive.fetch_cycles(bytes) / board.hls_clock_hz;
        assert!(t.boundary_s(bytes) < naive_s / 4.0);
    }

    #[test]
    fn typical_boundary_is_sub_millisecond() {
        // a 64 KiB fp32 activation (the synthetic VAE conv output) must
        // not dominate a ~1 ms DPU invoke — sanity for hybrid viability
        assert!(model().boundary_s(64 * 1024) < 1e-3);
    }
}
