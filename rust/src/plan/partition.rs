//! The subgraph partitioner: manifest + per-layer operator support →
//! candidate [`ExecutionPlan`]s.
//!
//! For every candidate lane (each registered backend target, plus a
//! derived DPU lane when the model has no whole-model DPU deployment)
//! the partitioner computes the per-layer support mask via
//! [`AccelModel::supports_layer`], groups the layer list into **maximal
//! contiguous runs** of supported layers on the preferred lane, and
//! assigns each unsupported run to the fastest registry lane that
//! covers all of its layers.  Segment operating points come from the
//! *existing simulators evaluated on sub-manifests*
//! ([`AccelModel::segment_cost`] on a borrowed
//! [`crate::model::ManifestView`] range, materialized only for proper
//! sub-ranges and memoized per `(lane, range)` — see [`BuildStats`]);
//! boundary transfers are priced by [`TransferModel`] from the
//! producing layer's output bytes.
//!
//! Degenerate invariant: a lane that supports the whole model yields a
//! **single-segment plan carrying the registry target's exact
//! whole-model operating point** (no re-simulation, an exactly-zero
//! transfer term), so plan-level dispatch over such plans is
//! bit-identical to the whole-model dispatcher — the golden suite's
//! guarantee.

use std::borrow::Cow;
use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::transfer::TransferModel;
use crate::backend::{AccelModel, DpuTarget, SegmentCost, Slot, TargetRegistry, TargetSet};
use crate::board::{Calibration, Zcu104};
use crate::dpu::DpuSize;
use crate::model::catalog::Catalog;
use crate::model::{Layer, Manifest, Precision};

/// Name of the derived (plan-only) DPU lane.  It reuses the B4096
/// registry spelling — unambiguous because the lane exists only when no
/// registry DPU target does.
pub const DERIVED_DPU_NAME: &str = "dpu";

/// Where a segment executes: a registered backend target, or a
/// plan-only derived lane (the PTQ-quantized DPU view of a model with
/// no deployed int8 variant — what the Vitis-AI compiler would emit for
/// the supported subgraph).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Index into the dispatcher's [`TargetRegistry`].
    Registry(usize),
    /// Index into the planner's derived-lane table.
    Derived(usize),
}

/// One contiguous run of layers bound to one execution lane, priced by
/// that lane's simulator on the run's sub-manifest.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Lane the segment executes on.
    pub lane: Lane,
    /// Lane name for reports / telemetry (`target_mix` keys).
    pub target: String,
    /// First layer index of the segment (inclusive).
    pub start: usize,
    /// One past the last layer index (exclusive).
    pub end: usize,
    /// Fixed per-batch submission overhead on this lane (s).
    pub setup_s: f64,
    /// Marginal time per inference for this segment (s).
    pub per_item_s: f64,
    /// Active MPSoC draw while the segment runs (W).
    pub power_w: f64,
    /// Boundary activation bytes handed to the next segment (0 for the
    /// final segment).
    pub out_bytes: u64,
    /// Per-inference host↔accelerator transfer time after this segment
    /// (s); exactly 0 for the final segment.
    pub transfer_out_s: f64,
}

impl Segment {
    /// Number of layers the segment covers.
    pub fn layer_count(&self) -> usize {
        self.end - self.start
    }
}

/// An ordered execution plan: segments that exactly partition the
/// model's layer list, plus the per-boundary transfer toll.  A
/// single-segment plan is a whole-model deployment; a multi-segment
/// plan is the paper's Vitis-AI-style hybrid (DPU subgraphs + fallback
/// for the operators the DPU lacks).
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Model the plan partitions.
    pub model: String,
    /// Name of the preferred lane the plan was grown around.
    pub preferred: String,
    /// Ordered segments; `segments[k].end == segments[k+1].start`.
    pub segments: Vec<Segment>,
    /// Total per-inference boundary transfer time (s); exactly 0 for
    /// single-segment plans.
    pub transfer_per_item_s: f64,
    /// Total boundary activation bytes crossing per inference.
    pub transfer_bytes: u64,
    /// Layer count of the partitioned manifest (for invariant checks).
    pub n_layers: usize,
}

impl ExecutionPlan {
    /// More than one segment — a genuine hybrid deployment.
    pub fn is_hybrid(&self) -> bool {
        self.segments.len() > 1
    }

    /// Predicted busy latency for a batch of `n` (s): every segment's
    /// setup paid once, per-item compute and boundary transfers paid per
    /// inference.  For a single-segment plan this reduces bit-exactly to
    /// [`AccelModel::batch_latency_s`] of the underlying target.
    pub fn batch_latency_s(&self, n: u64) -> f64 {
        let setup: f64 = self.segments.iter().map(|s| s.setup_s).sum();
        let per: f64 = self.segments.iter().map(|s| s.per_item_s).sum();
        setup + n as f64 * (per + self.transfer_per_item_s)
    }

    /// Predicted busy energy for a batch of `n` (J): each segment's
    /// active power over its own busy time.  Boundary transfers add
    /// latency, not energy (the DMA draw is inside the PS-poll floor
    /// every active-power figure already includes).
    pub fn batch_energy_j(&self, n: u64) -> f64 {
        self.segments
            .iter()
            .map(|s| s.power_w * (s.setup_s + n as f64 * s.per_item_s))
            .sum()
    }

    /// Peak active draw over the plan (W) — segments run sequentially,
    /// so this is what a mission power budget must clear.
    pub fn peak_power_w(&self) -> f64 {
        self.segments.iter().map(|s| s.power_w).fold(0.0, f64::max)
    }

    /// Human-readable partition, e.g. `cpu[0..2) -> dpu[2..5)`.
    pub fn describe(&self) -> String {
        self.segments
            .iter()
            .map(|s| format!("{}[{}..{})", s.target, s.start, s.end))
            .collect::<Vec<_>>()
            .join(" -> ")
    }
}

/// A plan-only lane: a target the registry could not register for the
/// whole model but whose subgraphs the planner can still place.
#[derive(Debug, Clone)]
struct DerivedLane {
    name: String,
}

/// Instrumentation of one planner build — what the segment-cost memo
/// and the borrowed [`crate::model::ManifestView`] ranges actually
/// bought.  Exposed so tests can pin the zero-clone invariant.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BuildStats {
    /// Simulator evaluations of a `(lane, layer range)` pair (memo
    /// misses).  Each distinct pair is priced at most once per build:
    /// the fallback search and plan growth share the table, so
    /// partition search is incremental rather than re-pricing.
    pub ranges_priced: usize,
    /// Owned sub-manifest materializations ([`Manifest::slice`]
    /// clones).  Exactly 0 when every priced range is whole-model —
    /// single-segment plans carry bound operating points or borrowed
    /// full-range views.
    pub manifests_sliced: usize,
}

/// Builds and holds the candidate plan set for one model: one plan per
/// lane that supports at least one layer (single-segment when the lane
/// covers the whole model, hybrid otherwise).  Immutable once built —
/// the dispatcher scores `plans()` per batch exactly as it scores
/// registry targets.
#[derive(Debug)]
pub struct Planner {
    model: String,
    registry_len: usize,
    derived: Vec<DerivedLane>,
    plans: Vec<ExecutionPlan>,
    primary_plan: Option<usize>,
    stats: BuildStats,
}

impl Planner {
    /// Partition `model` against every lane.  `set` is honored when
    /// deriving plan-only lanes (an explicit `--targets` list without
    /// `dpu` must not grow one).
    pub fn build(
        model: &str,
        catalog: &Catalog,
        calib: &Calibration,
        registry: &TargetRegistry,
        set: &TargetSet,
    ) -> Result<Planner> {
        let fp32 = catalog.manifest(model, Precision::Fp32)?;
        if fp32.layers.is_empty() {
            bail!("model {model:?} has no layers to partition");
        }
        let int8 = catalog.manifest(model, Precision::Int8).ok();
        let mut derived = Vec::new();
        let has_registry_dpu = registry.targets().iter().any(|t| t.slot() == Slot::Dpu);
        let any_mappable = fp32.layers.iter().any(Layer::dpu_mappable);
        if !has_registry_dpu && any_mappable && set.admits(DERIVED_DPU_NAME, true) {
            derived.push(DerivedLane { name: DERIVED_DPU_NAME.to_string() });
        }
        let board = Zcu104::default();
        let mut builder = PlanBuilder {
            registry,
            calib,
            transfer: TransferModel::new(&board),
            board,
            fp32,
            int8,
            derived: &derived,
            cost_memo: BTreeMap::new(),
            fallback_memo: BTreeMap::new(),
            stats: BuildStats::default(),
        };
        let lanes: Vec<Lane> = (0..registry.len())
            .map(Lane::Registry)
            .chain((0..derived.len()).map(Lane::Derived))
            .collect();
        let mut plans = Vec::new();
        let mut primary_plan = None;
        for lane in lanes {
            let mask: Vec<bool> =
                fp32.layers.iter().map(|l| builder.lane_supports(lane, l)).collect();
            if !mask.iter().any(|&m| m) {
                continue; // this lane runs nothing of the model
            }
            let Some(plan) = builder.build_plan(lane, &mask)? else {
                continue; // an unsupported run had no fallback lane
            };
            if plan.segments.len() == 1 {
                if let Lane::Registry(i) = lane {
                    if registry.primary_index() == Some(i) {
                        primary_plan = Some(plans.len());
                    }
                }
            }
            plans.push(plan);
        }
        if plans.is_empty() {
            bail!("no executable plan for model {model:?}");
        }
        let stats = builder.stats;
        Ok(Planner {
            model: model.to_string(),
            registry_len: registry.len(),
            derived,
            plans,
            primary_plan,
            stats,
        })
    }

    /// Instrumentation of this build: simulator evaluations and
    /// sub-manifest clones the partition search actually performed.
    pub fn build_stats(&self) -> BuildStats {
        self.stats
    }

    /// Model the plans partition.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// The candidate plan set, lane order (registry lanes first).
    pub fn plans(&self) -> &[ExecutionPlan] {
        &self.plans
    }

    /// Index into [`Planner::plans`] of the single-segment plan on the
    /// registry's primary (deployment-matrix) target, when one exists —
    /// what the static policy picks.
    pub fn primary_plan(&self) -> Option<usize> {
        self.primary_plan
    }

    /// Total timeline lanes: every registry target plus every derived
    /// lane (flat-indexed in that order).
    pub fn lane_count(&self) -> usize {
        self.registry_len + self.derived.len()
    }

    /// Flatten a [`Lane`] to its timeline index: registry lanes keep
    /// their registry index, derived lanes follow.
    pub fn flat(&self, lane: Lane) -> usize {
        match lane {
            Lane::Registry(i) => i,
            Lane::Derived(d) => self.registry_len + d,
        }
    }

    /// Names of the derived (plan-only) lanes, flat order.
    pub fn derived_lane_names(&self) -> impl Iterator<Item = &str> {
        self.derived.iter().map(|d| d.name.as_str())
    }
}

/// Everything the partitioning pass needs, borrowed for the build,
/// plus the segment-cost tables the search fills incrementally: every
/// `(lane, layer range)` pair is priced at most once per build and
/// every fallback search is resolved at most once per range, shared
/// across all preferred lanes' plans.
struct PlanBuilder<'a> {
    registry: &'a TargetRegistry,
    calib: &'a Calibration,
    transfer: TransferModel,
    board: Zcu104,
    fp32: &'a Manifest,
    int8: Option<&'a Manifest>,
    derived: &'a [DerivedLane],
    /// `(flat lane, start, end)` -> `(setup_s, per_item_s, power_w)`.
    cost_memo: BTreeMap<(usize, usize, usize), (f64, f64, f64)>,
    /// `(start, end)` -> resolved fallback lane (or none).
    fallback_memo: BTreeMap<(usize, usize), Option<Lane>>,
    stats: BuildStats,
}

impl<'a> PlanBuilder<'a> {
    fn lane_name(&self, lane: Lane) -> String {
        match lane {
            Lane::Registry(i) => self.registry.get(i).name().to_string(),
            Lane::Derived(d) => self.derived[d].name.clone(),
        }
    }

    fn lane_supports(&self, lane: Lane, layer: &Layer) -> bool {
        match lane {
            Lane::Registry(i) => self.registry.get(i).supports_layer(layer).is_ok(),
            Lane::Derived(_) => layer.dpu_mappable(),
        }
    }

    /// Memo key for a lane: registry index, derived lanes after.
    fn flat_key(&self, lane: Lane) -> usize {
        match lane {
            Lane::Registry(i) => i,
            Lane::Derived(d) => self.registry.len() + d,
        }
    }

    /// Fp32 manifest for `layers[start..end)` — borrowed for the full
    /// range, a counted [`Manifest::slice`] clone otherwise.
    fn fp32_range(&mut self, start: usize, end: usize) -> Cow<'a, Manifest> {
        let cow = self.fp32.view(start, end).materialize();
        if matches!(cow, Cow::Owned(_)) {
            self.stats.manifests_sliced += 1;
        }
        cow
    }

    /// Int8 manifest for a DPU segment: the deployed int8 variant's
    /// range when one exists, otherwise the PTQ byte-footprint view of
    /// the fp32 range (what quantizing the subgraph would yield; the
    /// PTQ conversion clone is inherent and not counted as a slice).
    fn int8_range(&mut self, start: usize, end: usize) -> Cow<'a, Manifest> {
        match self.int8 {
            Some(m) => {
                let cow = m.view(start, end).materialize();
                if matches!(cow, Cow::Owned(_)) {
                    self.stats.manifests_sliced += 1;
                }
                cow
            }
            None => {
                let fp32 = self.fp32_range(start, end);
                Cow::Owned(int8_view(&fp32))
            }
        }
    }

    /// Operating point of `layers[start..end)` on `lane`, from the
    /// lane's own simulator.  A registry lane covering the whole model
    /// returns its bound operating point bit-exactly (the degenerate
    /// invariant).  Memoized: a repeated `(lane, range)` query returns
    /// the tabled point without touching a simulator or a manifest.
    fn seg_cost(&mut self, lane: Lane, start: usize, end: usize) -> Result<SegmentCost> {
        let key = (self.flat_key(lane), start, end);
        if let Some(&(setup_s, per_item_s, active_power_w)) = self.cost_memo.get(&key) {
            return Ok(SegmentCost { setup_s, per_item_s, active_power_w });
        }
        let c = self.price_range(lane, start, end)?;
        self.stats.ranges_priced += 1;
        self.cost_memo.insert(key, (c.setup_s, c.per_item_s, c.active_power_w));
        Ok(c)
    }

    /// The uncached pricing pass behind [`PlanBuilder::seg_cost`].
    fn price_range(&mut self, lane: Lane, start: usize, end: usize) -> Result<SegmentCost> {
        match lane {
            Lane::Registry(i) => {
                let t = self.registry.get(i);
                if start == 0 && end == self.fp32.layers.len() {
                    return Ok(SegmentCost {
                        setup_s: t.setup_s(),
                        per_item_s: t.per_item_s(),
                        active_power_w: t.active_power_w(),
                    });
                }
                let sub = match t.precision() {
                    Precision::Int8 => self.int8_range(start, end),
                    Precision::Fp32 => self.fp32_range(start, end),
                };
                t.segment_cost(&sub)
            }
            Lane::Derived(_) => {
                let sub = self.int8_range(start, end);
                let t = DpuTarget::new(&sub, DpuSize::B4096, self.calib, &self.board)?;
                Ok(SegmentCost {
                    setup_s: t.setup_s(),
                    per_item_s: t.per_item_s(),
                    active_power_w: t.active_power_w(),
                })
            }
        }
    }

    /// Fastest registry lane supporting every layer of
    /// `layers[start..end)` (strict-less argmin on single-inference
    /// busy time: deterministic, registry-order tie-break).  Memoized —
    /// every preferred lane's plan shares the resolution for a range.
    fn fallback_lane(&mut self, start: usize, end: usize) -> Option<Lane> {
        if let Some(&cached) = self.fallback_memo.get(&(start, end)) {
            return cached;
        }
        let mut best: Option<(usize, f64)> = None;
        for i in 0..self.registry.len() {
            let t = self.registry.get(i);
            let covered = self.fp32.layers[start..end]
                .iter()
                .all(|l| t.supports_layer(l).is_ok());
            if !covered {
                continue;
            }
            let Ok(c) = self.seg_cost(Lane::Registry(i), start, end) else {
                continue;
            };
            let busy = c.setup_s + c.per_item_s;
            let better = match best {
                Some((_, b)) => busy < b,
                None => true,
            };
            if better {
                best = Some((i, busy));
            }
        }
        let lane = best.map(|(i, _)| Lane::Registry(i));
        self.fallback_memo.insert((start, end), lane);
        lane
    }

    /// Grow one plan around `preferred` from its support `mask`:
    /// maximal supported runs stay on the preferred lane, unsupported
    /// runs go to their fallback.  `None` when some unsupported run has
    /// no covering lane (possible under narrow `--targets` lists).
    fn build_plan(&mut self, preferred: Lane, mask: &[bool]) -> Result<Option<ExecutionPlan>> {
        let n_layers = mask.len();
        let mut ranges: Vec<(Lane, usize, usize)> = Vec::new();
        let mut start = 0;
        while start < n_layers {
            let on_preferred = mask[start];
            let mut end = start + 1;
            while end < n_layers && mask[end] == on_preferred {
                end += 1;
            }
            let lane = if on_preferred {
                preferred
            } else {
                match self.fallback_lane(start, end) {
                    Some(l) => l,
                    None => return Ok(None),
                }
            };
            ranges.push((lane, start, end));
            start = end;
        }
        let last = ranges.len() - 1;
        let mut segments = Vec::with_capacity(ranges.len());
        let mut transfer_per_item_s = 0.0;
        let mut transfer_bytes = 0u64;
        for (k, &(lane, s, e)) in ranges.iter().enumerate() {
            let cost = self.seg_cost(lane, s, e)?;
            let (out_bytes, transfer_out_s) = if k == last {
                (0, 0.0)
            } else {
                let bytes = self.fp32.layers[e - 1].act_bytes;
                (bytes, self.transfer.boundary_s(bytes))
            };
            transfer_per_item_s += transfer_out_s;
            transfer_bytes += out_bytes;
            segments.push(Segment {
                lane,
                target: self.lane_name(lane),
                start: s,
                end: e,
                setup_s: cost.setup_s,
                per_item_s: cost.per_item_s,
                power_w: cost.active_power_w,
                out_bytes,
                transfer_out_s,
            });
        }
        Ok(Some(ExecutionPlan {
            model: self.fp32.name.clone(),
            preferred: self.lane_name(preferred),
            segments,
            transfer_per_item_s,
            transfer_bytes,
            n_layers,
        }))
    }
}

/// PTQ byte-footprint view of a manifest: int8 precision, one weight
/// byte per parameter (the convention the real int8 artifacts follow).
/// Shapes and counts are unchanged — quantization does not move MACs.
fn int8_view(man: &Manifest) -> Manifest {
    let mut m = man.clone();
    m.precision = Precision::Int8;
    for l in &mut m.layers {
        l.weight_bytes = l.params;
    }
    m.weight_bytes = m.layers.iter().map(|l| l.weight_bytes).sum();
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(model: &str, set: &TargetSet) -> (TargetRegistry, Planner) {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let registry = TargetRegistry::build(model, &catalog, &calib, set).unwrap();
        let planner = Planner::build(model, &catalog, &calib, &registry, set).unwrap();
        (registry, planner)
    }

    #[test]
    fn fully_supported_model_yields_exact_single_segment_plans() {
        let (registry, planner) = build("vae", &TargetSet::Default);
        assert_eq!(planner.plans().len(), 3, "one plan per registry lane");
        assert_eq!(planner.primary_plan(), Some(1), "static picks the DPU plan");
        assert_eq!(planner.lane_count(), registry.len(), "no derived lanes");
        for (i, plan) in planner.plans().iter().enumerate() {
            assert_eq!(plan.segments.len(), 1);
            assert!(!plan.is_hybrid());
            let seg = &plan.segments[0];
            assert_eq!(seg.lane, Lane::Registry(i));
            assert_eq!((seg.start, seg.end), (0, plan.n_layers));
            let t = registry.get(i);
            assert_eq!(seg.target, t.name());
            // the degenerate invariant, cost side: bit-identical point
            assert_eq!(seg.setup_s.to_bits(), t.setup_s().to_bits());
            assert_eq!(seg.per_item_s.to_bits(), t.per_item_s().to_bits());
            assert_eq!(seg.power_w.to_bits(), t.active_power_w().to_bits());
            assert_eq!(plan.transfer_per_item_s.to_bits(), 0.0f64.to_bits());
            for n in [1u64, 8] {
                assert_eq!(
                    plan.batch_latency_s(n).to_bits(),
                    t.batch_latency_s(n).to_bits()
                );
                assert_eq!(plan.batch_energy_j(n).to_bits(), t.batch_energy_j(n).to_bits());
            }
            assert_eq!(plan.peak_power_w().to_bits(), t.active_power_w().to_bits());
        }
    }

    #[test]
    fn single_segment_pricing_is_zero_clone() {
        // every lane covers the whole model: pricing must never slice
        let (_r, planner) = build("vae", &TargetSet::Default);
        let s = planner.build_stats();
        assert_eq!(s.manifests_sliced, 0, "whole-model plans must not clone");
        assert_eq!(s.ranges_priced, planner.plans().len(), "one pricing per lane");
        // the derived whole-model lane prices a borrowed full view too
        // (the PTQ conversion is inherent, not a slice)
        let (_r, planner) = build("logistic", &TargetSet::Default);
        assert_eq!(planner.build_stats().manifests_sliced, 0);
    }

    #[test]
    fn hybrid_build_prices_each_range_at_most_once() {
        let (_r, planner) = build("baseline", &TargetSet::Default);
        let s = planner.build_stats();
        // the fallback search pre-prices the ranges plan growth reuses,
        // so slices stay strictly below simulator evaluations
        assert!(s.ranges_priced > 0);
        assert!(
            s.manifests_sliced < s.ranges_priced,
            "sliced {} vs priced {}",
            s.manifests_sliced,
            s.ranges_priced
        );
    }

    #[test]
    fn incompatible_model_grows_a_derived_dpu_hybrid() {
        let (registry, planner) = build("baseline", &TargetSet::Default);
        assert_eq!(planner.lane_count(), registry.len() + 1, "one derived lane");
        assert_eq!(planner.derived_lane_names().collect::<Vec<_>>(), vec!["dpu"]);
        let hybrid = planner
            .plans()
            .iter()
            .find(|p| p.is_hybrid())
            .expect("baseline must produce a hybrid plan");
        assert_eq!(hybrid.preferred, "dpu");
        assert_eq!(hybrid.segments.len(), 2);
        // conv3d+maxpool3d fall back (CPU beats naive HLS on 3-D ops),
        // flatten+dense+dense run on the derived DPU lane
        assert_eq!(hybrid.segments[0].target, "cpu");
        assert_eq!((hybrid.segments[0].start, hybrid.segments[0].end), (0, 2));
        assert_eq!(hybrid.segments[1].target, "dpu");
        assert_eq!((hybrid.segments[1].start, hybrid.segments[1].end), (2, 5));
        assert_eq!(hybrid.segments[1].lane, Lane::Derived(0));
        assert_eq!(planner.flat(hybrid.segments[1].lane), registry.len());
        assert!(hybrid.transfer_per_item_s > 0.0, "boundary toll is real");
        assert!(hybrid.transfer_bytes > 0);
        assert_eq!(hybrid.segments[1].out_bytes, 0, "final segment hands off nothing");
        // the hybrid must beat every whole-model plan on latency — the
        // reason the paper's flow partitions at all
        let best_single = planner
            .plans()
            .iter()
            .filter(|p| !p.is_hybrid())
            .map(|p| p.batch_latency_s(1))
            .fold(f64::INFINITY, f64::min);
        assert!(
            hybrid.batch_latency_s(1) < best_single,
            "hybrid {} vs best single {}",
            hybrid.batch_latency_s(1),
            best_single
        );
    }

    #[test]
    fn esperta_bank_has_no_dpu_lane() {
        // the bank layer itself is off the DPU (sigmoid + comparator),
        // so there is nothing to partition: whole-model plans only
        let (registry, planner) = build("esperta", &TargetSet::Default);
        assert_eq!(planner.lane_count(), registry.len());
        assert!(planner.plans().iter().all(|p| !p.is_hybrid()));
        assert_eq!(planner.plans().len(), 2); // cpu + hls
    }

    #[test]
    fn named_set_exclusion_suppresses_the_derived_lane() {
        let set = TargetSet::parse("cpu,hls").unwrap();
        let (_registry, planner) = build("baseline", &set);
        assert_eq!(planner.derived_lane_names().count(), 0);
        assert!(planner.plans().iter().all(|p| !p.is_hybrid()));
    }

    #[test]
    fn mappable_fp32_model_gets_a_quantize_whatif_plan() {
        // LogisticNet is operator-compatible but ships no int8 variant:
        // the derived lane prices what quantize-and-deploy would buy
        let (registry, planner) = build("logistic", &TargetSet::Default);
        let dpu_plan = planner
            .plans()
            .iter()
            .find(|p| p.preferred == "dpu")
            .expect("derived whole-model DPU plan");
        assert_eq!(dpu_plan.segments.len(), 1);
        assert_eq!(dpu_plan.segments[0].lane, Lane::Derived(0));
        assert!(registry.index_of("dpu").is_none(), "not a registry target");
    }

    #[test]
    fn plans_partition_exactly_and_deterministically() {
        for model in ["vae", "cnet", "esperta", "logistic", "reduced", "baseline"] {
            let (_r1, a) = build(model, &TargetSet::Default);
            let (_r2, b) = build(model, &TargetSet::Default);
            assert_eq!(a.plans().len(), b.plans().len(), "{model}");
            for (pa, pb) in a.plans().iter().zip(b.plans()) {
                // same seed-free inputs => bit-identical plan
                assert_eq!(pa.describe(), pb.describe(), "{model}");
                assert_eq!(
                    pa.batch_latency_s(8).to_bits(),
                    pb.batch_latency_s(8).to_bits(),
                    "{model}"
                );
                // segments partition [0, n_layers) in order
                assert_eq!(pa.segments[0].start, 0);
                assert_eq!(pa.segments.last().unwrap().end, pa.n_layers);
                for w in pa.segments.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "{model}: contiguous");
                }
                assert!(pa.transfer_per_item_s >= 0.0);
            }
        }
    }
}
