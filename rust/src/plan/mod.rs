//! Heterogeneous execution plans: per-layer operator support → subgraph
//! partitions → plan-level dispatch.
//!
//! The paper's Vitis-AI flow does not reject a model containing a
//! DPU-unsupported operator — the compiler *splits the graph* and falls
//! back to the ARM CPU for the unsupported subgraph (§III-B; the same
//! operator-coverage point drives the survey literature in PAPERS.md).
//! Whole-model gating therefore under-serves hybrid deployments: one
//! sigmoid layer used to push an entire model off the DPU.  This module
//! closes that gap:
//!
//! * [`Planner`] — partitions a manifest against every candidate lane
//!   using the backend layer's per-layer gate
//!   ([`crate::backend::AccelModel::supports_layer`]), producing one
//!   [`ExecutionPlan`] per lane: single-segment when the lane covers
//!   the whole model, hybrid (maximal preferred runs + fallback
//!   segments) otherwise;
//! * [`ExecutionPlan`] / [`Segment`] — ordered segments that exactly
//!   partition the layer list, each priced by *its own lane's
//!   simulator on the segment's sub-manifest*
//!   ([`crate::backend::AccelModel::segment_cost`] over
//!   [`crate::model::Manifest::slice`]);
//! * [`TransferModel`] — the per-boundary host↔accelerator toll,
//!   modeled from the producing layer's output bytes over the
//!   calibrated AXI/DDR path;
//! * plan-level dispatch — `coordinator::dispatch::Dispatcher::choose_plan`
//!   scores hybrid plans alongside single-target plans under every
//!   policy, and the pipeline executes the chosen plan segment by
//!   segment on the virtual clock (`--plan`).
//!
//! **Degenerate invariant:** a model fully supported by a lane yields a
//! single-segment plan carrying that target's exact whole-model
//! operating point with an exactly-zero transfer term, so plan-level
//! decisions on such models are bit-identical to the whole-model
//! dispatcher (`tests/golden_dispatch.rs` passes unchanged;
//! `tests/plan_partition.rs` pins the equivalence).

pub mod partition;
pub mod transfer;

pub use partition::{BuildStats, ExecutionPlan, Lane, Planner, Segment, DERIVED_DPU_NAME};
pub use transfer::TransferModel;
