//! Lightweight metrics: monotonically-increasing counters and log-bucket
//! latency histograms, rendered as a flat text report.

use std::collections::BTreeMap;
use std::time::Duration;

/// Log2-bucketed latency histogram (1 µs .. ~17 s).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) microseconds.
    buckets: [u64; 25],
    count: u64,
    sum_us: u128,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; 25], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        let us = (d.as_micros() as u64).max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(24);
        self.buckets[bucket] += 1;
        self.count += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean sample in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// Largest sample in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Fold another histogram into this one — the result is identical
    /// to having recorded the other's samples here directly.
    pub fn merge(&mut self, other: &Histogram) {
        for (d, s) in self.buckets.iter_mut().zip(&other.buckets) {
            *d += s;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    /// Nearest-rank p95 bucket bound (µs) — the same rank convention as
    /// the pipeline report's `p95_latency_s`, resolved to this
    /// histogram's power-of-two bucket granularity.
    pub fn p95_us(&self) -> u64 {
        self.quantile_us(0.95)
    }

    /// Nearest-rank p99 bucket bound (µs) — the serving-SLO tail the
    /// `serve` layer reports per lane.
    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }
}

/// Named counters + histograms.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    /// Increment a counter by one.
    ///
    /// ```
    /// use spaceinfer::telemetry::Metrics;
    /// let mut m = Metrics::default();
    /// m.inc("batches");
    /// m.add("batches", 4);
    /// assert_eq!(m.counter("batches"), 5);
    /// ```
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    /// Add to a counter.  Allocation-free once the key exists (the
    /// `String` key is only built on first occurrence).
    pub fn add(&mut self, name: &str, v: u64) {
        match self.counters.get_mut(name) {
            Some(c) => *c += v,
            None => {
                self.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Record a duration sample into the named histogram.
    /// Allocation-free once the key exists.
    pub fn observe(&mut self, name: &str, d: Duration) {
        match self.histograms.get_mut(name) {
            Some(h) => h.record(d),
            None => {
                let mut h = Histogram::default();
                h.record(d);
                self.histograms.insert(name.to_string(), h);
            }
        }
    }

    /// Fold a pre-accumulated histogram in under `name` — identical
    /// state to having observed every sample here directly.  Empty
    /// histograms leave no trace (matching the observe-on-demand
    /// behavior, so folded reports stay bit-identical).
    pub fn merge_histogram(&mut self, name: &str, h: &Histogram) {
        if h.count() == 0 {
            return;
        }
        match self.histograms.get_mut(name) {
            Some(dst) => dst.merge(h),
            None => {
                self.histograms.insert(name.to_string(), h.clone());
            }
        }
    }

    /// Current counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// The named histogram, if anything was observed.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Flat text dump.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k} = {v}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!(
                "{k}: n={} mean={:.0}us p50<={}us p99<={}us max={}us\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.99),
                h.max_us()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::default();
        m.inc("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.max_us() == 100_000);
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn zero_duration_goes_to_first_bucket() {
        let mut h = Histogram::default();
        h.record(Duration::from_nanos(1));
        assert_eq!(h.count(), 1);
        assert!(h.quantile_us(1.0) >= 1);
    }

    #[test]
    fn merge_histogram_matches_direct_observation() {
        let mut direct = Metrics::default();
        let mut h = Histogram::default();
        for us in [3u64, 700, 90_000] {
            direct.observe("lat", Duration::from_micros(us));
            h.record(Duration::from_micros(us));
        }
        let mut folded = Metrics::default();
        folded.merge_histogram("lat", &h);
        assert_eq!(direct.report(), folded.report());
        // empty histograms leave no trace (report stays bit-identical)
        folded.merge_histogram("untouched", &Histogram::default());
        assert!(folded.histogram("untouched").is_none());
        // merging on top accumulates
        folded.merge_histogram("lat", &h);
        assert_eq!(folded.histogram("lat").unwrap().count(), 6);
    }

    #[test]
    fn report_contains_everything() {
        let mut m = Metrics::default();
        m.inc("a");
        m.observe("lat", Duration::from_micros(500));
        let r = m.report();
        assert!(r.contains("a = 1"));
        assert!(r.contains("lat:"));
    }
}
