//! Coordinator telemetry: counters + latency histograms.

pub mod metrics;

pub use metrics::{Histogram, Metrics};
