//! The Vitis-AI DPU family targets (B512–B4096) behind [`AccelModel`].
//!
//! The paper instantiates one B4096; PG338 defines the size axis, and
//! the survey literature (PAPERS.md) motivates exploring it: smaller
//! arrays trade throughput for power and CRAM footprint — exactly the
//! axis a mission power budget or SEU environment cares about.

use anyhow::{bail, Result};

use super::{AccelModel, SegmentCost, Slot};
use crate::board::{Calibration, Zcu104};
use crate::dpu::{DpuArch, DpuSchedule, DpuSize};
use crate::model::{Layer, Manifest, Precision};
use crate::power::PowerModel;
use crate::resources::Utilization;

/// One DPU configuration running one int8 model: timing from the
/// per-layer cycle scheduler, power scaled from the calibrated B4096
/// anchor, footprint from the architecture description.
#[derive(Debug, Clone)]
pub struct DpuTarget {
    /// Convolution-architecture size this target instantiates.
    pub size: DpuSize,
    /// Per-layer schedule of the deployed int8 manifest on this array.
    pub sched: DpuSchedule,
    power_w: f64,
    /// Kept so sub-manifest segments re-schedule under the same
    /// calibration the bound model was built with.
    calib: Calibration,
    axi_bandwidth: f64,
}

impl DpuTarget {
    /// Schedule `man` onto a `size` array.  Errors when the manifest
    /// fails the §III-B operator gate.
    pub fn new(
        man: &Manifest,
        size: DpuSize,
        calib: &Calibration,
        board: &Zcu104,
    ) -> Result<DpuTarget> {
        let arch = DpuArch::of_size(size, calib, board.dpu_clock_hz);
        let sched = DpuSchedule::new(man, arch, calib, board.axi_bandwidth)?;
        let power_w =
            PowerModel::new(calib.clone()).dpu_family_w(size.frac(), sched.mac_duty());
        Ok(DpuTarget {
            size,
            sched,
            power_w,
            calib: calib.clone(),
            axi_bandwidth: board.axi_bandwidth,
        })
    }
}

impl AccelModel for DpuTarget {
    fn name(&self) -> &'static str {
        self.size.target_name()
    }

    fn slot(&self) -> Slot {
        Slot::Dpu
    }

    fn precision(&self) -> Precision {
        Precision::Int8
    }

    fn supports(&self, man: &Manifest) -> Result<()> {
        if man.dpu_compatible() {
            Ok(())
        } else {
            bail!(
                "model {:?} uses operators unsupported by the DPU \
                 (sigmoid / comparator / 3-D layers)",
                man.name
            )
        }
    }

    fn supports_layer(&self, layer: &Layer) -> Result<()> {
        if layer.dpu_mappable() {
            Ok(())
        } else {
            bail!(
                "{:?} (act {}) is outside the DPU operator set \
                 (paper §III-B: no sigmoid / comparators / 3-D layers)",
                layer.kind,
                layer.act.as_str()
            )
        }
    }

    fn segment_cost(&self, man: &Manifest) -> Result<SegmentCost> {
        // the per-layer cycle scheduler runs on the sub-manifest with
        // the identical array / calibration the bound model used
        let sched = DpuSchedule::new(man, self.sched.arch, &self.calib, self.axi_bandwidth)?;
        let power_w = PowerModel::new(self.calib.clone())
            .dpu_family_w(self.size.frac(), sched.mac_duty());
        Ok(SegmentCost {
            setup_s: sched.invoke_s,
            per_item_s: sched.latency_s() - sched.invoke_s,
            active_power_w: power_w,
        })
    }

    fn setup_s(&self) -> f64 {
        self.sched.invoke_s // PYNQ/VART runner submit-wait path
    }

    fn per_item_s(&self) -> f64 {
        self.sched.latency_s() - self.sched.invoke_s
    }

    fn active_power_w(&self) -> f64 {
        self.power_w
    }

    fn resources(&self) -> Utilization {
        let r = self.sched.arch.resources();
        Utilization {
            luts: r.luts,
            ffs: r.ffs,
            dsps: r.dsps,
            brams: r.brams,
            urams: r.urams,
        }
    }
}
