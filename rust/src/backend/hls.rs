//! The Vitis-HLS custom-IP targets behind [`AccelModel`]: the paper's
//! naive sequential design and the pipelined (II=1) variant its §V
//! explicitly leaves on the table ("the HLS use cases were deliberately
//! unoptimized ... pipelining and loop unrolling would increase
//! performance at the cost of resources").

use anyhow::Result;

use super::{AccelModel, Slot};
use crate::board::{Calibration, Zcu104};
use crate::hls::HlsDesign;
use crate::model::{Manifest, Precision};
use crate::power::{Implementation, PowerModel};
use crate::resources::{estimate_hls, estimate_hls_pipelined, Utilization};

/// One synthesized HLS accelerator (naive or pipelined) for one model.
#[derive(Debug, Clone)]
pub struct HlsTarget {
    /// The synthesized design (timing + BRAM plan).
    pub design: HlsDesign,
    /// True for the II=1 dataflow variant.
    pub pipelined: bool,
    util: Utilization,
    power_w: f64,
}

impl HlsTarget {
    /// Registry / telemetry name of the naive design.
    pub const NAME: &'static str = "hls";
    /// Registry / telemetry name of the pipelined (II=1) design.
    pub const PIPELINED_NAME: &'static str = "hls-pipe";

    /// The paper's un-pragma'd sequential design (exactly the seed
    /// dispatcher's construction).
    pub fn naive(man: &Manifest, board: &Zcu104, calib: &Calibration) -> HlsTarget {
        let design = HlsDesign::synthesize(man, board, calib);
        let util = estimate_hls(man, &design.plan);
        Self::finish(design, util, false, calib)
    }

    /// The II=1 dataflow variant: pipelined/unrolled datapath, BRAM
    /// partitioning pressure through the same allocator.
    pub fn pipelined(man: &Manifest, board: &Zcu104, calib: &Calibration) -> HlsTarget {
        let design = HlsDesign::synthesize_pipelined(man, board, calib);
        let util = estimate_hls_pipelined(man, &design.plan);
        Self::finish(design, util, true, calib)
    }

    fn finish(
        design: HlsDesign,
        util: Utilization,
        pipelined: bool,
        calib: &Calibration,
    ) -> HlsTarget {
        let power_w = PowerModel::new(calib.clone()).mpsoc_w(&Implementation::Hls {
            kiloluts: util.luts as f64 / 1000.0,
            brams: design.plan.brams(),
            duty: 1.0,
        });
        HlsTarget { design, pipelined, util, power_w }
    }
}

impl AccelModel for HlsTarget {
    fn name(&self) -> &'static str {
        if self.pipelined {
            Self::PIPELINED_NAME
        } else {
            Self::NAME
        }
    }

    fn slot(&self) -> Slot {
        Slot::Hls
    }

    fn precision(&self) -> Precision {
        Precision::Fp32
    }

    fn supports(&self, _man: &Manifest) -> Result<()> {
        Ok(()) // any manifest synthesizes (fp32, sigmoid/3-D included)
    }

    fn setup_s(&self) -> f64 {
        self.design.axi_setup_cycles / self.design.clock_hz
    }

    fn per_item_s(&self) -> f64 {
        self.design.latency_s() - self.setup_s()
    }

    fn active_power_w(&self) -> f64 {
        self.power_w
    }

    fn resources(&self) -> Utilization {
        self.util
    }
}
