//! The Vitis-HLS custom-IP targets behind [`AccelModel`]: the paper's
//! naive sequential design and the pipelined (II=1) variant its §V
//! explicitly leaves on the table ("the HLS use cases were deliberately
//! unoptimized ... pipelining and loop unrolling would increase
//! performance at the cost of resources").

use anyhow::Result;

use super::{AccelModel, SegmentCost, Slot};
use crate::board::{Calibration, Zcu104};
use crate::hls::HlsDesign;
use crate::model::{Layer, Manifest, Precision};
use crate::power::{Implementation, PowerModel};
use crate::resources::{estimate_hls, estimate_hls_pipelined, Utilization};

/// One synthesized HLS accelerator (naive or pipelined) for one model.
#[derive(Debug, Clone)]
pub struct HlsTarget {
    /// The synthesized design (timing + BRAM plan).
    pub design: HlsDesign,
    /// True for the II=1 dataflow variant.
    pub pipelined: bool,
    util: Utilization,
    power_w: f64,
    /// Kept so sub-manifest segments re-synthesize under the same
    /// calibration / board the bound model was built with.
    calib: Calibration,
    board: Zcu104,
}

impl HlsTarget {
    /// Registry / telemetry name of the naive design.
    pub const NAME: &'static str = "hls";
    /// Registry / telemetry name of the pipelined (II=1) design.
    pub const PIPELINED_NAME: &'static str = "hls-pipe";

    /// The paper's un-pragma'd sequential design (exactly the seed
    /// dispatcher's construction).
    pub fn naive(man: &Manifest, board: &Zcu104, calib: &Calibration) -> HlsTarget {
        let design = HlsDesign::synthesize(man, board, calib);
        let util = estimate_hls(man, &design.plan);
        Self::finish(design, util, false, calib, board)
    }

    /// The II=1 dataflow variant: pipelined/unrolled datapath, BRAM
    /// partitioning pressure through the same allocator.
    pub fn pipelined(man: &Manifest, board: &Zcu104, calib: &Calibration) -> HlsTarget {
        let design = HlsDesign::synthesize_pipelined(man, board, calib);
        let util = estimate_hls_pipelined(man, &design.plan);
        Self::finish(design, util, true, calib, board)
    }

    fn finish(
        design: HlsDesign,
        util: Utilization,
        pipelined: bool,
        calib: &Calibration,
        board: &Zcu104,
    ) -> HlsTarget {
        let power_w = PowerModel::new(calib.clone()).mpsoc_w(&Implementation::Hls {
            kiloluts: util.luts as f64 / 1000.0,
            brams: design.plan.brams(),
            duty: 1.0,
        });
        HlsTarget { design, pipelined, util, power_w, calib: calib.clone(), board: *board }
    }
}

impl AccelModel for HlsTarget {
    fn name(&self) -> &'static str {
        if self.pipelined {
            Self::PIPELINED_NAME
        } else {
            Self::NAME
        }
    }

    fn slot(&self) -> Slot {
        Slot::Hls
    }

    fn precision(&self) -> Precision {
        Precision::Fp32
    }

    fn supports(&self, _man: &Manifest) -> Result<()> {
        Ok(()) // any manifest synthesizes (fp32, sigmoid/3-D included)
    }

    fn supports_layer(&self, _layer: &Layer) -> Result<()> {
        Ok(()) // ONNX2C emits C for every operator in the taxonomy
    }

    fn segment_cost(&self, man: &Manifest) -> Result<SegmentCost> {
        // synthesize the sub-manifest as its own IP (per-model HLS is
        // per-subgraph HLS in a hybrid deployment) and re-estimate its
        // footprint-driven power
        let (design, util) = if self.pipelined {
            let d = HlsDesign::synthesize_pipelined(man, &self.board, &self.calib);
            let u = estimate_hls_pipelined(man, &d.plan);
            (d, u)
        } else {
            let d = HlsDesign::synthesize(man, &self.board, &self.calib);
            let u = estimate_hls(man, &d.plan);
            (d, u)
        };
        let power_w = PowerModel::new(self.calib.clone()).mpsoc_w(&Implementation::Hls {
            kiloluts: util.luts as f64 / 1000.0,
            brams: design.plan.brams(),
            duty: 1.0,
        });
        let setup_s = design.axi_setup_cycles / design.clock_hz;
        Ok(SegmentCost {
            setup_s,
            per_item_s: design.latency_s() - setup_s,
            active_power_w: power_w,
        })
    }

    fn setup_s(&self) -> f64 {
        self.design.axi_setup_cycles / self.design.clock_hz
    }

    fn per_item_s(&self) -> f64 {
        self.design.latency_s() - self.setup_s()
    }

    fn active_power_w(&self) -> f64 {
        self.power_w
    }

    fn resources(&self) -> Utilization {
        self.util
    }
}
