//! The A53 software target — the paper's baseline and the coordinator's
//! always-available escape hatch, behind the [`AccelModel`] seam.

use anyhow::Result;

use super::{AccelModel, SegmentCost, Slot};
use crate::board::Calibration;
use crate::cpu::A53Model;
use crate::model::catalog::ModelInfo;
use crate::model::{Layer, Manifest, Precision};
use crate::resources::Utilization;

/// PS software execution of one model: per-item latency from the
/// calibrated [`A53Model`], power from the paper's CPU row.
#[derive(Debug, Clone)]
pub struct CpuTarget {
    /// Calibrated per-model A53 timing model.
    pub model: A53Model,
    power_w: f64,
    /// Kept so sub-manifest segments re-simulate under the same
    /// calibration the bound model was built with.
    calib: Calibration,
}

impl CpuTarget {
    /// Registry / telemetry name of the CPU target.
    pub const NAME: &'static str = "cpu";

    /// Calibrate on the model's paper CPU row (Table III anchoring,
    /// exactly the seed dispatcher's construction).
    pub fn new(man: &Manifest, calib: &Calibration, info: &ModelInfo) -> CpuTarget {
        CpuTarget {
            model: A53Model::calibrated(man, calib, info.paper.cpu_fps),
            power_w: info.paper.cpu_p_mpsoc,
            calib: calib.clone(),
        }
    }
}

impl AccelModel for CpuTarget {
    fn name(&self) -> &'static str {
        Self::NAME
    }

    fn slot(&self) -> Slot {
        Slot::Cpu
    }

    fn precision(&self) -> Precision {
        Precision::Fp32
    }

    fn supports(&self, _man: &Manifest) -> Result<()> {
        Ok(()) // PyTorch-equivalent software path runs every operator
    }

    fn supports_layer(&self, _layer: &Layer) -> Result<()> {
        Ok(()) // per-operator coverage is total on the PS
    }

    fn segment_cost(&self, man: &Manifest) -> Result<SegmentCost> {
        // same NEON efficiency as the calibrated whole model, ops and
        // dispatch overhead recomputed for the sub-manifest
        let m = A53Model::with_util(man, &self.calib, self.model.util);
        Ok(SegmentCost {
            setup_s: 0.0,
            per_item_s: m.latency_s(),
            active_power_w: self.power_w,
        })
    }

    fn setup_s(&self) -> f64 {
        0.0
    }

    fn per_item_s(&self) -> f64 {
        self.model.latency_s()
    }

    fn active_power_w(&self) -> f64 {
        self.power_w
    }

    fn resources(&self) -> Utilization {
        Utilization::none() // the A53 lives in the PS, not in CRAM
    }
}
