//! Pluggable accelerator backends: the trait seam every execution
//! target enters through.
//!
//! The paper evaluates exactly three targets (A53 CPU, one Vitis-AI DPU
//! configuration, naive Vitis HLS) but frames them as points in a design
//! space: DPU cores ship in B512–B4096 sizes (PG338) and the HLS designs
//! are "deliberately unoptimized" with known pragma headroom (§V).  This
//! module turns that space into data:
//!
//! * [`AccelModel`] — the capability + cost interface one execution
//!   target exposes (operator support, batch latency/energy, precision,
//!   active power, PL footprint);
//! * [`TargetRegistry`] — the instantiated, ordered target table for one
//!   use-case model, built from the catalog and calibration;
//! * [`TargetSet`] — which targets to instantiate (`default` reproduces
//!   the paper's triple, `all` opens the full family, or an explicit
//!   comma list from `--targets`).
//!
//! The coordinator's dispatcher scores registry *indices*; nothing above
//! this layer matches on target kinds.  Adding a backend (INT4 DPU,
//! FINN-style streaming, a second FPGA) means implementing [`AccelModel`]
//! and registering it in [`TargetRegistry::build`] — the dispatcher,
//! pipeline, policy reports, telemetry, and SEU accounting pick it up
//! unchanged.

pub mod cpu;
pub mod dpu;
pub mod hls;

use anyhow::{bail, Result};

use crate::board::{Calibration, Zcu104};
use crate::dpu::DpuSize;
use crate::model::catalog::{model_info, Catalog, Target as PaperTarget};
use crate::model::{Layer, Manifest, Precision};
use crate::resources::Utilization;

pub use cpu::CpuTarget;
pub use dpu::DpuTarget;
pub use hls::HlsTarget;

/// Coarse execution-slot kind on the simulated MPSoC.  Several registry
/// targets may share a slot (the four DPU sizes are all [`Slot::Dpu`]);
/// the paper's deployment matrix and the report layer speak in slots,
/// the dispatcher in registry indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// A Vitis-AI DPU instance.
    Dpu,
    /// A per-model HLS IP.
    Hls,
    /// A53 software fallback.
    Cpu,
}

impl Slot {
    /// Short lower-case name used in reports.
    ///
    /// ```
    /// use spaceinfer::coordinator::Slot;
    /// assert_eq!(Slot::Dpu.name(), "dpu");
    /// ```
    pub fn name(&self) -> &'static str {
        match self {
            Slot::Dpu => "dpu",
            Slot::Hls => "hls",
            Slot::Cpu => "cpu",
        }
    }
}

/// Operating point of one target evaluated on a specific
/// (sub-)manifest — what [`AccelModel::segment_cost`] returns.  The
/// execution-plan partitioner (`crate::plan`) prices each segment of a
/// hybrid deployment with these, by running the target's *own*
/// calibrated simulator on the segment's sub-manifest.
#[derive(Debug, Clone, Copy)]
pub struct SegmentCost {
    /// Fixed per-batch submission overhead on this target (s).
    pub setup_s: f64,
    /// Marginal time per inference of the (sub-)manifest (s).
    pub per_item_s: f64,
    /// Active MPSoC draw while the (sub-)manifest runs (W).
    pub active_power_w: f64,
}

/// One pluggable execution target: the calibrated cost + capability
/// model the dispatcher scores.
///
/// Implementations are bound to one deployed model variant (they embed
/// the scheduled manifest), so the per-batch cost methods need no
/// manifest argument; [`AccelModel::supports`] answers the eligibility
/// question for an arbitrary manifest (the §III-B operator gate), and
/// [`AccelModel::supports_layer`] answers it per layer — the seam the
/// subgraph partitioner (`crate::plan`) builds hybrid execution plans
/// on.
pub trait AccelModel: std::fmt::Debug + Send + Sync {
    /// Stable registry / telemetry key (`target_mix` and `dispatch_*`
    /// counters use it).  The paper's three targets keep their seed-era
    /// names (`cpu` / `dpu` / `hls`); family members extend them
    /// (`dpu-b512`, `hls-pipe`).
    fn name(&self) -> &'static str;

    /// Coarse slot kind this target occupies.
    fn slot(&self) -> Slot;

    /// Precision the deployed variant runs at — also what the executor
    /// pool loads for this target.
    fn precision(&self) -> Precision;

    /// Can this target execute `man`?  `Err` carries the reason (e.g.
    /// the DPU's unsupported-operator gate).
    fn supports(&self, man: &Manifest) -> Result<()>;

    /// Can this target execute a single `layer`?  The per-layer form of
    /// [`AccelModel::supports`]: the Vitis-AI flow does not reject a
    /// model with one unsupported operator, it splits the graph there —
    /// this method is where a backend declares the split points.
    ///
    /// The default wraps the layer in a one-layer manifest and
    /// delegates to the whole-model gate, so existing external backends
    /// inherit layer granularity for free; the built-in adapters
    /// override it directly.
    fn supports_layer(&self, layer: &Layer) -> Result<()> {
        let single = Manifest {
            name: format!("<{:?}>", layer.kind),
            precision: self.precision(),
            inputs: vec![("x".to_string(), layer.in_shape.clone())],
            output_shape: layer.out_shape.clone(),
            layers: vec![layer.clone()],
            total_macs: layer.macs,
            total_ops: layer.ops,
            total_params: layer.params,
            weight_bytes: layer.weight_bytes,
        };
        self.supports(&single)
    }

    /// Evaluate this target's calibrated simulator on an arbitrary
    /// (sub-)manifest — how the plan layer prices one segment of a
    /// hybrid deployment.  The default returns the bound whole-model
    /// operating point (exact when `man` *is* the bound manifest, a
    /// conservative over-estimate for a strict sub-manifest); the
    /// built-in adapters re-simulate for real.
    fn segment_cost(&self, man: &Manifest) -> Result<SegmentCost> {
        self.supports(man)?;
        Ok(SegmentCost {
            setup_s: self.setup_s(),
            per_item_s: self.per_item_s(),
            active_power_w: self.active_power_w(),
        })
    }

    /// Fixed per-batch submission overhead (s) — runner invocation,
    /// AXI-Lite setup, zero for the CPU.
    fn setup_s(&self) -> f64;

    /// Marginal time per inference within a batch (s).
    fn per_item_s(&self) -> f64;

    /// Active MPSoC draw while this target runs (W) — what a mission
    /// power budget caps.
    fn active_power_w(&self) -> f64;

    /// PL footprint of the target's design — drives Table II reporting
    /// and `rad::seu` essential-bit scaling.  Empty for the CPU (the
    /// A53 lives in the PS, not configuration memory).
    fn resources(&self) -> Utilization;

    /// Predicted busy time for a batch of `n` (s): setup + n · per-item.
    fn batch_latency_s(&self, n: u64) -> f64 {
        self.setup_s() + n as f64 * self.per_item_s()
    }

    /// Predicted busy energy for a batch of `n` (J): active power ×
    /// busy time.
    fn batch_energy_j(&self, n: u64) -> f64 {
        self.active_power_w() * self.batch_latency_s(n)
    }
}

/// Which targets a registry instantiates.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TargetSet {
    /// The paper's triple: A53 + B4096 DPU + naive HLS.  Byte-identical
    /// dispatch behavior to the pre-registry coordinator.
    #[default]
    Default,
    /// Every target the model is eligible for (the full DPU family and
    /// both HLS variants).
    All,
    /// An explicit selection (`--targets cpu,dpu-b1024,hls-pipe`).
    /// Unknown names are rejected at parse time; requesting a DPU
    /// target for an operator-incompatible model errors at build time.
    Named(Vec<String>),
}

impl TargetSet {
    /// Every registrable target name, in registry order.
    pub const KNOWN: [&'static str; 7] = [
        "cpu", "dpu-b512", "dpu-b1024", "dpu-b2304", "dpu", "hls", "hls-pipe",
    ];

    /// Parse a CLI selection: `default` | `all` | a comma list of names
    /// from [`TargetSet::KNOWN`] (`dpu-b4096` is accepted as an alias
    /// for `dpu`).
    ///
    /// ```
    /// use spaceinfer::backend::TargetSet;
    /// assert_eq!(TargetSet::parse("all").unwrap(), TargetSet::All);
    /// assert!(TargetSet::parse("cpu,hls-pipe").is_ok());
    /// assert!(TargetSet::parse("gpu").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<TargetSet> {
        match s {
            "default" => Ok(TargetSet::Default),
            "all" => Ok(TargetSet::All),
            _ => {
                let mut names = Vec::new();
                for raw in s.split(',') {
                    let mut name = raw.trim();
                    if name == "dpu-b4096" {
                        name = "dpu";
                    }
                    if !Self::KNOWN.iter().any(|&k| k == name) {
                        bail!(
                            "unknown target {name:?} (known: {}, or `default` / `all`)",
                            Self::KNOWN.join(", ")
                        );
                    }
                    names.push(name.to_string());
                }
                Ok(TargetSet::Named(names))
            }
        }
    }

    /// Does this set admit a target?  `in_default` marks the paper's
    /// three seed targets.  `pub(crate)` so the plan layer can honor an
    /// explicit `--targets` exclusion when deriving plan-only lanes.
    pub(crate) fn admits(&self, name: &str, in_default: bool) -> bool {
        match self {
            TargetSet::Default => in_default,
            TargetSet::All => true,
            TargetSet::Named(list) => list.iter().any(|n| n == name),
        }
    }

    fn is_named(&self) -> bool {
        matches!(self, TargetSet::Named(_))
    }
}

/// The instantiated, ordered target table for one use-case model.
/// The table itself is immutable once built; per-run queue state lives
/// in the caller's timeline vector, index-aligned with
/// [`TargetRegistry::targets`].  The only mutable bit is per-target
/// *availability*: a mission event (an SEU in the target's
/// configuration memory, a thermal limit) can mark a target out of
/// service with [`TargetRegistry::set_available`] and the dispatcher
/// re-routes live until it is restored (typically when a
/// `rad::scrub` repair window elapses).
#[derive(Debug)]
pub struct TargetRegistry {
    targets: Vec<Box<dyn AccelModel>>,
    primary: Option<usize>,
    available: Vec<bool>,
}

impl TargetRegistry {
    /// Build the registry for `model` from the catalog and calibration.
    ///
    /// Order is fixed (CPU, DPU family ascending, naive HLS, pipelined
    /// HLS) so dispatcher tie-breaks stay deterministic; under
    /// [`TargetSet::Default`] this reduces to the seed coordinator's
    /// `[cpu, dpu, hls]` table exactly.  DPU entries exist only when the
    /// int8 variant passes the §III-B operator gate — silently skipped
    /// for `default`/`all`, a hard error when explicitly `Named`.
    pub fn build(
        model: &str,
        catalog: &Catalog,
        calib: &Calibration,
        set: &TargetSet,
    ) -> Result<TargetRegistry> {
        let info = model_info(model)?;
        let board = Zcu104::default();
        let cpu_man = catalog.manifest(model, Precision::Fp32)?;
        let int8_man = catalog.manifest(model, Precision::Int8).ok();
        let mut targets: Vec<Box<dyn AccelModel>> = Vec::new();
        let mut primary = None;

        if set.admits(CpuTarget::NAME, true) {
            targets.push(Box::new(CpuTarget::new(cpu_man, calib, info)));
        }
        for size in DpuSize::ALL {
            let name = size.target_name();
            if !set.admits(name, size == DpuSize::B4096) {
                continue;
            }
            match int8_man {
                Some(man) if man.dpu_compatible() => {
                    if size == DpuSize::B4096 && info.target == PaperTarget::Dpu {
                        primary = Some(targets.len());
                    }
                    targets.push(Box::new(DpuTarget::new(man, size, calib, &board)?));
                }
                _ => {
                    if set.is_named() {
                        bail!(
                            "target {name:?} requested but model {model:?} has no \
                             DPU-deployable int8 variant (operator gate / missing \
                             manifest)"
                        );
                    }
                }
            }
        }
        if set.admits(HlsTarget::NAME, true) {
            if info.target == PaperTarget::Hls {
                primary = Some(targets.len());
            }
            targets.push(Box::new(HlsTarget::naive(cpu_man, &board, calib)));
        }
        if set.admits(HlsTarget::PIPELINED_NAME, false) {
            targets.push(Box::new(HlsTarget::pipelined(cpu_man, &board, calib)));
        }
        if targets.is_empty() {
            bail!("target set selected no eligible target for model {model:?}");
        }
        let available = vec![true; targets.len()];
        Ok(TargetRegistry { targets, primary, available })
    }

    /// Assemble a registry from pre-built targets (tests, external
    /// backends).  `primary` indexes the static-policy target.
    pub fn from_targets(
        targets: Vec<Box<dyn AccelModel>>,
        primary: Option<usize>,
    ) -> TargetRegistry {
        let available = vec![true; targets.len()];
        TargetRegistry { targets, primary, available }
    }

    /// The ordered target table.
    pub fn targets(&self) -> &[Box<dyn AccelModel>] {
        &self.targets
    }

    /// One target by registry index.
    pub fn get(&self, index: usize) -> &dyn AccelModel {
        self.targets[index].as_ref()
    }

    /// Number of registered targets.
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    /// True when no target registered (never after a successful `build`).
    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Index of the paper's deployment-matrix target, when registered.
    pub fn primary_index(&self) -> Option<usize> {
        self.primary
    }

    /// Registry index of a target by its stable name, if registered.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.targets.iter().position(|t| t.name() == name)
    }

    /// Is the target at `index` currently in service?  Targets start
    /// available; mission events toggle this at runtime.
    pub fn is_available(&self, index: usize) -> bool {
        self.available[index]
    }

    /// Mark a target in or out of service.  An unavailable target is
    /// excluded from every dispatch decision (the static policy falls
    /// back to the fastest available target) until restored.
    pub fn set_available(&mut self, index: usize, available: bool) {
        self.available[index] = available;
    }

    /// Number of targets currently in service.
    pub fn available_count(&self) -> usize {
        self.available.iter().filter(|&&a| a).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rad::seu::essential_bits_of;

    fn registry(model: &str, set: &TargetSet) -> TargetRegistry {
        TargetRegistry::build(
            model,
            &Catalog::synthetic(),
            &Calibration::default(),
            set,
        )
        .unwrap()
    }

    fn names(r: &TargetRegistry) -> Vec<&'static str> {
        r.targets().iter().map(|t| t.name()).collect()
    }

    #[test]
    fn default_set_reproduces_the_paper_triple() {
        let r = registry("vae", &TargetSet::Default);
        assert_eq!(names(&r), vec!["cpu", "dpu", "hls"]);
        assert_eq!(r.primary_index(), Some(1));
        // HLS-primary model without an int8 variant: no DPU entry
        let r = registry("baseline", &TargetSet::Default);
        assert_eq!(names(&r), vec!["cpu", "hls"]);
        assert_eq!(r.primary_index(), Some(1));
    }

    #[test]
    fn all_set_opens_the_family() {
        let r = registry("vae", &TargetSet::All);
        assert_eq!(
            names(&r),
            vec!["cpu", "dpu-b512", "dpu-b1024", "dpu-b2304", "dpu", "hls", "hls-pipe"]
        );
        assert!(r.len() >= 6, "acceptance: >= 6 targets for a DPU model");
        // operator-incompatible model: DPU family absent, HLS pair present
        let r = registry("esperta", &TargetSet::All);
        assert_eq!(names(&r), vec!["cpu", "hls", "hls-pipe"]);
    }

    #[test]
    fn named_set_selects_and_rejects() {
        let r = registry("vae", &TargetSet::parse("cpu,dpu-b1024").unwrap());
        assert_eq!(names(&r), vec!["cpu", "dpu-b1024"]);
        assert_eq!(r.primary_index(), None, "b4096 not registered");
        // alias
        assert_eq!(
            TargetSet::parse("dpu-b4096").unwrap(),
            TargetSet::Named(vec!["dpu".into()])
        );
        // typo: parse-time error, not silent fall-through
        assert!(TargetSet::parse("dpu-b9999").is_err());
        // explicit DPU request for an incompatible model: build-time error
        let err = TargetRegistry::build(
            "esperta",
            &Catalog::synthetic(),
            &Calibration::default(),
            &TargetSet::parse("dpu").unwrap(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn dpu_family_scales_latency_power_and_footprint() {
        let r = registry("vae", &TargetSet::All);
        let dpus: Vec<&dyn AccelModel> = r
            .targets()
            .iter()
            .map(|t| t.as_ref())
            .filter(|t| t.slot() == Slot::Dpu)
            .collect();
        assert_eq!(dpus.len(), 4);
        for pair in dpus.windows(2) {
            // ascending array size: faster per item, hotter, bigger
            assert!(
                pair[0].per_item_s() >= pair[1].per_item_s(),
                "{} vs {}",
                pair[0].name(),
                pair[1].name()
            );
            assert!(pair[0].active_power_w() < pair[1].active_power_w());
            assert!(pair[0].resources().dsps < pair[1].resources().dsps);
            assert!(
                essential_bits_of(&pair[0].resources())
                    < essential_bits_of(&pair[1].resources())
            );
        }
    }

    #[test]
    fn pipelined_hls_is_faster_but_heavier() {
        let r = registry("esperta", &TargetSet::All);
        let naive = r.get(1);
        let pipe = r.get(2);
        assert_eq!(naive.name(), "hls");
        assert_eq!(pipe.name(), "hls-pipe");
        assert!(pipe.per_item_s() < naive.per_item_s(), "II=1 beats II=5");
        assert!(
            pipe.resources().brams >= naive.resources().brams,
            "partitioning raises BRAM pressure"
        );
        assert!(pipe.resources().dsps > naive.resources().dsps);
        assert!(pipe.active_power_w() > naive.active_power_w());
    }

    #[test]
    fn cpu_target_has_no_pl_footprint() {
        let r = registry("vae", &TargetSet::Default);
        let cpu = r.get(0);
        assert_eq!(cpu.name(), "cpu");
        assert_eq!(essential_bits_of(&cpu.resources()), 0);
        assert_eq!(cpu.setup_s(), 0.0);
    }

    #[test]
    fn supports_gates_the_dpu() {
        let catalog = Catalog::synthetic();
        let r = registry("vae", &TargetSet::Default);
        let dpu = r.get(1);
        let vae = catalog.manifest("vae", Precision::Int8).unwrap();
        let baseline = catalog.manifest("baseline", Precision::Fp32).unwrap();
        assert!(dpu.supports(vae).is_ok());
        assert!(dpu.supports(baseline).is_err(), "conv3d is off the DPU");
        // CPU and HLS take anything
        assert!(r.get(0).supports(baseline).is_ok());
        assert!(r.get(2).supports(baseline).is_ok());
    }

    #[test]
    fn availability_toggles_and_lookup_by_name() {
        let mut r = registry("vae", &TargetSet::Default);
        assert_eq!(r.available_count(), 3, "everything starts in service");
        let dpu = r.index_of("dpu").unwrap();
        assert!(r.is_available(dpu));
        r.set_available(dpu, false);
        assert!(!r.is_available(dpu));
        assert_eq!(r.available_count(), 2);
        r.set_available(dpu, true);
        assert_eq!(r.available_count(), 3);
        assert_eq!(r.index_of("warp-drive"), None);
    }

    #[test]
    fn supports_layer_moves_the_gate_to_layer_granularity() {
        let catalog = Catalog::synthetic();
        let r = registry("vae", &TargetSet::Default);
        let dpu = r.get(1);
        assert_eq!(dpu.name(), "dpu");
        // BaselineNet: conv3d/maxpool3d rejected, flatten/dense accepted
        let baseline = catalog.manifest("baseline", Precision::Fp32).unwrap();
        assert!(dpu.supports(baseline).is_err(), "whole-model gate still fails");
        let verdicts: Vec<bool> = baseline
            .layers
            .iter()
            .map(|l| dpu.supports_layer(l).is_ok())
            .collect();
        assert_eq!(verdicts, vec![false, false, true, true, true]);
        // sigmoid activation is a per-layer rejection too
        let esperta = catalog.manifest("esperta", Precision::Fp32).unwrap();
        assert!(dpu.supports_layer(&esperta.layers[0]).is_err());
        // CPU and HLS accept every layer
        for l in baseline.layers.iter().chain(&esperta.layers) {
            assert!(r.get(0).supports_layer(l).is_ok());
            assert!(r.get(2).supports_layer(l).is_ok());
        }
    }

    #[test]
    fn segment_cost_on_the_bound_manifest_is_the_whole_model_point() {
        // re-simulating the full manifest must land exactly on the
        // registered operating point — the degenerate-plan invariant's
        // cost-side half
        let catalog = Catalog::synthetic();
        let r = registry("vae", &TargetSet::Default);
        for (target, prec) in
            [(r.get(0), Precision::Fp32), (r.get(1), Precision::Int8), (r.get(2), Precision::Fp32)]
        {
            let man = catalog.manifest("vae", prec).unwrap();
            let c = target.segment_cost(man).unwrap();
            assert_eq!(c.setup_s.to_bits(), target.setup_s().to_bits(), "{}", target.name());
            assert_eq!(
                c.per_item_s.to_bits(),
                target.per_item_s().to_bits(),
                "{}",
                target.name()
            );
            assert_eq!(
                c.active_power_w.to_bits(),
                target.active_power_w().to_bits(),
                "{}",
                target.name()
            );
        }
    }

    #[test]
    fn segment_cost_scales_with_the_sub_manifest() {
        let catalog = Catalog::synthetic();
        let r = registry("vae", &TargetSet::Default);
        let man = catalog.manifest("vae", Precision::Fp32).unwrap();
        let head = man.slice(0, 1);
        let cpu = r.get(0);
        let part = cpu.segment_cost(&head).unwrap();
        let whole = cpu.segment_cost(man).unwrap();
        assert!(part.per_item_s < whole.per_item_s, "fewer layers, less time");
        assert!(part.per_item_s > 0.0);
        // the DPU rejects a sub-manifest with unsupported operators
        let baseline = catalog.manifest("baseline", Precision::Fp32).unwrap();
        assert!(r.get(1).segment_cost(&baseline.slice(0, 2)).is_err());
    }

    #[test]
    fn batch_cost_defaults_compose() {
        let r = registry("vae", &TargetSet::Default);
        let t = r.get(1);
        let one = t.batch_latency_s(1);
        let eight = t.batch_latency_s(8);
        assert!((eight - one - 7.0 * t.per_item_s()).abs() < 1e-15);
        assert_eq!(
            t.batch_energy_j(8).to_bits(),
            (t.active_power_w() * eight).to_bits()
        );
    }
}
