//! Downlink budget management — the resource the whole paper exists to
//! conserve ("easing downlink pressure in future missions", abstract).
//!
//! A daily byte budget is spent by kept decisions; low-priority items are
//! shed first when the budget tightens.  The manager also tracks the
//! *avoided* bytes (raw sensor data that did NOT need downlinking because
//! inference ran onboard) — the headline compression statistic.

use crate::coordinator::decision::Decision;

/// Verdict for one decision offered to the downlink.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownlinkVerdict {
    /// Kept: bytes spent from the budget.
    Sent,
    /// Shed: priority below the current floor given remaining budget.
    Shed,
}

/// The downlink budget manager.
#[derive(Debug)]
pub struct DownlinkManager {
    /// Total byte budget for the observation window.
    pub budget_bytes: u64,
    /// Bytes spent so far (can exceed the budget: alerts always pass).
    pub sent_bytes: u64,
    /// Decisions shed.
    pub shed_count: u64,
    /// Bytes the shed decisions would have cost — the backlog a later
    /// ground-station pass (or a relay neighbor) could still recover.
    /// The fleet layer's barrier arbitration reads this as per-craft
    /// downlink demand.
    pub shed_bytes: u64,
    /// Decisions sent.
    pub sent_count: u64,
    /// Raw sensor bytes represented by everything offered (what a
    /// no-onboard-inference mission would have had to send).
    pub raw_bytes_represented: u64,
}

impl DownlinkManager {
    /// Fresh manager with a byte budget.
    pub fn new(budget_bytes: u64) -> DownlinkManager {
        DownlinkManager {
            budget_bytes,
            sent_bytes: 0,
            shed_count: 0,
            shed_bytes: 0,
            sent_count: 0,
            raw_bytes_represented: 0,
        }
    }

    /// Remaining budget fraction, always a finite value in [0, 1]: a
    /// zero-byte budget reads as fully spent (no 0/0 NaN), and
    /// overspend (alerts pass even over budget) clamps at 0 rather than
    /// going negative.
    pub fn remaining_frac(&self) -> f64 {
        if self.budget_bytes == 0 {
            return 0.0;
        }
        1.0 - (self.sent_bytes as f64 / self.budget_bytes as f64).min(1.0)
    }

    /// Priority floor: as the budget drains, only higher-priority items
    /// pass.  Full budget -> floor 0 (everything passes); empty ->
    /// floor 200 (only alerts).
    pub fn priority_floor(&self) -> u8 {
        let spent = 1.0 - self.remaining_frac();
        if spent < 0.5 {
            0
        } else if spent < 0.8 {
            60
        } else if spent < 0.95 {
            120
        } else {
            200
        }
    }

    /// Offer a decision; `raw_bytes` is the sensor data it distills.
    pub fn offer(&mut self, decision: &Decision, raw_bytes: u64) -> DownlinkVerdict {
        self.raw_bytes_represented += raw_bytes;
        let bytes = decision.downlink_bytes();
        let over_budget = self.sent_bytes + bytes > self.budget_bytes;
        if decision.priority() < self.priority_floor()
            || (over_budget && decision.priority() < 200)
        {
            self.shed_count += 1;
            self.shed_bytes += bytes;
            return DownlinkVerdict::Shed;
        }
        self.sent_bytes += bytes;
        self.sent_count += 1;
        DownlinkVerdict::Sent
    }

    /// Effective compression ratio: raw bytes represented per byte
    /// sent.  Always finite, so the pipeline summary never renders
    /// NaN/inf at degenerate (e.g. zero-byte) budgets: with nothing
    /// sent, raw bytes represented count against a floor of one sent
    /// byte (`raw:1`), and with nothing offered at all the ratio is a
    /// neutral 1:1.
    ///
    /// ```
    /// use spaceinfer::coordinator::DownlinkManager;
    /// let d = DownlinkManager::new(0);
    /// assert_eq!(d.compression_ratio(), 1.0); // nothing offered yet
    /// ```
    pub fn compression_ratio(&self) -> f64 {
        if self.raw_bytes_represented == 0 {
            return 1.0;
        }
        self.raw_bytes_represented as f64 / self.sent_bytes.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensors::generators::Region;

    fn label() -> Decision {
        Decision::MmsRegion { region: Region::Sw, roi: false, logits: [0.0; 4] }
    }

    fn alert() -> Decision {
        Decision::SepAlert { warning: true, mask: [true; 6], max_prob: 0.99 }
    }

    #[test]
    fn sends_within_budget() {
        let mut d = DownlinkManager::new(10_000);
        assert_eq!(d.offer(&label(), 65536), DownlinkVerdict::Sent);
        assert_eq!(d.sent_count, 1);
        assert!(d.compression_ratio() > 3000.0);
    }

    #[test]
    fn sheds_low_priority_when_tight() {
        let mut d = DownlinkManager::new(100);
        // drain most of the budget with alerts (they always pass)
        while d.remaining_frac() > 0.15 {
            assert_eq!(d.offer(&alert(), 1000), DownlinkVerdict::Sent);
        }
        // now routine labels are shed, alerts still pass
        assert_eq!(d.offer(&label(), 1000), DownlinkVerdict::Shed);
        assert_eq!(d.offer(&alert(), 1000), DownlinkVerdict::Sent);
        // shed bytes track the demand the fleet layer arbitrates over
        assert_eq!(d.shed_bytes, label().downlink_bytes());
    }

    #[test]
    fn alerts_pass_even_over_budget() {
        let mut d = DownlinkManager::new(8);
        d.offer(&label(), 100); // eats the budget (17 bytes > 8)
        assert_eq!(d.offer(&alert(), 100), DownlinkVerdict::Sent);
    }

    #[test]
    fn priority_floor_monotone_in_spend() {
        let mut d = DownlinkManager::new(1000);
        let mut last = 0;
        for _ in 0..100 {
            d.offer(&label(), 10);
            let f = d.priority_floor();
            assert!(f >= last, "floor must not decrease");
            last = f;
        }
    }

    #[test]
    fn zero_budget_edge() {
        let d = DownlinkManager::new(0);
        assert_eq!(d.remaining_frac(), 0.0);
        assert_eq!(d.priority_floor(), 200);
        // fresh manager: neutral ratio, not 0/0
        assert_eq!(d.compression_ratio(), 1.0);
    }

    #[test]
    fn ratio_finite_when_everything_shed() {
        // zero budget + routine traffic: all shed, nothing sent — the
        // ratio must stay finite (raw:1 floor) for the summary line
        let mut d = DownlinkManager::new(0);
        for _ in 0..5 {
            assert_eq!(d.offer(&label(), 1000), DownlinkVerdict::Shed);
        }
        assert_eq!(d.sent_bytes, 0);
        let r = d.compression_ratio();
        assert!(r.is_finite());
        assert_eq!(r, 5000.0);
    }

    #[test]
    fn over_budget_fractions_stay_bounded() {
        // alerts pass even over budget: spent can exceed the budget but
        // remaining_frac must clamp, not go negative
        let mut d = DownlinkManager::new(3);
        d.offer(&alert(), 100);
        d.offer(&alert(), 100);
        assert!(d.sent_bytes > d.budget_bytes);
        assert_eq!(d.remaining_frac(), 0.0);
        assert!(d.compression_ratio().is_finite());
    }
}
