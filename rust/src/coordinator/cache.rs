//! Deterministic memoization of dispatch decisions — the raw-speed pass
//! on the plan/dispatch hot path.
//!
//! The dispatcher re-derives the same verdict from scratch for every
//! flushed batch, yet a steady-state run re-sees the same decision
//! inputs for long stretches: constant batch size, drained queues, a
//! fixed policy.  [`DispatchCache`] memoizes the *decision* — `(index,
//! power_shed)` for whole-model dispatch, `(plan index, power_shed)` in
//! plan mode — keyed by the exact bit patterns of every input the
//! decision depends on.
//!
//! # Determinism argument
//!
//! A cache hit is provably bit-identical to a fresh
//! [`Dispatcher::choose`] call because of two properties:
//!
//! 1. **Keys are exact.**  Every float that can influence the decision
//!    (per-lane queue backlog, power budget, deadline, already-spent
//!    wait) enters the key as its raw `f64::to_bits` pattern — no
//!    rounding, no bucketing.  Two states that collide on a key are
//!    states the policy cannot distinguish.
//! 2. **Costs are recomputed, never replayed.**  On a hit only the
//!    *pick* is reused; the chosen target's [`BatchCost`] is recomputed
//!    from the live inputs via [`Dispatcher::cost`], a pure function.
//!    Telemetry (`predicted_energy_j`, latency histograms) therefore
//!    sees exactly the floats an uncached run would produce.
//!
//! Keys are *relaxed* per policy for hit rate: a field the active
//! policy provably ignores (the deadline under `min-latency`, every
//! queue backlog under `min-energy`) is pinned to a constant so states
//! differing only in ignored inputs share an entry.  The relaxation
//! rule is itself a function of fields kept in the key (policy byte,
//! availability mask), so an entry can never be consulted under a rule
//! other than the one that stored it.
//!
//! # Invalidation rules
//!
//! Correctness never depends on invalidation — a knob mutation changes
//! a key field, so stale entries simply stop matching ("impossible by
//! construction").  The explicit `invalidate_*` hooks exist to bound
//! memory and to make knob churn observable: each drops exactly the
//! entries the mutated knob orphaned and counts them.
//!
//! | knob                     | entries dropped                              |
//! |--------------------------|----------------------------------------------|
//! | `set_policy(p)`          | every entry not keyed under `p`              |
//! | `set_power_budget_w(b)`  | dynamic-policy entries keyed under another budget |
//! | `set_deadline_s(d)`      | `deadline`-policy entries keyed under another deadline |
//! | `set_target_available`   | every entry keyed under another availability mask |
//!
//! The recovery path ([`Dispatcher::choose_constrained`]) never
//! consults the cache: per-attempt exclusion masks and brownout budget
//! overrides are transient, so fault-mode dispatch stays byte-identical
//! to the pre-cache pipeline by *not participating* (counted as
//! bypasses).

use std::collections::BTreeMap;

use crate::backend::TargetRegistry;
use crate::coordinator::dispatch::{BatchCost, Choice, Dispatcher, PlanChoice, Policy};
use crate::coordinator::scheduler::AccelTimeline;
use crate::plan::Planner;

// Imported for intra-doc links only.
#[allow(unused_imports)]
use crate::coordinator::pipeline::PipelineReport;

/// Maximum timeline lanes a key can fingerprint.  Wide enough for the
/// full `--targets all` registry (7) plus the derived plan lane; runs
/// with more lanes bypass the cache rather than truncate a key.
pub const MAX_CACHE_LANES: usize = 8;

/// Entry cap per decision table; reaching it clears the table (a full
/// rebuild costs one miss per live state — cheaper than tracking LRU
/// order, and deterministic).
const CACHE_CAPACITY: usize = 4096;

/// Exact decision fingerprint: every input [`Dispatcher::choose`] /
/// [`Dispatcher::choose_plan`] reads, as raw bit patterns.  Also the
/// storage key after per-policy relaxation (ignored fields pinned to
/// zero / `None`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Key {
    /// Discriminant of the active [`Policy`].
    policy: u8,
    /// Batch size.
    n: u64,
    /// Registry availability bitmask (bit i = target i in service).
    avail: u64,
    /// `power_budget_w` bits; `None` when unset or ignored (static).
    budget: Option<u64>,
    /// `deadline_s` bits; 0 when the policy ignores the deadline.
    deadline: u64,
    /// Already-spent wait `(now - oldest).max(0)` bits; 0 when ignored.
    wait: u64,
    /// Per-lane `backlog_s(now)` bits, zero-padded past `lanes`.
    backlogs: [u64; MAX_CACHE_LANES],
}

fn policy_tag(p: Policy) -> u8 {
    match p {
        Policy::Static => 0,
        Policy::MinLatency => 1,
        Policy::MinEnergy => 2,
        Policy::Deadline => 3,
    }
}

/// Hit / miss / invalidation counters, surfaced in
/// [`PipelineReport::cache`] and the `cache` section of
/// `BENCH_runtime.json`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Decisions served from the cache (hot-entry or table).
    pub hits: u64,
    /// Decisions computed fresh and inserted.
    pub misses: u64,
    /// Entries dropped by knob-mutation invalidation.
    pub invalidations: u64,
    /// Decisions that skipped the cache (recovery-path dispatch, or
    /// more timeline lanes than a key can fingerprint).
    pub bypasses: u64,
}

impl CacheStats {
    /// Total cache consultations (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when none).
    pub fn hit_rate(&self) -> f64 {
        let l = self.lookups();
        if l == 0 {
            0.0
        } else {
            self.hits as f64 / l as f64
        }
    }
}

/// Memoized dispatch decisions for one run.
///
/// Owned by the run (not the [`Dispatcher`], which stays immutable and
/// shareable) and threaded explicitly through the dispatch path — no
/// interior mutability, no locks.  Holds two decision tables
/// (whole-model and plan-mode; a run only exercises one) plus a
/// single-entry *hot* front cache per table: consecutive batches that
/// re-see the exact same state — the steady-state common case — return
/// a stored [`Choice`] without a table walk or a cost recomputation.
///
/// ```
/// use spaceinfer::backend::TargetSet;
/// use spaceinfer::board::Calibration;
/// use spaceinfer::coordinator::{DispatchCache, Dispatcher, Policy};
/// use spaceinfer::model::Catalog;
///
/// let catalog = Catalog::synthetic();
/// let d = Dispatcher::new("vae", &catalog, &Calibration::default(),
///                         Policy::MinLatency, 0.5, None,
///                         &TargetSet::Default).unwrap();
/// let tls = d.timelines();
/// let mut cache = DispatchCache::new(true);
/// let fresh = d.choose(&tls, 0.0, 0.0, 8);
/// let a = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8); // miss
/// let b = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8); // hit
/// assert_eq!((a.index, b.index), (fresh.index, fresh.index));
/// assert_eq!(cache.stats().hits, 1);
/// assert_eq!(cache.stats().misses, 1);
/// ```
#[derive(Debug, Default)]
pub struct DispatchCache {
    enabled: bool,
    map: BTreeMap<Key, (usize, bool)>,
    hot: Option<(Key, Choice)>,
    plan_map: BTreeMap<Key, (usize, bool)>,
    plan_hot: Option<(Key, PlanChoice)>,
    stats: CacheStats,
}

impl DispatchCache {
    /// A fresh cache.  `enabled: false` builds the escape hatch: every
    /// `choose_cached` call falls through to the uncached dispatcher
    /// and no counter moves.
    pub fn new(enabled: bool) -> DispatchCache {
        DispatchCache { enabled, ..Default::default() }
    }

    /// Is memoization on for this run?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Live entries across both decision tables (hot entries excluded —
    /// they always mirror a table entry's decision).
    pub fn entries(&self) -> usize {
        self.map.len() + self.plan_map.len()
    }

    /// Count one decision that skipped the cache by design (the
    /// recovery path's constrained dispatch).
    pub fn note_bypass(&mut self) {
        if self.enabled {
            self.stats.bypasses += 1;
        }
    }

    /// Registry availability bitmask — the `avail` key field, and the
    /// argument `invalidate_availability` expects after a flip.
    pub fn availability_mask(registry: &TargetRegistry) -> u64 {
        let mut mask = 0u64;
        for i in 0..registry.len().min(64) {
            if registry.is_available(i) {
                mask |= 1 << i;
            }
        }
        mask
    }

    /// Drop every entry not keyed under `policy` (they cannot match
    /// until the policy switches back; dropping bounds memory and makes
    /// the switch observable).
    pub fn invalidate_policy(&mut self, policy: Policy) {
        let tag = policy_tag(policy);
        self.retain(|k| k.policy == tag);
    }

    /// Drop dynamic-policy entries keyed under a different power
    /// budget.  Static entries are untouched — [`Dispatcher::choose`]
    /// ignores the budget under the static policy, so no static entry
    /// is affected by the knob.
    pub fn invalidate_power_budget(&mut self, budget_w: Option<f64>) {
        let bits = budget_w.map(f64::to_bits);
        let static_tag = policy_tag(Policy::Static);
        self.retain(|k| k.policy == static_tag || k.budget == bits);
    }

    /// Drop `deadline`-policy entries keyed under a different deadline.
    /// Every other policy's entries are untouched — the deadline is
    /// pinned out of their keys because it cannot change their pick.
    pub fn invalidate_deadline(&mut self, deadline_s: f64) {
        let bits = deadline_s.to_bits();
        let tag = policy_tag(Policy::Deadline);
        self.retain(|k| k.policy != tag || k.deadline == bits);
    }

    /// Drop every entry keyed under an availability mask other than
    /// `mask` (from [`DispatchCache::availability_mask`] after the
    /// flip).  Availability shapes the candidate set under every
    /// policy, so no policy's entries survive a mask change.
    pub fn invalidate_availability(&mut self, mask: u64) {
        self.retain(|k| k.avail == mask);
    }

    /// Keep entries satisfying `keep`; count the rest as invalidations.
    /// The hot entries are screened with the same predicate.
    fn retain(&mut self, keep: impl Fn(&Key) -> bool) {
        let before = self.entries();
        self.map.retain(|k, _| keep(k));
        self.plan_map.retain(|k, _| keep(k));
        self.stats.invalidations += (before - self.entries()) as u64;
        if self.hot.as_ref().is_some_and(|(k, _)| !keep(k)) {
            self.hot = None;
        }
        if self.plan_hot.as_ref().is_some_and(|(k, _)| !keep(k)) {
            self.plan_hot = None;
        }
    }

    /// Exact fingerprint of one whole-model decision's inputs.
    fn raw_key(
        d: &Dispatcher,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> Key {
        let mut backlogs = [0u64; MAX_CACHE_LANES];
        for (slot, tl) in backlogs.iter_mut().zip(timelines) {
            *slot = tl.backlog_s(now_s).to_bits();
        }
        Key {
            policy: policy_tag(d.policy),
            n,
            avail: DispatchCache::availability_mask(&d.registry),
            budget: d.power_budget_w.map(f64::to_bits),
            deadline: d.deadline_s.to_bits(),
            wait: (now_s - oldest_t_s).max(0.0).to_bits(),
            backlogs,
        }
    }

    /// Pin the fields the active policy provably ignores.  The rule
    /// only consults fields that stay in the key (policy, availability
    /// mask), so storage and lookup always agree on the relaxation.
    fn relax(d: &Dispatcher, mut key: Key, all_mask: u64, primary_bit: u64) -> Key {
        match d.policy {
            Policy::Static => {
                // static never sheds and never checks the deadline
                key.budget = None;
                key.deadline = 0;
                key.wait = 0;
                // primary in service (or the all-down fallback): the
                // pick is the primary regardless of queue state
                if key.avail & primary_bit != 0 || key.avail == all_mask || key.avail == 0
                {
                    key.backlogs = [0; MAX_CACHE_LANES];
                }
            }
            Policy::MinLatency => {
                // latency_s carries no wait term and no deadline check
                key.deadline = 0;
                key.wait = 0;
            }
            Policy::MinEnergy => {
                // batch energy is a function of n alone: queues, wait,
                // and deadline cannot move the argmin
                key.deadline = 0;
                key.wait = 0;
                key.backlogs = [0; MAX_CACHE_LANES];
            }
            Policy::Deadline => {} // reads everything
        }
        key
    }

    /// [`Dispatcher::choose`] through the cache: hot-entry fast path,
    /// then the decision table (pick reused, cost recomputed exactly),
    /// then a fresh scoring pass on a miss.
    pub(crate) fn choose(
        &mut self,
        d: &Dispatcher,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> Choice {
        if !self.enabled {
            return d.choose(timelines, now_s, oldest_t_s, n);
        }
        if timelines.len() > MAX_CACHE_LANES {
            self.stats.bypasses += 1;
            return d.choose(timelines, now_s, oldest_t_s, n);
        }
        let raw = DispatchCache::raw_key(d, timelines, now_s, oldest_t_s, n);
        if let Some((fp, choice)) = &self.hot {
            if *fp == raw {
                self.stats.hits += 1;
                return choice.clone();
            }
        }
        let all_mask = (1u64 << d.registry.len()) - 1;
        let key = DispatchCache::relax(d, raw, all_mask, 1u64 << d.primary_index());
        if let Some(&(index, power_shed)) = self.map.get(&key) {
            self.stats.hits += 1;
            let cost = d.cost(index, &timelines[index], now_s, oldest_t_s, n);
            let choice = Choice { index, cost, power_shed };
            self.hot = Some((raw, choice.clone()));
            return choice;
        }
        self.stats.misses += 1;
        let choice = d.choose(timelines, now_s, oldest_t_s, n);
        if self.map.len() >= CACHE_CAPACITY {
            self.map.clear();
        }
        self.map.insert(key, (choice.index, choice.power_shed));
        self.hot = Some((raw, choice.clone()));
        choice
    }

    /// [`Dispatcher::choose_plan`] through the cache — same contract as
    /// [`DispatchCache::choose`] over the planner's candidate set, with
    /// [`Dispatcher::plan_cost`] recomputing the chosen plan's cost
    /// exactly on a hit.
    pub(crate) fn choose_plan(
        &mut self,
        d: &Dispatcher,
        planner: &Planner,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> PlanChoice {
        if !self.enabled {
            return d.choose_plan(planner, timelines, now_s, oldest_t_s, n);
        }
        if timelines.len() > MAX_CACHE_LANES {
            self.stats.bypasses += 1;
            return d.choose_plan(planner, timelines, now_s, oldest_t_s, n);
        }
        let raw = DispatchCache::raw_key(d, timelines, now_s, oldest_t_s, n);
        // the plan-mode static pick is constant whenever every registry
        // lane is in service (avail == all ⇒ every plan in service ⇒
        // the primary plan wins); a partial outage falls back to the
        // backlog-dependent argmin, so those keys keep their queues.
        // Derived lanes have no availability state, so a mask of "all
        // registry lanes up" is exactly "every plan in service".
        let all_mask = (1u64 << d.registry.len()) - 1;
        let key = match d.policy {
            Policy::Static => {
                let mut k = raw;
                k.budget = None;
                k.deadline = 0;
                k.wait = 0;
                if k.avail == all_mask {
                    k.backlogs = [0; MAX_CACHE_LANES];
                }
                k
            }
            // plan energy is a function of n alone, as in whole-model
            _ => DispatchCache::relax(d, raw, all_mask, 0),
        };
        if let Some((fp, choice)) = &self.plan_hot {
            if *fp == raw {
                self.stats.hits += 1;
                return choice.clone();
            }
        }
        if let Some(&(index, power_shed)) = self.plan_map.get(&key) {
            self.stats.hits += 1;
            let cost = d.plan_cost(
                planner,
                &planner.plans()[index],
                timelines,
                now_s,
                oldest_t_s,
                n,
            );
            let choice = PlanChoice { index, cost, power_shed };
            self.plan_hot = Some((raw, choice.clone()));
            return choice;
        }
        self.stats.misses += 1;
        let choice = d.choose_plan(planner, timelines, now_s, oldest_t_s, n);
        if self.plan_map.len() >= CACHE_CAPACITY {
            self.plan_map.clear();
        }
        self.plan_map.insert(key, (choice.index, choice.power_shed));
        self.plan_hot = Some((raw, choice.clone()));
        choice
    }
}

/// Bit-level equality of two choices (test / assertion helper shared by
/// the regression harness and the benches).
pub fn choices_identical(a: &Choice, b: &Choice) -> bool {
    a.index == b.index && a.power_shed == b.power_shed && costs_identical(&a.cost, &b.cost)
}

fn costs_identical(a: &BatchCost, b: &BatchCost) -> bool {
    a.target == b.target
        && a.latency_s.to_bits() == b.latency_s.to_bits()
        && a.oldest_latency_s.to_bits() == b.oldest_latency_s.to_bits()
        && a.energy_j.to_bits() == b.energy_j.to_bits()
        && a.power_w.to_bits() == b.power_w.to_bits()
        && a.meets_deadline == b.meets_deadline
}

/// Bit-level equality of two plan choices.
pub fn plan_choices_identical(a: &PlanChoice, b: &PlanChoice) -> bool {
    a.index == b.index
        && a.power_shed == b.power_shed
        && a.cost.latency_s.to_bits() == b.cost.latency_s.to_bits()
        && a.cost.oldest_latency_s.to_bits() == b.cost.oldest_latency_s.to_bits()
        && a.cost.energy_j.to_bits() == b.cost.energy_j.to_bits()
        && a.cost.power_w.to_bits() == b.cost.power_w.to_bits()
        && a.cost.meets_deadline == b.cost.meets_deadline
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::TargetSet;
    use crate::board::Calibration;
    use crate::model::catalog::Catalog;

    fn dispatcher(policy: Policy, budget: Option<f64>) -> Dispatcher {
        let catalog = Catalog::synthetic();
        Dispatcher::new(
            "vae",
            &catalog,
            &Calibration::default(),
            policy,
            0.5,
            budget,
            &TargetSet::Default,
        )
        .unwrap()
    }

    #[test]
    fn hit_reproduces_the_fresh_choice_bit_for_bit() {
        for policy in
            [Policy::Static, Policy::MinLatency, Policy::MinEnergy, Policy::Deadline]
        {
            let d = dispatcher(policy, Some(4.0));
            let mut tls = d.timelines();
            tls[0].schedule(0.0, 40, d.run_of(0));
            let mut cache = DispatchCache::new(true);
            for (now, wait, n) in [(0.1, 0.05, 8u64), (0.1, 0.05, 8), (0.1, 0.05, 8)] {
                let fresh = d.choose(&tls, now, now - wait, n);
                let cached = d.choose_cached(&mut cache, &tls, now, now - wait, n);
                assert!(choices_identical(&fresh, &cached), "{policy:?}");
            }
            assert_eq!(cache.stats().misses, 1, "{policy:?}");
            assert_eq!(cache.stats().hits, 2, "{policy:?}");
        }
    }

    #[test]
    fn disabled_cache_never_counts() {
        let d = dispatcher(Policy::MinLatency, None);
        let tls = d.timelines();
        let mut cache = DispatchCache::new(false);
        let fresh = d.choose(&tls, 0.0, 0.0, 8);
        let cached = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        assert!(choices_identical(&fresh, &cached));
        assert_eq!(cache.stats(), CacheStats::default());
        assert_eq!(cache.entries(), 0);
    }

    #[test]
    fn min_energy_shares_entries_across_backlogs() {
        let d = dispatcher(Policy::MinEnergy, None);
        let mut tls = d.timelines();
        let mut cache = DispatchCache::new(true);
        d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        // pile queue on a target: min-energy provably ignores it, so
        // the relaxed key must hit (table path — the hot entry misses
        // because the raw fingerprint changed)
        tls[0].schedule(0.0, 100, d.run_of(0));
        let fresh = d.choose(&tls, 0.0, 0.0, 8);
        let cached = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        assert!(choices_identical(&fresh, &cached));
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.entries(), 1, "one relaxed entry covers both states");
    }

    #[test]
    fn min_latency_distinguishes_backlogs() {
        let d = dispatcher(Policy::MinLatency, None);
        let mut tls = d.timelines();
        let mut cache = DispatchCache::new(true);
        d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        tls[0].schedule(0.0, 100, d.run_of(0));
        let fresh = d.choose(&tls, 0.0, 0.0, 8);
        let cached = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        assert!(choices_identical(&fresh, &cached));
        assert_eq!(cache.stats().misses, 2, "queue state is decision-relevant");
    }

    #[test]
    fn knob_invalidation_drops_exactly_the_affected_entries() {
        let d = dispatcher(Policy::MinLatency, None);
        let mut tls = d.timelines();
        let mut cache = DispatchCache::new(true);
        // three distinct backlog states => three min-latency entries
        for _ in 0..3 {
            tls[0].schedule(0.0, 50, d.run_of(0));
            d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        }
        assert_eq!(cache.entries(), 3);
        // the deadline knob cannot affect min-latency entries: zero drop
        cache.invalidate_deadline(0.25);
        assert_eq!(cache.entries(), 3);
        assert_eq!(cache.stats().invalidations, 0);
        // the budget knob affects every dynamic entry
        cache.invalidate_power_budget(Some(4.0));
        assert_eq!(cache.entries(), 0);
        assert_eq!(cache.stats().invalidations, 3);
    }

    #[test]
    fn availability_flip_invalidates_and_redecides() {
        let mut d = dispatcher(Policy::MinLatency, None);
        let tls = d.timelines();
        let mut cache = DispatchCache::new(true);
        let up = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        d.registry.set_available(up.index, false);
        cache.invalidate_availability(DispatchCache::availability_mask(&d.registry));
        assert_eq!(cache.stats().invalidations, 1);
        let down = d.choose_cached(&mut cache, &tls, 0.0, 0.0, 8);
        assert_ne!(up.index, down.index, "knocked-out target cannot be re-picked");
        assert!(choices_identical(&down, &d.choose(&tls, 0.0, 0.0, 8)));
    }

    #[test]
    fn plan_choices_are_cached_and_exact() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        for policy in
            [Policy::Static, Policy::MinLatency, Policy::MinEnergy, Policy::Deadline]
        {
            let d = Dispatcher::new(
                "baseline",
                &catalog,
                &calib,
                policy,
                0.5,
                None,
                &TargetSet::Default,
            )
            .unwrap();
            let planner = Planner::build(
                "baseline",
                &catalog,
                &calib,
                &d.registry,
                &TargetSet::Default,
            )
            .unwrap();
            let mut tls = d.timelines();
            for name in planner.derived_lane_names() {
                tls.push(AccelTimeline::new(name));
            }
            let mut cache = DispatchCache::new(true);
            for _ in 0..3 {
                let fresh = d.choose_plan(&planner, &tls, 0.0, 0.0, 8);
                let cached =
                    d.choose_plan_cached(&mut cache, &planner, &tls, 0.0, 0.0, 8);
                assert!(plan_choices_identical(&fresh, &cached), "{policy:?}");
            }
            assert_eq!(cache.stats().misses, 1, "{policy:?}");
            assert_eq!(cache.stats().hits, 2, "{policy:?}");
        }
    }
}
