//! The on-board inference coordinator — Layer 3.
//!
//! The paper's motivation (§I) is the system this module implements:
//! high-fidelity sensors produce more data than the spacecraft can buffer
//! or downlink, so inference runs *in situ* and only distilled results —
//! region labels, SEP alerts, flux forecasts, latent vectors — reach the
//! radio.  The pipeline is:
//!
//! ```text
//! sensors -> router -> batcher -> dispatcher -> executor -> decision -> downlink
//!            (model      (flush    (cost model:   (PJRT       (per use case)
//!             variant)    policy)   CPU|DPU|HLS)   numerics)
//! ```
//!
//! Numerics are real (the AOT HLO runs on PJRT); time and energy are the
//! calibrated ZCU104 simulators' outputs, advanced on a virtual clock.
//! Per-batch target selection is cost-model-driven (`dispatch`): the
//! router resolves the model variant and the paper's primary slot, the
//! dispatcher scores every target registered in the backend layer
//! (`crate::backend`) under the configured policy — the coordinator
//! itself contains no per-target code.  The pipeline is steppable
//! (`Pipeline::begin` / `PipelineRun::tick`): every operational knob —
//! policy, power budget, deadline, cadence, target availability — is
//! mutable between ticks, which is how `crate::scenario` replays
//! mission timelines inside one deterministic run.  See
//! `docs/ARCHITECTURE.md` for the full module map and lifecycle.

pub mod backpressure;
pub mod batcher;
pub mod cache;
pub mod decision;
pub mod dispatch;
pub mod downlink;
pub mod pipeline;
pub mod router;
pub mod scheduler;

pub use backpressure::{BoundedQueue, OverflowPolicy};
pub use batcher::{Batch, Batcher};
pub use cache::{choices_identical, plan_choices_identical, CacheStats, DispatchCache};
pub use decision::{decide, Decision};
pub use dispatch::{
    default_deadline_s, BatchCost, Choice, Dispatcher, PlanChoice, PlanCost, Policy,
};
pub use downlink::{DownlinkManager, DownlinkVerdict};
pub use pipeline::{
    OwnedPipelineRun, PhaseReport, Pipeline, PipelineConfig, PipelineReport, PipelineRun,
};
pub use router::{Route, Router, Slot};
pub use scheduler::{AccelTimeline, ScheduledRun};
