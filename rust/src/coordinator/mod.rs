//! The on-board inference coordinator — Layer 3.
//!
//! The paper's motivation (§I) is the system this module implements:
//! high-fidelity sensors produce more data than the spacecraft can buffer
//! or downlink, so inference runs *in situ* and only distilled results —
//! region labels, SEP alerts, flux forecasts, latent vectors — reach the
//! radio.  The pipeline is:
//!
//! ```text
//! sensors -> router -> batcher -> accel executor -> decision -> downlink
//!                (CPU fallback)   (PJRT numerics +    (per use case)
//!                                  simulated timing)
//! ```
//!
//! Numerics are real (the AOT HLO runs on PJRT); time and energy are the
//! calibrated ZCU104 simulators' outputs, advanced on a virtual clock.

pub mod backpressure;
pub mod batcher;
pub mod decision;
pub mod downlink;
pub mod pipeline;
pub mod router;
pub mod scheduler;

pub use backpressure::BoundedQueue;
pub use batcher::{Batch, Batcher};
pub use decision::{decide, Decision};
pub use downlink::{DownlinkManager, DownlinkVerdict};
pub use pipeline::{Pipeline, PipelineConfig, PipelineReport};
pub use router::{Route, Router, Slot};
pub use scheduler::{AccelTimeline, ScheduledRun};
