//! Request routing: use case -> model variant + primary accelerator slot.
//!
//! Mirrors the paper's deployment matrix (§III-B): DPU-compatible CNNs go
//! to the Vitis-AI slot (INT8), operator-incompatible models to their HLS
//! IP (fp32), with the A53 as fallback when a slot's queue exceeds its
//! backpressure bound.  MMS traffic carries a sub-model selector
//! (Baseline / Reduced / Logistic) so the upload-minimization strategy of
//! Ekelund et al. can be exercised.
//!
//! The static matrix is only the *primary* mapping: per-batch target
//! selection is owned by [`crate::coordinator::dispatch::Dispatcher`],
//! which scores every target in the backend registry and reduces to this
//! table under `Policy::Static`.

use anyhow::Result;

use crate::model::catalog::{model_info, Target};
use crate::model::{Precision, UseCase};

pub use crate::backend::Slot;

/// A routed request: which model variant on which slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    /// Model variant name.
    pub model: String,
    /// Deployed precision on the primary slot.
    pub precision: Precision,
    /// Primary slot (paper deployment matrix).
    pub slot: Slot,
}

/// The router configuration.
#[derive(Debug, Clone)]
pub struct Router {
    /// MMS sub-model to deploy ("baseline" | "reduced" | "logistic").
    pub mms_model: String,
    /// Queue depth beyond which traffic falls back to the CPU.
    pub fallback_depth: usize,
}

impl Default for Router {
    fn default() -> Self {
        Router { mms_model: "baseline".into(), fallback_depth: 64 }
    }
}

impl Router {
    /// Route one use case given the current queue depth of its primary
    /// slot.
    pub fn route(&self, use_case: UseCase, queue_depth: usize) -> Result<Route> {
        let model = match use_case {
            UseCase::Vae => "vae".to_string(),
            UseCase::Cnet => "cnet".to_string(),
            UseCase::Esperta => "esperta".to_string(),
            UseCase::Mms => self.mms_model.clone(),
        };
        let info = model_info(&model)?;
        let (slot, precision) = match info.target {
            Target::Dpu => (Slot::Dpu, Precision::Int8),
            Target::Hls => (Slot::Hls, Precision::Fp32),
        };
        if queue_depth >= self.fallback_depth {
            // paper's CPU baseline doubles as the overload escape hatch
            return Ok(Route { model, precision: Precision::Fp32, slot: Slot::Cpu });
        }
        Ok(Route { model, precision, slot })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deployment_matrix_matches_paper() {
        let r = Router::default();
        assert_eq!(r.route(UseCase::Vae, 0).unwrap().slot, Slot::Dpu);
        assert_eq!(r.route(UseCase::Vae, 0).unwrap().precision, Precision::Int8);
        assert_eq!(r.route(UseCase::Cnet, 0).unwrap().slot, Slot::Dpu);
        let e = r.route(UseCase::Esperta, 0).unwrap();
        assert_eq!(e.slot, Slot::Hls);
        assert_eq!(e.precision, Precision::Fp32);
        assert_eq!(r.route(UseCase::Mms, 0).unwrap().model, "baseline");
    }

    #[test]
    fn mms_submodel_selector() {
        let mut r = Router::default();
        r.mms_model = "logistic".into();
        assert_eq!(r.route(UseCase::Mms, 0).unwrap().model, "logistic");
    }

    #[test]
    fn overload_falls_back_to_cpu() {
        let r = Router::default();
        let route = r.route(UseCase::Vae, 64).unwrap();
        assert_eq!(route.slot, Slot::Cpu);
        assert_eq!(route.precision, Precision::Fp32);
    }

    #[test]
    fn unknown_mms_submodel_rejected() {
        let mut r = Router::default();
        r.mms_model = "nonexistent".into();
        assert!(r.route(UseCase::Mms, 0).is_err());
    }
}
