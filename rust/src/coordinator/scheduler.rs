//! Accelerator timeline: serializes batches onto a slot and advances the
//! virtual ZCU104 clock.
//!
//! Each slot (DPU / per-model HLS IP / CPU) executes one batch at a time.
//! Per batch the slot pays its fixed invoke/setup overhead once, then the
//! per-inference compute time per event — the amortization the batcher
//! exists to exploit.  The timeline accumulates busy time and energy so
//! the pipeline report can cite simulated throughput, utilization, and
//! joules alongside the real (PJRT) outputs.

/// Per-run timing handed to the timeline by the pipeline (from the
/// A53 / DPU / HLS models).
#[derive(Debug, Clone, Copy)]
pub struct ScheduledRun {
    /// Fixed overhead per batch submission (s).
    pub setup_s: f64,
    /// Marginal time per inference in the batch (s).
    pub per_item_s: f64,
    /// MPSoC power while this slot runs (W).
    pub power_w: f64,
}

/// A slot's busy timeline.
#[derive(Debug, Clone)]
pub struct AccelTimeline {
    /// Slot name ("dpu" / "hls" / "cpu").
    pub name: String,
    /// Virtual time the slot becomes free.
    free_at_s: f64,
    /// Accumulated busy time (s).
    pub busy_s: f64,
    /// Accumulated energy (J) at the slot's active power.
    pub energy_j: f64,
    /// Inferences completed.
    pub completed: u64,
    /// Batches executed.
    pub batches: u64,
}

impl AccelTimeline {
    /// Fresh, idle timeline.
    pub fn new(name: &str) -> AccelTimeline {
        AccelTimeline {
            name: name.to_string(),
            free_at_s: 0.0,
            busy_s: 0.0,
            energy_j: 0.0,
            completed: 0,
            batches: 0,
        }
    }

    /// Schedule a batch of `n` items arriving at `now_s`; returns
    /// (start, completion) virtual times.
    pub fn schedule(&mut self, now_s: f64, n: u64, run: ScheduledRun) -> (f64, f64) {
        let start = now_s.max(self.free_at_s);
        let dur = run.setup_s + n as f64 * run.per_item_s;
        let done = start + dur;
        self.free_at_s = done;
        self.busy_s += dur;
        self.energy_j += run.power_w * dur;
        self.completed += n;
        self.batches += 1;
        (start, done)
    }

    /// Queue wait a batch arriving now would experience.
    pub fn backlog_s(&self, now_s: f64) -> f64 {
        (self.free_at_s - now_s).max(0.0)
    }

    /// Utilization over an observation window.
    pub fn utilization(&self, window_s: f64) -> f64 {
        (self.busy_s / window_s).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RUN: ScheduledRun = ScheduledRun {
        setup_s: 0.001,
        per_item_s: 0.0005,
        power_w: 5.0,
    };

    #[test]
    fn serializes_batches() {
        let mut t = AccelTimeline::new("dpu");
        let (s1, d1) = t.schedule(0.0, 2, RUN);
        assert_eq!(s1, 0.0);
        assert!((d1 - 0.002).abs() < 1e-12);
        // second batch arrives while busy: starts at d1
        let (s2, d2) = t.schedule(0.001, 1, RUN);
        assert_eq!(s2, d1);
        assert!((d2 - d1 - 0.0015).abs() < 1e-12);
        assert_eq!(t.completed, 3);
        assert_eq!(t.batches, 2);
    }

    #[test]
    fn idle_gap_not_counted_busy() {
        let mut t = AccelTimeline::new("hls");
        t.schedule(0.0, 1, RUN);
        t.schedule(10.0, 1, RUN); // long idle gap
        assert!((t.busy_s - 0.003).abs() < 1e-12);
        assert!(t.utilization(20.0) < 0.001);
    }

    #[test]
    fn energy_is_power_times_busy() {
        let mut t = AccelTimeline::new("dpu");
        t.schedule(0.0, 4, RUN);
        let expected = 5.0 * (0.001 + 4.0 * 0.0005);
        assert!((t.energy_j - expected).abs() < 1e-12);
    }

    #[test]
    fn backlog_reflects_queue() {
        let mut t = AccelTimeline::new("dpu");
        t.schedule(0.0, 100, RUN);
        assert!(t.backlog_s(0.0) > 0.05);
        assert_eq!(t.backlog_s(100.0), 0.0);
    }

    #[test]
    fn batching_amortizes_setup() {
        let mut batched = AccelTimeline::new("b");
        batched.schedule(0.0, 10, RUN);
        let mut singles = AccelTimeline::new("s");
        for i in 0..10 {
            singles.schedule(i as f64 * 1e-9, 1, RUN);
        }
        assert!(batched.busy_s < singles.busy_s);
        assert!((singles.busy_s - batched.busy_s - 9.0 * RUN.setup_s).abs() < 1e-12);
    }
}
