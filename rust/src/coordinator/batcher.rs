//! Dynamic batching under a latency budget.
//!
//! The DPU (and each HLS IP) executes inferences sequentially, but every
//! submission pays a fixed invoke overhead (the dominant term for small
//! nets — see the DPU timing model).  The batcher accumulates same-model
//! requests and flushes when either the batch is full or the oldest
//! request's latency budget is about to expire, amortizing the overhead
//! across the batch exactly like queued DPU jobs on the real runner.

use std::sync::Arc;

use crate::sensors::SensorEvent;

/// A flushed batch of same-route requests.
#[derive(Debug)]
pub struct Batch {
    /// Model the batch routes to (shared with the batcher — a flush
    /// bumps a refcount instead of cloning a `String`).
    pub model: Arc<str>,
    /// Member events, arrival order.
    pub events: Vec<SensorEvent>,
    /// Virtual time when the batch was flushed.
    pub flushed_at_s: f64,
}

impl Batch {
    /// Events in the batch.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Is the batch empty? (flush never emits one, but the API is
    /// complete)
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The events' input sets in batch order, for one whole-batch
    /// `ExecRequest`.  Refcount bumps only — the buffers stay where the
    /// sensor stream allocated them.
    pub fn input_sets(&self) -> Vec<Arc<Vec<Vec<f32>>>> {
        self.events.iter().map(|ev| ev.inputs.clone()).collect()
    }
}

/// Per-route batcher.
#[derive(Debug)]
pub struct Batcher {
    /// Model this batcher accumulates for.
    pub model: Arc<str>,
    /// Flush threshold (events).
    pub max_batch: usize,
    /// Max time the oldest request may wait before a forced flush (s).
    pub max_wait_s: f64,
    pending: Vec<SensorEvent>,
    oldest_arrival_s: f64,
}

impl Batcher {
    /// Empty batcher (panics on `max_batch == 0`).
    ///
    /// ```
    /// use spaceinfer::coordinator::Batcher;
    /// use spaceinfer::model::UseCase;
    /// use spaceinfer::sensors::SensorStream;
    /// let mut stream = SensorStream::new(UseCase::Esperta, 1, 0.1);
    /// let mut b = Batcher::new("esperta", 2, 10.0);
    /// assert!(b.offer(stream.next_event(), 0.0).is_none());
    /// let batch = b.offer(stream.next_event(), 0.1).expect("full at 2");
    /// assert_eq!(batch.len(), 2);
    /// ```
    pub fn new(model: &str, max_batch: usize, max_wait_s: f64) -> Batcher {
        assert!(max_batch >= 1, "batch size must be >= 1");
        Batcher {
            model: Arc::from(model),
            max_batch,
            max_wait_s,
            pending: Vec::new(),
            oldest_arrival_s: 0.0,
        }
    }

    /// Offer an event at virtual time `now_s`; returns a batch if the
    /// offer filled it.
    pub fn offer(&mut self, ev: SensorEvent, now_s: f64) -> Option<Batch> {
        if self.pending.is_empty() {
            self.oldest_arrival_s = now_s;
        }
        self.pending.push(ev);
        if self.pending.len() >= self.max_batch {
            return self.flush(now_s);
        }
        None
    }

    /// Called on clock ticks: flush if the oldest request's budget is up.
    ///
    /// The flush is stamped at `oldest_arrival + max_wait` — the moment
    /// a real timer would have fired — not at `now_s`.  The run loop
    /// only polls when the *next* event arrives, so stamping at `now_s`
    /// would charge every batch up to a full inter-arrival gap of
    /// phantom wait at low event rates (cadence > max_wait), inflating
    /// latencies and deadline misses with a simulation artifact.
    pub fn poll(&mut self, now_s: f64) -> Option<Batch> {
        if !self.pending.is_empty() && now_s - self.oldest_arrival_s >= self.max_wait_s {
            let fire_at = self.oldest_arrival_s + self.max_wait_s;
            return self.flush(fire_at);
        }
        None
    }

    /// Unconditional flush (shutdown / drain).
    pub fn flush(&mut self, now_s: f64) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        Some(Batch {
            model: self.model.clone(),
            events: std::mem::take(&mut self.pending),
            flushed_at_s: now_s,
        })
    }

    /// Hand back a drained event vector from a finished batch so its
    /// capacity feeds the next accumulation — the allocation-free
    /// steady state.  Stale contents are discarded; no-op unless the
    /// open batch is empty (pending events must not be disturbed) and
    /// the spare actually adds capacity.
    pub fn restock(&mut self, mut spare: Vec<SensorEvent>) {
        if self.pending.is_empty() && spare.capacity() > self.pending.capacity() {
            spare.clear();
            self.pending = spare;
        }
    }

    /// Events waiting in the open batch.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Age of the oldest pending request.
    pub fn oldest_wait_s(&self, now_s: f64) -> f64 {
        if self.pending.is_empty() {
            0.0
        } else {
            now_s - self.oldest_arrival_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::UseCase;
    use crate::sensors::SensorStream;

    fn ev(stream: &mut SensorStream) -> SensorEvent {
        stream.next_event()
    }

    #[test]
    fn flushes_when_full() {
        let mut s = SensorStream::new(UseCase::Esperta, 1, 0.1);
        let mut b = Batcher::new("esperta", 3, 10.0);
        assert!(b.offer(ev(&mut s), 0.0).is_none());
        assert!(b.offer(ev(&mut s), 0.1).is_none());
        let batch = b.offer(ev(&mut s), 0.2).expect("full batch");
        assert_eq!(batch.events.len(), 3);
        assert_eq!(batch.len(), 3);
        assert!(!batch.is_empty());
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn input_sets_share_event_buffers() {
        let mut s = SensorStream::new(UseCase::Mms, 4, 0.1);
        let mut b = Batcher::new("baseline", 2, 10.0);
        b.offer(ev(&mut s), 0.0);
        let batch = b.offer(ev(&mut s), 0.1).expect("full batch");
        let sets = batch.input_sets();
        assert_eq!(sets.len(), 2);
        for (set, event) in sets.iter().zip(&batch.events) {
            assert!(Arc::ptr_eq(set, &event.inputs), "must be zero-copy");
        }
    }

    #[test]
    fn restock_discards_stale_events_and_spares_open_batches() {
        let mut s = SensorStream::new(UseCase::Esperta, 1, 0.1);
        let mut b = Batcher::new("esperta", 4, 10.0);
        // restock into an empty batcher: stale contents are discarded,
        // only the capacity survives
        b.restock(vec![ev(&mut s), ev(&mut s)]);
        assert_eq!(b.pending_len(), 0);
        // an open batch is never disturbed by a restock
        b.offer(ev(&mut s), 0.3);
        b.restock(Vec::with_capacity(64));
        assert_eq!(b.pending_len(), 1);
        // the flushed model tag is the batcher's, shared not copied
        let mut full = Batcher::new("esperta", 1, 10.0);
        let batch = full.offer(ev(&mut s), 0.4).expect("full at 1");
        assert!(Arc::ptr_eq(&batch.model, &full.model));
    }

    #[test]
    fn flushes_on_deadline() {
        let mut s = SensorStream::new(UseCase::Esperta, 2, 0.1);
        let mut b = Batcher::new("esperta", 100, 0.5);
        b.offer(ev(&mut s), 0.0);
        assert!(b.poll(0.4).is_none());
        let batch = b.poll(0.51).expect("deadline flush");
        assert_eq!(batch.events.len(), 1);
        // stamped when the timer would have fired, not when the poll
        // happened to run (no phantom wait at low event rates)
        assert!((batch.flushed_at_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn late_poll_does_not_inflate_wait() {
        let mut s = SensorStream::new(UseCase::Esperta, 3, 0.1);
        let mut b = Batcher::new("esperta", 100, 0.05);
        b.offer(ev(&mut s), 1.0);
        // next event arrives a long gap later: flush fires at 1.05
        let batch = b.poll(2.0).expect("overdue flush");
        assert!((batch.flushed_at_s - 1.05).abs() < 1e-12);
    }

    #[test]
    fn empty_poll_is_none() {
        let mut b = Batcher::new("vae", 4, 1.0);
        assert!(b.poll(100.0).is_none());
        assert!(b.flush(100.0).is_none());
        assert_eq!(b.oldest_wait_s(5.0), 0.0);
    }

    #[test]
    fn oldest_wait_tracks_first_arrival() {
        let mut s = SensorStream::new(UseCase::Mms, 3, 0.1);
        let mut b = Batcher::new("baseline", 10, 99.0);
        b.offer(ev(&mut s), 2.0);
        b.offer(ev(&mut s), 3.0);
        assert!((b.oldest_wait_s(4.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "batch size")]
    fn zero_batch_rejected() {
        Batcher::new("vae", 0, 1.0);
    }
}
