//! Bounded queues with explicit overflow policy.
//!
//! When the accelerator cannot keep up with a sensor (the exact situation
//! the paper's BaselineNet-on-HLS row ends in), the coordinator must shed
//! load deterministically rather than buffer without bound — the
//! spacecraft has neither the RAM nor the downlink for a backlog.

use std::collections::VecDeque;

/// What to do when a bounded queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the incoming item (sensor decimation).
    DropNewest,
    /// Drop the oldest queued item (freshness priority).
    DropOldest,
}

/// A bounded FIFO with drop accounting.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    items: VecDeque<T>,
    /// Maximum queued items.
    pub capacity: usize,
    /// What happens to overflow.
    pub policy: OverflowPolicy,
    /// Items shed so far.
    pub dropped: u64,
    /// Items accepted so far.
    pub accepted: u64,
}

impl<T> BoundedQueue<T> {
    /// Empty queue with a capacity and overflow policy.
    pub fn new(capacity: usize, policy: OverflowPolicy) -> BoundedQueue<T> {
        assert!(capacity > 0, "queue capacity must be > 0");
        BoundedQueue {
            items: VecDeque::with_capacity(capacity),
            capacity,
            policy,
            dropped: 0,
            accepted: 0,
        }
    }

    /// Push with the configured overflow policy. Returns false iff the
    /// *incoming* item was shed.
    pub fn push(&mut self, item: T) -> bool {
        if self.items.len() < self.capacity {
            self.items.push_back(item);
            self.accepted += 1;
            return true;
        }
        self.dropped += 1;
        match self.policy {
            OverflowPolicy::DropNewest => false,
            OverflowPolicy::DropOldest => {
                self.items.pop_front();
                self.items.push_back(item);
                self.accepted += 1;
                true
            }
        }
    }

    /// Pop the oldest item.
    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    /// The oldest queued item, without removing it — what the serve
    /// layer's cross-tenant batch former inspects to pick a lane.
    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Fraction of offered items shed.
    pub fn drop_rate(&self) -> f64 {
        let offered = self.accepted + self.dropped;
        if offered == 0 {
            0.0
        } else {
            self.dropped as f64 / offered as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = BoundedQueue::new(3, OverflowPolicy::DropNewest);
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn drop_newest_sheds_incoming() {
        let mut q = BoundedQueue::new(2, OverflowPolicy::DropNewest);
        assert!(q.push(1));
        assert!(q.push(2));
        assert!(!q.push(3));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.dropped, 1);
    }

    #[test]
    fn drop_oldest_keeps_freshest() {
        let mut q = BoundedQueue::new(2, OverflowPolicy::DropOldest);
        q.push(1);
        q.push(2);
        assert!(q.push(3));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.dropped, 1);
    }

    #[test]
    fn drop_rate_accounting() {
        let mut q = BoundedQueue::new(1, OverflowPolicy::DropNewest);
        q.push(1);
        q.push(2);
        q.push(3);
        assert!((q.drop_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        BoundedQueue::<u8>::new(0, OverflowPolicy::DropNewest);
    }
}
