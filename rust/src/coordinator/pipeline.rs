//! The end-to-end on-board pipeline: wires sensors, router, batcher,
//! executor (real PJRT numerics), the timing/power simulators (virtual
//! ZCU104 clock), decision logic, and the downlink manager.
//!
//! The serving hot path is batch-native: each flushed `Batch` becomes
//! exactly one `ExecRequest` (input buffers `Arc`-shared, no per-event
//! copies or channel round trips), and completions are reaped
//! asynchronously so event generation, batching, and execution overlap.
//! Completions are *processed* in submission order regardless of
//! arrival order, which keeps the decision RNG stream — and therefore
//! the whole `PipelineReport` — deterministic for a given seed.

use std::collections::BTreeMap;
use std::sync::mpsc;

use anyhow::{anyhow, bail, Context, Result};

use crate::board::{Calibration, Zcu104};
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::decision::{decide, Decision};
use crate::coordinator::downlink::{DownlinkManager, DownlinkVerdict};
use crate::coordinator::router::{Route, Router, Slot};
use crate::coordinator::scheduler::{AccelTimeline, ScheduledRun};
use crate::cpu::A53Model;
use crate::dpu::{DpuArch, DpuSchedule};
use crate::hls::HlsDesign;
use crate::model::catalog::{model_info, Catalog};
use crate::power::{Implementation, PowerModel};
use crate::resources::estimate_hls;
use crate::runtime::{ExecRequest, ExecResult, ExecutorPool};
use crate::sensors::{SensorEvent, SensorStream};
use crate::telemetry::Metrics;
use crate::util::prng::Prng;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// "vae" | "cnet" | "esperta" | "mms"
    pub use_case: &'static str,
    /// Events to process.
    pub n_events: usize,
    /// Sensor cadence (s).
    pub cadence_s: f64,
    pub max_batch: usize,
    pub max_wait_s: f64,
    /// Downlink budget for the run (bytes).
    pub downlink_budget: u64,
    /// MMS sub-model ("baseline" | "reduced" | "logistic").
    pub mms_model: String,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            use_case: "mms",
            n_events: 100,
            cadence_s: 0.15,
            max_batch: 8,
            max_wait_s: 0.5,
            downlink_budget: 64 * 1024,
            mms_model: "baseline".into(),
            seed: 7,
        }
    }
}

/// Summary of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    pub use_case: String,
    pub model: String,
    pub slot: Slot,
    pub events: u64,
    /// Simulated wall time of the run (s).
    pub sim_elapsed_s: f64,
    /// Simulated mean end-to-end latency (arrival -> decision, s).
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
    /// Simulated accelerator throughput (inferences/s while busy).
    pub busy_fps: f64,
    pub accel_utilization: f64,
    /// Simulated MPSoC energy spent on inference (J).
    pub energy_j: f64,
    pub downlink_sent: u64,
    pub downlink_shed: u64,
    pub downlink_sent_bytes: u64,
    pub compression_ratio: f64,
    /// Decision accuracy vs ground truth, when truth exists.
    pub accuracy: Option<f64>,
    pub decisions: BTreeMap<String, u64>,
    pub metrics: Metrics,
}

impl PipelineReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pipeline [{}] model={} slot={:?}\n",
            self.use_case, self.model, self.slot
        ));
        out.push_str(&format!(
            "  events {}  sim_elapsed {:.3}s  mean_latency {:.4}s  p95 {:.4}s\n",
            self.events, self.sim_elapsed_s, self.mean_latency_s, self.p95_latency_s
        ));
        out.push_str(&format!(
            "  busy_fps {:.1}  util {:.1}%  energy {:.3}J\n",
            self.busy_fps,
            100.0 * self.accel_utilization,
            self.energy_j
        ));
        out.push_str(&format!(
            "  downlink: sent {} ({} B) shed {}  compression {:.0}:1\n",
            self.downlink_sent, self.downlink_sent_bytes, self.downlink_shed,
            self.compression_ratio
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!("  decision accuracy vs truth: {:.1}%\n", 100.0 * acc));
        }
        for (k, v) in &self.decisions {
            out.push_str(&format!("  decision[{k}] = {v}\n"));
        }
        out
    }
}

/// Mutable per-run state threaded through dispatch and reap.
struct RunState {
    timeline: AccelTimeline,
    downlink: DownlinkManager,
    metrics: Metrics,
    rng: Prng,
    latencies: Vec<f64>,
    decisions: BTreeMap<String, u64>,
    correct: u64,
    with_truth: u64,
    sim_end: f64,
}

impl RunState {
    /// Post-inference stages for one event: decision, truth scoring,
    /// downlink verdict.
    fn decide_one(
        &mut self,
        use_case: &'static str,
        ev: &SensorEvent,
        output: &[f32],
        input_bytes: u64,
    ) {
        let d = decide(use_case, output, &mut self.rng);
        if let Some(truth) = ev.truth {
            self.with_truth += 1;
            if decision_matches_truth(&d, truth) {
                self.correct += 1;
            }
        }
        *self.decisions.entry(decision_key(&d)).or_insert(0) += 1;
        match self.downlink.offer(&d, input_bytes) {
            DownlinkVerdict::Sent => self.metrics.inc("downlink_sent"),
            DownlinkVerdict::Shed => self.metrics.inc("downlink_shed"),
        }
    }
}

/// In-flight batches: submitted to the pool, awaiting reap.  Results
/// may arrive out of order across workers; processing is forced back
/// into submission order so runs are deterministic.
struct Reaper<'a> {
    pool: &'a ExecutorPool,
    reply_tx: mpsc::Sender<ExecResult>,
    reply_rx: mpsc::Receiver<ExecResult>,
    /// Next batch id to assign at submit.
    next_id: u64,
    /// Next batch id to process (strict submission order).
    next_done: u64,
    /// Events of submitted batches, keyed by batch id.
    pending: BTreeMap<u64, Vec<SensorEvent>>,
    /// Completions that arrived ahead of `next_done`.
    arrived: BTreeMap<u64, ExecResult>,
}

impl<'a> Reaper<'a> {
    fn new(pool: &'a ExecutorPool) -> Reaper<'a> {
        let (reply_tx, reply_rx) = mpsc::channel();
        Reaper {
            pool,
            reply_tx,
            reply_rx,
            next_id: 0,
            next_done: 0,
            pending: BTreeMap::new(),
            arrived: BTreeMap::new(),
        }
    }

    /// One `ExecRequest` for the whole batch — the only executor
    /// dispatch on this path.
    fn submit(&mut self, route: &Route, batch: Batch) -> Result<()> {
        let items = batch.input_sets(); // Arc clones, zero-copy
        let id = self.next_id;
        self.next_id += 1;
        self.pending.insert(id, batch.events);
        self.pool.submit(ExecRequest {
            model: route.model.clone(),
            precision: route.precision,
            items,
            reply: self.reply_tx.clone(),
            id,
        })
    }

    fn in_flight(&self) -> bool {
        self.next_done < self.next_id
    }

    /// Process every completion whose turn has come.
    fn process_arrived(
        &mut self,
        use_case: &'static str,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while let Some(res) = self.arrived.remove(&self.next_done) {
            let events = self
                .pending
                .remove(&res.id)
                .ok_or_else(|| anyhow!("reaped unknown batch id {}", res.id))?;
            let outputs = res
                .outputs
                .with_context(|| format!("executing batch {}", res.id))?;
            if outputs.len() != events.len() {
                bail!(
                    "batch {}: {} outputs for {} events",
                    res.id,
                    outputs.len(),
                    events.len()
                );
            }
            state.metrics.inc("exec_batches_reaped");
            state.metrics.observe("host_batch_execute", res.host_elapsed);
            state.metrics.observe(
                "host_per_inference",
                res.host_elapsed / events.len().max(1) as u32,
            );
            state.metrics.inc(&format!("exec_worker_{}", res.worker));
            for (ev, out) in events.iter().zip(&outputs) {
                state.decide_one(use_case, ev, out, input_bytes);
            }
            self.next_done += 1;
        }
        Ok(())
    }

    /// Non-blocking reap: absorb whatever has completed, process what's
    /// in order.  Called between submissions so the coordinator
    /// overlaps with execution instead of stalling on each batch.
    fn drain_ready(
        &mut self,
        use_case: &'static str,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while let Ok(res) = self.reply_rx.try_recv() {
            self.arrived.insert(res.id, res);
        }
        self.process_arrived(use_case, input_bytes, state)
    }

    /// Block until fewer than `cap` batches are in flight, so pending
    /// events and their input buffers stay bounded even when the
    /// backend is slower than event generation (the virtual clock
    /// generates events faster than any real backend executes them).
    fn throttle(
        &mut self,
        cap: u64,
        use_case: &'static str,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while self.next_id - self.next_done >= cap {
            let res = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("executor dropped the reply channel"))?;
            self.arrived.insert(res.id, res);
            self.process_arrived(use_case, input_bytes, state)?;
        }
        Ok(())
    }

    /// Blocking reap of everything still in flight (end of run).
    fn drain_all(
        &mut self,
        use_case: &'static str,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while self.in_flight() {
            let res = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("executor dropped the reply channel"))?;
            self.arrived.insert(res.id, res);
            self.process_arrived(use_case, input_bytes, state)?;
        }
        Ok(())
    }
}

/// The pipeline itself.
pub struct Pipeline {
    pub config: PipelineConfig,
    pub route: Route,
    run_params: ScheduledRun,
    input_bytes: u64,
}

impl Pipeline {
    /// Resolve routing and simulated timing for the configured use case.
    pub fn new(config: PipelineConfig, catalog: &Catalog, calib: &Calibration) -> Result<Pipeline> {
        let mut router = Router::default();
        router.mms_model = config.mms_model.clone();
        let route = router.route(config.use_case, 0)?;
        let board = Zcu104::default();
        let info = model_info(&route.model)?;
        let man = catalog
            .manifest(&route.model, route.precision)
            .context("pipeline needs `make artifacts` output")?;
        let power = PowerModel::new(calib.clone());
        let run_params = match route.slot {
            Slot::Dpu => {
                let sched = DpuSchedule::new(
                    man,
                    DpuArch::b4096(calib, board.dpu_clock_hz),
                    calib,
                    board.axi_bandwidth,
                )?;
                let per_item = sched.latency_s() - sched.invoke_s;
                ScheduledRun {
                    setup_s: sched.invoke_s,
                    per_item_s: per_item,
                    power_w: power.mpsoc_w(&PowerModel::dpu_impl(&sched)),
                }
            }
            Slot::Hls => {
                let design = HlsDesign::synthesize(man, &board, calib);
                let setup = design.axi_setup_cycles / design.clock_hz;
                let util = estimate_hls(man, &design.plan);
                ScheduledRun {
                    setup_s: setup,
                    per_item_s: design.latency_s() - setup,
                    power_w: power.mpsoc_w(&Implementation::Hls {
                        kiloluts: util.luts as f64 / 1000.0,
                        brams: design.plan.brams(),
                        duty: 1.0,
                    }),
                }
            }
            Slot::Cpu => {
                let a53 = A53Model::calibrated(man, calib, info.paper.cpu_fps);
                ScheduledRun {
                    setup_s: 0.0,
                    per_item_s: a53.latency_s(),
                    power_w: info.paper.cpu_p_mpsoc,
                }
            }
        };
        Ok(Pipeline {
            config,
            route,
            run_params,
            input_bytes: man.input_bytes(),
        })
    }

    /// Advance the virtual clock for one batch, then hand it to the
    /// executor (one request per batch) or run the surrogate inline.
    fn dispatch(
        &self,
        batch: Batch,
        state: &mut RunState,
        reaper: &mut Option<Reaper<'_>>,
    ) -> Result<()> {
        let cfg = &self.config;
        let n = batch.len() as u64;
        let (_start, done) =
            state.timeline.schedule(batch.flushed_at_s, n, self.run_params);
        state.sim_end = state.sim_end.max(done);
        state.metrics.add("batches", 1);
        state.metrics.add("inferences", n);
        for ev in &batch.events {
            state.latencies.push(done - ev.t_s);
        }
        match reaper {
            Some(r) => {
                r.submit(&self.route, batch)?;
                // overlap: absorb any batches that already finished,
                // then apply backpressure so in-flight work is bounded
                r.drain_ready(cfg.use_case, self.input_bytes, state)?;
                r.throttle(
                    MAX_INFLIGHT_BATCHES,
                    cfg.use_case,
                    self.input_bytes,
                    state,
                )
            }
            None => {
                // timing-only run: deterministic surrogate numerics,
                // processed inline (same RNG order as the PJRT path)
                for ev in &batch.events {
                    let out =
                        surrogate_output(cfg.use_case, ev, &mut state.rng)?;
                    state.decide_one(cfg.use_case, ev, &out, self.input_bytes);
                }
                Ok(())
            }
        }
    }

    /// Run the pipeline.  `executor` supplies real numerics through the
    /// sharded pool; pass `None` for a timing-only (simulated outputs)
    /// run — decisions then come from a deterministic surrogate so
    /// downstream stages still exercise.
    pub fn run(&self, executor: Option<&ExecutorPool>) -> Result<PipelineReport> {
        let cfg = &self.config;
        let mut stream = SensorStream::new(cfg.use_case, cfg.seed, cfg.cadence_s);
        let mut batcher = Batcher::new(&self.route.model, cfg.max_batch, cfg.max_wait_s);
        let mut state = RunState {
            timeline: AccelTimeline::new(self.route.slot_name()),
            downlink: DownlinkManager::new(cfg.downlink_budget),
            metrics: Metrics::default(),
            rng: Prng::new(cfg.seed ^ DECISION_RNG_SALT),
            latencies: Vec::with_capacity(cfg.n_events),
            decisions: BTreeMap::new(),
            correct: 0,
            with_truth: 0,
            sim_end: 0.0,
        };
        let mut reaper = executor.map(Reaper::new);

        for _ in 0..cfg.n_events {
            let ev = stream.next_event();
            let now = ev.t_s;
            if let Some(b) = batcher.poll(now) {
                self.dispatch(b, &mut state, &mut reaper)?;
            }
            if let Some(b) = batcher.offer(ev, now) {
                self.dispatch(b, &mut state, &mut reaper)?;
            }
        }
        let drain_t = cfg.n_events as f64 * cfg.cadence_s + cfg.max_wait_s;
        if let Some(b) = batcher.flush(drain_t) {
            self.dispatch(b, &mut state, &mut reaper)?;
        }
        if let Some(r) = &mut reaper {
            r.drain_all(cfg.use_case, self.input_bytes, &mut state)?;
        }

        let RunState {
            timeline,
            downlink,
            metrics,
            mut latencies,
            decisions,
            correct,
            with_truth,
            sim_end,
            ..
        } = state;
        latencies.sort_by(f64::total_cmp);
        let mean = latencies.iter().sum::<f64>() / latencies.len().max(1) as f64;
        let p95 = percentile_nearest_rank(&latencies, 0.95);
        let busy_fps = if timeline.busy_s > 0.0 {
            timeline.completed as f64 / timeline.busy_s
        } else {
            0.0
        };
        Ok(PipelineReport {
            use_case: cfg.use_case.to_string(),
            model: self.route.model.clone(),
            slot: self.route.slot,
            events: timeline.completed,
            sim_elapsed_s: sim_end,
            mean_latency_s: mean,
            p95_latency_s: p95,
            busy_fps,
            accel_utilization: timeline.utilization(sim_end.max(1e-9)),
            energy_j: timeline.energy_j,
            downlink_sent: downlink.sent_count,
            downlink_shed: downlink.shed_count,
            downlink_sent_bytes: downlink.sent_bytes,
            compression_ratio: downlink.compression_ratio(),
            accuracy: if with_truth > 0 {
                Some(correct as f64 / with_truth as f64)
            } else {
                None
            },
            decisions,
            metrics,
        })
    }
}

/// Nearest-rank percentile over a sorted sample: the smallest value
/// with at least `q` of the mass at or below it (`ceil(q*n)` as a
/// 1-indexed rank).  Truncating the rank instead (`(n*q) as usize`)
/// understates tail latency for small n.
fn percentile_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

impl Route {
    fn slot_name(&self) -> &'static str {
        match self.slot {
            Slot::Dpu => "dpu",
            Slot::Hls => "hls",
            Slot::Cpu => "cpu",
        }
    }
}

/// Salt separating the decision RNG stream from the sensor stream.
const DECISION_RNG_SALT: u64 = 0xD01E_57A7;

/// Backpressure cap on batches submitted but not yet reaped: enough to
/// keep every worker busy with headroom, small enough that pending
/// input buffers stay O(cap * max_batch) rather than O(n_events).
const MAX_INFLIGHT_BATCHES: u64 = 64;

/// Deterministic surrogate outputs for timing-only runs (no executor).
fn surrogate_output(
    use_case: &str,
    ev: &SensorEvent,
    rng: &mut Prng,
) -> Result<Vec<f32>> {
    Ok(match use_case {
        "mms" => {
            let mut v = vec![0.0f32; 4];
            if let Some(t) = ev.truth {
                v[t] = 1.0 + rng.f32();
            }
            v
        }
        "esperta" => {
            let mut v = vec![0.2f32; 12];
            if ev.truth == Some(1) {
                for i in 0..6 {
                    v[i] = 0.9;
                    v[6 + i] = 1.0;
                }
            }
            v
        }
        "vae" => (0..12).map(|_| rng.normal() as f32).collect(),
        "cnet" => vec![-6.0 + 2.0 * rng.f32()],
        other => bail!("no surrogate for unknown use case {other:?}"),
    })
}

fn decision_key(d: &Decision) -> String {
    match d {
        Decision::MmsRegion { region, .. } => format!("region_{}", region.label()),
        Decision::SepAlert { warning, .. } => {
            format!("sep_{}", if *warning { "alert" } else { "quiet" })
        }
        Decision::Latent { .. } => "latent".into(),
        Decision::FluxForecast { alert, .. } => {
            format!("flux_{}", if *alert { "alert" } else { "nominal" })
        }
    }
}

fn decision_matches_truth(d: &Decision, truth: usize) -> bool {
    match d {
        Decision::MmsRegion { region, .. } => region.index() == truth,
        Decision::SepAlert { warning, .. } => (*warning as usize) == truth,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentile() {
        // n=10, q=0.95 -> rank ceil(9.5)=10 -> last element (truncation
        // would pick index 9 too, but q=0.5 separates the conventions)
        let v: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&v, 0.95), 10.0);
        assert_eq!(percentile_nearest_rank(&v, 0.5), 5.0);
        // small n: p95 of 3 samples must be the max, not the middle
        let small = [1.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&small, 0.95), 3.0);
        assert_eq!(percentile_nearest_rank(&[], 0.95), 0.0);
        assert_eq!(percentile_nearest_rank(&[7.0], 0.95), 7.0);
        // q=1.0 and beyond-clamp stay in bounds
        assert_eq!(percentile_nearest_rank(&small, 1.0), 3.0);
        assert_eq!(percentile_nearest_rank(&small, 0.0), 1.0);
    }

    #[test]
    fn surrogate_rejects_unknown_use_case() {
        let mut rng = Prng::new(1);
        let ev = SensorEvent {
            t_s: 0.0,
            use_case: "mms",
            inputs: std::sync::Arc::new(vec![vec![0.0; 4]]),
            truth: Some(1),
            seq: 0,
        };
        assert!(surrogate_output("mms", &ev, &mut rng).is_ok());
        assert!(surrogate_output("radar", &ev, &mut rng).is_err());
    }
}
