//! The end-to-end on-board pipeline: wires sensors, router, batcher,
//! cost-model dispatcher, executor (real PJRT numerics), the
//! timing/power simulators (virtual ZCU104 clock), decision logic, and
//! the downlink manager.
//!
//! The serving hot path is batch-native: each flushed `Batch` becomes
//! exactly one `ExecRequest` (input buffers `Arc`-shared, no per-event
//! copies or channel round trips), and completions are reaped
//! asynchronously so event generation, batching, and execution overlap.
//! Completions are *processed* in submission order regardless of
//! arrival order, which keeps the decision RNG stream — and therefore
//! the whole `PipelineReport` — deterministic for a given seed.
//!
//! Target selection is per batch: the [`Dispatcher`] scores every
//! target in the backend registry (the paper's A53 / DPU / HLS triple
//! by default; the full DPU family and pipelined HLS under
//! `--targets all`) and picks under the configured [`Policy`].  Each
//! batch's predicted latency/energy land in telemetry next to the
//! "measured" (virtual clock) values, so calibration drift between the
//! cost model and the timeline shows up as a nonzero prediction error.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{AccelModel, TargetSet};
use crate::board::Calibration;
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::decision::{decide, Decision};
use crate::coordinator::dispatch::{default_deadline_s, Dispatcher, Policy};
use crate::coordinator::downlink::{DownlinkManager, DownlinkVerdict};
use crate::coordinator::router::{Route, Router, Slot};
use crate::coordinator::scheduler::AccelTimeline;
use crate::model::catalog::Catalog;
use crate::model::{Precision, UseCase};
use crate::runtime::{ExecRequest, ExecResult, ExecutorPool};
use crate::sensors::{SensorEvent, SensorStream};
use crate::telemetry::Metrics;
use crate::util::prng::Prng;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Which paper use case the run serves.
    pub use_case: UseCase,
    /// Events to process.
    pub n_events: usize,
    /// Sensor cadence (s).
    pub cadence_s: f64,
    /// Batcher flush threshold (events).
    pub max_batch: usize,
    /// Batcher latency budget before a forced flush (s).
    pub max_wait_s: f64,
    /// Downlink budget for the run (bytes).
    pub downlink_budget: u64,
    /// MMS sub-model ("baseline" | "reduced" | "logistic").
    pub mms_model: String,
    /// Seed for the sensor + decision RNG streams.
    pub seed: u64,
    /// Per-batch target-selection policy.
    pub policy: Policy,
    /// End-to-end deadline override (s); `None` uses the per-use-case
    /// default (`dispatch::default_deadline_s`).
    pub deadline_s: Option<f64>,
    /// Mission power budget: cap on active MPSoC draw (W), `None` = off.
    pub power_budget_w: Option<f64>,
    /// Which backend targets to register (`default` = the paper's
    /// triple; `all` opens the DPU family + pipelined HLS).
    pub targets: TargetSet,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            use_case: UseCase::Mms,
            n_events: 100,
            cadence_s: 0.15,
            max_batch: 8,
            max_wait_s: 0.5,
            downlink_budget: 64 * 1024,
            mms_model: "baseline".into(),
            seed: 7,
            policy: Policy::Static,
            deadline_s: None,
            power_budget_w: None,
            targets: TargetSet::Default,
        }
    }
}

/// Summary of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    /// Use case the run served.
    pub use_case: UseCase,
    /// Model variant name.
    pub model: String,
    /// Primary (paper deployment-matrix) slot.
    pub slot: Slot,
    /// Dispatch policy the run used.
    pub policy: String,
    /// Batches dispatched per registry target name ("cpu" / "dpu" /
    /// "dpu-b512" / "hls" / "hls-pipe" / ...).
    pub target_mix: BTreeMap<String, u64>,
    /// Events completed on the virtual clock.
    pub events: u64,
    /// Simulated wall time of the run (s).
    pub sim_elapsed_s: f64,
    /// Simulated mean end-to-end latency (arrival -> decision, s).
    pub mean_latency_s: f64,
    /// Simulated p95 end-to-end latency (s).
    pub p95_latency_s: f64,
    /// Simulated accelerator throughput (inferences/s while busy).
    pub busy_fps: f64,
    /// Aggregate busy time over the run window, summed across targets —
    /// exceeds 1.0 when several targets run concurrently (each target's
    /// own timeline is serial, so a single-target run stays ≤ 1.0).
    pub accel_utilization: f64,
    /// Simulated MPSoC energy spent on inference (J), all targets.
    pub energy_j: f64,
    /// Cost-model predicted energy (J) — equals `energy_j` while the
    /// dispatcher and the timeline share calibration; drift is a bug.
    pub predicted_energy_j: f64,
    /// Batches whose oldest event missed the deadline.
    pub deadline_misses: u64,
    /// Batches the power budget steered away from the policy's pick.
    pub power_sheds: u64,
    /// Decisions the downlink kept.
    pub downlink_sent: u64,
    /// Decisions the downlink shed.
    pub downlink_shed: u64,
    /// Bytes actually downlinked.
    pub downlink_sent_bytes: u64,
    /// Raw sensor bytes represented per byte downlinked.
    pub compression_ratio: f64,
    /// Decision accuracy vs ground truth, when truth exists.
    pub accuracy: Option<f64>,
    /// Decision label -> count.
    pub decisions: BTreeMap<String, u64>,
    /// Counters + histograms collected during the run.
    pub metrics: Metrics,
}

impl PipelineReport {
    /// The target mix as `cpu:3 dpu:9` (`-` when no batch dispatched) —
    /// the one formatting shared by the report, the policy table, and
    /// the examples.
    pub fn mix_str(mix: &BTreeMap<String, u64>) -> String {
        if mix.is_empty() {
            return "-".into();
        }
        mix.iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// This run's target mix, formatted.
    pub fn target_mix_str(&self) -> String {
        PipelineReport::mix_str(&self.target_mix)
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pipeline [{}] model={} slot={:?} policy={}\n",
            self.use_case, self.model, self.slot, self.policy
        ));
        out.push_str(&format!(
            "  target mix [{}]  deadline_misses {}  power_sheds {}\n",
            self.target_mix_str(),
            self.deadline_misses,
            self.power_sheds
        ));
        out.push_str(&format!(
            "  events {}  sim_elapsed {:.3}s  mean_latency {:.4}s  p95 {:.4}s\n",
            self.events, self.sim_elapsed_s, self.mean_latency_s, self.p95_latency_s
        ));
        out.push_str(&format!(
            "  busy_fps {:.1}  util {:.1}%  energy {:.3}J (predicted {:.3}J)\n",
            self.busy_fps,
            100.0 * self.accel_utilization,
            self.energy_j,
            self.predicted_energy_j
        ));
        out.push_str(&format!(
            "  downlink: sent {} ({} B) shed {}  compression {:.0}:1\n",
            self.downlink_sent, self.downlink_sent_bytes, self.downlink_shed,
            self.compression_ratio
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!("  decision accuracy vs truth: {:.1}%\n", 100.0 * acc));
        }
        for (k, v) in &self.decisions {
            out.push_str(&format!("  decision[{k}] = {v}\n"));
        }
        out
    }
}

/// Mutable per-run state threaded through dispatch and reap.
struct RunState {
    /// Per-target queue state, index-aligned with `Dispatcher::targets`.
    timelines: Vec<AccelTimeline>,
    downlink: DownlinkManager,
    metrics: Metrics,
    rng: Prng,
    latencies: Vec<f64>,
    decisions: BTreeMap<String, u64>,
    target_batches: BTreeMap<String, u64>,
    predicted_energy_j: f64,
    deadline_misses: u64,
    power_sheds: u64,
    correct: u64,
    with_truth: u64,
    sim_end: f64,
}

impl RunState {
    /// Post-inference stages for one event: decision, truth scoring,
    /// downlink verdict.
    fn decide_one(
        &mut self,
        use_case: UseCase,
        ev: &SensorEvent,
        output: &[f32],
        input_bytes: u64,
    ) {
        let d = decide(use_case, output, &mut self.rng);
        if let Some(truth) = ev.truth {
            self.with_truth += 1;
            if decision_matches_truth(&d, truth) {
                self.correct += 1;
            }
        }
        *self.decisions.entry(decision_key(&d)).or_insert(0) += 1;
        match self.downlink.offer(&d, input_bytes) {
            DownlinkVerdict::Sent => self.metrics.inc("downlink_sent"),
            DownlinkVerdict::Shed => self.metrics.inc("downlink_shed"),
        }
    }
}

/// In-flight batches: submitted to the pool, awaiting reap.  Results
/// may arrive out of order across workers; processing is forced back
/// into submission order so runs are deterministic.
struct Reaper<'a> {
    pool: &'a ExecutorPool,
    reply_tx: mpsc::Sender<ExecResult>,
    reply_rx: mpsc::Receiver<ExecResult>,
    /// Next batch id to assign at submit.
    next_id: u64,
    /// Next batch id to process (strict submission order).
    next_done: u64,
    /// Events of submitted batches, keyed by batch id.
    pending: BTreeMap<u64, Vec<SensorEvent>>,
    /// Completions that arrived ahead of `next_done`.
    arrived: BTreeMap<u64, ExecResult>,
}

impl<'a> Reaper<'a> {
    fn new(pool: &'a ExecutorPool) -> Reaper<'a> {
        let (reply_tx, reply_rx) = mpsc::channel();
        Reaper {
            pool,
            reply_tx,
            reply_rx,
            next_id: 0,
            next_done: 0,
            pending: BTreeMap::new(),
            arrived: BTreeMap::new(),
        }
    }

    /// One `ExecRequest` for the whole batch — the only executor
    /// dispatch on this path.  `precision` follows the chosen target
    /// (int8 on the DPU slot, fp32 elsewhere).
    fn submit(&mut self, model: &str, precision: Precision, batch: Batch) -> Result<()> {
        let items = batch.input_sets(); // Arc clones, zero-copy
        let id = self.next_id;
        self.next_id += 1;
        self.pending.insert(id, batch.events);
        self.pool.submit(ExecRequest {
            model: model.to_string(),
            precision,
            items,
            reply: self.reply_tx.clone(),
            id,
        })
    }

    fn in_flight(&self) -> bool {
        self.next_done < self.next_id
    }

    /// Process every completion whose turn has come.
    fn process_arrived(
        &mut self,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while let Some(res) = self.arrived.remove(&self.next_done) {
            let events = self
                .pending
                .remove(&res.id)
                .ok_or_else(|| anyhow!("reaped unknown batch id {}", res.id))?;
            let outputs = res
                .outputs
                .with_context(|| format!("executing batch {}", res.id))?;
            if outputs.len() != events.len() {
                bail!(
                    "batch {}: {} outputs for {} events",
                    res.id,
                    outputs.len(),
                    events.len()
                );
            }
            state.metrics.inc("exec_batches_reaped");
            state.metrics.observe("host_batch_execute", res.host_elapsed);
            state.metrics.observe(
                "host_per_inference",
                res.host_elapsed / events.len().max(1) as u32,
            );
            state.metrics.inc(&format!("exec_worker_{}", res.worker));
            for (ev, out) in events.iter().zip(&outputs) {
                state.decide_one(use_case, ev, out, input_bytes);
            }
            self.next_done += 1;
        }
        Ok(())
    }

    /// Non-blocking reap: absorb whatever has completed, process what's
    /// in order.  Called between submissions so the coordinator
    /// overlaps with execution instead of stalling on each batch.
    fn drain_ready(
        &mut self,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while let Ok(res) = self.reply_rx.try_recv() {
            self.arrived.insert(res.id, res);
        }
        self.process_arrived(use_case, input_bytes, state)
    }

    /// Block until fewer than `cap` batches are in flight, so pending
    /// events and their input buffers stay bounded even when the
    /// backend is slower than event generation (the virtual clock
    /// generates events faster than any real backend executes them).
    fn throttle(
        &mut self,
        cap: u64,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while self.next_id - self.next_done >= cap {
            let res = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("executor dropped the reply channel"))?;
            self.arrived.insert(res.id, res);
            self.process_arrived(use_case, input_bytes, state)?;
        }
        Ok(())
    }

    /// Blocking reap of everything still in flight (end of run).
    fn drain_all(
        &mut self,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while self.in_flight() {
            let res = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("executor dropped the reply channel"))?;
            self.arrived.insert(res.id, res);
            self.process_arrived(use_case, input_bytes, state)?;
        }
        Ok(())
    }
}

/// The pipeline itself.
pub struct Pipeline {
    /// Run configuration.
    pub config: PipelineConfig,
    /// Primary route (paper deployment matrix) for the use case.
    pub route: Route,
    /// Per-batch target selection (cost model + policy).
    pub dispatcher: Dispatcher,
    input_bytes: u64,
}

impl Pipeline {
    /// Resolve routing, build the dispatcher's cost table, and bind the
    /// simulated timing for the configured use case.
    pub fn new(config: PipelineConfig, catalog: &Catalog, calib: &Calibration) -> Result<Pipeline> {
        let mut router = Router::default();
        router.mms_model = config.mms_model.clone();
        let route = router.route(config.use_case, 0)?;
        let man = catalog
            .manifest(&route.model, route.precision)
            .context("pipeline needs `make artifacts` output")?;
        let input_bytes = man.input_bytes();
        let deadline_s = config
            .deadline_s
            .unwrap_or_else(|| default_deadline_s(config.use_case));
        let dispatcher = Dispatcher::new(
            &route.model,
            catalog,
            calib,
            config.policy,
            deadline_s,
            config.power_budget_w,
            &config.targets,
        )?;
        Ok(Pipeline { config, route, dispatcher, input_bytes })
    }

    /// Pick a target for one batch, advance its virtual-clock timeline,
    /// then hand the batch to the executor (one request per batch) or
    /// run the surrogate inline.
    fn dispatch(
        &self,
        batch: Batch,
        state: &mut RunState,
        reaper: &mut Option<Reaper<'_>>,
    ) -> Result<()> {
        let cfg = &self.config;
        let n = batch.len() as u64;
        let oldest_t_s = batch.events.first().map(|e| e.t_s).unwrap_or(batch.flushed_at_s);
        let choice =
            self.dispatcher
                .choose(&state.timelines, batch.flushed_at_s, oldest_t_s, n);
        let target = self.dispatcher.registry.get(choice.index);
        let (_start, done) = state.timelines[choice.index].schedule(
            batch.flushed_at_s,
            n,
            self.dispatcher.run_of(choice.index),
        );
        state.sim_end = state.sim_end.max(done);
        state.metrics.add("batches", 1);
        state.metrics.add("inferences", n);
        state.metrics.inc(&format!("dispatch_{}", target.name()));
        *state
            .target_batches
            .entry(target.name().to_string())
            .or_insert(0) += 1;
        // predicted-vs-"measured" (virtual clock) telemetry: equal while
        // the cost model and the timeline share calibration; drift here
        // means the dispatcher is optimizing against a stale model
        state.predicted_energy_j += choice.cost.energy_j;
        state.metrics.observe(
            "predicted_batch_latency",
            Duration::from_secs_f64(choice.cost.latency_s.max(0.0)),
        );
        state.metrics.observe(
            "measured_batch_latency",
            Duration::from_secs_f64((done - batch.flushed_at_s).max(0.0)),
        );
        if done - oldest_t_s > self.dispatcher.deadline_s {
            state.deadline_misses += 1;
            state.metrics.inc("deadline_miss_batches");
        }
        if choice.power_shed {
            state.power_sheds += 1;
            state.metrics.inc("power_shed_batches");
        }
        for ev in &batch.events {
            state.latencies.push(done - ev.t_s);
        }
        match reaper {
            Some(r) => {
                r.submit(&self.route.model, target.precision(), batch)?;
                // overlap: absorb any batches that already finished,
                // then apply backpressure so in-flight work is bounded
                r.drain_ready(cfg.use_case, self.input_bytes, state)?;
                r.throttle(
                    MAX_INFLIGHT_BATCHES,
                    cfg.use_case,
                    self.input_bytes,
                    state,
                )
            }
            None => {
                // timing-only run: deterministic surrogate numerics,
                // processed inline (same RNG order as the PJRT path)
                for ev in &batch.events {
                    let out = surrogate_output(cfg.use_case, ev, &mut state.rng);
                    state.decide_one(cfg.use_case, ev, &out, self.input_bytes);
                }
                Ok(())
            }
        }
    }

    /// Run the pipeline.  `executor` supplies real numerics through the
    /// sharded pool; pass `None` for a timing-only (simulated outputs)
    /// run — decisions then come from a deterministic surrogate so
    /// downstream stages still exercise.
    pub fn run(&self, executor: Option<&ExecutorPool>) -> Result<PipelineReport> {
        let cfg = &self.config;
        let mut stream = SensorStream::new(cfg.use_case, cfg.seed, cfg.cadence_s);
        let mut batcher = Batcher::new(&self.route.model, cfg.max_batch, cfg.max_wait_s);
        let mut state = RunState {
            timelines: self.dispatcher.timelines(),
            downlink: DownlinkManager::new(cfg.downlink_budget),
            metrics: Metrics::default(),
            rng: Prng::new(cfg.seed ^ DECISION_RNG_SALT),
            latencies: Vec::with_capacity(cfg.n_events),
            decisions: BTreeMap::new(),
            target_batches: BTreeMap::new(),
            predicted_energy_j: 0.0,
            deadline_misses: 0,
            power_sheds: 0,
            correct: 0,
            with_truth: 0,
            sim_end: 0.0,
        };
        let mut reaper = executor.map(Reaper::new);

        for _ in 0..cfg.n_events {
            let ev = stream.next_event();
            let now = ev.t_s;
            if let Some(b) = batcher.poll(now) {
                self.dispatch(b, &mut state, &mut reaper)?;
            }
            if let Some(b) = batcher.offer(ev, now) {
                self.dispatch(b, &mut state, &mut reaper)?;
            }
        }
        let drain_t = cfg.n_events as f64 * cfg.cadence_s + cfg.max_wait_s;
        // end-of-run drain: by drain_t the wait timer is always overdue,
        // so poll() stamps the flush when that timer would have fired
        // (oldest + max_wait) instead of charging the full drain gap;
        // the unconditional flush below is only the empty-batcher no-op.
        if let Some(b) = batcher.poll(drain_t) {
            self.dispatch(b, &mut state, &mut reaper)?;
        }
        if let Some(b) = batcher.flush(drain_t) {
            self.dispatch(b, &mut state, &mut reaper)?;
        }
        if let Some(r) = &mut reaper {
            r.drain_all(cfg.use_case, self.input_bytes, &mut state)?;
        }

        let RunState {
            timelines,
            downlink,
            metrics,
            mut latencies,
            decisions,
            target_batches,
            predicted_energy_j,
            deadline_misses,
            power_sheds,
            correct,
            with_truth,
            sim_end,
            ..
        } = state;
        latencies.sort_by(f64::total_cmp);
        let mean = latencies.iter().sum::<f64>() / latencies.len().max(1) as f64;
        let p95 = percentile_nearest_rank(&latencies, 0.95);
        let completed: u64 = timelines.iter().map(|t| t.completed).sum();
        let busy_s: f64 = timelines.iter().map(|t| t.busy_s).sum();
        let energy_j: f64 = timelines.iter().map(|t| t.energy_j).sum();
        let busy_fps = if busy_s > 0.0 { completed as f64 / busy_s } else { 0.0 };
        Ok(PipelineReport {
            use_case: cfg.use_case,
            model: self.route.model.clone(),
            slot: self.route.slot,
            policy: cfg.policy.as_str().to_string(),
            target_mix: target_batches,
            events: completed,
            sim_elapsed_s: sim_end,
            mean_latency_s: mean,
            p95_latency_s: p95,
            busy_fps,
            accel_utilization: busy_s / sim_end.max(1e-9),
            energy_j,
            predicted_energy_j,
            deadline_misses,
            power_sheds,
            downlink_sent: downlink.sent_count,
            downlink_shed: downlink.shed_count,
            downlink_sent_bytes: downlink.sent_bytes,
            compression_ratio: downlink.compression_ratio(),
            accuracy: if with_truth > 0 {
                Some(correct as f64 / with_truth as f64)
            } else {
                None
            },
            decisions,
            metrics,
        })
    }
}

/// Nearest-rank percentile over a sorted sample: the smallest value
/// with at least `q` of the mass at or below it (`ceil(q*n)` as a
/// 1-indexed rank).  Truncating the rank instead (`(n*q) as usize`)
/// understates tail latency for small n.
fn percentile_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Salt separating the decision RNG stream from the sensor stream.
const DECISION_RNG_SALT: u64 = 0xD01E_57A7;

/// Backpressure cap on batches submitted but not yet reaped: enough to
/// keep every worker busy with headroom, small enough that pending
/// input buffers stay O(cap * max_batch) rather than O(n_events).
const MAX_INFLIGHT_BATCHES: u64 = 64;

/// Deterministic surrogate outputs for timing-only runs (no executor).
/// Exhaustive over [`UseCase`] — infallible by construction.
fn surrogate_output(use_case: UseCase, ev: &SensorEvent, rng: &mut Prng) -> Vec<f32> {
    match use_case {
        UseCase::Mms => {
            let mut v = vec![0.0f32; 4];
            if let Some(t) = ev.truth {
                v[t] = 1.0 + rng.f32();
            }
            v
        }
        UseCase::Esperta => {
            let mut v = vec![0.2f32; 12];
            if ev.truth == Some(1) {
                for i in 0..6 {
                    v[i] = 0.9;
                    v[6 + i] = 1.0;
                }
            }
            v
        }
        UseCase::Vae => (0..12).map(|_| rng.normal() as f32).collect(),
        UseCase::Cnet => vec![-6.0 + 2.0 * rng.f32()],
    }
}

fn decision_key(d: &Decision) -> String {
    match d {
        Decision::MmsRegion { region, .. } => format!("region_{}", region.label()),
        Decision::SepAlert { warning, .. } => {
            format!("sep_{}", if *warning { "alert" } else { "quiet" })
        }
        Decision::Latent { .. } => "latent".into(),
        Decision::FluxForecast { alert, .. } => {
            format!("flux_{}", if *alert { "alert" } else { "nominal" })
        }
    }
}

fn decision_matches_truth(d: &Decision, truth: usize) -> bool {
    match d {
        Decision::MmsRegion { region, .. } => region.index() == truth,
        Decision::SepAlert { warning, .. } => (*warning as usize) == truth,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentile() {
        // n=10, q=0.95 -> rank ceil(9.5)=10 -> last element (truncation
        // would pick index 9 too, but q=0.5 separates the conventions)
        let v: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&v, 0.95), 10.0);
        assert_eq!(percentile_nearest_rank(&v, 0.5), 5.0);
        // small n: p95 of 3 samples must be the max, not the middle
        let small = [1.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&small, 0.95), 3.0);
        assert_eq!(percentile_nearest_rank(&[], 0.95), 0.0);
        assert_eq!(percentile_nearest_rank(&[7.0], 0.95), 7.0);
        // q=1.0 and beyond-clamp stay in bounds
        assert_eq!(percentile_nearest_rank(&small, 1.0), 3.0);
        assert_eq!(percentile_nearest_rank(&small, 0.0), 1.0);
    }

    #[test]
    fn surrogate_encodes_truth() {
        let mut rng = Prng::new(1);
        let ev = SensorEvent {
            t_s: 0.0,
            use_case: UseCase::Mms,
            inputs: std::sync::Arc::new(vec![vec![0.0; 4]]),
            truth: Some(1),
            seq: 0,
        };
        let out = surrogate_output(UseCase::Mms, &ev, &mut rng);
        assert_eq!(out.len(), 4);
        assert!(out[1] >= 1.0, "truth class must carry the max logit");
    }

    #[test]
    fn default_config_is_static_policy_on_default_targets() {
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.policy, Policy::Static);
        assert!(cfg.deadline_s.is_none());
        assert!(cfg.power_budget_w.is_none());
        assert_eq!(cfg.targets, TargetSet::Default);
    }
}
