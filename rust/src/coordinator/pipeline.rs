//! The end-to-end on-board pipeline: wires sensors, router, batcher,
//! cost-model dispatcher, executor (real PJRT numerics), the
//! timing/power simulators (virtual ZCU104 clock), decision logic, and
//! the downlink manager.
//!
//! The serving hot path is batch-native: each flushed `Batch` becomes
//! exactly one `ExecRequest` (input buffers `Arc`-shared, no per-event
//! copies or channel round trips), and completions are reaped
//! asynchronously so event generation, batching, and execution overlap.
//! Completions are *processed* in submission order regardless of
//! arrival order, which keeps the decision RNG stream — and therefore
//! the whole `PipelineReport` — deterministic for a given seed.
//!
//! Target selection is per batch: the [`Dispatcher`] scores every
//! target in the backend registry (the paper's A53 / DPU / HLS triple
//! by default; the full DPU family and pipelined HLS under
//! `--targets all`) and picks under the configured [`Policy`].  Each
//! batch's predicted latency/energy land in telemetry next to the
//! "measured" (virtual clock) values, so calibration drift between the
//! cost model and the timeline shows up as a nonzero prediction error.
//!
//! # Steppable execution
//!
//! The pipeline is a *steppable state machine*: [`Pipeline::begin`]
//! opens a [`PipelineRun`], each [`PipelineRun::tick`] advances the
//! virtual clock by exactly one sensor event, and
//! [`PipelineRun::finish`] drains and produces the report.
//! [`Pipeline::run`] is now only the thin driver loop over those three
//! calls.  Between ticks every operational knob is live: dispatch
//! policy, power budget, deadline, sensor cadence/burst, downlink
//! budget, and per-target availability — the seam `crate::scenario`
//! uses to replay whole mission timelines (eclipse entry, SEP storms,
//! ground-station passes, SEU upsets) inside a single deterministic
//! run.  [`PipelineRun::begin_phase`] segments the report: every batch,
//! joule, deadline miss, ingress drop, and downlink verdict is credited
//! to the mission phase that dispatched it.

use std::collections::BTreeMap;
use std::ops::{Deref, DerefMut};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{AccelModel, TargetSet};
use crate::board::{Calibration, Zcu104};
use crate::coordinator::backpressure::{BoundedQueue, OverflowPolicy};
use crate::coordinator::batcher::{Batch, Batcher};
use crate::coordinator::cache::{CacheStats, DispatchCache};
use crate::coordinator::decision::{decide, Decision};
use crate::coordinator::dispatch::{default_deadline_s, Dispatcher, Policy};
use crate::coordinator::downlink::{DownlinkManager, DownlinkVerdict};
use crate::coordinator::router::{Route, Router, Slot};
use crate::coordinator::scheduler::{AccelTimeline, ScheduledRun};
use crate::fault::{
    tmr_cost_of, FaultInjector, FaultKind, FaultProfile, FaultState, FaultStats,
    RecoveryPolicy, TmrCost,
};
use crate::model::catalog::Catalog;
use crate::model::{Precision, UseCase};
use crate::plan::{Lane, Planner};
use crate::rad::seu::essential_bits_of;
use crate::runtime::{ExecRequest, ExecResult, ExecutorPool, InputSet};
use crate::sensors::{Frame, FramePool, PoolStats, SensorEvent, SensorStream};
use crate::telemetry::{Histogram, Metrics};
use crate::util::prng::Prng;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Which paper use case the run serves.
    pub use_case: UseCase,
    /// Events to process.
    pub n_events: usize,
    /// Sensor cadence (s).
    pub cadence_s: f64,
    /// Batcher flush threshold (events).
    pub max_batch: usize,
    /// Batcher latency budget before a forced flush (s).
    pub max_wait_s: f64,
    /// Downlink budget for the run (bytes).
    pub downlink_budget: u64,
    /// MMS sub-model ("baseline" | "reduced" | "logistic").
    pub mms_model: String,
    /// Seed for the sensor + decision RNG streams.
    pub seed: u64,
    /// Per-batch target-selection policy.
    pub policy: Policy,
    /// End-to-end deadline override (s); `None` uses the per-use-case
    /// default (`dispatch::default_deadline_s`).
    pub deadline_s: Option<f64>,
    /// Mission power budget: cap on active MPSoC draw (W), `None` = off.
    pub power_budget_w: Option<f64>,
    /// Which backend targets to register (`default` = the paper's
    /// triple; `all` opens the DPU family + pipelined HLS).
    pub targets: TargetSet,
    /// Ingress-queue capacity (events) between the sensor and the
    /// batcher.  `None` (default) admits every event unconditionally —
    /// the pre-ingress behavior, bit for bit.  `Some(cap)` bounds the
    /// coordinator's event buffer: while every in-service target's
    /// backlog exceeds [`PipelineConfig::ingress_max_backlog_s`],
    /// events pool in the queue and overflow is shed per
    /// [`PipelineConfig::ingress_policy`] — deterministic sensor
    /// decimation instead of an unbounded backlog.
    pub ingress_cap: Option<usize>,
    /// What the ingress queue does with overflow (only read when
    /// [`PipelineConfig::ingress_cap`] is set).
    pub ingress_policy: OverflowPolicy,
    /// Admission threshold (s): events leave the ingress queue for the
    /// batcher only while the *least-loaded* in-service target is at
    /// most this far behind the virtual clock.
    pub ingress_max_backlog_s: f64,
    /// Dispatch over heterogeneous *execution plans* instead of whole
    /// models.  `false` (default) keeps the whole-model dispatcher bit
    /// for bit.  `true` builds the `crate::plan` partition set at
    /// construction and scores hybrid plans (DPU subgraphs + fallback
    /// segments, the paper's Vitis-AI graph-splitting behavior)
    /// alongside single-target plans under the configured policy; the
    /// chosen plan executes segment by segment on the virtual clock,
    /// boundary transfers included.  Models fully supported by one
    /// target produce single-segment plans whose decisions and charges
    /// are bit-identical to `plan_mode: false`.
    pub plan_mode: bool,
    /// Seed for the deterministic [`FaultInjector`].  `None` (default)
    /// runs fault-free — dispatch decisions and reports stay
    /// bit-identical to a build without the fault layer.  `Some(seed)`
    /// arms the injector: same seed ⇒ bit-identical fault timeline.
    /// Incompatible with [`PipelineConfig::plan_mode`].
    pub fault_seed: Option<u64>,
    /// Fault-class probabilities and severities drawn by the injector
    /// (only read when [`PipelineConfig::fault_seed`] is set).
    pub fault_profile: FaultProfile,
    /// How dispatch recovers from injected (or forced) faults: retry
    /// bounds, backoff, quarantine, TMR voting.
    pub recovery: RecoveryPolicy,
    /// Memoize dispatch decisions in a [`DispatchCache`] (default on).
    /// Hits are provably bit-identical to fresh scoring — see the cache
    /// module's determinism argument — so this knob changes throughput,
    /// never behavior; `false` (`--no-dispatch-cache`) is the escape
    /// hatch the equivalence harness diffs against.
    pub dispatch_cache: bool,
    /// Recycle sensor input frames through a [`FramePool`] (default
    /// on), and skip pixel synthesis outright on timing-only runs of
    /// the truth-free image streams (the pixels are never read — see
    /// [`SensorStream::synthesis_is_pixels_only`]).  Both are
    /// throughput knobs, never behavior: reports stay bit-identical
    /// with the pool off; `false` (`--no-frame-pool`) is the escape
    /// hatch the equivalence harness diffs against.
    pub frame_pool: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            use_case: UseCase::Mms,
            n_events: 100,
            cadence_s: 0.15,
            max_batch: 8,
            max_wait_s: 0.5,
            downlink_budget: 64 * 1024,
            mms_model: "baseline".into(),
            seed: 7,
            policy: Policy::Static,
            deadline_s: None,
            power_budget_w: None,
            targets: TargetSet::Default,
            ingress_cap: None,
            ingress_policy: OverflowPolicy::DropNewest,
            ingress_max_backlog_s: 0.25,
            plan_mode: false,
            fault_seed: None,
            fault_profile: FaultProfile::default(),
            recovery: RecoveryPolicy::default(),
            dispatch_cache: true,
            frame_pool: true,
        }
    }
}

/// Per-phase slice of a [`PipelineReport`]: what one mission phase
/// dispatched, spent, missed, shed, and downlinked.  A legacy
/// (non-scenario) run has exactly one phase named `"run"` spanning the
/// whole timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseReport {
    /// Phase name (from [`PipelineRun::begin_phase`]).
    pub name: String,
    /// Virtual time the phase began (s).
    pub start_s: f64,
    /// Virtual time the phase ended (s) — the next phase's start, or
    /// for the final phase the completion of the last batch.
    pub end_s: f64,
    /// Sensor events generated during the phase.
    pub events: u64,
    /// Batches dispatched during the phase.
    pub batches: u64,
    /// Batches per registry target name, for this phase only.
    pub target_mix: BTreeMap<String, u64>,
    /// Simulated inference energy charged by this phase's batches (J).
    pub energy_j: f64,
    /// Mean end-to-end latency of this phase's batches (s).
    pub mean_latency_s: f64,
    /// p95 end-to-end latency of this phase's batches (s).
    pub p95_latency_s: f64,
    /// p99 end-to-end latency of this phase's batches (s), nearest-rank
    /// like p95 — the serving-SLO tail.
    pub p99_latency_s: f64,
    /// Batches whose oldest event missed the deadline.
    pub deadline_misses: u64,
    /// Batches the power budget steered away from the policy's pick.
    pub power_sheds: u64,
    /// Events the ingress queue shed during the phase (decimation).
    pub dropped: u64,
    /// Decisions the downlink kept, for batches dispatched this phase.
    pub downlink_sent: u64,
    /// Decisions the downlink shed, for batches dispatched this phase.
    pub downlink_shed: u64,
    /// Faults injected (or forced) against this phase's dispatches,
    /// plus environment fault windows opened during the phase.
    pub faults: u64,
    /// Same-target retry attempts scheduled during the phase.
    pub retries: u64,
    /// Targets quarantined during the phase.
    pub quarantines: u64,
    /// Single-replica faults masked by TMR during the phase.
    pub tmr_masked: u64,
    /// Batches dispatched under a brownout-degraded budget.
    pub degraded: u64,
    /// Decisions dropped to a downlink dropout window.
    pub link_dropped: u64,
}

/// Summary of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    /// Use case the run served.
    pub use_case: UseCase,
    /// Model variant name.
    pub model: String,
    /// Primary (paper deployment-matrix) slot.
    pub slot: Slot,
    /// Dispatch policy the run used (the run's *final* policy when a
    /// scenario changed it mid-run).
    pub policy: String,
    /// Batches dispatched per registry target name ("cpu" / "dpu" /
    /// "dpu-b512" / "hls" / "hls-pipe" / ...).
    pub target_mix: BTreeMap<String, u64>,
    /// Events completed on the virtual clock.
    pub events: u64,
    /// Simulated wall time of the run (s).
    pub sim_elapsed_s: f64,
    /// Simulated mean end-to-end latency (arrival -> decision, s).
    pub mean_latency_s: f64,
    /// Simulated p95 end-to-end latency (s).
    pub p95_latency_s: f64,
    /// Simulated p99 end-to-end latency (s), nearest-rank like p95 —
    /// the tail that serving SLOs are written against.
    pub p99_latency_s: f64,
    /// Simulated accelerator throughput (inferences/s while busy).
    pub busy_fps: f64,
    /// Aggregate busy time over the run window, summed across targets —
    /// exceeds 1.0 when several targets run concurrently (each target's
    /// own timeline is serial, so a single-target run stays ≤ 1.0).
    pub accel_utilization: f64,
    /// Simulated MPSoC energy spent on inference (J), all targets.
    pub energy_j: f64,
    /// Cost-model predicted energy (J) — equals `energy_j` while the
    /// dispatcher and the timeline share calibration; drift is a bug.
    pub predicted_energy_j: f64,
    /// Batches whose oldest event missed the deadline.
    pub deadline_misses: u64,
    /// Batches the power budget steered away from the policy's pick.
    pub power_sheds: u64,
    /// Events admitted past the ingress queue (equals `n_events` when
    /// no queue is configured).
    pub ingress_accepted: u64,
    /// Events the ingress queue shed (always 0 without a queue).
    pub ingress_dropped: u64,
    /// Batches dispatched as execution plans (equals the batch count in
    /// plan mode, 0 otherwise).
    pub plan_batches: u64,
    /// Plan-dispatched batches whose chosen plan was hybrid (more than
    /// one segment — a DPU subgraph plus fallback).
    pub plan_hybrid_batches: u64,
    /// Virtual seconds spent moving boundary activations between
    /// segments (the hybrid toll; 0 without hybrid batches).
    pub plan_transfer_s: f64,
    /// Decisions the downlink kept.
    pub downlink_sent: u64,
    /// Decisions the downlink shed.
    pub downlink_shed: u64,
    /// Bytes actually downlinked.
    pub downlink_sent_bytes: u64,
    /// Bytes the shed decisions would have cost — the per-craft
    /// downlink demand signal the fleet layer aggregates.
    pub downlink_shed_bytes: u64,
    /// Raw sensor bytes represented per byte downlinked.
    pub compression_ratio: f64,
    /// Decision accuracy vs ground truth, when truth exists.
    pub accuracy: Option<f64>,
    /// Decision label -> count.
    pub decisions: BTreeMap<String, u64>,
    /// Per-phase segmentation of the run.  Exactly one entry (named
    /// `"run"`) for a legacy single-phase run; one entry per
    /// [`PipelineRun::begin_phase`] otherwise.
    pub phases: Vec<PhaseReport>,
    /// Fault / recovery accounting (all zero for a fault-free run).
    pub faults: FaultStats,
    /// Typed execution errors survived on the serving path (real
    /// executor batches whose results were lost); capped, oldest first.
    pub exec_errors: Vec<String>,
    /// Dispatch-cache accounting (all zero when the cache is disabled).
    /// Deliberately *outside* [`PipelineReport::metrics`]: cache-on and
    /// cache-off runs must compare equal on every behavioral field, and
    /// these counters are the one legitimate difference.
    pub cache: CacheStats,
    /// Counters + histograms collected during the run.
    pub metrics: Metrics,
}

impl PipelineReport {
    /// The target mix as `cpu:3 dpu:9` (`-` when no batch dispatched) —
    /// the one formatting shared by the report, the policy table, and
    /// the examples.
    pub fn mix_str(mix: &BTreeMap<String, u64>) -> String {
        if mix.is_empty() {
            return "-".into();
        }
        mix.iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// This run's target mix, formatted.
    pub fn target_mix_str(&self) -> String {
        PipelineReport::mix_str(&self.target_mix)
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pipeline [{}] model={} slot={:?} policy={}\n",
            self.use_case, self.model, self.slot, self.policy
        ));
        out.push_str(&format!(
            "  target mix [{}]  deadline_misses {}  power_sheds {}\n",
            self.target_mix_str(),
            self.deadline_misses,
            self.power_sheds
        ));
        out.push_str(&format!(
            "  events {}  sim_elapsed {:.3}s  mean_latency {:.4}s  p95 {:.4}s  p99 {:.4}s\n",
            self.events,
            self.sim_elapsed_s,
            self.mean_latency_s,
            self.p95_latency_s,
            self.p99_latency_s
        ));
        out.push_str(&format!(
            "  busy_fps {:.1}  util {:.1}%  energy {:.3}J (predicted {:.3}J)\n",
            self.busy_fps,
            100.0 * self.accel_utilization,
            self.energy_j,
            self.predicted_energy_j
        ));
        if self.ingress_dropped > 0 {
            out.push_str(&format!(
                "  ingress: accepted {}  dropped {} (sensor decimation)\n",
                self.ingress_accepted, self.ingress_dropped
            ));
        }
        if self.plan_batches > 0 {
            out.push_str(&format!(
                "  plans: {} dispatched ({} hybrid)  transfer {:.4}s\n",
                self.plan_batches, self.plan_hybrid_batches, self.plan_transfer_s
            ));
        }
        if self.cache.lookups() + self.cache.bypasses > 0 {
            out.push_str(&format!(
                "  cache: {} hits / {} lookups ({:.0}% hit rate)  \
                 invalidations {}  bypasses {}\n",
                self.cache.hits,
                self.cache.lookups(),
                100.0 * self.cache.hit_rate(),
                self.cache.invalidations,
                self.cache.bypasses,
            ));
        }
        if self.faults.any() {
            let f = &self.faults;
            out.push_str(&format!(
                "  faults: injected {}  retries {}  redispatches {}  \
                 quarantines {}/{}  tmr {}/{} masked  degraded {}  \
                 link_dropped {}  forced {}\n",
                f.faults_injected,
                f.retries,
                f.redispatches,
                f.quarantines,
                f.reinstates,
                f.tmr_masked,
                f.tmr_batches,
                f.degraded_batches,
                f.link_dropped,
                f.forced_completions,
            ));
        }
        for err in &self.exec_errors {
            out.push_str(&format!("  exec error: {err}\n"));
        }
        out.push_str(&format!(
            "  downlink: sent {} ({} B) shed {}  compression {:.0}:1\n",
            self.downlink_sent, self.downlink_sent_bytes, self.downlink_shed,
            self.compression_ratio
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!("  decision accuracy vs truth: {:.1}%\n", 100.0 * acc));
        }
        for (k, v) in &self.decisions {
            out.push_str(&format!("  decision[{k}] = {v}\n"));
        }
        if self.phases.len() > 1 {
            out.push_str("  phases:\n");
            for p in &self.phases {
                out.push_str(&format!(
                    "    {:<16} [{:8.2}s..{:8.2}s]  events {:<5} mix [{}]  \
                     energy {:.3}J  p95 {:.4}s  p99 {:.4}s  misses {}  sheds {}  \
                     drops {}  dl {}/{}\n",
                    p.name,
                    p.start_s,
                    p.end_s,
                    p.events,
                    PipelineReport::mix_str(&p.target_mix),
                    p.energy_j,
                    p.p95_latency_s,
                    p.p99_latency_s,
                    p.deadline_misses,
                    p.power_sheds,
                    p.dropped,
                    p.downlink_sent,
                    p.downlink_shed,
                ));
                let fault_activity = p.faults
                    + p.retries
                    + p.quarantines
                    + p.tmr_masked
                    + p.degraded
                    + p.link_dropped;
                if fault_activity > 0 {
                    out.push_str(&format!(
                        "                     faults {}  retries {}  \
                         quarantines {}  tmr_masked {}  degraded {}  \
                         link_dropped {}\n",
                        p.faults,
                        p.retries,
                        p.quarantines,
                        p.tmr_masked,
                        p.degraded,
                        p.link_dropped,
                    ));
                }
            }
        }
        out
    }
}

/// Per-phase accumulator (finalized into a [`PhaseReport`] at
/// [`PipelineRun::finish`]).
#[derive(Debug)]
struct PhaseAccum {
    name: String,
    start_s: f64,
    end_s: f64,
    events: u64,
    batches: u64,
    /// Batches per flat lane index (registry targets, then derived
    /// plan lanes).  Rendered to a name-keyed map only at `finalize` —
    /// the hot path never touches a string key.
    target_mix: Vec<u64>,
    energy_j: f64,
    deadline_misses: u64,
    power_sheds: u64,
    dropped: u64,
    downlink_sent: u64,
    downlink_shed: u64,
    faults: u64,
    retries: u64,
    quarantines: u64,
    tmr_masked: u64,
    degraded: u64,
    link_dropped: u64,
    latencies: Vec<f64>,
}

impl PhaseAccum {
    /// `lanes` sizes the per-lane mix array; `latency_cap` pre-sizes
    /// the latency sample buffer so steady-state pushes never
    /// reallocate (the zero-allocation tick invariant).
    fn new(name: &str, start_s: f64, lanes: usize, latency_cap: usize) -> PhaseAccum {
        PhaseAccum {
            name: name.to_string(),
            start_s,
            end_s: start_s,
            events: 0,
            batches: 0,
            target_mix: vec![0; lanes],
            energy_j: 0.0,
            deadline_misses: 0,
            power_sheds: 0,
            dropped: 0,
            downlink_sent: 0,
            downlink_shed: 0,
            faults: 0,
            retries: 0,
            quarantines: 0,
            tmr_masked: 0,
            degraded: 0,
            link_dropped: 0,
            latencies: Vec::with_capacity(latency_cap),
        }
    }

    /// True while nothing has been credited to the phase — the initial
    /// `"run"` placeholder can then be renamed in place.
    fn is_untouched(&self) -> bool {
        self.events == 0
            && self.batches == 0
            && self.dropped == 0
            && self.downlink_sent == 0
            && self.downlink_shed == 0
            && self.latencies.is_empty()
    }

    fn finalize(&mut self, lane_names: &[String]) -> PhaseReport {
        self.latencies.sort_by(f64::total_cmp);
        let mean =
            self.latencies.iter().sum::<f64>() / self.latencies.len().max(1) as f64;
        let target_mix: BTreeMap<String, u64> = lane_names
            .iter()
            .zip(&self.target_mix)
            .filter(|(_, &n)| n > 0)
            .map(|(name, &n)| (name.clone(), n))
            .collect();
        PhaseReport {
            name: self.name.clone(),
            start_s: self.start_s,
            end_s: self.end_s,
            events: self.events,
            batches: self.batches,
            target_mix,
            energy_j: self.energy_j,
            mean_latency_s: mean,
            p95_latency_s: percentile_nearest_rank(&self.latencies, 0.95),
            p99_latency_s: percentile_nearest_rank(&self.latencies, 0.99),
            deadline_misses: self.deadline_misses,
            power_sheds: self.power_sheds,
            dropped: self.dropped,
            downlink_sent: self.downlink_sent,
            downlink_shed: self.downlink_shed,
            faults: self.faults,
            retries: self.retries,
            quarantines: self.quarantines,
            tmr_masked: self.tmr_masked,
            degraded: self.degraded,
            link_dropped: self.link_dropped,
        }
    }
}

/// Mutable per-run state threaded through dispatch and reap.
struct RunState {
    /// Per-target queue state, index-aligned with `Dispatcher::targets`.
    timelines: Vec<AccelTimeline>,
    downlink: DownlinkManager,
    metrics: Metrics,
    /// Interned hot-path counters and histograms — resolved to slot
    /// indices at `RunCore::build`, folded into `metrics` (and the
    /// report's name-keyed maps) once at `finish`.
    bank: MetricBank,
    /// Recycled sensor input frames (a no-op passthrough when
    /// `frame_pool` is off).
    pool: FramePool,
    /// Scratch output buffer for the inline surrogate (timing-only
    /// runs) — reused across every event of every batch.
    surrogate_buf: Vec<f32>,
    /// Drained event vector from the last completed batch, handed back
    /// to the batcher at the next tick so its capacity is reused.
    spare_events: Vec<SensorEvent>,
    /// Recycled input-set vector for executor submissions — the
    /// capacity cycles submit → reap → submit.
    spare_items: Vec<InputSet>,
    /// Per-dispatch exclusion mask scratch for the recovery path
    /// (cleared and resized per batch, allocated once).
    excluded: Vec<bool>,
    rng: Prng,
    latencies: Vec<f64>,
    predicted_energy_j: f64,
    deadline_misses: u64,
    power_sheds: u64,
    plan_batches: u64,
    plan_hybrid_batches: u64,
    plan_transfer_s: f64,
    /// Events whose batch has been dispatched (each event counted once,
    /// regardless of how many plan segments executed it).
    events_done: u64,
    correct: u64,
    with_truth: u64,
    sim_end: f64,
    /// Phase accumulators; the last entry is the current phase.  Never
    /// empty — `begin` seeds the `"run"` placeholder.
    phases: Vec<PhaseAccum>,
    /// Fault injection + recovery working state.  Inactive (and
    /// byte-invisible to dispatch) unless armed by `fault_seed`, a
    /// fault mission event, or a test knob.
    fault: FaultState,
    /// Typed executor errors survived on the serving path (capped).
    exec_errors: Vec<String>,
    /// Memoized dispatch decisions (a no-op passthrough when disabled).
    cache: DispatchCache,
}

impl RunState {
    /// Index of the current phase (what a dispatched batch is credited
    /// to, and what its reaped decisions later credit).
    fn phase_index(&self) -> usize {
        self.phases.len() - 1
    }

    /// Post-inference stages for one event: decision, truth scoring,
    /// downlink verdict.  `phase` is the phase the event's batch was
    /// *dispatched* in, so executor-path decisions reaped after a phase
    /// transition still land in the right segment.  `done_s` is the
    /// batch's virtual completion time — a decision ready inside a
    /// downlink dropout window is lost before the budget is consulted.
    fn decide_one(
        &mut self,
        use_case: UseCase,
        ev: &SensorEvent,
        output: &[f32],
        input_bytes: u64,
        phase: usize,
        done_s: f64,
    ) {
        let d = decide(use_case, output, &mut self.rng);
        if let Some(truth) = ev.truth {
            self.with_truth += 1;
            if decision_matches_truth(&d, truth) {
                self.correct += 1;
            }
        }
        self.bank.decisions[decision_slot(&d)] += 1;
        if self.fault.link_down(done_s) {
            self.fault.stats.link_dropped += 1;
            self.phases[phase].link_dropped += 1;
            self.bank.downlink_dropped_link += 1;
            return;
        }
        match self.downlink.offer(&d, input_bytes) {
            DownlinkVerdict::Sent => {
                self.bank.downlink_sent += 1;
                self.phases[phase].downlink_sent += 1;
            }
            DownlinkVerdict::Shed => {
                self.bank.downlink_shed += 1;
                self.phases[phase].downlink_shed += 1;
            }
        }
    }
}

/// Decision-counter slots, index-aligned with [`decision_slot`].  The
/// report's `decisions` map is rebuilt from these at `finish`; the
/// `#[cfg(test)]` twin `decision_key` pins the legacy string for each
/// slot so the rendered map cannot drift.
const DECISION_KEYS: [&str; 9] = [
    "region_SW",
    "region_IF",
    "region_MSH",
    "region_MSP",
    "sep_quiet",
    "sep_alert",
    "latent",
    "flux_nominal",
    "flux_alert",
];

/// Slot in [`DECISION_KEYS`] for a decision — constant-time, no string
/// construction on the per-event path.
fn decision_slot(d: &Decision) -> usize {
    match d {
        Decision::MmsRegion { region, .. } => region.index(),
        Decision::SepAlert { warning, .. } => 4 + *warning as usize,
        Decision::Latent { .. } => 6,
        Decision::FluxForecast { alert, .. } => 7 + *alert as usize,
    }
}

/// Static metric name for an injected fault kind — the recovery path's
/// counterpart of the interned dispatch counters (no per-fault
/// `format!`).
fn fault_metric(kind: FaultKind) -> &'static str {
    match kind {
        FaultKind::ExecFail => "fault_exec_fail",
        FaultKind::ExecTimeout => "fault_exec_timeout",
        FaultKind::SeuCorrupt => "fault_seu_corrupt",
    }
}

/// Interned metric storage for the tick hot path.  Every counter the
/// steady state touches is a struct field or a slot in a fixed array,
/// resolved once at `RunCore::build`; names exist only at the edges —
/// built at `fold_into` / `target_batches_map` time, once per run.
struct MetricBank {
    /// Flat lane names: registry targets in index order, then derived
    /// plan lanes (matching `Planner::flat`).
    lane_names: Vec<String>,
    /// Batches dispatched per flat lane — serves both the
    /// `dispatch_{name}` counters and the report's `target_mix`.
    lane_batches: Vec<u64>,
    /// Decision counts, slot-aligned with [`DECISION_KEYS`].
    decisions: [u64; DECISION_KEYS.len()],
    batches: u64,
    inferences: u64,
    deadline_miss_batches: u64,
    power_shed_batches: u64,
    downlink_sent: u64,
    downlink_shed: u64,
    downlink_dropped_link: u64,
    /// Reaped batches per executor worker index (grown on demand —
    /// bounded by the pool's worker count).
    worker_reaps: Vec<u64>,
    predicted_batch_latency: Histogram,
    measured_batch_latency: Histogram,
}

impl MetricBank {
    fn new(lane_names: Vec<String>) -> MetricBank {
        let lanes = lane_names.len();
        MetricBank {
            lane_names,
            lane_batches: vec![0; lanes],
            decisions: [0; DECISION_KEYS.len()],
            batches: 0,
            inferences: 0,
            deadline_miss_batches: 0,
            power_shed_batches: 0,
            downlink_sent: 0,
            downlink_shed: 0,
            downlink_dropped_link: 0,
            worker_reaps: Vec::new(),
            predicted_batch_latency: Histogram::default(),
            measured_batch_latency: Histogram::default(),
        }
    }

    /// Fold every interned counter into the name-keyed metrics — the
    /// same final state as incrementing the named counters per event
    /// (zero counters leave no key, matching the on-demand behavior).
    fn fold_into(&self, m: &mut Metrics) {
        let named = [
            ("batches", self.batches),
            ("inferences", self.inferences),
            ("deadline_miss_batches", self.deadline_miss_batches),
            ("power_shed_batches", self.power_shed_batches),
            ("downlink_sent", self.downlink_sent),
            ("downlink_shed", self.downlink_shed),
            ("downlink_dropped_link", self.downlink_dropped_link),
        ];
        for (name, v) in named {
            if v > 0 {
                m.add(name, v);
            }
        }
        for (name, &n) in self.lane_names.iter().zip(&self.lane_batches) {
            if n > 0 {
                m.add(&format!("dispatch_{name}"), n);
            }
        }
        for (w, &n) in self.worker_reaps.iter().enumerate() {
            if n > 0 {
                m.add(&format!("exec_worker_{w}"), n);
            }
        }
        m.merge_histogram("predicted_batch_latency", &self.predicted_batch_latency);
        m.merge_histogram("measured_batch_latency", &self.measured_batch_latency);
    }

    /// The report's `target_mix`: lane counts rendered to a name-keyed
    /// map (dispatched lanes only, matching the legacy entry-on-demand
    /// behavior).
    fn target_batches_map(&self) -> BTreeMap<String, u64> {
        self.lane_names
            .iter()
            .zip(&self.lane_batches)
            .filter(|(_, &n)| n > 0)
            .map(|(name, &n)| (name.clone(), n))
            .collect()
    }

    /// The report's `decisions` map from the slot array (taken
    /// decisions only).
    fn decisions_map(&self) -> BTreeMap<String, u64> {
        DECISION_KEYS
            .iter()
            .zip(&self.decisions)
            .filter(|(_, &n)| n > 0)
            .map(|(&k, &n)| (k.to_string(), n))
            .collect()
    }
}

/// In-flight batches: submitted to the pool, awaiting reap.  Results
/// may arrive out of order across workers; processing is forced back
/// into submission order so runs are deterministic.
struct Reaper<'a> {
    pool: &'a ExecutorPool,
    reply_tx: mpsc::Sender<ExecResult>,
    reply_rx: mpsc::Receiver<ExecResult>,
    /// Next batch id to assign at submit.
    next_id: u64,
    /// Next batch id to process (strict submission order).
    next_done: u64,
    /// (dispatch phase, events, virtual completion time) of submitted
    /// batches, keyed by batch id.
    pending: BTreeMap<u64, (usize, Vec<SensorEvent>, f64)>,
    /// Completions that arrived ahead of `next_done`.
    arrived: BTreeMap<u64, ExecResult>,
}

impl<'a> Reaper<'a> {
    fn new(pool: &'a ExecutorPool) -> Reaper<'a> {
        let (reply_tx, reply_rx) = mpsc::channel();
        Reaper {
            pool,
            reply_tx,
            reply_rx,
            next_id: 0,
            next_done: 0,
            pending: BTreeMap::new(),
            arrived: BTreeMap::new(),
        }
    }

    /// One `ExecRequest` for the whole batch — the only executor
    /// dispatch on this path.  `precision` follows the chosen target
    /// (int8 on the DPU slot, fp32 elsewhere); `phase` is the mission
    /// phase the batch was dispatched in.
    fn submit(
        &mut self,
        model: &str,
        precision: Precision,
        phase: usize,
        batch: Batch,
        done_s: f64,
        spare_items: &mut Vec<InputSet>,
    ) -> Result<()> {
        // Arc clones, zero-copy; the item vector itself reuses the
        // capacity handed back by the last reaped batch
        let mut items = std::mem::take(spare_items);
        items.clear();
        items.extend(batch.events.iter().map(|ev| ev.inputs.clone()));
        let id = self.next_id;
        self.next_id += 1;
        self.pending.insert(id, (phase, batch.events, done_s));
        self.pool.submit(ExecRequest {
            model: model.to_string(),
            precision,
            items,
            reply: self.reply_tx.clone(),
            id,
        })
    }

    fn in_flight(&self) -> bool {
        self.next_done < self.next_id
    }

    /// Process every completion whose turn has come.
    ///
    /// Panic-audit contract: a batch whose execution failed (worker
    /// panic, engine error, output-count mismatch) is *recorded* — a
    /// typed line in the report's `exec_errors`, a counter, a
    /// `FaultStats` increment — and skipped, instead of aborting a
    /// mission run that has healthy batches still in flight.  Only a
    /// structurally impossible condition (an id we never submitted)
    /// remains a hard error.
    fn process_arrived(
        &mut self,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while let Some(res) = self.arrived.remove(&self.next_done) {
            let (phase, events, done_s) = self
                .pending
                .remove(&res.id)
                .ok_or_else(|| anyhow!("reaped unknown batch id {}", res.id))?;
            let outputs = match res.outputs {
                Ok(o) if o.len() == events.len() => o,
                Ok(o) => {
                    record_exec_error(
                        state,
                        format!(
                            "batch {}: {} outputs for {} events",
                            res.id,
                            o.len(),
                            events.len()
                        ),
                    );
                    self.next_done += 1;
                    continue;
                }
                Err(e) => {
                    record_exec_error(state, format!("batch {}: {e:#}", res.id));
                    self.next_done += 1;
                    continue;
                }
            };
            state.metrics.inc("exec_batches_reaped");
            state.metrics.observe("host_batch_execute", res.host_elapsed);
            state.metrics.observe(
                "host_per_inference",
                res.host_elapsed / events.len().max(1) as u32,
            );
            if state.bank.worker_reaps.len() <= res.worker {
                state.bank.worker_reaps.resize(res.worker + 1, 0);
            }
            state.bank.worker_reaps[res.worker] += 1;
            for (ev, out) in events.iter().zip(&outputs) {
                state.decide_one(use_case, ev, out, input_bytes, phase, done_s);
            }
            // recycle the batch: the executor's input-set clones drop
            // first, then each event's own clone is the last reference
            // and its frame returns to the pool; the drained event
            // vector's capacity goes back to the batcher via restock
            let mut items = res.items;
            for item in items.drain(..) {
                state.pool.reclaim(item);
            }
            if items.capacity() > state.spare_items.capacity() {
                state.spare_items = items;
            }
            let mut events = events;
            for ev in events.drain(..) {
                state.pool.reclaim(ev.inputs);
            }
            if events.capacity() > state.spare_events.capacity() {
                state.spare_events = events;
            }
            self.next_done += 1;
        }
        Ok(())
    }

    /// Non-blocking reap: absorb whatever has completed, process what's
    /// in order.  Called between submissions so the coordinator
    /// overlaps with execution instead of stalling on each batch.
    fn drain_ready(
        &mut self,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while let Ok(res) = self.reply_rx.try_recv() {
            self.arrived.insert(res.id, res);
        }
        self.process_arrived(use_case, input_bytes, state)
    }

    /// Block until fewer than `cap` batches are in flight, so pending
    /// events and their input buffers stay bounded even when the
    /// backend is slower than event generation (the virtual clock
    /// generates events faster than any real backend executes them).
    fn throttle(
        &mut self,
        cap: u64,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while self.next_id - self.next_done >= cap {
            let res = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("executor dropped the reply channel"))?;
            self.arrived.insert(res.id, res);
            self.process_arrived(use_case, input_bytes, state)?;
        }
        Ok(())
    }

    /// Blocking reap of everything still in flight (end of run).
    fn drain_all(
        &mut self,
        use_case: UseCase,
        input_bytes: u64,
        state: &mut RunState,
    ) -> Result<()> {
        while self.in_flight() {
            let res = self
                .reply_rx
                .recv()
                .map_err(|_| anyhow!("executor dropped the reply channel"))?;
            self.arrived.insert(res.id, res);
            self.process_arrived(use_case, input_bytes, state)?;
        }
        Ok(())
    }
}

/// The pipeline itself.
pub struct Pipeline {
    /// Run configuration.
    pub config: PipelineConfig,
    /// Primary route (paper deployment matrix) for the use case.
    pub route: Route,
    /// Per-batch target selection (cost model + policy).  Its `policy`,
    /// `deadline_s`, `power_budget_w`, and registry availability are
    /// the knobs a [`PipelineRun`] mutates between ticks.
    pub dispatcher: Dispatcher,
    /// Candidate execution plans, present when
    /// [`PipelineConfig::plan_mode`] is set: batches then dispatch over
    /// plans instead of whole-model targets.
    planner: Option<Planner>,
    input_bytes: u64,
    /// Per-target TMR cost mode, index-aligned with the registry
    /// (derived once at construction from `rad::tmr` on the ZU7EV pool).
    tmr_costs: Vec<TmrCost>,
    /// Reconfiguration time (s) from calibration — what a quarantined
    /// target's scrub-and-reinstate window adds past the scrub period.
    t_config_s: f64,
}

impl Pipeline {
    /// Resolve routing, build the dispatcher's cost table, and bind the
    /// simulated timing for the configured use case.
    pub fn new(config: PipelineConfig, catalog: &Catalog, calib: &Calibration) -> Result<Pipeline> {
        let mut router = Router::default();
        router.mms_model = config.mms_model.clone();
        let route = router.route(config.use_case, 0)?;
        let man = catalog
            .manifest(&route.model, route.precision)
            .context("pipeline needs `make artifacts` output")?;
        let input_bytes = man.input_bytes();
        let deadline_s = config
            .deadline_s
            .unwrap_or_else(|| default_deadline_s(config.use_case));
        let dispatcher = Dispatcher::new(
            &route.model,
            catalog,
            calib,
            config.policy,
            deadline_s,
            config.power_budget_w,
            &config.targets,
        )?;
        let planner = if config.plan_mode {
            if config.fault_seed.is_some() {
                bail!(
                    "fault injection is not supported in plan mode \
                     (drop --plan or --faults)"
                );
            }
            Some(Planner::build(
                &route.model,
                catalog,
                calib,
                &dispatcher.registry,
                &config.targets,
            )?)
        } else {
            None
        };
        let pl = Zcu104::default().pl;
        let tmr_costs = dispatcher
            .registry
            .targets()
            .iter()
            .map(|t| tmr_cost_of(t.as_ref(), &pl))
            .collect();
        let t_config_s = calib.t_config;
        Ok(Pipeline {
            config,
            route,
            dispatcher,
            planner,
            input_bytes,
            tmr_costs,
            t_config_s,
        })
    }

    /// The candidate plan set, when the pipeline runs in plan mode.
    pub fn planner(&self) -> Option<&Planner> {
        self.planner.as_ref()
    }

    /// Pick a target for one batch, advance its virtual-clock timeline,
    /// then hand the batch to the executor (one request per batch) or
    /// run the surrogate inline.  In plan mode the batch dispatches
    /// over execution plans instead ([`Pipeline::dispatch_plan`]);
    /// with any fault source armed it takes the recovery path
    /// ([`Pipeline::dispatch_recovering`]).  The fault check costs no
    /// RNG draws and no float ops, so fault-free runs stay
    /// byte-identical to the pre-fault-layer pipeline.
    fn dispatch(
        &mut self,
        batch: Batch,
        state: &mut RunState,
        reaper: &mut Option<Reaper<'_>>,
    ) -> Result<()> {
        if self.planner.is_some() {
            return self.dispatch_plan(batch, state, reaper);
        }
        if state.fault.active() {
            return self.dispatch_recovering(batch, state, reaper);
        }
        let phase = state.phase_index();
        let n = batch.len() as u64;
        let oldest_t_s = batch.events.first().map(|e| e.t_s).unwrap_or(batch.flushed_at_s);
        let choice = self.dispatcher.choose_cached(
            &mut state.cache,
            &state.timelines,
            batch.flushed_at_s,
            oldest_t_s,
            n,
        );
        let target = self.dispatcher.registry.get(choice.index);
        let srun = self.dispatcher.run_of(choice.index);
        let (start, done) =
            state.timelines[choice.index].schedule(batch.flushed_at_s, n, srun);
        state.sim_end = state.sim_end.max(done);
        state.events_done += n;
        state.bank.batches += 1;
        state.bank.inferences += n;
        state.bank.lane_batches[choice.index] += 1;
        // predicted-vs-"measured" (virtual clock) telemetry: equal while
        // the cost model and the timeline share calibration; drift here
        // means the dispatcher is optimizing against a stale model
        state.predicted_energy_j += choice.cost.energy_j;
        state.bank.predicted_batch_latency.record(
            Duration::from_secs_f64(choice.cost.latency_s.max(0.0)),
        );
        state.bank.measured_batch_latency.record(
            Duration::from_secs_f64((done - batch.flushed_at_s).max(0.0)),
        );
        let missed = done - oldest_t_s > self.dispatcher.deadline_s;
        if missed {
            state.deadline_misses += 1;
            state.bank.deadline_miss_batches += 1;
        }
        if choice.power_shed {
            state.power_sheds += 1;
            state.bank.power_shed_batches += 1;
        }
        for ev in &batch.events {
            state.latencies.push(done - ev.t_s);
        }
        // phase-segmented accounting: credit the dispatching phase
        {
            let ph = &mut state.phases[phase];
            ph.batches += 1;
            ph.target_mix[choice.index] += 1;
            ph.energy_j += srun.power_w * (done - start);
            if missed {
                ph.deadline_misses += 1;
            }
            if choice.power_shed {
                ph.power_sheds += 1;
            }
            for ev in &batch.events {
                ph.latencies.push(done - ev.t_s);
            }
        }
        self.run_numerics(batch, phase, target.precision(), state, reaper, done)
    }

    /// Dispatch one batch with the fault layer armed: every attempt
    /// rolls the injector (or consumes a forced fault), a faulted
    /// attempt burns its virtual time and power and then retries with
    /// exponential backoff on the same target (bounded by
    /// [`RecoveryPolicy::max_retries_per_target`]), escalates to the
    /// next-best non-excluded target when retries run out, and
    /// quarantines a target whose consecutive-fault streak crosses the
    /// threshold (reinstated after the next scrub window +
    /// reconfiguration).  Under TMR each attempt rolls three replicas
    /// — a single faulty replica is outvoted (masked), two or more
    /// fail the attempt.  A brownout window tightens the power budget
    /// for every policy (degraded-mode dispatch).  The attempt at
    /// [`RecoveryPolicy::max_attempts`] is forced to complete, so
    /// every admitted batch finishes and the accounting invariants
    /// (events, batches, downlink conservation) hold under any fault
    /// timeline.
    fn dispatch_recovering(
        &mut self,
        batch: Batch,
        state: &mut RunState,
        reaper: &mut Option<Reaper<'_>>,
    ) -> Result<()> {
        let phase = state.phase_index();
        let n = batch.len() as u64;
        let oldest_t_s = batch.events.first().map(|e| e.t_s).unwrap_or(batch.flushed_at_s);
        // recovery-mode dispatch never consults the cache: per-attempt
        // exclusion masks and brownout overrides are transient inputs a
        // cache key does not carry
        state.cache.note_bypass();
        let mut excluded = std::mem::take(&mut state.excluded);
        excluded.clear();
        excluded.resize(self.dispatcher.registry.len(), false);
        let mut at = batch.flushed_at_s;
        let mut attempt: u32 = 0;
        let mut retries_same: u32 = 0;
        enum Outcome {
            Success { masked: u64 },
            Failure(FaultKind),
        }
        loop {
            attempt += 1;
            let forced = attempt >= state.fault.recovery.max_attempts;
            let budget = state.fault.brownout_budget(at);
            let choice = self.dispatcher.choose_constrained(
                &state.timelines,
                at,
                oldest_t_s,
                n,
                &excluded,
                budget,
            );
            let index = choice.index;
            let precision = self.dispatcher.registry.get(index).precision();
            let mut srun = self.dispatcher.run_of(index);
            let throttle = state.fault.throttle_factor(index, at);
            if throttle != 1.0 {
                srun.setup_s *= throttle;
                srun.per_item_s *= throttle;
            }
            let tmr = state.fault.recovery.tmr;
            if tmr {
                match self.tmr_costs[index] {
                    TmrCost::Spatial(pf) => srun.power_w *= pf,
                    TmrCost::Temporal => {
                        srun.setup_s *= 3.0;
                        srun.per_item_s *= 3.0;
                    }
                }
            }
            let (outcome, thermal) = if forced {
                // the attempt cap: complete unconditionally, no rolls
                (Outcome::Success { masked: 0 }, false)
            } else if tmr {
                let mut faults = [None; 3];
                let mut n_faults = 0usize;
                let mut thermal = false;
                for slot in &mut faults {
                    let (f, th) = state.fault.roll_attempt(index);
                    if f.is_some() {
                        *slot = f;
                        n_faults += 1;
                    }
                    thermal |= th;
                }
                let out = match n_faults {
                    0 => Outcome::Success { masked: 0 },
                    1 => Outcome::Success { masked: 1 },
                    _ => Outcome::Failure(
                        faults.iter().flatten().copied().next().expect("n_faults >= 2"),
                    ),
                };
                (out, thermal)
            } else {
                let (f, th) = state.fault.roll_attempt(index);
                let out = match f {
                    None => Outcome::Success { masked: 0 },
                    Some(kind) => Outcome::Failure(kind),
                };
                (out, th)
            };
            if let Outcome::Failure(FaultKind::ExecTimeout) = outcome {
                // a hung attempt occupies the target well past budget
                let tf = state.fault.timeout_factor();
                srun.setup_s *= tf;
                srun.per_item_s *= tf;
            }
            let (start, done) = state.timelines[index].schedule(at, n, srun);
            state.sim_end = state.sim_end.max(done);
            if thermal {
                if let Some((derate, duration)) = state.fault.thermal_params() {
                    state.fault.open_throttle(index, derate, start + duration);
                    state.fault.stats.faults_injected += 1;
                    state.phases[phase].faults += 1;
                    state.metrics.inc("fault_thermal_throttle");
                }
            }
            match outcome {
                Outcome::Failure(kind) => {
                    // the failed attempt still burned real time + power
                    state.fault.stats.faults_injected += 1;
                    state.phases[phase].faults += 1;
                    state.phases[phase].energy_j += srun.power_w * (done - start);
                    state.metrics.inc(fault_metric(kind));
                    if tmr {
                        state.fault.stats.tmr_batches += 1;
                        state.metrics.inc("tmr_batches");
                    }
                    let streak = state.fault.note_fault(index);
                    let threshold = state.fault.recovery.quarantine_threshold;
                    if threshold > 0
                        && streak >= threshold
                        && !state.fault.is_quarantined(index)
                        && self.dispatcher.registry.is_available(index)
                    {
                        // flaky target: out of service until the next
                        // scrub window repairs it (plus reconfiguration)
                        self.dispatcher.registry.set_available(index, false);
                        state.cache.invalidate_availability(
                            DispatchCache::availability_mask(&self.dispatcher.registry),
                        );
                        let period = state.fault.recovery.quarantine_scrub_period_s;
                        let wait = period - (done % period);
                        state.fault.quarantine(index, done + wait + self.t_config_s);
                        state.fault.stats.quarantines += 1;
                        state.phases[phase].quarantines += 1;
                        state.metrics.inc("quarantine");
                    }
                    let retry_ok = retries_same
                        < state.fault.recovery.max_retries_per_target
                        && self.dispatcher.registry.is_available(index)
                        && !excluded[index];
                    if retry_ok {
                        retries_same += 1;
                        state.fault.stats.retries += 1;
                        state.phases[phase].retries += 1;
                        state.metrics.inc("fault_retry");
                    } else {
                        // escalate: burn this target for the batch and
                        // let the policy pick the next-best candidate
                        excluded[index] = true;
                        retries_same = 0;
                        state.fault.stats.redispatches += 1;
                        state.metrics.inc("redispatch_escalation");
                    }
                    let exp = (attempt.min(20) - 1) as i32;
                    at = done + state.fault.recovery.backoff_base_s * 2f64.powi(exp);
                }
                Outcome::Success { masked } => {
                    state.events_done += n;
                    state.bank.batches += 1;
                    state.bank.inferences += n;
                    state.bank.lane_batches[index] += 1;
                    state.predicted_energy_j += choice.cost.energy_j;
                    state.bank.predicted_batch_latency.record(
                        Duration::from_secs_f64(choice.cost.latency_s.max(0.0)),
                    );
                    state.bank.measured_batch_latency.record(
                        Duration::from_secs_f64((done - batch.flushed_at_s).max(0.0)),
                    );
                    let missed = done - oldest_t_s > self.dispatcher.deadline_s;
                    if missed {
                        state.deadline_misses += 1;
                        state.bank.deadline_miss_batches += 1;
                    }
                    if choice.power_shed {
                        state.power_sheds += 1;
                        state.bank.power_shed_batches += 1;
                    }
                    for ev in &batch.events {
                        state.latencies.push(done - ev.t_s);
                    }
                    if tmr {
                        state.fault.stats.tmr_batches += 1;
                        state.metrics.inc("tmr_batches");
                    }
                    if masked > 0 {
                        // a single faulty replica was outvoted: the
                        // fault happened, the batch still stands
                        state.fault.stats.tmr_masked += masked;
                        state.fault.stats.faults_injected += masked;
                        state.phases[phase].tmr_masked += masked;
                        state.phases[phase].faults += masked;
                        state.metrics.add("tmr_masked", masked);
                    }
                    if budget.is_some() {
                        state.fault.stats.degraded_batches += 1;
                        state.phases[phase].degraded += 1;
                        state.metrics.inc("degraded_batches");
                    }
                    if forced && attempt > 1 {
                        state.fault.stats.forced_completions += 1;
                        state.metrics.inc("forced_completions");
                    }
                    state.fault.note_success(index);
                    {
                        let ph = &mut state.phases[phase];
                        ph.batches += 1;
                        ph.target_mix[index] += 1;
                        ph.energy_j += srun.power_w * (done - start);
                        if missed {
                            ph.deadline_misses += 1;
                        }
                        if choice.power_shed {
                            ph.power_sheds += 1;
                        }
                        for ev in &batch.events {
                            ph.latencies.push(done - ev.t_s);
                        }
                    }
                    state.excluded = excluded;
                    return self.run_numerics(batch, phase, precision, state, reaper, done);
                }
            }
        }
    }

    /// Pick an execution plan for one batch, advance every segment's
    /// lane timeline in order (boundary transfers between them), then
    /// run the numerics exactly like the whole-model path.
    fn dispatch_plan(
        &self,
        batch: Batch,
        state: &mut RunState,
        reaper: &mut Option<Reaper<'_>>,
    ) -> Result<()> {
        let planner = match self.planner.as_ref() {
            Some(p) => p,
            None => bail!("dispatch_plan called without plan mode (internal error)"),
        };
        let phase = state.phase_index();
        let n = batch.len() as u64;
        let oldest_t_s = batch.events.first().map(|e| e.t_s).unwrap_or(batch.flushed_at_s);
        let pc = self.dispatcher.choose_plan_cached(
            &mut state.cache,
            planner,
            &state.timelines,
            batch.flushed_at_s,
            oldest_t_s,
            n,
        );
        let plan = &planner.plans()[pc.index];
        // segments execute sequentially: each lane's timeline is
        // charged in order, and the batch's activations pay the
        // boundary transfer before the next segment may start
        let mut at = batch.flushed_at_s;
        let mut done = at;
        let mut energy = 0.0;
        for seg in &plan.segments {
            let srun = ScheduledRun {
                setup_s: seg.setup_s,
                per_item_s: seg.per_item_s,
                power_w: seg.power_w,
            };
            let (start, d) = state.timelines[planner.flat(seg.lane)].schedule(at, n, srun);
            energy += seg.power_w * (d - start);
            done = d;
            at = d + n as f64 * seg.transfer_out_s;
            state.bank.lane_batches[planner.flat(seg.lane)] += 1;
        }
        state.sim_end = state.sim_end.max(done);
        state.events_done += n;
        state.bank.batches += 1;
        state.bank.inferences += n;
        state.metrics.inc("plan_batches");
        state.plan_batches += 1;
        if plan.is_hybrid() {
            state.metrics.inc("plan_hybrid_batches");
            state.plan_hybrid_batches += 1;
        }
        state.plan_transfer_s += n as f64 * plan.transfer_per_item_s;
        state.predicted_energy_j += pc.cost.energy_j;
        state.bank.predicted_batch_latency.record(
            Duration::from_secs_f64(pc.cost.latency_s.max(0.0)),
        );
        state.bank.measured_batch_latency.record(
            Duration::from_secs_f64((done - batch.flushed_at_s).max(0.0)),
        );
        let missed = done - oldest_t_s > self.dispatcher.deadline_s;
        if missed {
            state.deadline_misses += 1;
            state.bank.deadline_miss_batches += 1;
        }
        if pc.power_shed {
            state.power_sheds += 1;
            state.bank.power_shed_batches += 1;
        }
        for ev in &batch.events {
            state.latencies.push(done - ev.t_s);
        }
        {
            let ph = &mut state.phases[phase];
            ph.batches += 1;
            for seg in &plan.segments {
                ph.target_mix[planner.flat(seg.lane)] += 1;
            }
            ph.energy_j += energy;
            if missed {
                ph.deadline_misses += 1;
            }
            if pc.power_shed {
                ph.power_sheds += 1;
            }
            for ev in &batch.events {
                ph.latencies.push(done - ev.t_s);
            }
        }
        // numerics follow the deployed variant: a single-segment plan on
        // a registry target keeps that target's precision (bit-identical
        // to the whole-model path); hybrids run the host-visible fp32
        // variant (per-segment quantization is a timing/energy concern,
        // not a numerics path we have artifacts for)
        let precision = match (plan.segments.len(), plan.segments[0].lane) {
            (1, Lane::Registry(i)) => self.dispatcher.registry.get(i).precision(),
            _ => Precision::Fp32,
        };
        self.run_numerics(batch, phase, precision, state, reaper, done)
    }

    /// Post-scheduling numerics, shared by all dispatch paths: one
    /// `ExecRequest` per batch through the pool, or the inline
    /// deterministic surrogate for timing-only runs.  `done_s` is the
    /// batch's virtual completion time (the downlink dropout check).
    fn run_numerics(
        &self,
        batch: Batch,
        phase: usize,
        precision: Precision,
        state: &mut RunState,
        reaper: &mut Option<Reaper<'_>>,
        done_s: f64,
    ) -> Result<()> {
        let cfg = &self.config;
        match reaper {
            Some(r) => {
                r.submit(
                    &self.route.model,
                    precision,
                    phase,
                    batch,
                    done_s,
                    &mut state.spare_items,
                )?;
                // overlap: absorb any batches that already finished,
                // then apply backpressure so in-flight work is bounded
                r.drain_ready(cfg.use_case, self.input_bytes, state)?;
                r.throttle(
                    MAX_INFLIGHT_BATCHES,
                    cfg.use_case,
                    self.input_bytes,
                    state,
                )
            }
            None => {
                // timing-only run: deterministic surrogate numerics,
                // processed inline (same RNG order as the PJRT path)
                let mut out = std::mem::take(&mut state.surrogate_buf);
                for ev in &batch.events {
                    surrogate_output_into(cfg.use_case, ev, &mut state.rng, &mut out);
                    state.decide_one(
                        cfg.use_case,
                        ev,
                        &out,
                        self.input_bytes,
                        phase,
                        done_s,
                    );
                }
                state.surrogate_buf = out;
                // recycle the batch: frames back to the pool (each
                // event's clone is the last reference on this path),
                // the drained vector's capacity back to the batcher
                let Batch { mut events, .. } = batch;
                for ev in events.drain(..) {
                    state.pool.reclaim(ev.inputs);
                }
                if events.capacity() > state.spare_events.capacity() {
                    state.spare_events = events;
                }
                Ok(())
            }
        }
    }

    /// Open a steppable run: the state machine behind [`Pipeline::run`]
    /// and the `crate::scenario` engine.  `executor` supplies real
    /// numerics through the sharded pool; pass `None` for a timing-only
    /// (deterministic surrogate outputs) run.
    ///
    /// The run borrows the pipeline mutably so knob mutations between
    /// ticks ([`PipelineRun::set_policy`] and friends) are visible to
    /// the very next dispatch.  Mutations persist on the `Pipeline`
    /// after the run finishes — scenario drivers build a fresh
    /// `Pipeline` per run.
    pub fn begin<'e>(
        &mut self,
        executor: Option<&'e ExecutorPool>,
    ) -> PipelineRun<'_, 'e> {
        let reaper = executor.map(Reaper::new);
        PipelineRun { core: RunCore::build(PipelineHandle::Borrowed(self)), reaper }
    }

    /// Open an *owned* run: like [`Pipeline::begin`] but the run takes
    /// the pipeline with it, so the whole state machine is `Send` and
    /// can migrate across threads — the seam `crate::fleet` uses to
    /// shard one run per spacecraft over a scoped worker pool.
    ///
    /// Timing-only by construction: real-numerics reaping borrows the
    /// executor pool for the life of the run, which would pin it to one
    /// thread, so the owned form structurally excludes it.  Decisions
    /// come from the deterministic surrogate, exactly as
    /// `Pipeline::begin(None)`.
    pub fn begin_owned(self) -> OwnedPipelineRun {
        OwnedPipelineRun {
            core: Some(RunCore::build(PipelineHandle::Owned(Box::new(self)))),
        }
    }

    /// Run the pipeline: the thin driver loop over [`Pipeline::begin`],
    /// `config.n_events` ticks, and [`PipelineRun::finish`].  `executor`
    /// supplies real numerics through the sharded pool; pass `None` for
    /// a timing-only (simulated outputs) run — decisions then come from
    /// a deterministic surrogate so downstream stages still exercise.
    pub fn run(&mut self, executor: Option<&ExecutorPool>) -> Result<PipelineReport> {
        let n = self.config.n_events;
        let mut run = self.begin(executor);
        for _ in 0..n {
            run.tick()?;
        }
        run.finish()
    }

    /// Request-driven variant of the tick loop: rebind the seed and
    /// event count, then replay the whole begin → tick → finish cycle
    /// on the already-built dispatcher and registry.  This is the seam
    /// the serving layer (`crate::serve`) calls once per admitted
    /// request — construction (routing, registry build, planner) is
    /// amortized across every request sharing a lane, while the run
    /// itself is a pure function of `(config, seed, n_events)`, so the
    /// report is bit-identical to a fresh [`Pipeline::new`] with the
    /// same config.  Timing-only (`executor = None`) by design: serving
    /// replies carry virtual-clock telemetry, not host numerics.
    pub fn run_request(&mut self, seed: u64, n_events: usize) -> Result<PipelineReport> {
        self.config.seed = seed;
        self.config.n_events = n_events;
        self.run(None)
    }
}

/// How a run holds its pipeline: borrowed (the classic
/// [`Pipeline::begin`] form) or owned (the `Send`-able
/// [`Pipeline::begin_owned`] form).  `Deref` to [`Pipeline`] keeps the
/// run's method bodies identical across both, which is what makes the
/// borrowed and owned state machines bit-identical by construction.
enum PipelineHandle<'p> {
    /// Run borrows the pipeline; knob mutations persist after `finish`.
    Borrowed(&'p mut Pipeline),
    /// Run owns the pipeline; the whole machine can cross threads.
    Owned(Box<Pipeline>),
}

impl Deref for PipelineHandle<'_> {
    type Target = Pipeline;
    fn deref(&self) -> &Pipeline {
        match self {
            PipelineHandle::Borrowed(p) => p,
            PipelineHandle::Owned(p) => p,
        }
    }
}

impl DerefMut for PipelineHandle<'_> {
    fn deref_mut(&mut self) -> &mut Pipeline {
        match self {
            PipelineHandle::Borrowed(p) => p,
            PipelineHandle::Owned(p) => p,
        }
    }
}

/// The run state machine proper — everything a [`PipelineRun`] is,
/// *minus* the reaper (whose executor-pool borrow is the one thing
/// that cannot move across threads).  Public entry points thread the
/// reaper back in as a parameter, so the borrowed and owned run types
/// are thin wrappers over identical logic.
struct RunCore<'p> {
    pipeline: PipelineHandle<'p>,
    stream: SensorStream,
    batcher: Batcher,
    ingress: Option<BoundedQueue<SensorEvent>>,
    state: RunState,
    emitted: u64,
    base_cadence_s: f64,
    base_deadline_s: f64,
    /// One shared empty frame for pixel-free husk events (timing-only
    /// image streams) — every husk event bumps its refcount instead of
    /// allocating.
    husk_frame: Frame,
}

/// One in-progress pipeline run: the steppable state machine.
///
/// Obtained from [`Pipeline::begin`].  Each [`PipelineRun::tick`]
/// advances the virtual clock by one sensor event (generate → ingress
/// admission → batch → dispatch → decide/downlink); between ticks the
/// caller may retune any operational knob — dispatch policy, power
/// budget, deadline, cadence/burst, downlink budget, per-target
/// availability — and the next dispatch obeys it.  `crate::scenario`
/// drives this interface from declarative mission timelines, and
/// `crate::fleet` drives it through [`OwnedPipelineRun::with_run`].
pub struct PipelineRun<'p, 'e> {
    core: RunCore<'p>,
    reaper: Option<Reaper<'e>>,
}

impl<'p> RunCore<'p> {
    /// Shared constructor behind [`Pipeline::begin`] (borrowed handle)
    /// and [`Pipeline::begin_owned`] (owned handle).
    fn build(pipeline: PipelineHandle<'p>) -> RunCore<'p> {
        let cfg = &pipeline.config;
        let stream = SensorStream::new(cfg.use_case, cfg.seed, cfg.cadence_s);
        let batcher = Batcher::new(&pipeline.route.model, cfg.max_batch, cfg.max_wait_s);
        let ingress = cfg
            .ingress_cap
            .map(|cap| BoundedQueue::new(cap, cfg.ingress_policy));
        // plan mode appends one timeline per derived (plan-only) lane
        // after the registry lanes, matching `Planner::flat` indexing
        let mut timelines = pipeline.dispatcher.timelines();
        if let Some(p) = &pipeline.planner {
            for name in p.derived_lane_names() {
                timelines.push(AccelTimeline::new(name));
            }
        }
        // SEU exposure scales per-target corruption probability by
        // essential configuration bits, normalized to the fleet max
        // (the A53 exposes none and never draws a corruption)
        let injector = cfg.fault_seed.map(|seed| {
            let bits: Vec<u64> = pipeline
                .dispatcher
                .registry
                .targets()
                .iter()
                .map(|t| essential_bits_of(&t.resources()))
                .collect();
            let max = bits.iter().copied().max().unwrap_or(0).max(1);
            let exposure = bits.iter().map(|&b| b as f64 / max as f64).collect();
            FaultInjector::new(seed, cfg.fault_profile, exposure)
        });
        let fault =
            FaultState::new(pipeline.dispatcher.registry.len(), injector, cfg.recovery);
        // intern every hot-path counter once: flat lane names follow
        // `Planner::flat` (registry targets, then derived plan lanes)
        let registry = &pipeline.dispatcher.registry;
        let mut lane_names: Vec<String> =
            (0..registry.len()).map(|i| registry.get(i).name().to_string()).collect();
        if let Some(p) = &pipeline.planner {
            lane_names.extend(p.derived_lane_names().map(String::from));
        }
        let lanes = lane_names.len();
        let pool = if cfg.frame_pool {
            // enough free frames to cover every batch the coordinator
            // can hold in flight between flush and reap
            FramePool::new((4 * cfg.max_batch).max(16))
        } else {
            FramePool::disabled()
        };
        let state = RunState {
            timelines,
            downlink: DownlinkManager::new(cfg.downlink_budget),
            metrics: Metrics::default(),
            bank: MetricBank::new(lane_names),
            pool,
            surrogate_buf: Vec::new(),
            spare_events: Vec::new(),
            spare_items: Vec::new(),
            excluded: Vec::new(),
            rng: Prng::new(cfg.seed ^ DECISION_RNG_SALT),
            latencies: Vec::with_capacity(cfg.n_events),
            predicted_energy_j: 0.0,
            deadline_misses: 0,
            power_sheds: 0,
            plan_batches: 0,
            plan_hybrid_batches: 0,
            plan_transfer_s: 0.0,
            events_done: 0,
            correct: 0,
            with_truth: 0,
            sim_end: 0.0,
            phases: vec![PhaseAccum::new("run", 0.0, lanes, cfg.n_events)],
            fault,
            exec_errors: Vec::new(),
            cache: DispatchCache::new(cfg.dispatch_cache),
        };
        let base_cadence_s = cfg.cadence_s;
        let base_deadline_s = pipeline.dispatcher.deadline_s;
        RunCore {
            stream,
            batcher,
            ingress,
            state,
            emitted: 0,
            base_cadence_s,
            base_deadline_s,
            husk_frame: Arc::new(Vec::new()),
            pipeline,
        }
    }
}

impl RunCore<'_> {
    /// The virtual-clock frontier (s): the timestamp the next generated
    /// event will carry.
    pub fn now_s(&self) -> f64 {
        self.stream.t_s
    }

    /// Sensor events generated so far.
    pub fn events_emitted(&self) -> u64 {
        self.emitted
    }

    /// The deadline the run started with (s) — what
    /// [`PipelineRun::set_deadline_s`] restores after a storm tightens
    /// it.
    pub fn base_deadline_s(&self) -> f64 {
        self.base_deadline_s
    }

    /// Dispatch-cache counters so far (all zero when the cache is off).
    pub fn cache_stats(&self) -> CacheStats {
        self.state.cache.stats()
    }

    /// Frame-pool counters so far (all zero when the pool is off) —
    /// what the reuse tests assert recycling with.
    pub fn pool_stats(&self) -> PoolStats {
        self.state.pool.stats()
    }

    /// Live dispatch-cache entries — what the invalidation-exactness
    /// tests count before and after a knob mutation.
    pub fn cache_entries(&self) -> usize {
        self.state.cache.entries()
    }

    /// Switch the dispatch policy; the next batch is scored under it.
    /// Cache entries keyed under any other policy are invalidated.
    pub fn set_policy(&mut self, policy: Policy) {
        self.pipeline.dispatcher.policy = policy;
        self.state.cache.invalidate_policy(policy);
    }

    /// Set or lift the mission power budget (cap on active MPSoC draw,
    /// W).  Only dynamic policies consult it — and only their cache
    /// entries are invalidated.
    pub fn set_power_budget_w(&mut self, budget_w: Option<f64>) {
        self.pipeline.dispatcher.power_budget_w = budget_w;
        self.state.cache.invalidate_power_budget(budget_w);
    }

    /// Retune the end-to-end deadline (s).  Errors on a non-positive
    /// or non-finite value instead of aborting a mission run.  Only
    /// `deadline`-policy cache entries are invalidated — no other
    /// policy reads the deadline.
    pub fn set_deadline_s(&mut self, deadline_s: f64) -> Result<()> {
        if !(deadline_s > 0.0 && deadline_s.is_finite()) {
            bail!("deadline must be positive and finite, got {deadline_s}");
        }
        self.pipeline.dispatcher.deadline_s = deadline_s;
        self.state.cache.invalidate_deadline(deadline_s);
        Ok(())
    }

    /// Change the sensor cadence (s between samples) from the next
    /// inter-event gap on.
    pub fn set_cadence_s(&mut self, cadence_s: f64) {
        self.stream.set_cadence(cadence_s);
    }

    /// Multiply the *base* event rate: `set_burst(100.0)` runs the
    /// sensor 100× faster than the configured cadence,
    /// `set_burst(1.0)` restores it.  Errors on a non-positive or
    /// non-finite multiplier instead of aborting a mission run.
    pub fn set_burst(&mut self, burst_x: f64) -> Result<()> {
        if !(burst_x > 0.0 && burst_x.is_finite()) {
            bail!("burst multiplier must be positive and finite, got {burst_x}");
        }
        self.stream.set_cadence(self.base_cadence_s / burst_x);
        Ok(())
    }

    /// Grant additional downlink byte budget (a ground-station pass).
    pub fn grant_downlink_bytes(&mut self, bytes: u64) {
        self.state.downlink.budget_bytes += bytes;
        self.state.metrics.add("downlink_budget_granted", bytes);
    }

    /// Registry index of a dispatch target by name, if registered for
    /// this run's model.
    pub fn target_index(&self, name: &str) -> Option<usize> {
        self.pipeline.dispatcher.registry.index_of(name)
    }

    /// Mark a dispatch target in or out of service (see
    /// [`crate::backend::TargetRegistry::set_available`]).  The next
    /// batch re-dispatches around an out-of-service target.
    pub fn set_target_available(&mut self, index: usize, available: bool) {
        self.pipeline.dispatcher.registry.set_available(index, available);
        self.state.cache.invalidate_availability(DispatchCache::availability_mask(
            &self.pipeline.dispatcher.registry,
        ));
        self.state.metrics.inc(if available {
            "target_restored"
        } else {
            "target_knocked_out"
        });
    }

    /// Open a downlink dropout window from the current virtual time:
    /// decisions whose batch completes inside it are lost before the
    /// byte budget is consulted.  Overlapping windows extend.
    pub fn set_link_dropout(&mut self, duration_s: f64) -> Result<()> {
        if !(duration_s > 0.0 && duration_s.is_finite()) {
            bail!("dropout duration must be positive and finite, got {duration_s}");
        }
        let until = self.stream.t_s + duration_s;
        self.state.fault.open_link_dropout(until);
        self.count_window_fault("fault_link_dropout");
        Ok(())
    }

    /// Open a brownout window from the current virtual time: every
    /// policy (including `static`) dispatches under `budget_w` until it
    /// closes — degraded-mode dispatch.  Re-opening overwrites.
    pub fn set_brownout(&mut self, budget_w: f64, duration_s: f64) -> Result<()> {
        if !(budget_w > 0.0 && budget_w.is_finite()) {
            bail!("brownout budget must be positive and finite, got {budget_w}");
        }
        if !(duration_s > 0.0 && duration_s.is_finite()) {
            bail!("brownout duration must be positive and finite, got {duration_s}");
        }
        let until = self.stream.t_s + duration_s;
        self.state.fault.open_brownout(until, budget_w);
        self.count_window_fault("fault_brownout");
        Ok(())
    }

    /// Open a thermal throttle window on one registry target from the
    /// current virtual time: its setup and per-item latencies multiply
    /// by `derate_x` until the window closes.
    pub fn set_thermal_throttle(
        &mut self,
        index: usize,
        derate_x: f64,
        duration_s: f64,
    ) -> Result<()> {
        if index >= self.pipeline.dispatcher.registry.len() {
            bail!("thermal throttle: no registry target at index {index}");
        }
        if !(derate_x >= 1.0 && derate_x.is_finite()) {
            bail!("thermal derate must be >= 1 and finite, got {derate_x}");
        }
        if !(duration_s > 0.0 && duration_s.is_finite()) {
            bail!("throttle duration must be positive and finite, got {duration_s}");
        }
        let until = self.stream.t_s + duration_s;
        self.state.fault.open_throttle(index, derate_x, until);
        self.count_window_fault("fault_thermal_throttle");
        Ok(())
    }

    /// Queue one forced transient execution failure against a registry
    /// target — consumed (and counted) by the next attempt dispatched
    /// there.  The deterministic handle mission events and tests use.
    pub fn inject_transient_fault(&mut self, index: usize) -> Result<()> {
        if index >= self.pipeline.dispatcher.registry.len() {
            bail!("transient fault: no registry target at index {index}");
        }
        self.state.fault.force_exec_fail(index);
        Ok(())
    }

    /// Queue one forced SEU corruption against a registry target —
    /// consumed by the next attempt there (a single TMR replica
    /// outvotes it; without TMR the attempt fails and recovers).
    pub fn inject_corruption(&mut self, index: usize) -> Result<()> {
        if index >= self.pipeline.dispatcher.registry.len() {
            bail!("corruption: no registry target at index {index}");
        }
        self.state.fault.force_corrupt(index);
        Ok(())
    }

    /// Count one opened environment fault window (aggregate + current
    /// phase + metric).
    fn count_window_fault(&mut self, metric: &str) {
        let idx = self.state.phase_index();
        self.state.fault.stats.faults_injected += 1;
        self.state.phases[idx].faults += 1;
        self.state.metrics.inc(metric);
    }

    /// Per-tick fault housekeeping: reinstate quarantined targets whose
    /// scrub window elapsed, then roll the injector's tick-granularity
    /// environment faults (brownout, downlink dropout).  A no-op — no
    /// RNG, no float ops — while the fault layer is inactive.
    fn tick_faults(&mut self, now_s: f64) {
        if !self.state.fault.active() {
            return;
        }
        for index in self.state.fault.take_due_reinstates(now_s) {
            self.pipeline.dispatcher.registry.set_available(index, true);
            self.state.cache.invalidate_availability(
                DispatchCache::availability_mask(&self.pipeline.dispatcher.registry),
            );
            self.state.fault.stats.reinstates += 1;
            self.state.metrics.inc("quarantine_reinstate");
        }
        if let Some((ticks, profile)) = self.state.fault.roll_tick() {
            if ticks.brownout {
                self.state.fault.open_brownout(
                    now_s + profile.brownout_duration_s,
                    profile.brownout_budget_w,
                );
                self.count_window_fault("fault_brownout");
            }
            if ticks.dropout {
                self.state
                    .fault
                    .open_link_dropout(now_s + profile.dropout_duration_s);
                self.count_window_fault("fault_link_dropout");
            }
        }
    }

    /// Start a new report phase at the current virtual time.  All
    /// subsequent batches, drops, and downlink verdicts are credited to
    /// it.  The very first call renames the initial `"run"` placeholder
    /// in place (so a scenario's first phase is the report's first
    /// phase); later calls close the current phase and open a new one.
    pub fn begin_phase(&mut self, name: &str) {
        let now = self.stream.t_s;
        let latency_cap = self.pipeline.config.n_events;
        let phases = &mut self.state.phases;
        let lanes = phases[0].target_mix.len();
        if phases.len() == 1 && phases[0].is_untouched() && phases[0].name == "run" {
            phases[0].name = name.to_string();
            phases[0].start_s = now;
            phases[0].end_s = now;
            return;
        }
        if let Some(last) = phases.last_mut() {
            last.end_s = now;
        }
        phases.push(PhaseAccum::new(name, now, lanes, latency_cap));
    }

    /// Can the ingress queue release an event to the batcher right now?
    /// Yes while the least-loaded in-service target is within the
    /// configured backlog bound — otherwise events pool (and overflow
    /// sheds) instead of growing an unbounded batch backlog.  With
    /// *nothing* in service the gate falls back to the full set, the
    /// same "a spacecraft cannot stop deciding" fallback the dispatcher
    /// applies — the two layers must agree on whether work proceeds.
    fn admission_open(&self, now_s: f64) -> bool {
        let d = &self.pipeline.dispatcher;
        let bound = self.pipeline.config.ingress_max_backlog_s;
        let min_over = |available_only: bool| {
            (0..d.registry.len())
                .filter(|&i| !available_only || d.registry.is_available(i))
                .map(|i| self.state.timelines[i].backlog_s(now_s))
                .fold(f64::INFINITY, f64::min)
        };
        let min_backlog = if d.registry.available_count() > 0 {
            min_over(true)
        } else {
            min_over(false)
        };
        min_backlog <= bound
    }

    /// Advance the virtual clock by exactly one sensor event: generate
    /// it, run ingress admission (when configured), feed the batcher,
    /// and dispatch whatever flushes.
    ///
    /// Event generation is the allocation-free fast path when the
    /// frame pool is on: frames recycle through the pool, and on
    /// timing-only runs of the truth-free image streams the pixels are
    /// never synthesized at all (nothing downstream reads them — the
    /// batch is priced from the model manifest and decisions come from
    /// the separately-seeded decision RNG).
    fn tick(&mut self, reaper: &mut Option<Reaper<'_>>) -> Result<()> {
        if self.state.spare_events.capacity() > 0 {
            let spare = std::mem::take(&mut self.state.spare_events);
            self.batcher.restock(spare);
        }
        let ev = if !self.state.pool.is_enabled() {
            self.stream.next_event()
        } else if reaper.is_none() && self.stream.synthesis_is_pixels_only() {
            self.stream.next_event_husk(&self.husk_frame)
        } else {
            self.stream.next_event_pooled(&mut self.state.pool)
        };
        let now = ev.t_s;
        self.tick_faults(now);
        self.emitted += 1;
        {
            let idx = self.state.phase_index();
            self.state.phases[idx].events += 1;
        }
        if let Some(b) = self.batcher.poll(now) {
            self.pipeline.dispatch(b, &mut self.state, reaper)?;
        }
        if self.ingress.is_none() {
            if let Some(b) = self.batcher.offer(ev, now) {
                self.pipeline.dispatch(b, &mut self.state, reaper)?;
            }
            return Ok(());
        }
        let dropped_before = self.ingress.as_ref().map(|q| q.dropped).unwrap_or(0);
        // free queue space first — if the backlog has drained since the
        // last tick, the pooled events leave before the new one arrives
        self.drain_ingress(now, reaper)?;
        if let Some(q) = self.ingress.as_mut() {
            q.push(ev);
        }
        self.drain_ingress(now, reaper)?;
        let dropped_now = self.ingress.as_ref().map(|q| q.dropped).unwrap_or(0);
        let shed = dropped_now - dropped_before;
        if shed > 0 {
            let idx = self.state.phase_index();
            self.state.phases[idx].dropped += shed;
            self.state.metrics.add("ingress_dropped", shed);
        }
        Ok(())
    }

    /// Admission loop: release queued events into the batcher while
    /// some in-service target is keeping up.  Each release may flush a
    /// batch, which grows the backlog, so the gate is re-checked per
    /// event.
    fn drain_ingress(
        &mut self,
        now_s: f64,
        reaper: &mut Option<Reaper<'_>>,
    ) -> Result<()> {
        loop {
            if !self.admission_open(now_s) {
                return Ok(());
            }
            let ev = match self.ingress.as_mut().and_then(|q| q.pop()) {
                Some(ev) => ev,
                None => return Ok(()),
            };
            if let Some(b) = self.batcher.offer(ev, now_s) {
                self.pipeline.dispatch(b, &mut self.state, reaper)?;
            }
        }
    }

    /// Drain everything in flight and assemble the report.  For a
    /// constant-cadence single-phase run the aggregate fields are
    /// bit-identical to the pre-steppable `Pipeline::run`.
    fn finish(mut self, mut reaper: Option<Reaper<'_>>) -> Result<PipelineReport> {
        let cfg = self.pipeline.config.clone();
        // release any events still pooled at ingress: they were
        // accepted, so they run (the queue bounds memory, not the tail)
        let now = self.stream.t_s;
        loop {
            let ev = match self.ingress.as_mut().and_then(|q| q.pop()) {
                Some(ev) => ev,
                None => break,
            };
            if let Some(b) = self.batcher.offer(ev, now) {
                self.pipeline.dispatch(b, &mut self.state, &mut reaper)?;
            }
        }
        // end-of-run drain: by drain_t the wait timer is always overdue,
        // so poll() stamps the flush when that timer would have fired
        // (oldest + max_wait) instead of charging the full drain gap;
        // the unconditional flush below is only the empty-batcher no-op.
        // (stream.t_s is the virtual frontier — for a constant cadence
        // it equals n_events * cadence_s, the pre-steppable formula.)
        let drain_t = self.stream.t_s + cfg.max_wait_s;
        if let Some(b) = self.batcher.poll(drain_t) {
            self.pipeline.dispatch(b, &mut self.state, &mut reaper)?;
        }
        if let Some(b) = self.batcher.flush(drain_t) {
            self.pipeline.dispatch(b, &mut self.state, &mut reaper)?;
        }
        if let Some(r) = &mut reaper {
            r.drain_all(cfg.use_case, self.pipeline.input_bytes, &mut self.state)?;
        }

        // accepted = events that got past ingress.  Derived from the
        // drop count rather than the queue's `accepted` counter so the
        // invariant accepted + dropped == events emitted holds under
        // BOTH overflow policies (DropOldest counts an evicted item as
        // accepted-then-dropped in the queue's own bookkeeping).
        let (ingress_accepted, ingress_dropped) = match &self.ingress {
            Some(q) => (self.emitted - q.dropped, q.dropped),
            None => (self.emitted, 0),
        };
        let RunState {
            timelines,
            downlink,
            mut metrics,
            bank,
            mut latencies,
            predicted_energy_j,
            deadline_misses,
            power_sheds,
            plan_batches,
            plan_hybrid_batches,
            plan_transfer_s,
            events_done,
            correct,
            with_truth,
            sim_end,
            mut phases,
            fault,
            exec_errors,
            cache,
            ..
        } = self.state;
        latencies.sort_by(f64::total_cmp);
        let mean = latencies.iter().sum::<f64>() / latencies.len().max(1) as f64;
        let p95 = percentile_nearest_rank(&latencies, 0.95);
        let p99 = percentile_nearest_rank(&latencies, 0.99);
        // events counted per dispatched batch, not per timeline charge:
        // a hybrid plan schedules the same batch on several lanes, and
        // those segment charges must not inflate the event count
        let completed = events_done;
        let busy_s: f64 = timelines.iter().map(|t| t.busy_s).sum();
        let energy_j: f64 = timelines.iter().map(|t| t.energy_j).sum();
        let busy_fps = if busy_s > 0.0 { completed as f64 / busy_s } else { 0.0 };
        // the final phase ends when the last batch completes (or at the
        // event frontier, whichever is later)
        let run_end = sim_end.max(self.stream.t_s);
        if let Some(last) = phases.last_mut() {
            last.end_s = run_end;
        }
        let phases: Vec<PhaseReport> =
            phases.iter_mut().map(|p| p.finalize(&bank.lane_names)).collect();
        // interned counters fold into the name-keyed maps exactly once,
        // at the run boundary — identical final state to per-event
        // string-keyed increments
        bank.fold_into(&mut metrics);
        Ok(PipelineReport {
            use_case: cfg.use_case,
            model: self.pipeline.route.model.clone(),
            slot: self.pipeline.route.slot,
            policy: self.pipeline.dispatcher.policy.as_str().to_string(),
            target_mix: bank.target_batches_map(),
            events: completed,
            sim_elapsed_s: sim_end,
            mean_latency_s: mean,
            p95_latency_s: p95,
            p99_latency_s: p99,
            busy_fps,
            accel_utilization: busy_s / sim_end.max(1e-9),
            energy_j,
            predicted_energy_j,
            deadline_misses,
            power_sheds,
            plan_batches,
            plan_hybrid_batches,
            plan_transfer_s,
            ingress_accepted,
            ingress_dropped,
            downlink_sent: downlink.sent_count,
            downlink_shed: downlink.shed_count,
            downlink_sent_bytes: downlink.sent_bytes,
            downlink_shed_bytes: downlink.shed_bytes,
            compression_ratio: downlink.compression_ratio(),
            accuracy: if with_truth > 0 {
                Some(correct as f64 / with_truth as f64)
            } else {
                None
            },
            decisions: bank.decisions_map(),
            phases,
            faults: fault.stats,
            exec_errors,
            cache: cache.stats(),
            metrics,
        })
    }
}

impl PipelineRun<'_, '_> {
    /// The virtual-clock frontier (s): the timestamp the next generated
    /// event will carry.
    pub fn now_s(&self) -> f64 {
        self.core.now_s()
    }

    /// Sensor events generated so far.
    pub fn events_emitted(&self) -> u64 {
        self.core.events_emitted()
    }

    /// The deadline the run started with (s) — what
    /// [`PipelineRun::set_deadline_s`] restores after a storm tightens
    /// it.
    pub fn base_deadline_s(&self) -> f64 {
        self.core.base_deadline_s()
    }

    /// Dispatch-cache counters so far (all zero when the cache is off).
    pub fn cache_stats(&self) -> CacheStats {
        self.core.cache_stats()
    }

    /// Frame-pool counters so far (all zero when the pool is off).
    pub fn pool_stats(&self) -> PoolStats {
        self.core.pool_stats()
    }

    /// Live dispatch-cache entries — what the invalidation-exactness
    /// tests count before and after a knob mutation.
    pub fn cache_entries(&self) -> usize {
        self.core.cache_entries()
    }

    /// Bytes sent over the downlink so far.
    pub fn downlink_sent_bytes(&self) -> u64 {
        self.core.state.downlink.sent_bytes
    }

    /// Bytes shed from the downlink so far — the unmet demand the
    /// fleet layer arbitrates at ground-station pass barriers.
    pub fn downlink_shed_bytes(&self) -> u64 {
        self.core.state.downlink.shed_bytes
    }

    /// Switch the dispatch policy; the next batch is scored under it.
    /// Cache entries keyed under any other policy are invalidated.
    pub fn set_policy(&mut self, policy: Policy) {
        self.core.set_policy(policy);
    }

    /// Set or lift the mission power budget (cap on active MPSoC draw,
    /// W).  Only dynamic policies consult it — and only their cache
    /// entries are invalidated.
    pub fn set_power_budget_w(&mut self, budget_w: Option<f64>) {
        self.core.set_power_budget_w(budget_w);
    }

    /// Retune the end-to-end deadline (s).  Errors on a non-positive
    /// or non-finite value instead of aborting a mission run.  Only
    /// `deadline`-policy cache entries are invalidated — no other
    /// policy reads the deadline.
    pub fn set_deadline_s(&mut self, deadline_s: f64) -> Result<()> {
        self.core.set_deadline_s(deadline_s)
    }

    /// Change the sensor cadence (s between samples) from the next
    /// inter-event gap on.
    pub fn set_cadence_s(&mut self, cadence_s: f64) {
        self.core.set_cadence_s(cadence_s);
    }

    /// Multiply the *base* event rate: `set_burst(100.0)` runs the
    /// sensor 100× faster than the configured cadence,
    /// `set_burst(1.0)` restores it.  Errors on a non-positive or
    /// non-finite multiplier instead of aborting a mission run.
    pub fn set_burst(&mut self, burst_x: f64) -> Result<()> {
        self.core.set_burst(burst_x)
    }

    /// Grant additional downlink byte budget (a ground-station pass).
    pub fn grant_downlink_bytes(&mut self, bytes: u64) {
        self.core.grant_downlink_bytes(bytes);
    }

    /// Registry index of a dispatch target by name, if registered for
    /// this run's model.
    pub fn target_index(&self, name: &str) -> Option<usize> {
        self.core.target_index(name)
    }

    /// Mark a dispatch target in or out of service (see
    /// [`crate::backend::TargetRegistry::set_available`]).  The next
    /// batch re-dispatches around an out-of-service target.
    pub fn set_target_available(&mut self, index: usize, available: bool) {
        self.core.set_target_available(index, available);
    }

    /// Open a downlink dropout window from the current virtual time:
    /// decisions whose batch completes inside it are lost before the
    /// byte budget is consulted.  Overlapping windows extend.
    pub fn set_link_dropout(&mut self, duration_s: f64) -> Result<()> {
        self.core.set_link_dropout(duration_s)
    }

    /// Open a brownout window from the current virtual time: every
    /// policy (including `static`) dispatches under `budget_w` until it
    /// closes — degraded-mode dispatch.  Re-opening overwrites.
    pub fn set_brownout(&mut self, budget_w: f64, duration_s: f64) -> Result<()> {
        self.core.set_brownout(budget_w, duration_s)
    }

    /// Open a thermal throttle window on one registry target from the
    /// current virtual time: its setup and per-item latencies multiply
    /// by `derate_x` until the window closes.
    pub fn set_thermal_throttle(
        &mut self,
        index: usize,
        derate_x: f64,
        duration_s: f64,
    ) -> Result<()> {
        self.core.set_thermal_throttle(index, derate_x, duration_s)
    }

    /// Queue one forced transient execution failure against a registry
    /// target — consumed (and counted) by the next attempt dispatched
    /// there.  The deterministic handle mission events and tests use.
    pub fn inject_transient_fault(&mut self, index: usize) -> Result<()> {
        self.core.inject_transient_fault(index)
    }

    /// Queue one forced SEU corruption against a registry target —
    /// consumed by the next attempt there (a single TMR replica
    /// outvotes it; without TMR the attempt fails and recovers).
    pub fn inject_corruption(&mut self, index: usize) -> Result<()> {
        self.core.inject_corruption(index)
    }

    /// Start a new report phase at the current virtual time.  All
    /// subsequent batches, drops, and downlink verdicts are credited to
    /// it.  The very first call renames the initial `"run"` placeholder
    /// in place (so a scenario's first phase is the report's first
    /// phase); later calls close the current phase and open a new one.
    pub fn begin_phase(&mut self, name: &str) {
        self.core.begin_phase(name);
    }

    /// Advance the virtual clock by exactly one sensor event: generate
    /// it, run ingress admission (when configured), feed the batcher,
    /// and dispatch whatever flushes.
    pub fn tick(&mut self) -> Result<()> {
        self.core.tick(&mut self.reaper)
    }

    /// Drain everything in flight and assemble the report.  For a
    /// constant-cadence single-phase run the aggregate fields are
    /// bit-identical to the pre-steppable `Pipeline::run`.
    pub fn finish(self) -> Result<PipelineReport> {
        let PipelineRun { core, reaper } = self;
        core.finish(reaper)
    }
}

/// An owned, `Send` pipeline run from [`Pipeline::begin_owned`]: the
/// fleet layer's per-spacecraft shard, free to migrate between worker
/// threads because it holds no executor-pool borrow (timing-only by
/// construction).
///
/// Drive it through [`OwnedPipelineRun::with_run`], which lends the
/// state machine out as an ordinary [`PipelineRun`] so every scenario
/// hook (`tick`, `begin_phase`, knob setters, `apply_event`) works
/// unchanged, then [`OwnedPipelineRun::finish`] it for the report.
pub struct OwnedPipelineRun {
    /// `Some` until `finish`; `take`n around each `with_run` lend.
    core: Option<RunCore<'static>>,
}

impl OwnedPipelineRun {
    /// Lend the run out as a [`PipelineRun`] for `f` to drive.
    ///
    /// # Panics
    /// Panics if called after [`OwnedPipelineRun::finish`] consumed the
    /// run, or re-entrantly from inside `f` (the core is lent out).
    pub fn with_run<T>(
        &mut self,
        f: impl FnOnce(&mut PipelineRun<'static, 'static>) -> T,
    ) -> T {
        let core = self.core.take().expect("owned run already finished");
        let mut run = PipelineRun { core, reaper: None };
        let out = f(&mut run);
        self.core = Some(run.core);
        out
    }

    /// Drain everything in flight and assemble the report — the owned
    /// counterpart of [`PipelineRun::finish`].
    ///
    /// # Panics
    /// Panics if the run was already finished.
    pub fn finish(mut self) -> Result<PipelineReport> {
        let core = self.core.take().expect("owned run already finished");
        core.finish(None)
    }
}

/// Compile-time pin: an owned run must stay `Send`, or fleet shards
/// could not migrate between scoped worker threads.  Breaks the build
/// (rather than a distant fleet test) if a non-`Send` type ever lands
/// inside the pipeline state machine.
const fn assert_send<T: Send>() {}
const _: () = assert_send::<OwnedPipelineRun>();

/// Nearest-rank percentile over a sorted sample: the smallest value
/// with at least `q` of the mass at or below it (`ceil(q*n)` as a
/// 1-indexed rank).  Truncating the rank instead (`(n*q) as usize`)
/// understates tail latency for small n.
fn percentile_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Salt separating the decision RNG stream from the sensor stream.
const DECISION_RNG_SALT: u64 = 0xD01E_57A7;

/// Cap on execution-error lines kept for the report (oldest first);
/// the counter keeps the full count.
const MAX_EXEC_ERRORS: usize = 8;

/// Record a survived serving-path execution error: counted and
/// surfaced in the report instead of aborting the run.
fn record_exec_error(state: &mut RunState, line: String) {
    state.metrics.inc("exec_failed_batches");
    state.fault.stats.exec_failed_batches += 1;
    if state.exec_errors.len() < MAX_EXEC_ERRORS {
        state.exec_errors.push(line);
    }
}

/// Backpressure cap on batches submitted but not yet reaped: enough to
/// keep every worker busy with headroom, small enough that pending
/// input buffers stay O(cap * max_batch) rather than O(n_events).
const MAX_INFLIGHT_BATCHES: u64 = 64;

/// Deterministic surrogate outputs for timing-only runs (no executor),
/// written into a reusable scratch buffer — the steady state allocates
/// nothing.  RNG draw order and every produced value are identical to
/// the historical allocating form (kept below for the unit tests).
/// Exhaustive over [`UseCase`] — infallible by construction.
fn surrogate_output_into(
    use_case: UseCase,
    ev: &SensorEvent,
    rng: &mut Prng,
    out: &mut Vec<f32>,
) {
    out.clear();
    match use_case {
        UseCase::Mms => {
            out.resize(4, 0.0);
            if let Some(t) = ev.truth {
                out[t] = 1.0 + rng.f32();
            }
        }
        UseCase::Esperta => {
            out.resize(12, 0.2);
            if ev.truth == Some(1) {
                for i in 0..6 {
                    out[i] = 0.9;
                    out[6 + i] = 1.0;
                }
            }
        }
        UseCase::Vae => {
            for _ in 0..12 {
                out.push(rng.normal() as f32);
            }
        }
        UseCase::Cnet => out.push(-6.0 + 2.0 * rng.f32()),
    }
}

/// Allocating wrapper over [`surrogate_output_into`] — test-only.
#[cfg(test)]
fn surrogate_output(use_case: UseCase, ev: &SensorEvent, rng: &mut Prng) -> Vec<f32> {
    let mut out = Vec::new();
    surrogate_output_into(use_case, ev, rng, &mut out);
    out
}

/// The legacy string key for a decision — superseded by
/// [`decision_slot`] on the hot path, kept so the tests can pin the
/// slot table to the exact strings the report always used.
#[cfg(test)]
fn decision_key(d: &Decision) -> String {
    match d {
        Decision::MmsRegion { region, .. } => format!("region_{}", region.label()),
        Decision::SepAlert { warning, .. } => {
            format!("sep_{}", if *warning { "alert" } else { "quiet" })
        }
        Decision::Latent { .. } => "latent".into(),
        Decision::FluxForecast { alert, .. } => {
            format!("flux_{}", if *alert { "alert" } else { "nominal" })
        }
    }
}

fn decision_matches_truth(d: &Decision, truth: usize) -> bool {
    match d {
        Decision::MmsRegion { region, .. } => region.index() == truth,
        Decision::SepAlert { warning, .. } => (*warning as usize) == truth,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentile() {
        // n=10, q=0.95 -> rank ceil(9.5)=10 -> last element (truncation
        // would pick index 9 too, but q=0.5 separates the conventions)
        let v: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&v, 0.95), 10.0);
        assert_eq!(percentile_nearest_rank(&v, 0.5), 5.0);
        // small n: p95 of 3 samples must be the max, not the middle
        let small = [1.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&small, 0.95), 3.0);
        assert_eq!(percentile_nearest_rank(&[], 0.95), 0.0);
        assert_eq!(percentile_nearest_rank(&[7.0], 0.95), 7.0);
        // q=1.0 and beyond-clamp stay in bounds
        assert_eq!(percentile_nearest_rank(&small, 1.0), 3.0);
        assert_eq!(percentile_nearest_rank(&small, 0.0), 1.0);
    }

    #[test]
    fn surrogate_encodes_truth() {
        let mut rng = Prng::new(1);
        let ev = SensorEvent {
            t_s: 0.0,
            use_case: UseCase::Mms,
            inputs: std::sync::Arc::new(vec![vec![0.0; 4]]),
            truth: Some(1),
            seq: 0,
        };
        let out = surrogate_output(UseCase::Mms, &ev, &mut rng);
        assert_eq!(out.len(), 4);
        assert!(out[1] >= 1.0, "truth class must carry the max logit");
    }

    #[test]
    fn decision_slots_match_legacy_keys() {
        use crate::sensors::Region;
        let samples = [
            Decision::MmsRegion { region: Region::Sw, roi: false, logits: [0.0; 4] },
            Decision::MmsRegion { region: Region::If, roi: true, logits: [0.0; 4] },
            Decision::MmsRegion { region: Region::Msh, roi: true, logits: [0.0; 4] },
            Decision::MmsRegion { region: Region::Msp, roi: false, logits: [0.0; 4] },
            Decision::SepAlert { warning: false, mask: [false; 6], max_prob: 0.1 },
            Decision::SepAlert { warning: true, mask: [true; 6], max_prob: 0.9 },
            Decision::Latent { z: [0.0; 6] },
            Decision::FluxForecast { log_flux: -6.5, alert: false },
            Decision::FluxForecast { log_flux: -4.0, alert: true },
        ];
        // every slot is hit exactly once and renders the exact string
        // the legacy per-event key built
        let mut seen = [false; DECISION_KEYS.len()];
        for d in &samples {
            let slot = decision_slot(d);
            assert_eq!(DECISION_KEYS[slot], decision_key(d), "slot {slot}");
            assert!(!seen[slot], "slot {slot} hit twice");
            seen[slot] = true;
        }
        assert!(seen.iter().all(|&s| s), "every slot covered");
    }

    #[test]
    fn default_config_is_static_policy_on_default_targets() {
        let cfg = PipelineConfig::default();
        assert_eq!(cfg.policy, Policy::Static);
        assert!(cfg.deadline_s.is_none());
        assert!(cfg.power_budget_w.is_none());
        assert_eq!(cfg.targets, TargetSet::Default);
        assert!(cfg.ingress_cap.is_none(), "ingress off by default");
    }

    fn vae_pipeline(policy: Policy) -> Pipeline {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Vae,
                n_events: 60,
                cadence_s: 0.05,
                policy,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap()
    }

    #[test]
    fn stepped_run_matches_driver_loop_bitwise() {
        // run() is only a driver over begin/tick/finish: stepping by
        // hand must produce the identical report
        let mut a = vae_pipeline(Policy::MinLatency);
        let ra = a.run(None).unwrap();
        let mut b = vae_pipeline(Policy::MinLatency);
        let mut run = b.begin(None);
        for _ in 0..60 {
            run.tick().unwrap();
        }
        let rb = run.finish().unwrap();
        assert_eq!(ra.target_mix, rb.target_mix);
        assert_eq!(ra.mean_latency_s.to_bits(), rb.mean_latency_s.to_bits());
        assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits());
        assert_eq!(ra.decisions, rb.decisions);
        assert_eq!(ra.phases.len(), 1);
        assert_eq!(ra.phases[0].name, "run");
        assert_eq!(ra.phases[0].energy_j.to_bits(), rb.phases[0].energy_j.to_bits());
    }

    #[test]
    fn single_phase_totals_match_phase_slice() {
        let mut p = vae_pipeline(Policy::Static);
        let r = p.run(None).unwrap();
        assert_eq!(r.phases.len(), 1);
        let ph = &r.phases[0];
        assert_eq!(ph.target_mix, r.target_mix);
        assert_eq!(ph.deadline_misses, r.deadline_misses);
        assert_eq!(ph.downlink_sent, r.downlink_sent);
        assert_eq!(ph.downlink_shed, r.downlink_shed);
        assert_eq!(ph.events, 60);
        // phase energy is per-dispatch accumulation of the same charges
        // the timelines integrate
        assert!((ph.energy_j - r.energy_j).abs() < 1e-9);
        assert_eq!(ph.mean_latency_s.to_bits(), r.mean_latency_s.to_bits());
        assert_eq!(ph.p95_latency_s.to_bits(), r.p95_latency_s.to_bits());
        assert_eq!(ph.p99_latency_s.to_bits(), r.p99_latency_s.to_bits());
        // nearest-rank on the same sorted sample: the tail orders
        assert!(r.p99_latency_s >= r.p95_latency_s);
    }

    #[test]
    fn run_request_matches_fresh_pipeline() {
        // the serving seam: rebinding seed + n_events on a built
        // pipeline must reproduce a fresh construction bit for bit
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let mut template = vae_pipeline(Policy::MinLatency);
        let a = template.run_request(191, 48).unwrap();
        let cfg = PipelineConfig {
            use_case: UseCase::Vae,
            n_events: 48,
            cadence_s: 0.05,
            seed: 191,
            policy: Policy::MinLatency,
            ..PipelineConfig::default()
        };
        let b = Pipeline::new(cfg, &catalog, &calib).unwrap().run(None).unwrap();
        assert_eq!(a.target_mix, b.target_mix);
        assert_eq!(a.mean_latency_s.to_bits(), b.mean_latency_s.to_bits());
        assert_eq!(a.p99_latency_s.to_bits(), b.p99_latency_s.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.decisions, b.decisions);
        // and a second request on the same template stays independent of
        // the first — no cross-request state bleeds through
        let c = template.run_request(191, 48).unwrap();
        assert_eq!(c.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(c.render(), b.render());
    }

    #[test]
    fn power_budget_change_between_ticks_shifts_the_mix() {
        let mut p = vae_pipeline(Policy::MinLatency);
        let mut run = p.begin(None);
        run.begin_phase("sunlit");
        for _ in 0..30 {
            run.tick().unwrap();
        }
        run.begin_phase("eclipse");
        run.set_power_budget_w(Some(4.0));
        for _ in 0..30 {
            run.tick().unwrap();
        }
        let r = run.finish().unwrap();
        assert_eq!(r.phases.len(), 2);
        assert_eq!(r.phases[0].name, "sunlit");
        // unconstrained min-latency keeps the VAE on the 5.75 W DPU; the
        // 4 W eclipse budget excludes it mid-run — visible per phase
        assert!(r.phases[0].target_mix.contains_key("dpu"));
        assert_eq!(r.phases[0].power_sheds, 0);
        assert!(!r.phases[1].target_mix.contains_key("dpu"));
        assert!(r.phases[1].power_sheds > 0, "budget changed decisions");
    }

    #[test]
    fn target_knockout_between_ticks_redispatches() {
        let mut p = vae_pipeline(Policy::Static);
        let mut run = p.begin(None);
        run.begin_phase("nominal");
        for _ in 0..24 {
            run.tick().unwrap();
        }
        let dpu = run.target_index("dpu").unwrap();
        run.begin_phase("upset");
        run.set_target_available(dpu, false);
        for _ in 0..24 {
            run.tick().unwrap();
        }
        let r = run.finish().unwrap();
        assert!(r.phases[0].target_mix.contains_key("dpu"));
        assert!(
            !r.phases[1].target_mix.contains_key("dpu"),
            "static policy must re-dispatch off the knocked-out primary: {:?}",
            r.phases[1].target_mix
        );
        assert!(r.phases[1].batches > 0);
    }

    #[test]
    fn ingress_queue_decimates_saturated_runs() {
        // BaselineNet on HLS serves ~0.21 fps against 6.7 events/s: the
        // ingress queue must shed most of the stream instead of growing
        // an unbounded backlog
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let mut p = Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Mms,
                mms_model: "baseline".into(),
                n_events: 120,
                ingress_cap: Some(8),
                ingress_max_backlog_s: 1.0,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap();
        let r = p.run(None).unwrap();
        assert!(r.ingress_dropped > 0, "saturated run must decimate");
        assert_eq!(r.phases[0].dropped, r.ingress_dropped);
        assert!(r.events < 120, "dropped events never execute");
        assert_eq!(
            r.ingress_accepted + r.ingress_dropped,
            120,
            "every event is accepted or dropped"
        );
        // without the queue the same run executes everything
        let mut free = Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Mms,
                mms_model: "baseline".into(),
                n_events: 120,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap();
        let rf = free.run(None).unwrap();
        assert_eq!(rf.events, 120);
        assert_eq!(rf.ingress_dropped, 0);
        assert_eq!(rf.ingress_accepted, 120);
    }

    #[test]
    fn ingress_accounting_holds_for_drop_oldest() {
        // the queue's own counters mark an evicted item as
        // accepted-then-dropped; the report must still partition the
        // emitted events exactly
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let mut p = Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Mms,
                mms_model: "baseline".into(),
                n_events: 120,
                ingress_cap: Some(8),
                ingress_policy: OverflowPolicy::DropOldest,
                ingress_max_backlog_s: 1.0,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap();
        let r = p.run(None).unwrap();
        assert!(r.ingress_dropped > 0, "saturated run must evict");
        assert_eq!(
            r.ingress_accepted + r.ingress_dropped,
            120,
            "accepted + dropped must partition the emitted events"
        );
        assert_eq!(r.events, r.ingress_accepted, "survivors execute at drain");
    }

    #[test]
    fn plan_mode_is_bit_identical_for_fully_supported_models() {
        // VAE: every default target supports the whole model, so every
        // candidate plan is single-segment and plan-mode runs must be
        // bit-identical to the whole-model dispatcher — the pipeline
        // half of the degenerate-plan invariant
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        for policy in
            [Policy::Static, Policy::MinLatency, Policy::MinEnergy, Policy::Deadline]
        {
            let run = |plan_mode: bool| {
                let mut p = Pipeline::new(
                    PipelineConfig {
                        use_case: UseCase::Vae,
                        n_events: 60,
                        cadence_s: 0.05,
                        policy,
                        plan_mode,
                        ..Default::default()
                    },
                    &catalog,
                    &calib,
                )
                .unwrap();
                p.run(None).unwrap()
            };
            let whole = run(false);
            let plan = run(true);
            assert_eq!(whole.target_mix, plan.target_mix, "{policy:?}");
            assert_eq!(
                whole.mean_latency_s.to_bits(),
                plan.mean_latency_s.to_bits(),
                "{policy:?}"
            );
            assert_eq!(whole.energy_j.to_bits(), plan.energy_j.to_bits(), "{policy:?}");
            assert_eq!(
                whole.predicted_energy_j.to_bits(),
                plan.predicted_energy_j.to_bits(),
                "{policy:?}"
            );
            assert_eq!(whole.decisions, plan.decisions, "{policy:?}");
            assert_eq!(whole.deadline_misses, plan.deadline_misses, "{policy:?}");
            assert_eq!(plan.plan_batches, plan.metrics.counter("batches"));
            assert_eq!(plan.plan_hybrid_batches, 0, "no hybrid exists for vae");
            assert_eq!(plan.plan_transfer_s.to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn plan_mode_dispatches_baseline_as_a_dpu_hybrid() {
        // acceptance: a 3-D model dispatches as a multi-segment
        // DPU+fallback plan under min-latency, transfer toll accounted
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let mut p = Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Mms,
                mms_model: "baseline".into(),
                n_events: 40,
                policy: Policy::MinLatency,
                plan_mode: true,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap();
        let r = p.run(None).unwrap();
        assert_eq!(r.events, 40, "each event counts once, not once per segment");
        assert!(r.plan_hybrid_batches > 0, "hybrid plan must win min-latency");
        assert_eq!(r.plan_hybrid_batches, r.plan_batches);
        assert!(r.plan_transfer_s > 0.0, "boundary transfers are charged");
        assert!(
            r.target_mix.contains_key("dpu") && r.target_mix.contains_key("cpu"),
            "mix shows both segment lanes: {:?}",
            r.target_mix
        );
        // prediction and virtual clock share calibration in plan mode too
        let rel = (r.predicted_energy_j - r.energy_j).abs() / r.energy_j.max(1e-12);
        assert!(rel < 1e-9, "predicted {} vs measured {}", r.predicted_energy_j, r.energy_j);
        // the hybrid clears the whole-model static mapping by a wide
        // margin: same workload, static policy, no plans
        let mut st = Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Mms,
                mms_model: "baseline".into(),
                n_events: 40,
                policy: Policy::Static,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap();
        let rs = st.run(None).unwrap();
        assert!(r.mean_latency_s < rs.mean_latency_s / 10.0, "{} vs {}", r.mean_latency_s, rs.mean_latency_s);
    }

    #[test]
    fn burst_and_deadline_retune_between_ticks() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let mut p = Pipeline::new(
            PipelineConfig {
                use_case: UseCase::Esperta,
                n_events: 40,
                cadence_s: 0.5,
                max_wait_s: 0.05,
                policy: Policy::Deadline,
                ..Default::default()
            },
            &catalog,
            &calib,
        )
        .unwrap();
        let mut run = p.begin(None);
        let base = run.base_deadline_s();
        for _ in 0..10 {
            run.tick().unwrap();
        }
        let t_quiet = run.now_s();
        run.set_burst(100.0).unwrap();
        run.set_deadline_s(0.05).unwrap();
        for _ in 0..20 {
            run.tick().unwrap();
        }
        let t_storm = run.now_s();
        run.set_burst(1.0).unwrap();
        run.set_deadline_s(base).unwrap();
        for _ in 0..10 {
            run.tick().unwrap();
        }
        let t_recover = run.now_s();
        // 20 storm events advanced the clock ~100x slower than 10 quiet
        let quiet_span = t_quiet; // 10 events at 0.5 s
        let storm_span = t_storm - t_quiet; // 20 events at 5 ms
        let recover_span = t_recover - t_storm; // 10 events at 0.5 s
        assert!(storm_span < quiet_span / 10.0, "{storm_span} vs {quiet_span}");
        assert!(recover_span > storm_span, "cadence must restore");
        run.finish().unwrap();
    }
}
