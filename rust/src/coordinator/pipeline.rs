//! The end-to-end on-board pipeline: wires sensors, router, batcher,
//! executor (real PJRT numerics), the timing/power simulators (virtual
//! ZCU104 clock), decision logic, and the downlink manager.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::board::{Calibration, Zcu104};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::decision::{decide, Decision};
use crate::coordinator::downlink::{DownlinkManager, DownlinkVerdict};
use crate::coordinator::router::{Route, Router, Slot};
use crate::coordinator::scheduler::{AccelTimeline, ScheduledRun};
use crate::cpu::A53Model;
use crate::dpu::{DpuArch, DpuSchedule};
use crate::hls::HlsDesign;
use crate::model::catalog::{model_info, Catalog};
use crate::power::{Implementation, PowerModel};
use crate::resources::estimate_hls;
use crate::runtime::ExecutorPool;
use crate::sensors::SensorStream;
use crate::telemetry::Metrics;
use crate::util::prng::Prng;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// "vae" | "cnet" | "esperta" | "mms"
    pub use_case: &'static str,
    /// Events to process.
    pub n_events: usize,
    /// Sensor cadence (s).
    pub cadence_s: f64,
    pub max_batch: usize,
    pub max_wait_s: f64,
    /// Downlink budget for the run (bytes).
    pub downlink_budget: u64,
    /// MMS sub-model ("baseline" | "reduced" | "logistic").
    pub mms_model: String,
    pub seed: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            use_case: "mms",
            n_events: 100,
            cadence_s: 0.15,
            max_batch: 8,
            max_wait_s: 0.5,
            downlink_budget: 64 * 1024,
            mms_model: "baseline".into(),
            seed: 7,
        }
    }
}

/// Summary of a pipeline run.
#[derive(Debug)]
pub struct PipelineReport {
    pub use_case: String,
    pub model: String,
    pub slot: Slot,
    pub events: u64,
    /// Simulated wall time of the run (s).
    pub sim_elapsed_s: f64,
    /// Simulated mean end-to-end latency (arrival -> decision, s).
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
    /// Simulated accelerator throughput (inferences/s while busy).
    pub busy_fps: f64,
    pub accel_utilization: f64,
    /// Simulated MPSoC energy spent on inference (J).
    pub energy_j: f64,
    pub downlink_sent: u64,
    pub downlink_shed: u64,
    pub downlink_sent_bytes: u64,
    pub compression_ratio: f64,
    /// Decision accuracy vs ground truth, when truth exists.
    pub accuracy: Option<f64>,
    pub decisions: BTreeMap<String, u64>,
    pub metrics: Metrics,
}

impl PipelineReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pipeline [{}] model={} slot={:?}\n",
            self.use_case, self.model, self.slot
        ));
        out.push_str(&format!(
            "  events {}  sim_elapsed {:.3}s  mean_latency {:.4}s  p95 {:.4}s\n",
            self.events, self.sim_elapsed_s, self.mean_latency_s, self.p95_latency_s
        ));
        out.push_str(&format!(
            "  busy_fps {:.1}  util {:.1}%  energy {:.3}J\n",
            self.busy_fps,
            100.0 * self.accel_utilization,
            self.energy_j
        ));
        out.push_str(&format!(
            "  downlink: sent {} ({} B) shed {}  compression {:.0}:1\n",
            self.downlink_sent, self.downlink_sent_bytes, self.downlink_shed,
            self.compression_ratio
        ));
        if let Some(acc) = self.accuracy {
            out.push_str(&format!("  decision accuracy vs truth: {:.1}%\n", 100.0 * acc));
        }
        for (k, v) in &self.decisions {
            out.push_str(&format!("  decision[{k}] = {v}\n"));
        }
        out
    }
}

/// The pipeline itself.
pub struct Pipeline {
    pub config: PipelineConfig,
    pub route: Route,
    run_params: ScheduledRun,
    input_bytes: u64,
}

impl Pipeline {
    /// Resolve routing and simulated timing for the configured use case.
    pub fn new(config: PipelineConfig, catalog: &Catalog, calib: &Calibration) -> Result<Pipeline> {
        let mut router = Router::default();
        router.mms_model = config.mms_model.clone();
        let route = router.route(config.use_case, 0)?;
        let board = Zcu104::default();
        let info = model_info(&route.model)?;
        let man = catalog
            .manifest(&route.model, route.precision)
            .context("pipeline needs `make artifacts` output")?;
        let power = PowerModel::new(calib.clone());
        let run_params = match route.slot {
            Slot::Dpu => {
                let sched = DpuSchedule::new(
                    man,
                    DpuArch::b4096(calib, board.dpu_clock_hz),
                    calib,
                    board.axi_bandwidth,
                )?;
                let per_item = sched.latency_s() - sched.invoke_s;
                ScheduledRun {
                    setup_s: sched.invoke_s,
                    per_item_s: per_item,
                    power_w: power.mpsoc_w(&PowerModel::dpu_impl(&sched)),
                }
            }
            Slot::Hls => {
                let design = HlsDesign::synthesize(man, &board, calib);
                let setup = design.axi_setup_cycles / design.clock_hz;
                let util = estimate_hls(man, &design.plan);
                ScheduledRun {
                    setup_s: setup,
                    per_item_s: design.latency_s() - setup,
                    power_w: power.mpsoc_w(&Implementation::Hls {
                        kiloluts: util.luts as f64 / 1000.0,
                        brams: design.plan.brams(),
                        duty: 1.0,
                    }),
                }
            }
            Slot::Cpu => {
                let a53 = A53Model::calibrated(man, calib, info.paper.cpu_fps);
                ScheduledRun {
                    setup_s: 0.0,
                    per_item_s: a53.latency_s(),
                    power_w: info.paper.cpu_p_mpsoc,
                }
            }
        };
        Ok(Pipeline {
            config,
            route,
            run_params,
            input_bytes: man.input_bytes(),
        })
    }

    /// Run the pipeline.  `executor` supplies real PJRT numerics; pass
    /// `None` for a timing-only (simulated outputs) run — decisions then
    /// come from a deterministic surrogate so downstream stages still
    /// exercise.
    pub fn run(&self, executor: Option<&ExecutorPool>) -> Result<PipelineReport> {
        let cfg = &self.config;
        let mut stream = SensorStream::new(cfg.use_case, cfg.seed, cfg.cadence_s);
        let mut batcher = Batcher::new(&self.route.model, cfg.max_batch, cfg.max_wait_s);
        let mut timeline = AccelTimeline::new(self.route.slot_name());
        let mut downlink = DownlinkManager::new(cfg.downlink_budget);
        let mut metrics = Metrics::default();
        let mut rng = Prng::new(cfg.seed ^ DECISION_RNG_SALT);
        let mut latencies: Vec<f64> = Vec::with_capacity(cfg.n_events);
        let mut decisions: BTreeMap<String, u64> = BTreeMap::new();
        let mut correct = 0u64;
        let mut with_truth = 0u64;
        let mut sim_end = 0.0f64;

        let process_batch = |batch: crate::coordinator::batcher::Batch,
                                 timeline: &mut AccelTimeline,
                                 downlink: &mut DownlinkManager,
                                 metrics: &mut Metrics,
                                 rng: &mut Prng,
                                 latencies: &mut Vec<f64>,
                                 decisions: &mut BTreeMap<String, u64>,
                                 correct: &mut u64,
                                 with_truth: &mut u64,
                                 sim_end: &mut f64|
         -> Result<()> {
            let n = batch.events.len() as u64;
            let (_start, done) =
                timeline.schedule(batch.flushed_at_s, n, self.run_params);
            *sim_end = sim_end.max(done);
            metrics.add("batches", 1);
            metrics.add("inferences", n);
            for ev in &batch.events {
                latencies.push(done - ev.t_s);
                let output = match executor {
                    Some(pool) => pool.run_sync(
                        &self.route.model,
                        self.route.precision,
                        ev.inputs.clone(),
                    )?,
                    None => surrogate_output(cfg.use_case, ev, rng),
                };
                let d = decide(cfg.use_case, &output, rng);
                if let Some(truth) = ev.truth {
                    *with_truth += 1;
                    if decision_matches_truth(&d, truth) {
                        *correct += 1;
                    }
                }
                *decisions.entry(decision_key(&d)).or_insert(0) += 1;
                match downlink.offer(&d, self.input_bytes) {
                    DownlinkVerdict::Sent => metrics.inc("downlink_sent"),
                    DownlinkVerdict::Shed => metrics.inc("downlink_shed"),
                }
            }
            Ok(())
        };

        for _ in 0..cfg.n_events {
            let ev = stream.next_event();
            let now = ev.t_s;
            if let Some(b) = batcher.poll(now) {
                process_batch(b, &mut timeline, &mut downlink, &mut metrics,
                              &mut rng, &mut latencies, &mut decisions,
                              &mut correct, &mut with_truth, &mut sim_end)?;
            }
            if let Some(b) = batcher.offer(ev, now) {
                process_batch(b, &mut timeline, &mut downlink, &mut metrics,
                              &mut rng, &mut latencies, &mut decisions,
                              &mut correct, &mut with_truth, &mut sim_end)?;
            }
        }
        let drain_t = cfg.n_events as f64 * cfg.cadence_s + cfg.max_wait_s;
        if let Some(b) = batcher.flush(drain_t) {
            process_batch(b, &mut timeline, &mut downlink, &mut metrics,
                          &mut rng, &mut latencies, &mut decisions,
                          &mut correct, &mut with_truth, &mut sim_end)?;
        }

        latencies.sort_by(f64::total_cmp);
        let mean = latencies.iter().sum::<f64>() / latencies.len().max(1) as f64;
        let p95 = latencies
            .get(((latencies.len() as f64 * 0.95) as usize).min(latencies.len().saturating_sub(1)))
            .copied()
            .unwrap_or(0.0);
        let busy_fps = if timeline.busy_s > 0.0 {
            timeline.completed as f64 / timeline.busy_s
        } else {
            0.0
        };
        Ok(PipelineReport {
            use_case: cfg.use_case.to_string(),
            model: self.route.model.clone(),
            slot: self.route.slot,
            events: timeline.completed,
            sim_elapsed_s: sim_end,
            mean_latency_s: mean,
            p95_latency_s: p95,
            busy_fps,
            accel_utilization: timeline.utilization(sim_end.max(1e-9)),
            energy_j: timeline.energy_j,
            downlink_sent: downlink.sent_count,
            downlink_shed: downlink.shed_count,
            downlink_sent_bytes: downlink.sent_bytes,
            compression_ratio: downlink.compression_ratio(),
            accuracy: if with_truth > 0 {
                Some(correct as f64 / with_truth as f64)
            } else {
                None
            },
            decisions,
            metrics,
        })
    }
}

impl Route {
    fn slot_name(&self) -> &'static str {
        match self.slot {
            Slot::Dpu => "dpu",
            Slot::Hls => "hls",
            Slot::Cpu => "cpu",
        }
    }
}

/// Salt separating the decision RNG stream from the sensor stream.
const DECISION_RNG_SALT: u64 = 0xD01E_57A7;

/// Deterministic surrogate outputs for timing-only runs (no PJRT).
fn surrogate_output(use_case: &str, ev: &crate::sensors::SensorEvent, rng: &mut Prng) -> Vec<f32> {
    match use_case {
        "mms" => {
            let mut v = vec![0.0f32; 4];
            if let Some(t) = ev.truth {
                v[t] = 1.0 + rng.f32();
            }
            v
        }
        "esperta" => {
            let mut v = vec![0.2f32; 12];
            if ev.truth == Some(1) {
                for i in 0..6 {
                    v[i] = 0.9;
                    v[6 + i] = 1.0;
                }
            }
            v
        }
        "vae" => (0..12).map(|_| rng.normal() as f32).collect(),
        "cnet" => vec![-6.0 + 2.0 * rng.f32()],
        _ => unreachable!(),
    }
}

fn decision_key(d: &Decision) -> String {
    match d {
        Decision::MmsRegion { region, .. } => format!("region_{}", region.label()),
        Decision::SepAlert { warning, .. } => {
            format!("sep_{}", if *warning { "alert" } else { "quiet" })
        }
        Decision::Latent { .. } => "latent".into(),
        Decision::FluxForecast { alert, .. } => {
            format!("flux_{}", if *alert { "alert" } else { "nominal" })
        }
    }
}

fn decision_matches_truth(d: &Decision, truth: usize) -> bool {
    match d {
        Decision::MmsRegion { region, .. } => region.index() == truth,
        Decision::SepAlert { warning, .. } => (*warning as usize) == truth,
        _ => false,
    }
}
