//! Cost-model-driven dispatch: score every registered target per batch.
//!
//! The paper's core result is a *trade-space*, not a fixed mapping: the
//! DPU reaches up to 34.16× the A53 inference rate but draws 5.75–6.75 W,
//! the naive HLS IPs add the operators the DPU lacks at 1.5–1.75 W, and
//! the A53 is always available at 2.0–2.75 W.  Which target a workload
//! belongs on therefore depends on latency, energy, and operator support
//! — so the coordinator decides *at runtime*, per flushed batch.
//!
//! The dispatcher owns no target-specific knowledge: it scores the
//! [`crate::backend::TargetRegistry`] — each entry an opaque
//! [`crate::backend::AccelModel`] supplying batch latency, batch energy,
//! and active power — plus each target's current queue backlog from its
//! `AccelTimeline`.  Adding a backend never touches this file.
//!
//! Policies ([`Policy`]): `static` reproduces the paper's deployment
//! matrix, `min-latency` / `min-energy` optimize one axis, and `deadline`
//! picks the cheapest target that still meets a per-use-case latency
//! deadline.  An optional mission power budget (a cap on *active* MPSoC
//! draw — what the spacecraft EPS must supply while inference runs)
//! filters targets under every dynamic policy and sheds to the
//! lowest-power target when nothing fits.

use anyhow::{bail, Result};

use crate::backend::{AccelModel, TargetRegistry, TargetSet};
use crate::board::Calibration;
use crate::coordinator::cache::DispatchCache;
use crate::coordinator::scheduler::{AccelTimeline, ScheduledRun};
use crate::model::catalog::Catalog;
use crate::model::UseCase;
use crate::plan::{ExecutionPlan, Lane, Planner};

/// How the dispatcher picks a target for each flushed batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// The paper's static deployment matrix (§III-B): DPU-compatible
    /// CNNs to Vitis AI, everything else to its HLS IP.  Default;
    /// byte-identical to the pre-dispatcher pipeline.
    Static,
    /// Minimize predicted batch completion latency (queue + setup +
    /// per-item compute).
    MinLatency,
    /// Minimize predicted batch energy (busy time × active power).
    MinEnergy,
    /// Meet the per-use-case latency deadline at minimum energy; fall
    /// back to min-latency when no target can meet it.
    Deadline,
}

impl Policy {
    /// Parse a CLI policy name (`static` | `min-latency` | `min-energy`
    /// | `deadline`).
    ///
    /// ```
    /// use spaceinfer::coordinator::Policy;
    /// assert_eq!(Policy::parse("min-energy").unwrap(), Policy::MinEnergy);
    /// assert!(Policy::parse("fastest").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Policy> {
        Ok(match s {
            "static" => Policy::Static,
            "min-latency" => Policy::MinLatency,
            "min-energy" => Policy::MinEnergy,
            "deadline" => Policy::Deadline,
            other => bail!(
                "unknown policy {other:?} (static | min-latency | min-energy | deadline)"
            ),
        })
    }

    /// The CLI / report spelling of this policy.
    pub fn as_str(&self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::MinLatency => "min-latency",
            Policy::MinEnergy => "min-energy",
            Policy::Deadline => "deadline",
        }
    }
}

/// Default end-to-end deadline (event arrival → decision, seconds) per
/// use case, used when the CLI does not override it.  SEP alerts are
/// time-critical; flux forecasts ride a slow cadence.  Exhaustive over
/// [`UseCase`] — no stringly-typed fall-through.
///
/// The deadline races the batcher: a batch force-flushed after
/// `max_wait_s` has already spent that long waiting, so a deadline is
/// only meetable when the batcher wait is tightened below it.  The
/// vae/mms/cnet defaults sit above the default 0.5 s wait; ESPERTA's
/// 0.1 s alert deadline deliberately does not — pair it with
/// `--max-wait` ≤ ~0.05 s (as the `sep_storm` example does) or every
/// batch counts as late.
pub fn default_deadline_s(use_case: UseCase) -> f64 {
    match use_case {
        UseCase::Esperta => 0.1,
        UseCase::Cnet => 2.0,
        UseCase::Vae | UseCase::Mms => 1.0,
    }
}

/// Predicted cost of one batch on one target.
#[derive(Debug, Clone)]
pub struct BatchCost {
    /// Registry name of the target this cost was scored for.
    pub target: &'static str,
    /// Flush → predicted completion (queue wait + setup + n·per-item), s.
    pub latency_s: f64,
    /// Oldest-event arrival → predicted completion, s (what the deadline
    /// is checked against).
    pub oldest_latency_s: f64,
    /// Predicted busy energy for the batch, J.
    pub energy_j: f64,
    /// Active MPSoC draw while the batch runs, W.
    pub power_w: f64,
    /// Does `oldest_latency_s` meet the dispatcher's deadline?
    pub meets_deadline: bool,
}

/// The dispatcher's verdict for one batch.
#[derive(Debug, Clone)]
pub struct Choice {
    /// Index into the registry (and the run's timeline vector).
    pub index: usize,
    /// The predicted cost of the chosen target.
    pub cost: BatchCost,
    /// True when the power budget changed the decision (the batch was
    /// shed away from the target the bare policy would have picked).
    pub power_shed: bool,
}

/// Predicted cost of one batch under one execution plan — the
/// plan-level analogue of [`BatchCost`].
#[derive(Debug, Clone)]
pub struct PlanCost {
    /// Flush → predicted completion (bottleneck queue wait + every
    /// segment's setup + n·(per-item + boundary transfers)), s.
    pub latency_s: f64,
    /// Oldest-event arrival → predicted completion, s.
    pub oldest_latency_s: f64,
    /// Predicted busy energy for the batch across all segments, J.
    pub energy_j: f64,
    /// Peak active draw over the plan's segments, W (what the power
    /// budget must clear — segments run sequentially).
    pub power_w: f64,
    /// Does `oldest_latency_s` meet the dispatcher's deadline?
    pub meets_deadline: bool,
}

/// The dispatcher's verdict for one batch in plan mode.
#[derive(Debug, Clone)]
pub struct PlanChoice {
    /// Index into [`Planner::plans`].
    pub index: usize,
    /// The predicted cost of the chosen plan.
    pub cost: PlanCost,
    /// True when the power budget changed the decision.
    pub power_shed: bool,
}

/// Scores every registered target for each batch and picks one under
/// the configured policy.  Immutable once built — per-run queue state
/// lives in the caller's `AccelTimeline` vector (index-aligned with the
/// registry), so one dispatcher can serve many runs.
///
/// ```
/// use spaceinfer::backend::{AccelModel, TargetSet};
/// use spaceinfer::board::Calibration;
/// use spaceinfer::coordinator::{Dispatcher, Policy, Slot};
/// use spaceinfer::model::Catalog;
///
/// let catalog = Catalog::synthetic();
/// let d = Dispatcher::new("vae", &catalog, &Calibration::default(),
///                         Policy::MinLatency, 0.5, None,
///                         &TargetSet::Default).unwrap();
/// // VAE is DPU-compatible: CPU + DPU + HLS are all registered
/// assert_eq!(d.registry.len(), 3);
/// let mut timelines = d.timelines();
/// let choice = d.choose(&timelines, 0.0, 0.0, 8);
/// assert_eq!(d.registry.get(choice.index).slot(), Slot::Dpu);
/// // commit the batch to the chosen target's queue
/// timelines[choice.index].schedule(0.0, 8, d.run_of(choice.index));
/// ```
#[derive(Debug)]
pub struct Dispatcher {
    /// Active policy.
    pub policy: Policy,
    /// The instantiated target table for this model.
    pub registry: TargetRegistry,
    /// End-to-end deadline (oldest event arrival → completion), s.
    pub deadline_s: f64,
    /// Cap on active MPSoC draw (W); `None` disables the budget filter.
    pub power_budget_w: Option<f64>,
}

impl Dispatcher {
    /// Build the registry for one model from the catalog and the
    /// calibrated simulators.  Errors when the paper's primary target
    /// is needed (static policy, or the default set) but not
    /// registrable (missing int8 manifest variant).
    pub fn new(
        model: &str,
        catalog: &Catalog,
        calib: &Calibration,
        policy: Policy,
        deadline_s: f64,
        power_budget_w: Option<f64>,
        targets: &TargetSet,
    ) -> Result<Dispatcher> {
        let registry = TargetRegistry::build(model, catalog, calib, targets)?;
        if registry.primary_index().is_none()
            && (policy == Policy::Static || *targets == TargetSet::Default)
        {
            bail!(
                "model {model:?}: the paper's primary slot has no registered \
                 target (missing int8 manifest?)"
            );
        }
        Ok(Dispatcher { policy, registry, deadline_s, power_budget_w })
    }

    /// Fresh per-run queue state, index-aligned with the registry.
    pub fn timelines(&self) -> Vec<AccelTimeline> {
        self.registry
            .targets()
            .iter()
            .map(|t| AccelTimeline::new(t.name()))
            .collect()
    }

    /// Index of the paper's deployment-matrix target (0 when the
    /// registry was assembled without one — tests, custom sets).
    pub fn primary_index(&self) -> usize {
        self.registry.primary_index().unwrap_or(0)
    }

    /// Timeline parameters (setup / per-item / power) of one registered
    /// target — what the virtual-clock scheduler charges.
    pub fn run_of(&self, index: usize) -> ScheduledRun {
        let t = self.registry.get(index);
        ScheduledRun {
            setup_s: t.setup_s(),
            per_item_s: t.per_item_s(),
            power_w: t.active_power_w(),
        }
    }

    /// Score one registered target for a batch of `n` events flushed at
    /// `now_s` whose oldest event arrived at `oldest_t_s`.
    pub fn cost(
        &self,
        index: usize,
        timeline: &AccelTimeline,
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> BatchCost {
        let target = self.registry.get(index);
        let queue_s = timeline.backlog_s(now_s);
        let busy_s = target.batch_latency_s(n);
        let latency_s = queue_s + busy_s;
        let oldest_latency_s = (now_s - oldest_t_s).max(0.0) + latency_s;
        BatchCost {
            target: target.name(),
            latency_s,
            oldest_latency_s,
            energy_j: target.batch_energy_j(n),
            power_w: target.active_power_w(),
            meets_deadline: oldest_latency_s <= self.deadline_s,
        }
    }

    /// Pick a target for one batch.  `timelines` is the run's queue
    /// state (from [`Dispatcher::timelines`]); the caller commits the
    /// batch by calling `schedule` on the chosen entry.  Deterministic:
    /// ties break toward the first target in registry order.
    ///
    /// Targets the registry marks unavailable (an SEU awaiting its
    /// scrub repair, a thermal trip) leave the candidate set: dynamic
    /// policies score only in-service targets, and the static policy
    /// falls back to the fastest available target while its primary
    /// slot is down.  When *nothing* is in service the full set is
    /// used — a spacecraft cannot stop deciding.  With every target
    /// available (the default) the decision is bit-identical to the
    /// unfiltered dispatcher.
    pub fn choose(
        &self,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> Choice {
        let costs: Vec<BatchCost> = (0..self.registry.len())
            .zip(timelines)
            .map(|(i, tl)| self.cost(i, tl, now_s, oldest_t_s, n))
            .collect();
        let mut avail: Vec<usize> = (0..costs.len())
            .filter(|&i| self.registry.is_available(i))
            .collect();
        if avail.is_empty() {
            avail = (0..costs.len()).collect();
        }
        if self.policy == Policy::Static {
            let primary = self.primary_index();
            let index = if self.registry.is_available(primary) || avail.len() == costs.len()
            {
                primary
            } else {
                // the deployment-matrix slot is knocked out: re-dispatch
                // to the fastest in-service target until it is repaired
                argmin(&avail, &costs, |c| c.latency_s)
            };
            return Choice { index, cost: costs[index].clone(), power_shed: false };
        }
        let pick = |idxs: &[usize]| -> usize {
            match self.policy {
                Policy::MinLatency => argmin(idxs, &costs, |c| c.latency_s),
                Policy::MinEnergy => argmin(idxs, &costs, |c| c.energy_j),
                Policy::Deadline => {
                    let meeting: Vec<usize> = idxs
                        .iter()
                        .copied()
                        .filter(|&i| costs[i].meets_deadline)
                        .collect();
                    if meeting.is_empty() {
                        // nothing meets the deadline: damage control,
                        // finish as early as possible
                        argmin(idxs, &costs, |c| c.latency_s)
                    } else {
                        argmin(&meeting, &costs, |c| c.energy_j)
                    }
                }
                Policy::Static => unreachable!("handled above"),
            }
        };
        let (index, power_shed) = match self.power_budget_w {
            // no budget: one scoring pass, never a shed
            None => (pick(&avail), false),
            Some(budget) => {
                let fits: Vec<usize> = avail
                    .iter()
                    .copied()
                    .filter(|&i| costs[i].power_w <= budget)
                    .collect();
                let index = if fits.is_empty() {
                    // nothing fits the budget: shed to the lowest-power
                    // target outright
                    argmin(&avail, &costs, |c| c.power_w)
                } else {
                    pick(&fits)
                };
                (index, index != pick(&avail))
            }
        };
        Choice { index, cost: costs[index].clone(), power_shed }
    }

    /// [`Dispatcher::choose`] under recovery-layer constraints: an
    /// `excluded` mask (targets the retry escalation has already
    /// burned for this batch) and an optional brownout power budget
    /// that tightens — never loosens — the dispatcher's own.
    ///
    /// Candidate order: in-service and not excluded; if that empties,
    /// any not-excluded target; if everything is excluded, the full
    /// set (a spacecraft cannot stop deciding).  Unlike
    /// [`Dispatcher::choose`], the budget applies to *every* policy
    /// including `static` — a brownout overrides the deployment matrix
    /// by design.  `choose` itself is untouched, so fault-free runs
    /// stay byte-identical.
    pub fn choose_constrained(
        &self,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
        excluded: &[bool],
        budget_override_w: Option<f64>,
    ) -> Choice {
        let costs: Vec<BatchCost> = (0..self.registry.len())
            .zip(timelines)
            .map(|(i, tl)| self.cost(i, tl, now_s, oldest_t_s, n))
            .collect();
        let mut avail: Vec<usize> = (0..costs.len())
            .filter(|&i| self.registry.is_available(i) && !excluded[i])
            .collect();
        if avail.is_empty() {
            avail = (0..costs.len()).filter(|&i| !excluded[i]).collect();
        }
        if avail.is_empty() {
            avail = (0..costs.len()).collect();
        }
        let budget = match (self.power_budget_w, budget_override_w) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        let pick = |idxs: &[usize]| -> usize {
            match self.policy {
                Policy::Static => {
                    let primary = self.primary_index();
                    if idxs.contains(&primary) {
                        primary
                    } else {
                        argmin(idxs, &costs, |c| c.latency_s)
                    }
                }
                Policy::MinLatency => argmin(idxs, &costs, |c| c.latency_s),
                Policy::MinEnergy => argmin(idxs, &costs, |c| c.energy_j),
                Policy::Deadline => {
                    let meeting: Vec<usize> = idxs
                        .iter()
                        .copied()
                        .filter(|&i| costs[i].meets_deadline)
                        .collect();
                    if meeting.is_empty() {
                        argmin(idxs, &costs, |c| c.latency_s)
                    } else {
                        argmin(&meeting, &costs, |c| c.energy_j)
                    }
                }
            }
        };
        let (index, power_shed) = match budget {
            None => (pick(&avail), false),
            Some(budget) => {
                let fits: Vec<usize> = avail
                    .iter()
                    .copied()
                    .filter(|&i| costs[i].power_w <= budget)
                    .collect();
                let index = if fits.is_empty() {
                    // nothing fits the sagging bus: shed to the
                    // lowest-power candidate outright
                    argmin(&avail, &costs, |c| c.power_w)
                } else {
                    pick(&fits)
                };
                (index, index != pick(&avail))
            }
        };
        Choice { index, cost: costs[index].clone(), power_shed }
    }

    /// Score one execution plan for a batch of `n` events flushed at
    /// `now_s`.  `timelines` is the run's *lane* queue state (registry
    /// lanes first, then the planner's derived lanes — see
    /// [`Planner::flat`]).  The queue term is the bottleneck backlog
    /// over the plan's lanes; busy time and energy come from the plan
    /// itself.  For a single-segment plan this is arithmetically
    /// identical, bit for bit, to [`Dispatcher::cost`] on the
    /// underlying target.
    pub fn plan_cost(
        &self,
        planner: &Planner,
        plan: &ExecutionPlan,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> PlanCost {
        let queue_s = plan
            .segments
            .iter()
            .map(|s| timelines[planner.flat(s.lane)].backlog_s(now_s))
            .fold(0.0, f64::max);
        let busy_s = plan.batch_latency_s(n);
        let latency_s = queue_s + busy_s;
        let oldest_latency_s = (now_s - oldest_t_s).max(0.0) + latency_s;
        PlanCost {
            latency_s,
            oldest_latency_s,
            energy_j: plan.batch_energy_j(n),
            power_w: plan.peak_power_w(),
            meets_deadline: oldest_latency_s <= self.deadline_s,
        }
    }

    /// Pick an execution plan for one batch — the plan-level analogue
    /// of [`Dispatcher::choose`], same policy logic over the planner's
    /// candidate set (hybrid plans scored alongside single-target
    /// plans).  A plan is in service only while every registry lane it
    /// touches is available (derived lanes have no availability state);
    /// the static policy picks the primary's single-segment plan,
    /// re-dispatching to the fastest available plan while the primary
    /// is down.  For a model fully supported by every lane the decision
    /// is bit-identical to [`Dispatcher::choose`] — the degenerate-plan
    /// invariant the golden suite relies on.
    pub fn choose_plan(
        &self,
        planner: &Planner,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> PlanChoice {
        let plans = planner.plans();
        let costs: Vec<PlanCost> = plans
            .iter()
            .map(|p| self.plan_cost(planner, p, timelines, now_s, oldest_t_s, n))
            .collect();
        let in_service = |p: &ExecutionPlan| {
            p.segments.iter().all(|s| match s.lane {
                Lane::Registry(i) => self.registry.is_available(i),
                Lane::Derived(_) => true,
            })
        };
        let mut avail: Vec<usize> =
            (0..plans.len()).filter(|&i| in_service(&plans[i])).collect();
        if avail.is_empty() {
            avail = (0..plans.len()).collect();
        }
        if self.policy == Policy::Static {
            let primary = planner.primary_plan().unwrap_or(0);
            let index = if avail.contains(&primary) || avail.len() == plans.len() {
                primary
            } else {
                argmin(&avail, &costs, |c| c.latency_s)
            };
            return PlanChoice { index, cost: costs[index].clone(), power_shed: false };
        }
        let pick = |idxs: &[usize]| -> usize {
            match self.policy {
                Policy::MinLatency => argmin(idxs, &costs, |c| c.latency_s),
                Policy::MinEnergy => argmin(idxs, &costs, |c| c.energy_j),
                Policy::Deadline => {
                    let meeting: Vec<usize> = idxs
                        .iter()
                        .copied()
                        .filter(|&i| costs[i].meets_deadline)
                        .collect();
                    if meeting.is_empty() {
                        argmin(idxs, &costs, |c| c.latency_s)
                    } else {
                        argmin(&meeting, &costs, |c| c.energy_j)
                    }
                }
                Policy::Static => unreachable!("handled above"),
            }
        };
        let (index, power_shed) = match self.power_budget_w {
            None => (pick(&avail), false),
            Some(budget) => {
                let fits: Vec<usize> = avail
                    .iter()
                    .copied()
                    .filter(|&i| costs[i].power_w <= budget)
                    .collect();
                let index = if fits.is_empty() {
                    argmin(&avail, &costs, |c| c.power_w)
                } else {
                    pick(&fits)
                };
                (index, index != pick(&avail))
            }
        };
        PlanChoice { index, cost: costs[index].clone(), power_shed }
    }

    /// [`Dispatcher::choose`] through a [`DispatchCache`]: identical
    /// verdicts (bit for bit — see the cache module's determinism
    /// argument), served from the memo table when this decision state
    /// has been scored before.  The hot path of the pipeline; with the
    /// cache disabled this is exactly `choose`.
    pub fn choose_cached(
        &self,
        cache: &mut DispatchCache,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> Choice {
        cache.choose(self, timelines, now_s, oldest_t_s, n)
    }

    /// [`Dispatcher::choose_plan`] through a [`DispatchCache`] — the
    /// plan-mode analogue of [`Dispatcher::choose_cached`].
    pub fn choose_plan_cached(
        &self,
        cache: &mut DispatchCache,
        planner: &Planner,
        timelines: &[AccelTimeline],
        now_s: f64,
        oldest_t_s: f64,
        n: u64,
    ) -> PlanChoice {
        cache.choose_plan(self, planner, timelines, now_s, oldest_t_s, n)
    }
}

/// First index minimizing `key` (strict-less fold: deterministic ties).
fn argmin<T, F: Fn(&T) -> f64>(idxs: &[usize], costs: &[T], key: F) -> usize {
    let mut best = idxs[0];
    for &i in &idxs[1..] {
        if key(&costs[i]) < key(&costs[best]) {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{AccelModel, Slot};
    use crate::model::{Manifest, Precision};
    use crate::resources::Utilization;

    /// Minimal registry stub: the dispatcher must work against any
    /// `AccelModel`, not just the built-in simulators.
    #[derive(Debug)]
    struct Stub {
        name: &'static str,
        slot: Slot,
        per_item_s: f64,
        power_w: f64,
    }

    impl AccelModel for Stub {
        fn name(&self) -> &'static str {
            self.name
        }
        fn slot(&self) -> Slot {
            self.slot
        }
        fn precision(&self) -> Precision {
            Precision::Fp32
        }
        fn supports(&self, _man: &Manifest) -> anyhow::Result<()> {
            Ok(())
        }
        fn setup_s(&self) -> f64 {
            0.0
        }
        fn per_item_s(&self) -> f64 {
            self.per_item_s
        }
        fn active_power_w(&self) -> f64 {
            self.power_w
        }
        fn resources(&self) -> Utilization {
            Utilization::none()
        }
    }

    /// fast-but-hot / slow-but-frugal / very-slow-middling table: the
    /// constructed trade-space where every policy picks differently.
    fn table(policy: Policy, deadline_s: f64, budget: Option<f64>) -> Dispatcher {
        let t = |name, slot, per_item_s, power_w| -> Box<dyn AccelModel> {
            Box::new(Stub { name, slot, per_item_s, power_w })
        };
        Dispatcher {
            policy,
            registry: TargetRegistry::from_targets(
                vec![
                    t("dpu", Slot::Dpu, 0.001, 6.0),  // 6 mJ/item, fastest
                    t("hls", Slot::Hls, 0.002, 1.5),  // 3 mJ/item, cheapest
                    t("cpu", Slot::Cpu, 0.040, 2.75), // 110 mJ/item, slowest
                ],
                Some(0),
            ),
            deadline_s,
            power_budget_w: budget,
        }
    }

    fn slot_of(d: &Dispatcher, tl: &[AccelTimeline]) -> Slot {
        d.registry.get(d.choose(tl, 0.0, 0.0, 1).index).slot()
    }

    #[test]
    fn min_energy_and_min_latency_disagree() {
        let lat = table(Policy::MinLatency, 1.0, None);
        let en = table(Policy::MinEnergy, 1.0, None);
        let tl = lat.timelines();
        assert_eq!(slot_of(&lat, &tl), Slot::Dpu);
        assert_eq!(slot_of(&en, &tl), Slot::Hls);
    }

    #[test]
    fn static_always_picks_primary() {
        let d = table(Policy::Static, 1.0, None);
        let mut tl = d.timelines();
        // pile work on the primary: static must not steer away
        tl[0].schedule(0.0, 1000, d.run_of(0));
        assert_eq!(slot_of(&d, &tl), Slot::Dpu);
    }

    #[test]
    fn deadline_prefers_cheapest_that_meets() {
        // loose deadline: the frugal 2 ms target qualifies
        let d = table(Policy::Deadline, 0.010, None);
        assert_eq!(slot_of(&d, &d.timelines()), Slot::Hls);
        // tight deadline: only the 1 ms target meets it
        let d = table(Policy::Deadline, 0.0015, None);
        assert_eq!(slot_of(&d, &d.timelines()), Slot::Dpu);
    }

    #[test]
    fn deadline_violation_falls_back_to_min_latency() {
        // nothing can meet 0.1 ms: fall back to the fastest target
        let d = table(Policy::Deadline, 0.0001, None);
        let tl = d.timelines();
        let c = d.choose(&tl, 0.0, 0.0, 1);
        assert_eq!(d.registry.get(c.index).slot(), Slot::Dpu);
        assert!(!c.cost.meets_deadline);
    }

    #[test]
    fn power_budget_sheds_off_hot_target() {
        // 4 W budget excludes the 6 W DPU: min-latency lands on HLS
        let d = table(Policy::MinLatency, 1.0, Some(4.0));
        let tl = d.timelines();
        let c = d.choose(&tl, 0.0, 0.0, 1);
        assert_eq!(d.registry.get(c.index).slot(), Slot::Hls);
        assert!(c.power_shed, "budget changed the decision");
        // budget below every target: lowest-power wins outright
        let d = table(Policy::MinLatency, 1.0, Some(1.0));
        let c = d.choose(&tl, 0.0, 0.0, 1);
        assert_eq!(d.registry.get(c.index).slot(), Slot::Hls);
        assert!(c.power_shed);
    }

    #[test]
    fn backlog_steers_min_latency_but_not_min_energy() {
        let lat = table(Policy::MinLatency, 1.0, None);
        let en = table(Policy::MinEnergy, 1.0, None);
        let mut tl = lat.timelines();
        // 100 ms of queue on the fast target
        tl[0].schedule(0.0, 100, lat.run_of(0));
        assert_eq!(slot_of(&lat, &tl), Slot::Hls, "latency policy routes around the queue");
        assert_eq!(slot_of(&en, &tl), Slot::Hls);
        // pile onto HLS too: min-latency goes to the CPU, min-energy stays
        tl[1].schedule(0.0, 100, lat.run_of(1));
        assert_eq!(slot_of(&lat, &tl), Slot::Cpu);
        assert_eq!(slot_of(&en, &tl), Slot::Hls, "energy policy ignores queues");
    }

    #[test]
    fn cost_accounts_queue_and_batch_size() {
        let d = table(Policy::MinLatency, 1.0, None);
        let mut tl = d.timelines();
        let c1 = d.cost(0, &tl[0], 0.0, 0.0, 1);
        let c8 = d.cost(0, &tl[0], 0.0, 0.0, 8);
        assert!((c8.latency_s - 8.0 * c1.latency_s).abs() < 1e-12);
        assert!((c8.energy_j - 8.0 * c1.energy_j).abs() < 1e-12);
        tl[0].schedule(0.0, 10, d.run_of(0)); // 10 ms backlog
        let queued = d.cost(0, &tl[0], 0.0, 0.0, 1);
        assert!((queued.latency_s - (0.010 + 0.001)).abs() < 1e-12);
        // waiting already spent counts against the deadline
        let waited = d.cost(0, &tl[0], 0.5, 0.0, 1);
        assert!(waited.oldest_latency_s > 0.5);
        assert_eq!(waited.target, "dpu");
    }

    #[test]
    fn synthetic_catalog_builds_expected_targets() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        // DPU-compatible model: all three default targets
        let d = Dispatcher::new(
            "vae", &catalog, &calib, Policy::Static, 0.5, None, &TargetSet::Default,
        )
        .unwrap();
        assert_eq!(d.registry.len(), 3);
        assert_eq!(d.registry.get(d.primary_index()).slot(), Slot::Dpu);
        // conv3d model: no DPU target, primary HLS
        let d = Dispatcher::new(
            "baseline", &catalog, &calib, Policy::Static, 0.5, None, &TargetSet::Default,
        )
        .unwrap();
        assert_eq!(d.registry.len(), 2);
        assert!(d.registry.targets().iter().all(|t| t.slot() != Slot::Dpu));
        assert_eq!(d.registry.get(d.primary_index()).slot(), Slot::Hls);
        // the full family opens the design space
        let d = Dispatcher::new(
            "vae", &catalog, &calib, Policy::MinLatency, 0.5, None, &TargetSet::All,
        )
        .unwrap();
        assert_eq!(d.registry.len(), 7);
    }

    #[test]
    fn unavailable_target_is_never_chosen() {
        // knock out the fast DPU: min-latency must land on the HLS stub
        let mut d = table(Policy::MinLatency, 1.0, None);
        d.registry.set_available(0, false);
        let tl = d.timelines();
        assert_eq!(slot_of(&d, &tl), Slot::Hls);
        // restore: decisions return to the unfiltered pick
        d.registry.set_available(0, true);
        assert_eq!(slot_of(&d, &tl), Slot::Dpu);
    }

    #[test]
    fn static_redispatches_while_primary_is_down() {
        let mut d = table(Policy::Static, 1.0, None);
        let tl = d.timelines();
        assert_eq!(slot_of(&d, &tl), Slot::Dpu, "primary up: paper mapping");
        d.registry.set_available(0, false);
        // fastest available target takes over (HLS at 2 ms beats CPU)
        assert_eq!(slot_of(&d, &tl), Slot::Hls);
        d.registry.set_available(0, true);
        assert_eq!(slot_of(&d, &tl), Slot::Dpu, "repair restores the mapping");
    }

    #[test]
    fn all_targets_down_falls_back_to_full_set() {
        let mut d = table(Policy::MinLatency, 1.0, None);
        for i in 0..d.registry.len() {
            d.registry.set_available(i, false);
        }
        // the spacecraft cannot stop deciding: the full set is scored
        let tl = d.timelines();
        assert_eq!(slot_of(&d, &tl), Slot::Dpu);
    }

    #[test]
    fn constrained_matches_choose_when_unconstrained() {
        for policy in [Policy::Static, Policy::MinLatency, Policy::MinEnergy, Policy::Deadline]
        {
            let d = table(policy, 0.010, Some(4.0));
            let tl = d.timelines();
            let plain = d.choose(&tl, 0.0, 0.0, 4);
            let none = [false; 3];
            let constrained = d.choose_constrained(&tl, 0.0, 0.0, 4, &none, None);
            if policy == Policy::Static {
                // static ignores the budget in `choose` but not here
                assert_eq!(constrained.index, 1, "4 W excludes the 6 W primary");
            } else {
                assert_eq!(plain.index, constrained.index, "{policy:?}");
                assert_eq!(plain.power_shed, constrained.power_shed);
            }
        }
    }

    #[test]
    fn exclusion_forces_the_next_best_target() {
        let d = table(Policy::MinLatency, 1.0, None);
        let tl = d.timelines();
        // burn the fast DPU for this batch: the HLS stub takes over
        let c = d.choose_constrained(&tl, 0.0, 0.0, 1, &[true, false, false], None);
        assert_eq!(d.registry.get(c.index).slot(), Slot::Hls);
        // burn both accelerators: the CPU is the last resort
        let c = d.choose_constrained(&tl, 0.0, 0.0, 1, &[true, true, false], None);
        assert_eq!(d.registry.get(c.index).slot(), Slot::Cpu);
        // everything burned: the full set returns (cannot stop deciding)
        let c = d.choose_constrained(&tl, 0.0, 0.0, 1, &[true, true, true], None);
        assert_eq!(d.registry.get(c.index).slot(), Slot::Dpu);
    }

    #[test]
    fn brownout_override_tightens_the_budget_for_every_policy() {
        // static normally never sheds; a 2 W brownout forces the 1.5 W HLS
        let d = table(Policy::Static, 1.0, None);
        let tl = d.timelines();
        let none = [false; 3];
        let c = d.choose_constrained(&tl, 0.0, 0.0, 1, &none, Some(2.0));
        assert_eq!(d.registry.get(c.index).slot(), Slot::Hls);
        assert!(c.power_shed, "the sag changed the decision");
        // the override can only tighten an existing budget
        let d = table(Policy::MinLatency, 1.0, Some(3.0));
        let c = d.choose_constrained(&tl, 0.0, 0.0, 1, &none, Some(10.0));
        assert_eq!(d.registry.get(c.index).slot(), Slot::Hls, "3 W still binds");
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [Policy::Static, Policy::MinLatency, Policy::MinEnergy, Policy::Deadline] {
            assert_eq!(Policy::parse(p.as_str()).unwrap(), p);
        }
        assert!(Policy::parse("turbo").is_err());
    }

    #[test]
    fn deadline_defaults_ranked_by_urgency() {
        assert!(default_deadline_s(UseCase::Esperta) < default_deadline_s(UseCase::Mms));
        assert!(default_deadline_s(UseCase::Mms) < default_deadline_s(UseCase::Cnet));
    }
}
