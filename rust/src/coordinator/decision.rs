//! Per-use-case decision logic: turn raw model outputs into the
//! downlink-relevant verdicts the paper's §III motivates.
//!
//! * **MMS** — argmax over the 4 region logits; IF (ion foreshock) and
//!   MSH (magnetosheath) mark a region of interest for high-rate capture;
//!   all labels drive selective downlink.
//! * **ESPERTA** — outputs are `[probs(6) | alerts(6)]`; any alert bit
//!   set raises the SEP warning.
//! * **VAE** — the HLO emits `[mu(6) | logvar(6)]`; the sampling +
//!   exponent the paper moved off-FPGA happen *here* (rust post-
//!   processing on the "CPU"), producing the 6-float latent to downlink.
//! * **CNet** — the scalar forecast, with an M-class threshold alert.

use crate::model::UseCase;
use crate::sensors::generators::Region;
use crate::util::prng::Prng;

/// A decision produced from one inference.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// MMS: classified region + ROI flag.
    MmsRegion { region: Region, roi: bool, logits: [f32; 4] },
    /// ESPERTA: SEP warning with per-model alert mask.
    SepAlert { warning: bool, mask: [bool; 6], max_prob: f32 },
    /// VAE: sampled 6-float latent (the 1:16384 compression product).
    Latent { z: [f32; 6] },
    /// CNet: predicted log X-ray flux + alert above threshold.
    FluxForecast { log_flux: f32, alert: bool },
}

/// log10 flux above which CNet raises an alert (M-class: 1e-5 W/m^2).
pub const FLUX_ALERT_THRESHOLD: f32 = -5.0;

/// Decide from a model's raw output vector.  Exhaustive over
/// [`UseCase`]: there is no catch-all arm to fall through.
pub fn decide(use_case: UseCase, output: &[f32], rng: &mut Prng) -> Decision {
    match use_case {
        UseCase::Mms => {
            assert_eq!(output.len(), 4, "MMS nets emit 4 logits");
            let mut logits = [0f32; 4];
            logits.copy_from_slice(output);
            let arg = argmax(output);
            let region = Region::ALL[arg];
            Decision::MmsRegion {
                region,
                roi: matches!(region, Region::If | Region::Msh),
                logits,
            }
        }
        UseCase::Esperta => {
            assert_eq!(output.len(), 12, "multi-ESPERTA emits probs|alerts");
            let mut mask = [false; 6];
            let mut max_prob = 0f32;
            for i in 0..6 {
                mask[i] = output[6 + i] > 0.5;
                max_prob = max_prob.max(output[i]);
            }
            Decision::SepAlert { warning: mask.iter().any(|&b| b), mask, max_prob }
        }
        UseCase::Vae => {
            assert_eq!(output.len(), 12, "VAE encoder emits mu|logvar");
            // reparameterization on the PS: z = mu + exp(0.5*logvar)*eps
            let mut z = [0f32; 6];
            for i in 0..6 {
                let sigma = (0.5 * output[6 + i]).exp();
                z[i] = output[i] + sigma * rng.normal() as f32;
            }
            Decision::Latent { z }
        }
        UseCase::Cnet => {
            assert_eq!(output.len(), 1, "CNet emits one flux value");
            Decision::FluxForecast {
                log_flux: output[0],
                alert: output[0] > FLUX_ALERT_THRESHOLD,
            }
        }
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

impl Decision {
    /// Bytes this decision puts on the downlink if kept.
    pub fn downlink_bytes(&self) -> u64 {
        match self {
            // label + logits
            Decision::MmsRegion { .. } => 1 + 16,
            // mask byte + max prob
            Decision::SepAlert { .. } => 1 + 4,
            // 6 f32 latents
            Decision::Latent { .. } => 24,
            // flux f32 + alert bit
            Decision::FluxForecast { .. } => 5,
        }
    }

    /// Downlink priority (higher = more urgent).
    pub fn priority(&self) -> u8 {
        match self {
            Decision::SepAlert { warning: true, .. } => 255,
            Decision::FluxForecast { alert: true, .. } => 200,
            Decision::MmsRegion { roi: true, .. } => 150,
            Decision::Latent { .. } => 100,
            Decision::MmsRegion { .. } => 50,
            Decision::SepAlert { .. } => 40,
            Decision::FluxForecast { .. } => 40,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mms_argmax_and_roi() {
        let mut rng = Prng::new(1);
        let d = decide(UseCase::Mms, &[0.1, 3.0, -1.0, 0.2], &mut rng);
        match d {
            Decision::MmsRegion { region, roi, .. } => {
                assert_eq!(region, Region::If);
                assert!(roi);
            }
            _ => panic!("wrong decision kind"),
        }
        let d = decide(UseCase::Mms, &[9.0, 3.0, -1.0, 0.2], &mut rng);
        match d {
            Decision::MmsRegion { region, roi, .. } => {
                assert_eq!(region, Region::Sw);
                assert!(!roi);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn esperta_warning_on_any_alert() {
        let mut rng = Prng::new(2);
        let mut out = vec![0.2; 12];
        out[6 + 3] = 1.0;
        match decide(UseCase::Esperta, &out, &mut rng) {
            Decision::SepAlert { warning, mask, .. } => {
                assert!(warning);
                assert!(mask[3]);
                assert_eq!(mask.iter().filter(|&&b| b).count(), 1);
            }
            _ => panic!(),
        }
        let quiet = vec![0.2; 12];
        match decide(UseCase::Esperta, &quiet, &mut rng) {
            Decision::SepAlert { warning, .. } => assert!(!warning),
            _ => panic!(),
        }
    }

    #[test]
    fn vae_sampling_uses_logvar() {
        let mut rng = Prng::new(3);
        // logvar -> -inf means sigma -> 0: z == mu exactly
        let mut out = vec![0.0; 12];
        for i in 0..6 {
            out[i] = i as f32;
            out[6 + i] = -80.0;
        }
        match decide(UseCase::Vae, &out, &mut rng) {
            Decision::Latent { z } => {
                for i in 0..6 {
                    assert!((z[i] - i as f32).abs() < 1e-6);
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn cnet_alert_threshold() {
        let mut rng = Prng::new(4);
        match decide(UseCase::Cnet, &[-4.2], &mut rng) {
            Decision::FluxForecast { alert, .. } => assert!(alert),
            _ => panic!(),
        }
        match decide(UseCase::Cnet, &[-6.5], &mut rng) {
            Decision::FluxForecast { alert, .. } => assert!(!alert),
            _ => panic!(),
        }
    }

    #[test]
    fn priorities_rank_alerts_first() {
        let sep = Decision::SepAlert { warning: true, mask: [true; 6], max_prob: 0.9 };
        let lat = Decision::Latent { z: [0.0; 6] };
        let sw = Decision::MmsRegion { region: Region::Sw, roi: false, logits: [0.0; 4] };
        assert!(sep.priority() > lat.priority());
        assert!(lat.priority() > sw.priority());
    }

    #[test]
    fn downlink_bytes_are_tiny_vs_raw() {
        // MMS raw input: 32*16*32 f32 = 65536 B; decision: 17 B
        let d = Decision::MmsRegion { region: Region::Sw, roi: false, logits: [0.0; 4] };
        assert!(d.downlink_bytes() * 1000 < 32 * 16 * 32 * 4);
    }
}
