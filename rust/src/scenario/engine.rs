//! The scenario engine: drives a steppable pipeline run from a
//! declarative [`Scenario`].
//!
//! The engine owns exactly three responsibilities, all on the virtual
//! clock: (1) open a report phase and apply that phase's
//! [`MissionEvent`]s at entry, (2) tick the pipeline once per sensor
//! event, and (3) complete pending SEU repairs — a struck target
//! returns to service at the first scrub boundary after the upset plus
//! the bitstream reconfiguration time (`Calibration::t_config`), the
//! same reload the Fig 13 power spike prices.

use anyhow::{anyhow, bail, Result};

use crate::board::Calibration;
use crate::coordinator::{Pipeline, PipelineReport, PipelineRun};
use crate::model::catalog::Catalog;
use crate::runtime::ExecutorPool;

use super::{MissionEvent, Scenario};

/// A target awaiting its scrub repair.
#[derive(Debug)]
struct PendingRepair {
    /// Registry index of the struck target.
    index: usize,
    /// Virtual time the repair completes (s).
    ready_at_s: f64,
}

/// Stepwise scenario execution state: which phase runs next, plus the
/// SEU repairs still pending from earlier phases.
///
/// Deliberately holds no reference to the [`Scenario`] or the run, so a
/// fleet craft can own its cursor alongside both and step one phase per
/// epoch — [`ScenarioCursor::step_phase`] is exactly one iteration of
/// [`run_scenario`]'s phase loop, so stepping every phase and finishing
/// is bit-identical to the one-shot driver.
#[derive(Debug)]
pub struct ScenarioCursor {
    repairs: Vec<PendingRepair>,
    next_phase: usize,
}

impl Default for ScenarioCursor {
    fn default() -> ScenarioCursor {
        ScenarioCursor::new()
    }
}

impl ScenarioCursor {
    /// Fresh cursor: first phase next, no pending repairs.
    pub fn new() -> ScenarioCursor {
        ScenarioCursor { repairs: Vec::new(), next_phase: 0 }
    }

    /// True once every phase of `scenario` has been stepped.
    pub fn done(&self, scenario: &Scenario) -> bool {
        self.next_phase >= scenario.phases.len()
    }

    /// Drive `run` through the next phase: open the report phase, apply
    /// its mission events, then tick `n_events` times completing scrub
    /// repairs on schedule.  Returns `Ok(false)` without touching the
    /// run when the cursor is already past the last phase.
    pub fn step_phase(
        &mut self,
        scenario: &Scenario,
        calib: &Calibration,
        run: &mut PipelineRun<'_, '_>,
    ) -> Result<bool> {
        let phase = match scenario.phases.get(self.next_phase) {
            Some(p) => p,
            None => return Ok(false),
        };
        self.next_phase += 1;
        run.begin_phase(&phase.name);
        for event in &phase.events {
            apply_event(event, run, &mut self.repairs, scenario, calib)?;
        }
        for _ in 0..phase.n_events {
            let now = run.now_s();
            let repairs = &mut self.repairs;
            repairs.retain(|r| {
                if now >= r.ready_at_s {
                    run.set_target_available(r.index, true);
                    false
                } else {
                    true
                }
            });
            run.tick()?;
        }
        Ok(true)
    }
}

/// Run a scenario end to end and return the phase-segmented report.
///
/// Deterministic: the same scenario and seed produce a bit-identical
/// report.  `executor` supplies real numerics through the sharded pool;
/// `None` runs timing-only (deterministic surrogate outputs), which is
/// what `spaceinfer scenario` uses so every built-in runs without
/// artifacts.
pub fn run_scenario(
    scenario: &Scenario,
    catalog: &Catalog,
    calib: &Calibration,
    executor: Option<&ExecutorPool>,
) -> Result<PipelineReport> {
    let mut pipeline = Pipeline::new(scenario.config.clone(), catalog, calib)?;
    let mut run = pipeline.begin(executor);
    let mut cursor = ScenarioCursor::new();
    while cursor.step_phase(scenario, calib, &mut run)? {}
    run.finish()
}

/// Apply one mission event to the run.  SEU upsets also schedule the
/// repair that restores the target when the scrub window elapses.
fn apply_event(
    event: &MissionEvent,
    run: &mut PipelineRun<'_, '_>,
    repairs: &mut Vec<PendingRepair>,
    scenario: &Scenario,
    calib: &Calibration,
) -> Result<()> {
    match event {
        MissionEvent::EnterEclipse { budget_w } => {
            run.set_power_budget_w(Some(*budget_w));
        }
        MissionEvent::ExitEclipse => run.set_power_budget_w(None),
        MissionEvent::SepStorm { burst_x, deadline_s } => {
            run.set_burst(*burst_x)?;
            run.set_deadline_s(*deadline_s)?;
        }
        MissionEvent::StormSubsides => {
            run.set_burst(1.0)?;
            let base = run.base_deadline_s();
            run.set_deadline_s(base)?;
        }
        MissionEvent::DownlinkPass { budget_bytes } => {
            run.grant_downlink_bytes(*budget_bytes);
        }
        MissionEvent::SetPolicy { policy } => run.set_policy(*policy),
        MissionEvent::SeuUpset { target } => {
            let index = run.target_index(target).ok_or_else(|| {
                anyhow!(
                    "scenario {:?} strikes unknown target {target:?} \
                     (not registered for this model)",
                    scenario.name
                )
            })?;
            run.set_target_available(index, false);
            let now = run.now_s();
            let period = scenario.scrub.period_s;
            if !(period > 0.0 && period.is_finite()) {
                bail!(
                    "scenario {:?}: scrub period must be positive and finite \
                     to schedule the SEU repair, got {period}",
                    scenario.name
                );
            }
            // a re-strike supersedes any repair already scheduled for
            // this target — otherwise the stale (earlier) repair would
            // end the new outage prematurely
            repairs.retain(|r| r.index != index);
            // the scrubber reloads on its fixed cycle: the upset waits
            // for the next boundary, then pays the reconfiguration time
            let wait = period - (now % period);
            repairs.push(PendingRepair { index, ready_at_s: now + wait + calib.t_config });
        }
        MissionEvent::LinkDropout { duration_s } => {
            run.set_link_dropout(*duration_s)?;
        }
        MissionEvent::ThermalThrottle { target, derate_x, duration_s } => {
            let index = run.target_index(target).ok_or_else(|| {
                anyhow!(
                    "scenario {:?} throttles unknown target {target:?} \
                     (not registered for this model)",
                    scenario.name
                )
            })?;
            run.set_thermal_throttle(index, *derate_x, *duration_s)?;
        }
        MissionEvent::Brownout { budget_w, duration_s } => {
            run.set_brownout(*budget_w, *duration_s)?;
        }
        MissionEvent::TransientFault { target } => {
            let index = run.target_index(target).ok_or_else(|| {
                anyhow!(
                    "scenario {:?} faults unknown target {target:?} \
                     (not registered for this model)",
                    scenario.name
                )
            })?;
            run.inject_transient_fault(index)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{PipelineConfig, Policy};
    use crate::model::UseCase;
    use crate::rad::ScrubPolicy;
    use crate::scenario::Phase;

    fn catalog() -> Catalog {
        Catalog::synthetic()
    }

    fn esperta_seu_scenario(period_s: f64) -> Scenario {
        Scenario {
            name: "test-seu".into(),
            summary: "seu strike on the hls target".into(),
            config: PipelineConfig {
                use_case: UseCase::Esperta,
                cadence_s: 0.1,
                ..Default::default()
            },
            scrub: ScrubPolicy { period_s },
            phases: vec![
                Phase::new("monitoring", 40, vec![]),
                Phase::new(
                    "post-upset",
                    120,
                    vec![MissionEvent::SeuUpset { target: "hls".into() }],
                ),
            ],
        }
    }

    #[test]
    fn seu_upset_shifts_mix_then_scrub_restores() {
        // phase 2 starts at t=4 s; with a 6 s scrub period the repair
        // lands at 6 s + t_config (~6.8 s), mid-way through the phase
        let calib = Calibration::default();
        let r = run_scenario(&esperta_seu_scenario(6.0), &catalog(), &calib, None)
            .unwrap();
        assert_eq!(r.phases.len(), 2);
        let nominal = &r.phases[0];
        let upset = &r.phases[1];
        assert_eq!(nominal.target_mix.keys().collect::<Vec<_>>(), vec!["hls"]);
        assert!(
            upset.target_mix.contains_key("cpu"),
            "knocked-out primary must re-dispatch: {:?}",
            upset.target_mix
        );
        assert!(
            upset.target_mix.contains_key("hls"),
            "scrub must restore the target within the phase: {:?}",
            upset.target_mix
        );
    }

    #[test]
    fn unrepaired_upset_keeps_target_out_all_phase() {
        // a day-long scrub period: the repair never lands inside the run
        let calib = Calibration::default();
        let r = run_scenario(
            &esperta_seu_scenario(86_400.0),
            &catalog(),
            &calib,
            None,
        )
        .unwrap();
        let upset = &r.phases[1];
        assert!(!upset.target_mix.contains_key("hls"), "{:?}", upset.target_mix);
        assert!(upset.target_mix.contains_key("cpu"));
    }

    #[test]
    fn restrike_during_reload_supersedes_the_stale_repair() {
        // first strike at t=4 schedules its repair for the t=6 scrub
        // boundary + 0.8 s reload (6.8).  The second strike lands at
        // t=6.5 — *inside* that reload window — so its own repair waits
        // for the NEXT boundary (12.8).  The stale 6.8 repair must not
        // restore the freshly re-struck target.
        let calib = Calibration::default();
        let sc = Scenario {
            name: "restrike".into(),
            summary: "second SEU during the scrub reload".into(),
            config: PipelineConfig {
                use_case: UseCase::Esperta,
                cadence_s: 0.1,
                ..Default::default()
            },
            scrub: ScrubPolicy { period_s: 6.0 },
            phases: vec![
                Phase::new("nominal", 40, vec![]),
                Phase::new(
                    "first-hit",
                    25,
                    vec![MissionEvent::SeuUpset { target: "hls".into() }],
                ),
                Phase::new(
                    "second-hit",
                    50,
                    vec![MissionEvent::SeuUpset { target: "hls".into() }],
                ),
            ],
        };
        let r = run_scenario(&sc, &catalog(), &calib, None).unwrap();
        // second-hit spans t = 6.5 .. 11.5, entirely before the 12.8
        // repair: the target must stay out of service the whole phase
        assert!(
            !r.phases[2].target_mix.contains_key("hls"),
            "stale repair restored a re-struck target: {:?}",
            r.phases[2].target_mix
        );
        assert!(r.phases[2].target_mix.contains_key("cpu"));
    }

    #[test]
    fn unknown_target_is_an_error() {
        let mut sc = esperta_seu_scenario(60.0);
        sc.phases[1].events =
            vec![MissionEvent::SeuUpset { target: "dpu-b9999".into() }];
        let calib = Calibration::default();
        assert!(run_scenario(&sc, &catalog(), &calib, None).is_err());
    }

    #[test]
    fn policy_switch_event_applies() {
        let calib = Calibration::default();
        let sc = Scenario {
            name: "policy-flip".into(),
            summary: "min-latency then eclipse budget".into(),
            config: PipelineConfig {
                use_case: UseCase::Vae,
                cadence_s: 0.05,
                policy: Policy::MinLatency,
                ..Default::default()
            },
            scrub: ScrubPolicy { period_s: 60.0 },
            phases: vec![
                Phase::new("sunlit", 40, vec![]),
                Phase::new(
                    "umbra",
                    40,
                    vec![
                        MissionEvent::SetPolicy { policy: Policy::Deadline },
                        MissionEvent::EnterEclipse { budget_w: 4.0 },
                    ],
                ),
                Phase::new("egress", 20, vec![MissionEvent::ExitEclipse]),
            ],
        };
        let r = run_scenario(&sc, &catalog(), &calib, None).unwrap();
        assert_eq!(r.phases.len(), 3);
        assert!(r.phases[0].target_mix.contains_key("dpu"));
        assert!(
            !r.phases[1].target_mix.contains_key("dpu"),
            "4 W budget excludes the 5.75 W DPU: {:?}",
            r.phases[1].target_mix
        );
        assert!(r.phases[1].power_sheds > 0);
    }
}
