//! Seeded scenario fuzzer: generated mission timelines with the fault
//! layer armed, replayed twice and checked against global invariants.
//!
//! Each fuzz seed deterministically expands into a random scenario
//! (use case, policy, phases, mission events, fault profile, recovery
//! policy) which then runs twice; [`fuzz_one`] asserts the two reports
//! are bit-identical and that the accounting invariants hold under any
//! fault timeline:
//!
//! * conservation — ingress accepted + dropped equals events emitted,
//!   and every accepted event completes (the forced attempt cap
//!   guarantees no batch is lost to faults);
//! * partition — per-phase events, drops, batches, misses, sheds,
//!   downlink verdicts, fault/recovery counters, energy, and target
//!   mix each sum to the aggregate report;
//! * downlink — every decision is sent, shed, or lost to a dropout
//!   window, exactly once;
//! * recovery — reinstatements never exceed quarantines.
//!
//! `spaceinfer fuzz --seeds N` drives this from the CLI (the CI smoke
//! runs 25 seeds); `tests/fault_recovery.rs` runs a slice per build.

use anyhow::{ensure, Context, Result};

use crate::board::Calibration;
use crate::coordinator::{PipelineConfig, PipelineReport, Policy};
use crate::fault::{FaultProfile, FaultStats, RecoveryPolicy};
use crate::model::catalog::Catalog;
use crate::model::UseCase;
use crate::rad::ScrubPolicy;
use crate::util::prng::{stream_seed, Prng};

use super::{run_scenario, MissionEvent, Phase, Scenario};

/// Salt XORed into the fuzz seed so scenario generation never aliases
/// the decision or fault RNG streams derived from the same seed.
const FUZZ_RNG_SALT: u64 = 0x5CE7_A210;

/// What one fuzz seed ran and observed (all invariants already held).
#[derive(Debug, Clone)]
pub struct FuzzOutcome {
    /// The fuzz seed that generated and ran the scenario.
    pub seed: u64,
    /// Use case the generated scenario served.
    pub use_case: UseCase,
    /// Dispatch policy the scenario started under.
    pub policy: String,
    /// Mission phases in the generated timeline.
    pub phases: usize,
    /// Events completed on the virtual clock.
    pub events: u64,
    /// Events the ingress queue shed.
    pub dropped: u64,
    /// Fault / recovery accounting for the run.
    pub faults: FaultStats,
}

/// Deterministically expand one fuzz seed into a scenario with the
/// fault injector always armed.  Struck / throttled / faulted targets
/// are limited to `"hls"` and `"cpu"`, which register for every model
/// under the default target set.
pub fn generate(seed: u64) -> Scenario {
    let mut rng = Prng::new(seed ^ FUZZ_RNG_SALT);
    let use_case =
        [UseCase::Vae, UseCase::Cnet, UseCase::Esperta, UseCase::Mms][rng.below(4)];
    let policy = [
        Policy::Static,
        Policy::MinLatency,
        Policy::MinEnergy,
        Policy::Deadline,
    ][rng.below(4)];
    let cadence_s = rng.range_f64(0.05, 0.2);
    let n_phases = 1 + rng.below(3);
    let mut phases = Vec::with_capacity(n_phases);
    for i in 0..n_phases {
        let n_events = 30 + rng.below(51);
        let n_mission = rng.below(3);
        let mut events = Vec::with_capacity(n_mission);
        for _ in 0..n_mission {
            events.push(random_event(&mut rng));
        }
        phases.push(Phase::new(&format!("phase-{i}"), n_events, events));
    }
    let total: usize = phases.iter().map(|p| p.n_events).sum();
    // storm-scaled probabilities, capped so runs terminate briskly even
    // at the top of the range
    let scale = rng.range_f64(0.5, 4.0);
    let base = FaultProfile::default();
    let fault_profile = FaultProfile {
        exec_fail_p: (base.exec_fail_p * scale).min(0.3),
        timeout_p: (base.timeout_p * scale).min(0.2),
        seu_corrupt_p: (base.seu_corrupt_p * scale).min(0.3),
        thermal_p: (base.thermal_p * scale).min(0.2),
        brownout_p: (base.brownout_p * scale).min(0.05),
        dropout_p: (base.dropout_p * scale).min(0.05),
        ..base
    };
    let recovery = RecoveryPolicy {
        tmr: rng.chance(0.3),
        quarantine_threshold: (2 + rng.below(3)) as u32,
        max_retries_per_target: rng.below(3) as u32,
        ..Default::default()
    };
    let ingress_cap = if rng.chance(0.3) { Some(16 + rng.below(49)) } else { None };
    let downlink_budget = (4 + rng.below(61) as u64) * 1024;
    let scrub_period_s = rng.range_f64(5.0, 60.0);
    let fault_seed = Some(rng.next_u64());
    Scenario {
        name: format!("fuzz-{seed}"),
        summary: format!("generated fault-campaign scenario, fuzz seed {seed}"),
        config: PipelineConfig {
            use_case,
            n_events: total,
            cadence_s,
            policy,
            downlink_budget,
            ingress_cap,
            fault_seed,
            fault_profile,
            recovery,
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: scrub_period_s },
        phases,
    }
}

/// One random mission event from the full vocabulary.
fn random_event(rng: &mut Prng) -> MissionEvent {
    match rng.below(9) {
        0 => MissionEvent::EnterEclipse { budget_w: rng.range_f64(2.0, 6.0) },
        1 => MissionEvent::ExitEclipse,
        2 => MissionEvent::DownlinkPass {
            budget_bytes: (4 + rng.below(29) as u64) * 1024,
        },
        3 => MissionEvent::SeuUpset { target: "hls".into() },
        4 => MissionEvent::LinkDropout { duration_s: rng.range_f64(1.0, 10.0) },
        5 => MissionEvent::ThermalThrottle {
            target: "hls".into(),
            derate_x: rng.range_f64(1.5, 4.0),
            duration_s: rng.range_f64(1.0, 8.0),
        },
        6 => MissionEvent::Brownout {
            budget_w: rng.range_f64(2.0, 4.0),
            duration_s: rng.range_f64(1.0, 8.0),
        },
        7 => MissionEvent::TransientFault {
            target: if rng.chance(0.5) { "hls".into() } else { "cpu".into() },
        },
        _ => MissionEvent::SetPolicy {
            policy: [
                Policy::Static,
                Policy::MinLatency,
                Policy::MinEnergy,
                Policy::Deadline,
            ][rng.below(4)],
        },
    }
}

/// Generate, run twice, and check one fuzz seed.  Errors name the seed
/// so a CI failure reproduces with `spaceinfer fuzz --exact-seed
/// <seed>`.
pub fn fuzz_one(seed: u64, catalog: &Catalog, calib: &Calibration) -> Result<FuzzOutcome> {
    let scenario = generate(seed);
    let a = run_scenario(&scenario, catalog, calib, None)
        .with_context(|| format!("fuzz seed {seed}: first run"))?;
    let b = run_scenario(&scenario, catalog, calib, None)
        .with_context(|| format!("fuzz seed {seed}: replay"))?;
    ensure_identical(&a, &b, seed)?;
    check_invariants(&a, &scenario, seed)?;
    Ok(FuzzOutcome {
        seed,
        use_case: scenario.config.use_case,
        policy: scenario.config.policy.as_str().to_string(),
        phases: scenario.phases.len(),
        events: a.events,
        dropped: a.ingress_dropped,
        faults: a.faults,
    })
}

/// Run `n` fuzz cases derived from `base_seed`.
///
/// Case `i` runs seed [`stream_seed`]`(base_seed, i)` — a proper
/// stream split rather than the old ad-hoc `base_seed + i` offset, so
/// neighboring cases share no RNG structure and two base seeds less
/// than `n` apart no longer re-fuzz overlapping scenario sets.  The
/// derived seed is recorded in each [`FuzzOutcome`]; a failure
/// replays directly with `spaceinfer fuzz --exact-seed <seed>`, which
/// calls [`fuzz_one`] on that seed without re-splitting.
pub fn fuzz_many(
    base_seed: u64,
    n: usize,
    catalog: &Catalog,
    calib: &Calibration,
) -> Result<Vec<FuzzOutcome>> {
    (0..n)
        .map(|i| fuzz_one(stream_seed(base_seed, i as u64), catalog, calib))
        .collect()
}

/// Bit-level determinism: the same scenario and seed must replay to an
/// identical report, fault timeline included.
fn ensure_identical(a: &PipelineReport, b: &PipelineReport, seed: u64) -> Result<()> {
    ensure!(a.target_mix == b.target_mix, "seed {seed}: target mix diverged");
    ensure!(a.events == b.events, "seed {seed}: event count diverged");
    ensure!(
        a.sim_elapsed_s.to_bits() == b.sim_elapsed_s.to_bits(),
        "seed {seed}: sim time diverged"
    );
    ensure!(
        a.mean_latency_s.to_bits() == b.mean_latency_s.to_bits()
            && a.p95_latency_s.to_bits() == b.p95_latency_s.to_bits(),
        "seed {seed}: latency stats diverged"
    );
    ensure!(
        a.energy_j.to_bits() == b.energy_j.to_bits()
            && a.predicted_energy_j.to_bits() == b.predicted_energy_j.to_bits(),
        "seed {seed}: energy diverged"
    );
    ensure!(
        a.deadline_misses == b.deadline_misses && a.power_sheds == b.power_sheds,
        "seed {seed}: miss/shed counts diverged"
    );
    ensure!(
        a.ingress_accepted == b.ingress_accepted
            && a.ingress_dropped == b.ingress_dropped,
        "seed {seed}: ingress counts diverged"
    );
    ensure!(
        a.downlink_sent == b.downlink_sent
            && a.downlink_shed == b.downlink_shed
            && a.downlink_sent_bytes == b.downlink_sent_bytes
            && a.downlink_shed_bytes == b.downlink_shed_bytes,
        "seed {seed}: downlink counts diverged"
    );
    ensure!(a.decisions == b.decisions, "seed {seed}: decisions diverged");
    ensure!(a.phases == b.phases, "seed {seed}: phase reports diverged");
    ensure!(a.faults == b.faults, "seed {seed}: fault stats diverged");
    ensure!(
        a.exec_errors == b.exec_errors,
        "seed {seed}: exec errors diverged"
    );
    Ok(())
}

/// The global accounting invariants that must hold under any fault
/// timeline.
fn check_invariants(r: &PipelineReport, scenario: &Scenario, seed: u64) -> Result<()> {
    let emitted = scenario.total_events() as u64;
    ensure!(
        r.ingress_accepted + r.ingress_dropped == emitted,
        "seed {seed}: accepted {} + dropped {} != emitted {emitted}",
        r.ingress_accepted,
        r.ingress_dropped
    );
    ensure!(
        r.events == r.ingress_accepted,
        "seed {seed}: {} accepted events but {} completed — a batch was lost",
        r.ingress_accepted,
        r.events
    );

    // per-phase totals partition every aggregate
    let p_events: u64 = r.phases.iter().map(|p| p.events).sum();
    ensure!(
        p_events == emitted,
        "seed {seed}: phase events {p_events} != emitted {emitted}"
    );
    let p_dropped: u64 = r.phases.iter().map(|p| p.dropped).sum();
    ensure!(
        p_dropped == r.ingress_dropped,
        "seed {seed}: phase drops {p_dropped} != {}",
        r.ingress_dropped
    );
    let p_batches: u64 = r.phases.iter().map(|p| p.batches).sum();
    let batches = r.metrics.counter("batches");
    ensure!(
        p_batches == batches,
        "seed {seed}: phase batches {p_batches} != dispatched {batches}"
    );
    let p_misses: u64 = r.phases.iter().map(|p| p.deadline_misses).sum();
    ensure!(
        p_misses == r.deadline_misses,
        "seed {seed}: phase misses {p_misses} != {}",
        r.deadline_misses
    );
    let p_sheds: u64 = r.phases.iter().map(|p| p.power_sheds).sum();
    ensure!(
        p_sheds == r.power_sheds,
        "seed {seed}: phase sheds {p_sheds} != {}",
        r.power_sheds
    );
    let p_sent: u64 = r.phases.iter().map(|p| p.downlink_sent).sum();
    let p_shed: u64 = r.phases.iter().map(|p| p.downlink_shed).sum();
    ensure!(
        p_sent == r.downlink_sent && p_shed == r.downlink_shed,
        "seed {seed}: phase downlink {p_sent}/{p_shed} != {}/{}",
        r.downlink_sent,
        r.downlink_shed
    );
    let p_faults: u64 = r.phases.iter().map(|p| p.faults).sum();
    ensure!(
        p_faults == r.faults.faults_injected,
        "seed {seed}: phase faults {p_faults} != {}",
        r.faults.faults_injected
    );
    let p_retries: u64 = r.phases.iter().map(|p| p.retries).sum();
    ensure!(
        p_retries == r.faults.retries,
        "seed {seed}: phase retries {p_retries} != {}",
        r.faults.retries
    );
    let p_quar: u64 = r.phases.iter().map(|p| p.quarantines).sum();
    ensure!(
        p_quar == r.faults.quarantines,
        "seed {seed}: phase quarantines {p_quar} != {}",
        r.faults.quarantines
    );
    let p_masked: u64 = r.phases.iter().map(|p| p.tmr_masked).sum();
    ensure!(
        p_masked == r.faults.tmr_masked,
        "seed {seed}: phase tmr_masked {p_masked} != {}",
        r.faults.tmr_masked
    );
    let p_degraded: u64 = r.phases.iter().map(|p| p.degraded).sum();
    ensure!(
        p_degraded == r.faults.degraded_batches,
        "seed {seed}: phase degraded {p_degraded} != {}",
        r.faults.degraded_batches
    );
    let p_link: u64 = r.phases.iter().map(|p| p.link_dropped).sum();
    ensure!(
        p_link == r.faults.link_dropped,
        "seed {seed}: phase link drops {p_link} != {}",
        r.faults.link_dropped
    );
    let p_energy: f64 = r.phases.iter().map(|p| p.energy_j).sum();
    ensure!(
        (p_energy - r.energy_j).abs() <= 1e-9 * r.energy_j.abs().max(1.0),
        "seed {seed}: phase energy {p_energy} != {}",
        r.energy_j
    );
    let mut p_mix = std::collections::BTreeMap::new();
    for p in &r.phases {
        for (name, n) in &p.target_mix {
            *p_mix.entry(name.clone()).or_insert(0u64) += n;
        }
    }
    ensure!(
        p_mix == r.target_mix,
        "seed {seed}: phase mix {p_mix:?} != {:?}",
        r.target_mix
    );

    // every completed event decides exactly once, and every decision is
    // sent, shed, or lost to a dropout window
    let n_decisions: u64 = r.decisions.values().sum();
    ensure!(
        n_decisions == r.events,
        "seed {seed}: {n_decisions} decisions for {} events",
        r.events
    );
    ensure!(
        r.downlink_sent + r.downlink_shed + r.faults.link_dropped == n_decisions,
        "seed {seed}: downlink {} + {} + link-dropped {} != decisions {n_decisions}",
        r.downlink_sent,
        r.downlink_shed,
        r.faults.link_dropped
    );
    ensure!(
        r.faults.quarantines >= r.faults.reinstates,
        "seed {seed}: {} reinstates exceed {} quarantines",
        r.faults.reinstates,
        r.faults.quarantines
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = generate(17);
        let b = generate(17);
        assert_eq!(a.config.fault_seed, b.config.fault_seed);
        assert_eq!(a.phases, b.phases);
        assert_eq!(a.config.use_case, b.config.use_case);
        assert!(a.config.fault_seed.is_some(), "the injector is always armed");
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut distinct = false;
        let base = generate(1);
        for seed in 2..10 {
            if generate(seed).phases != base.phases {
                distinct = true;
                break;
            }
        }
        assert!(distinct, "nine seeds produced identical timelines");
    }

    #[test]
    fn a_fuzz_seed_passes_end_to_end() {
        let catalog = Catalog::synthetic();
        let calib = Calibration::default();
        let out = fuzz_one(1, &catalog, &calib).unwrap();
        assert!(out.events > 0);
    }
}
