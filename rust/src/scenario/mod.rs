//! Mission scenario engine: declarative timelines over the steppable
//! pipeline.
//!
//! The paper's numbers only matter operationally when conditions change
//! *during* a run: MPSoC inference power spans 1.5–6.75 W, so an umbra
//! crossing or a SEP storm forces re-dispatch under a new power budget,
//! cadence, or deadline — the deployment concern the companion FPGA
//! survey raises and duty-cycled CubeSat deployments live with.  This
//! module turns those condition changes into data:
//!
//! * [`Scenario`] — a name, a base [`PipelineConfig`], a scrubbing
//!   policy, and an ordered list of [`Phase`]s;
//! * [`Phase`] — a named span of `n_events` sensor events, entered by
//!   applying zero or more [`MissionEvent`]s;
//! * [`MissionEvent`] — the vocabulary of mid-run condition changes:
//!   eclipse entry/exit (power budget), SEP storms (burst rate +
//!   deadline), ground-station passes (downlink budget), SEU upsets
//!   (target knocked out until its `rad::scrub` repair window elapses),
//!   and policy switches;
//! * [`engine::run_scenario`] — drives a
//!   [`crate::coordinator::PipelineRun`] tick by tick, applying events
//!   at phase boundaries and completing scrub repairs on the virtual
//!   clock;
//! * [`library`] — the built-in scenarios behind
//!   `spaceinfer scenario <name>`, re-expressing the repo's former
//!   hand-rolled examples as data.
//!
//! Everything is deterministic: the same seed and scenario produce a
//! bit-identical segmented [`crate::coordinator::PipelineReport`], and
//! a single-phase scenario with no events reproduces the legacy
//! `Pipeline::run` report exactly.

pub mod engine;
pub mod fuzz;
pub mod library;

use crate::coordinator::{PipelineConfig, Policy};
use crate::rad::ScrubPolicy;

pub use engine::{run_scenario, ScenarioCursor};
pub use library::{all_builtins, builtin, builtin_names};

/// A mid-run change of mission conditions, applied between ticks of the
/// steppable pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum MissionEvent {
    /// Umbra entry: the EPS caps active inference draw at `budget_w`
    /// watts.  Applies to dynamic dispatch policies (the static policy
    /// reproduces the paper's fixed mapping and ignores budgets).
    EnterEclipse {
        /// Cap on active MPSoC draw while inference runs (W).
        budget_w: f64,
    },
    /// Umbra exit: the power cap is lifted.
    ExitEclipse,
    /// Solar-energetic-particle storm: the instrument bursts to
    /// `burst_x` times the base event rate and the end-to-end alert
    /// deadline tightens to `deadline_s`.
    SepStorm {
        /// Event-rate multiplier over the scenario's base cadence.
        burst_x: f64,
        /// Storm-time end-to-end deadline (s).
        deadline_s: f64,
    },
    /// The storm subsides: cadence and deadline return to baseline.
    StormSubsides,
    /// A ground-station pass grants `budget_bytes` of additional
    /// downlink budget.
    DownlinkPass {
        /// Bytes granted to the downlink manager.
        budget_bytes: u64,
    },
    /// A single-event upset corrupts the named target's configuration
    /// memory: the target is marked unavailable (dispatch re-routes
    /// live) until the scrubber's repair window elapses — the next
    /// scrub boundary plus the bitstream reconfiguration time.
    SeuUpset {
        /// Registry name of the struck target (`"dpu"`, `"hls"`, ...).
        target: String,
    },
    /// Switch the dispatch policy from the next batch on.
    SetPolicy {
        /// The policy to dispatch under.
        policy: Policy,
    },
    /// The ground link drops: decisions completing within the window
    /// are lost before the downlink byte budget is consulted.
    LinkDropout {
        /// Dropout window length (virtual seconds).
        duration_s: f64,
    },
    /// The named target overheats: its latencies multiply by `derate_x`
    /// until the window closes.
    ThermalThrottle {
        /// Registry name of the throttled target (`"dpu"`, `"hls"`, ...).
        target: String,
        /// Latency multiplier while throttled (>= 1).
        derate_x: f64,
        /// Throttle window length (virtual seconds).
        duration_s: f64,
    },
    /// Bus brownout: every policy (including `static`) dispatches under
    /// `budget_w` until the window closes — degraded-mode dispatch.
    Brownout {
        /// Power budget enforced during the sag (W).
        budget_w: f64,
        /// Brownout window length (virtual seconds).
        duration_s: f64,
    },
    /// One forced transient execution failure on the named target,
    /// consumed by the next batch attempt dispatched there — exercises
    /// the retry / escalation / quarantine machinery deterministically.
    TransientFault {
        /// Registry name of the faulted target.
        target: String,
    },
}

impl MissionEvent {
    /// Short human-readable label for logs and phase listings.
    pub fn label(&self) -> String {
        match self {
            MissionEvent::EnterEclipse { budget_w } => {
                format!("eclipse({budget_w} W)")
            }
            MissionEvent::ExitEclipse => "eclipse-exit".into(),
            MissionEvent::SepStorm { burst_x, deadline_s } => {
                format!("storm({burst_x}x, {deadline_s} s)")
            }
            MissionEvent::StormSubsides => "storm-subsides".into(),
            MissionEvent::DownlinkPass { budget_bytes } => {
                format!("downlink-pass({budget_bytes} B)")
            }
            MissionEvent::SeuUpset { target } => format!("seu({target})"),
            MissionEvent::SetPolicy { policy } => {
                format!("policy({})", policy.as_str())
            }
            MissionEvent::LinkDropout { duration_s } => {
                format!("link-dropout({duration_s} s)")
            }
            MissionEvent::ThermalThrottle { target, derate_x, duration_s } => {
                format!("throttle({target}, {derate_x}x, {duration_s} s)")
            }
            MissionEvent::Brownout { budget_w, duration_s } => {
                format!("brownout({budget_w} W, {duration_s} s)")
            }
            MissionEvent::TransientFault { target } => {
                format!("transient({target})")
            }
        }
    }
}

/// One named span of a scenario: `events` are applied when the phase
/// begins, then `n_events` sensor events tick through the pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    /// Phase name (becomes the report segment's name).
    pub name: String,
    /// Sensor events generated during the phase.
    pub n_events: usize,
    /// Mission events applied at phase entry, in order.
    pub events: Vec<MissionEvent>,
}

impl Phase {
    /// Convenience constructor.
    pub fn new(name: &str, n_events: usize, events: Vec<MissionEvent>) -> Phase {
        Phase { name: name.to_string(), n_events, events }
    }
}

/// A declarative mission timeline: base configuration + ordered phases.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario name (`spaceinfer scenario <name>`).
    pub name: String,
    /// One-line mission summary for listings.
    pub summary: String,
    /// Base pipeline configuration the run starts from.  `n_events` is
    /// informational — the phases drive the event count.
    pub config: PipelineConfig,
    /// Scrubbing policy governing SEU repair windows.
    pub scrub: ScrubPolicy,
    /// Ordered mission phases.
    pub phases: Vec<Phase>,
}

impl Scenario {
    /// Total sensor events across all phases.
    pub fn total_events(&self) -> usize {
        self.phases.iter().map(|p| p.n_events).sum()
    }

    /// The phase names joined as `a → b → c` (for listings).
    pub fn phase_chain(&self) -> String {
        self.phases
            .iter()
            .map(|p| p.name.as_str())
            .collect::<Vec<_>>()
            .join(" → ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_labels_are_compact() {
        assert_eq!(
            MissionEvent::EnterEclipse { budget_w: 4.0 }.label(),
            "eclipse(4 W)"
        );
        assert_eq!(
            MissionEvent::SeuUpset { target: "dpu".into() }.label(),
            "seu(dpu)"
        );
        assert_eq!(
            MissionEvent::SetPolicy { policy: Policy::MinEnergy }.label(),
            "policy(min-energy)"
        );
    }

    #[test]
    fn scenario_totals_and_chain() {
        let sc = Scenario {
            name: "t".into(),
            summary: "test".into(),
            config: PipelineConfig::default(),
            scrub: ScrubPolicy { period_s: 60.0 },
            phases: vec![
                Phase::new("a", 10, vec![]),
                Phase::new("b", 20, vec![MissionEvent::ExitEclipse]),
            ],
        };
        assert_eq!(sc.total_events(), 30);
        assert_eq!(sc.phase_chain(), "a → b");
    }
}
