//! Built-in mission scenarios: the repo's former hand-rolled examples
//! re-expressed as data.
//!
//! Each scenario runs without artifacts (synthetic stand-in catalog,
//! timing-only pipeline) and exercises a different slice of the
//! trade-space the paper measures: eclipse power budgets, SEP burst
//! load against the alert deadline, downlink budget management, SEU
//! recovery through scrubbing, and energy-optimal compression.  List
//! them with `spaceinfer scenario --list`, run one with
//! `spaceinfer scenario <name>`.

use anyhow::{bail, Result};

use crate::coordinator::{PipelineConfig, Policy};
use crate::fault::{FaultProfile, RecoveryPolicy};
use crate::model::UseCase;
use crate::rad::ScrubPolicy;

use super::{MissionEvent, Phase, Scenario};

/// Names of every built-in scenario, in listing order.
pub fn builtin_names() -> Vec<&'static str> {
    vec![
        "eclipse-ops",
        "sep-storm",
        "onboard-downlink",
        "sep-alert",
        "solar-compress",
        "sep-campaign",
    ]
}

/// Every built-in scenario, in listing order.
pub fn all_builtins() -> Vec<Scenario> {
    builtin_names()
        .into_iter()
        .map(|n| builtin(n).expect("builtin names are constructible"))
        .collect()
}

/// Look up a built-in scenario by name.
pub fn builtin(name: &str) -> Result<Scenario> {
    Ok(match name {
        "eclipse-ops" => eclipse_ops(),
        "sep-storm" => sep_storm(),
        "onboard-downlink" => onboard_downlink(),
        "sep-alert" => sep_alert(),
        "solar-compress" => solar_compress(),
        "sep-campaign" => sep_campaign(),
        other => bail!(
            "unknown scenario {other:?} (known: {})",
            builtin_names().join(", ")
        ),
    })
}

/// VAE compression through an umbra crossing: latency-optimal in
/// sunlight, then the EPS caps active draw at 4 W and the same workload
/// re-dispatches under the deadline policy until egress.
fn eclipse_ops() -> Scenario {
    Scenario {
        name: "eclipse-ops".into(),
        summary: "VAE compression through an umbra crossing: min-latency in \
                  sunlight, 4 W deadline ops in eclipse, restored at egress"
            .into(),
        config: PipelineConfig {
            use_case: UseCase::Vae,
            n_events: 300,
            cadence_s: 0.05,
            policy: Policy::MinLatency,
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: 120.0 },
        phases: vec![
            Phase::new("sunlit", 120, vec![]),
            Phase::new(
                "umbra",
                120,
                vec![
                    MissionEvent::SetPolicy { policy: Policy::Deadline },
                    MissionEvent::EnterEclipse { budget_w: 4.0 },
                ],
            ),
            Phase::new(
                "egress",
                60,
                vec![
                    MissionEvent::ExitEclipse,
                    MissionEvent::SetPolicy { policy: Policy::MinLatency },
                ],
            ),
        ],
    }
}

/// ESPERTA early-warning chain through a solar-energetic-particle
/// storm: the burst raises the event rate four orders of magnitude past
/// what any target serves, so the bounded ingress queue decimates
/// deterministically while the tightened alert deadline binds.
fn sep_storm() -> Scenario {
    Scenario {
        name: "sep-storm".into(),
        summary: "ESPERTA under a SEP storm: 20000x burst saturates every \
                  target, the ingress queue sheds load, the alert deadline \
                  binds until the storm subsides"
            .into(),
        config: PipelineConfig {
            use_case: UseCase::Esperta,
            n_events: 6100,
            cadence_s: 0.1,
            max_wait_s: 0.05,
            policy: Policy::Deadline,
            ingress_cap: Some(64),
            ingress_max_backlog_s: 0.01,
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: 120.0 },
        phases: vec![
            Phase::new("quiet-sun", 50, vec![]),
            // the 5 ms storm deadline sits below the 10 ms ingress gate
            // on purpose: admitted work rides a ~10 ms backlog, so the
            // report shows both pathologies — deadline misses on what
            // runs, decimation on what does not
            Phase::new(
                "storm",
                6000,
                vec![MissionEvent::SepStorm { burst_x: 20_000.0, deadline_s: 0.005 }],
            ),
            Phase::new("recovery", 50, vec![MissionEvent::StormSubsides]),
        ],
    }
}

/// MMS selective downlink on the LogisticNet slot: a tight pass budget
/// drains mid-survey and routine region labels shed until a
/// ground-station pass grants fresh bytes.
fn onboard_downlink() -> Scenario {
    Scenario {
        name: "onboard-downlink".into(),
        summary: "MMS selective downlink: the 2 KiB pass budget drains and \
                  routine labels shed until a ground-station pass grants \
                  16 KiB more"
            .into(),
        config: PipelineConfig {
            use_case: UseCase::Mms,
            mms_model: "logistic".into(),
            n_events: 320,
            cadence_s: 0.15,
            downlink_budget: 2048,
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: 120.0 },
        phases: vec![
            Phase::new("survey", 160, vec![]),
            Phase::new(
                "ground-pass",
                100,
                vec![MissionEvent::DownlinkPass { budget_bytes: 16 * 1024 }],
            ),
            Phase::new("late-orbit", 60, vec![]),
        ],
    }
}

/// ESPERTA monitoring through an SEU strike on its HLS IP: the paper's
/// static deployment matrix re-dispatches to the A53 until the
/// scrubber's reconfiguration window restores the target.
fn sep_alert() -> Scenario {
    Scenario {
        name: "sep-alert".into(),
        summary: "ESPERTA monitoring: an SEU knocks out the HLS IP, alerts \
                  re-dispatch to the A53, scrubbing restores the slot mid-phase"
            .into(),
        config: PipelineConfig {
            use_case: UseCase::Esperta,
            n_events: 300,
            cadence_s: 0.1,
            ..Default::default()
        },
        // monitoring ends at t = 10 s; a 12 s scrub cycle repairs the
        // strike at 12 s + t_config, mid-way through the upset phase
        scrub: ScrubPolicy { period_s: 12.0 },
        phases: vec![
            Phase::new("monitoring", 100, vec![]),
            Phase::new(
                "post-upset",
                150,
                vec![MissionEvent::SeuUpset { target: "hls".into() }],
            ),
            Phase::new("scrubbed", 50, vec![]),
        ],
    }
}

/// VAE latent compression run energy-optimally: the 2 W eclipse budget
/// forces the 1.5 W HLS IP off the DPU, and an egress downlink pass
/// replenishes the latent budget.
fn solar_compress() -> Scenario {
    Scenario {
        name: "solar-compress".into(),
        summary: "VAE latent compression: min-energy on the DPU, a 2 W \
                  eclipse forces the 1.5 W HLS IP, an egress pass grants \
                  32 KiB of downlink"
            .into(),
        config: PipelineConfig {
            use_case: UseCase::Vae,
            n_events: 260,
            cadence_s: 0.05,
            policy: Policy::MinEnergy,
            downlink_budget: 4096,
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: 120.0 },
        phases: vec![
            Phase::new("imaging", 100, vec![]),
            Phase::new(
                "eclipse",
                100,
                vec![MissionEvent::EnterEclipse { budget_w: 2.0 }],
            ),
            Phase::new(
                "egress",
                60,
                vec![
                    MissionEvent::ExitEclipse,
                    MissionEvent::DownlinkPass { budget_bytes: 32 * 1024 },
                ],
            ),
        ],
    }
}

/// ESPERTA through a full SEP campaign with the fault layer armed: a
/// seeded injector at storm-elevated rates, TMR voting, quarantine on a
/// three-fault streak, plus scripted brownout / throttle / SEU /
/// dropout events at phase boundaries.  The end-to-end exercise of the
/// fault vocabulary and every recovery mechanism — deterministic, so
/// the same build replays the same campaign bit for bit.
fn sep_campaign() -> Scenario {
    Scenario {
        name: "sep-campaign".into(),
        summary: "ESPERTA fault campaign: seeded injector at storm rates, \
                  TMR voting and quarantine armed, scripted brownout, \
                  throttle, SEU strike, and downlink dropout"
            .into(),
        config: PipelineConfig {
            use_case: UseCase::Esperta,
            n_events: 420,
            cadence_s: 0.1,
            policy: Policy::MinLatency,
            fault_seed: Some(41),
            fault_profile: FaultProfile {
                exec_fail_p: 0.08,
                timeout_p: 0.04,
                seu_corrupt_p: 0.08,
                ..Default::default()
            },
            recovery: RecoveryPolicy {
                tmr: true,
                quarantine_threshold: 3,
                ..Default::default()
            },
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: 12.0 },
        phases: vec![
            Phase::new("quiet-sun", 100, vec![]),
            Phase::new(
                "storm-onset",
                120,
                vec![
                    MissionEvent::Brownout { budget_w: 2.5, duration_s: 4.0 },
                    MissionEvent::ThermalThrottle {
                        target: "hls".into(),
                        derate_x: 2.0,
                        duration_s: 5.0,
                    },
                ],
            ),
            Phase::new(
                "peak-flux",
                120,
                vec![
                    MissionEvent::SeuUpset { target: "hls".into() },
                    MissionEvent::LinkDropout { duration_s: 6.0 },
                ],
            ),
            Phase::new("recovery", 80, vec![]),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_is_constructible_and_consistent() {
        let names = builtin_names();
        assert_eq!(names.len(), 6, "five former examples + the fault campaign");
        for sc in all_builtins() {
            assert!(names.contains(&sc.name.as_str()));
            assert!(!sc.phases.is_empty());
            assert!(sc.total_events() > 0);
            assert_eq!(
                sc.config.n_events,
                sc.total_events(),
                "{}: config.n_events documents the phase total",
                sc.name
            );
            assert!(sc.scrub.period_s > 0.0);
            assert!(!sc.summary.is_empty());
        }
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        let err = builtin("warp-speed").unwrap_err().to_string();
        assert!(err.contains("eclipse-ops"), "error lists known names: {err}");
    }

    #[test]
    fn lookup_matches_listing_order() {
        for name in builtin_names() {
            assert_eq!(builtin(name).unwrap().name, name);
        }
    }
}
