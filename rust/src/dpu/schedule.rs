//! Per-layer DPU scheduling / cycle model.
//!
//! For each manifest layer the scheduler computes MAC-array cycles with
//! dimension padding (PP/ICP/OCP), weight-stream cycles from the on-chip
//! store, and misc-engine cycles for pooling; the layer takes the max
//! (the engines overlap).  A fixed runner-invocation overhead plus a
//! per-layer instruction-dispatch cost models the PYNQ/VART submit path
//! the paper measured through.

use anyhow::{bail, Result};

use super::arch::DpuArch;
use crate::board::Calibration;
use crate::model::{Layer, LayerKind, Manifest};

/// Timing breakdown for one layer.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    /// Layer kind the timing was derived for.
    pub kind: LayerKind,
    /// MAC-array cycles (dimension-padded).
    pub mac_cycles: u64,
    /// Weight-stream cycles from BRAM/URAM.
    pub weight_cycles: u64,
    /// Misc-engine cycles (pool / elementwise).
    pub misc_cycles: u64,
    /// Feature-map DDR streaming cycles (in + out, int8).
    pub act_cycles: u64,
    /// Effective cycles = max(engines) + activation streaming.
    pub cycles: u64,
    /// Useful MACs (un-padded) — for utilization reporting.
    pub useful_macs: u64,
}

/// A scheduled model: per-layer timings + per-inference overheads.
#[derive(Debug, Clone)]
pub struct DpuSchedule {
    /// Scheduled model name.
    pub model: String,
    /// Per-layer timing breakdown, manifest order.
    pub layers: Vec<LayerTiming>,
    /// Architecture the schedule targets.
    pub arch: DpuArch,
    /// Fixed runner overhead (s).
    pub invoke_s: f64,
    /// Per-layer instruction overhead (s).
    pub layer_s: f64,
    /// Input DMA time (s).
    pub input_dma_s: f64,
}

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

impl DpuSchedule {
    /// Schedule a manifest onto the DPU.  Errors if any layer uses an
    /// operator outside the DPU's set (the paper's Vitis-AI inspector
    /// gate, §III-B.1).
    pub fn new(
        man: &Manifest,
        arch: DpuArch,
        calib: &Calibration,
        axi_bandwidth: f64,
    ) -> Result<DpuSchedule> {
        if !man.dpu_compatible() {
            bail!(
                "model {:?} uses operators unsupported by the DPU \
                 (sigmoid / comparator / 3-D layers) — paper routes such \
                 models to HLS",
                man.name
            );
        }
        let mut layers = Vec::with_capacity(man.layers.len());
        for l in &man.layers {
            layers.push(Self::schedule_layer(l, &arch)?);
        }
        Ok(DpuSchedule {
            model: man.name.clone(),
            layers,
            arch,
            invoke_s: calib.dpu_invoke_s,
            layer_s: calib.dpu_layer_s,
            input_dma_s: man.input_bytes() as f64 / axi_bandwidth,
        })
    }

    fn schedule_layer(l: &Layer, arch: &DpuArch) -> Result<LayerTiming> {
        let in_elems: u64 = l.in_shape.iter().skip(1).product::<usize>() as u64;
        let mut t = LayerTiming {
            kind: l.kind,
            mac_cycles: 0,
            weight_cycles: 0,
            misc_cycles: 0,
            // int8 feature maps stream through DDR (they exceed the
            // on-chip store for the big CNNs): 1 byte per element
            act_cycles: ((in_elems + l.out_elems()) as f64
                / arch.ddr_bytes_per_cycle)
                .ceil() as u64,
            cycles: 0,
            useful_macs: l.macs,
        };
        match l.kind {
            LayerKind::Conv2d => {
                let cin = *l.in_shape.last().unwrap() as u64;
                let cout = *l.out_shape.last().unwrap() as u64;
                let out_px: u64 =
                    l.out_shape[1..l.out_shape.len() - 1].iter().product::<usize>() as u64;
                let kvol = l.params / cout - 1; // k*k*cin
                let kspatial = kvol / cin;
                t.mac_cycles = ceil_div(out_px, arch.pp)
                    * kspatial
                    * ceil_div(cin, arch.icp)
                    * ceil_div(cout, arch.ocp);
                // int8 weights streamed ICP*OCP bytes/cycle
                t.weight_cycles = ceil_div(l.weight_bytes, arch.icp * arch.ocp);
            }
            LayerKind::Dense | LayerKind::DenseHeads => {
                let din = l.in_shape[1] as u64;
                let dout = l.out_shape[1] as u64;
                // dense = 1x1 conv on a single output pixel
                t.mac_cycles = ceil_div(din, arch.icp) * ceil_div(dout, arch.ocp);
                t.weight_cycles = ceil_div(l.weight_bytes, arch.icp * arch.ocp);
            }
            LayerKind::MaxPool2d | LayerKind::Flatten | LayerKind::ConcatScalar => {
                t.misc_cycles =
                    (l.out_elems() as f64 / arch.misc_elems_per_cycle).ceil() as u64;
            }
            other => bail!("DPU cannot schedule {other:?}"),
        }
        t.cycles = t.mac_cycles.max(t.weight_cycles).max(t.misc_cycles)
            + t.act_cycles;
        Ok(t)
    }

    /// Array cycles for the whole model.
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    /// Per-inference latency (s), excluding input DMA (the paper excludes
    /// input staging from inference time, §IV / Fig 11 discussion).
    pub fn latency_s(&self) -> f64 {
        self.total_cycles() as f64 / self.arch.clock_hz
            + self.invoke_s
            + self.layers.len() as f64 * self.layer_s
    }

    /// Latency including input DMA (what the power trace shows).
    pub fn latency_with_dma_s(&self) -> f64 {
        self.latency_s() + self.input_dma_s
    }

    /// Inferences per second (input DMA excluded, like the paper).
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s()
    }

    /// MAC-array duty cycle during an inference — drives dynamic power.
    pub fn mac_duty(&self) -> f64 {
        let mac: u64 = self.layers.iter().map(|l| l.mac_cycles).sum();
        let wall = self.latency_s() * self.arch.clock_hz;
        (mac as f64 / wall).clamp(0.0, 1.0)
    }

    /// Achieved / peak MAC utilization (useful MACs over array capacity).
    pub fn mac_utilization(&self) -> f64 {
        let useful: u64 = self.layers.iter().map(|l| l.useful_macs).sum();
        let capacity =
            self.latency_s() * self.arch.clock_hz * self.arch.macs_per_cycle() as f64;
        useful as f64 / capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use crate::util::json::Json;

    fn conv_manifest(cin: usize, cout: usize, px: usize) -> Manifest {
        let kvol = 9 * cin;
        let params = cout * (kvol + 1);
        let macs = (px * kvol * cout) as u64;
        let side = (px as f64).sqrt() as usize;
        let src = format!(
            r#"{{"name":"c","precision":"int8",
              "inputs":{{"x":[1,{side},{side},{cin}]}},"input_order":["x"],
              "output_shape":[1,{side},{side},{cout}],
              "layers":[{{"kind":"conv2d",
                "in_shape":[1,{side},{side},{cin}],
                "out_shape":[1,{side},{side},{cout}],
                "macs":{macs},"ops":{ops},"params":{params},
                "weight_bytes":{params},"act_bytes":4,"act":"relu"}}],
              "total_macs":{macs},"total_ops":{ops},
              "total_params":{params},"weight_bytes":{params}}}"#,
            ops = 2 * macs + 2 * (px * cout) as u64,
        );
        Manifest::from_json(&Json::parse(&src).unwrap()).unwrap()
    }

    fn sched(man: &Manifest) -> DpuSchedule {
        let c = Calibration::default();
        DpuSchedule::new(man, DpuArch::b4096(&c, 300e6), &c, 2e9).unwrap()
    }

    #[test]
    fn aligned_conv_is_fully_utilized() {
        // 16-ch in, 16-ch out, 64 px: no padding waste
        let man = conv_manifest(16, 16, 64);
        let s = sched(&man);
        let t = &s.layers[0];
        // cycles = 64/8 * 9 * 1 * 1 = 72
        assert_eq!(t.mac_cycles, 72);
        assert_eq!(t.useful_macs, 64 * 9 * 16 * 16);
        // useful macs == padded macs
        assert_eq!(t.useful_macs, t.mac_cycles * 2048);
    }

    #[test]
    fn narrow_input_wastes_icp() {
        // 3-ch input (VAE conv1 situation): ICP padded 3 -> 16
        let man = conv_manifest(3, 16, 64);
        let s = sched(&man);
        let t = &s.layers[0];
        let padded = t.mac_cycles * 2048;
        assert!(t.useful_macs * 5 < padded, "padding waste must exceed 5x");
    }

    #[test]
    fn rejects_3d_models() {
        let src = r#"{"name":"m3","precision":"fp32",
          "inputs":{"x":[1,4,4,4,1]},"input_order":["x"],
          "output_shape":[1,4,4,4,2],
          "layers":[{"kind":"conv3d","in_shape":[1,4,4,4,1],
            "out_shape":[1,4,4,4,2],"macs":3456,"ops":7040,"params":56,
            "weight_bytes":224,"act_bytes":512,"act":"none"}],
          "total_macs":3456,"total_ops":7040,"total_params":56,
          "weight_bytes":224}"#;
        let man = Manifest::from_json(&Json::parse(src).unwrap()).unwrap();
        let c = Calibration::default();
        assert!(DpuSchedule::new(&man, DpuArch::b4096(&c, 300e6), &c, 2e9).is_err());
    }

    #[test]
    fn latency_includes_invoke_overhead() {
        let man = conv_manifest(16, 16, 64);
        let s = sched(&man);
        // 72 cycles @300MHz = 0.24us; invoke (1ms) dominates
        assert!(s.latency_s() > 1.0e-3);
        assert!(s.latency_s() < 1.2e-3);
    }

    #[test]
    fn duty_and_utilization_bounded() {
        let man = conv_manifest(32, 64, 4096);
        let s = sched(&man);
        assert!(s.mac_duty() > 0.0 && s.mac_duty() <= 1.0);
        assert!(s.mac_utilization() > 0.0 && s.mac_utilization() <= 1.0);
    }
}
