//! DPUCZDX8G B4096 architecture description (paper §II-B.1, PG338).
//!
//! The B4096 configuration executes 4096 INT8 ops (2048 MACs) per MAC-array
//! clock, organized as *pixel parallelism × input-channel parallelism ×
//! output-channel parallelism* = 8 × 16 × 16.  Work that does not fill a
//! dimension is padded to it — the mechanism behind the paper's
//! observation that CNetPlusScalar (wide channels) speeds up more than the
//! VAE encoder (3-channel input layer wastes 13/16 of ICP).

use crate::board::Calibration;
use crate::board::zcu104::PlResources;

/// DPUCZDX8G convolution-architecture sizes (PG338 Table 5): peak INT8
/// ops per cycle = 2 × PP × ICP × OCP.  The paper instantiates B4096;
/// the smaller members trade throughput for power and CRAM footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DpuSize {
    /// 4×8×8 — 256 MACs/cycle.
    B512,
    /// 8×8×8 — 512 MACs/cycle.
    B1024,
    /// 8×12×12 — 1152 MACs/cycle.
    B2304,
    /// 8×16×16 — 2048 MACs/cycle (the paper's configuration).
    B4096,
}

impl DpuSize {
    /// All sizes, ascending — registry order for the DPU family.
    pub const ALL: [DpuSize; 4] =
        [DpuSize::B512, DpuSize::B1024, DpuSize::B2304, DpuSize::B4096];

    /// (pixel, input-channel, output-channel) parallelism.
    pub fn dims(&self) -> (u64, u64, u64) {
        match self {
            DpuSize::B512 => (4, 8, 8),
            DpuSize::B1024 => (8, 8, 8),
            DpuSize::B2304 => (8, 12, 12),
            DpuSize::B4096 => (8, 16, 16),
        }
    }

    /// MAC-array capacity relative to the B4096 anchor (1.0 for B4096).
    pub fn frac(&self) -> f64 {
        let (pp, icp, ocp) = self.dims();
        (pp * icp * ocp) as f64 / 2048.0
    }

    /// Registry / telemetry name.  B4096 keeps the seed era's bare
    /// `dpu` so `target_mix` keys stay stable for the default set.
    pub fn target_name(&self) -> &'static str {
        match self {
            DpuSize::B512 => "dpu-b512",
            DpuSize::B1024 => "dpu-b1024",
            DpuSize::B2304 => "dpu-b2304",
            DpuSize::B4096 => "dpu",
        }
    }
}

/// Fixed architectural description of the instantiated DPU IP.
#[derive(Debug, Clone, Copy)]
pub struct DpuArch {
    /// Pixel parallelism (output pixels per cycle).
    pub pp: u64,
    /// Input-channel parallelism.
    pub icp: u64,
    /// Output-channel parallelism.
    pub ocp: u64,
    /// MAC-array clock (Hz).
    pub clock_hz: f64,
    /// Misc-engine throughput (elements/cycle) for pool / elementwise.
    pub misc_elems_per_cycle: f64,
    /// Feature-map DDR streaming bandwidth (bytes/cycle).
    pub ddr_bytes_per_cycle: f64,
    /// On-chip weight/activation store (bytes) — BRAM + URAM of the IP.
    pub onchip_bytes: u64,
}

impl DpuArch {
    /// The B4096 configuration the paper instantiates (8×16×16).
    pub fn b4096(calib: &Calibration, clock_hz: f64) -> DpuArch {
        DpuArch {
            pp: calib.dpu_pp,
            icp: calib.dpu_icp,
            ocp: calib.dpu_ocp,
            clock_hz,
            misc_elems_per_cycle: calib.dpu_misc_elems_per_cycle,
            ddr_bytes_per_cycle: calib.dpu_ddr_bytes_per_cycle,
            // 165 BRAM36 + 92 URAM (Table II) ~= 3.92 MB
            onchip_bytes: 165 * 4608 + 92 * 36_864,
        }
    }

    /// Any family member: B4096 is the calibrated anchor (identical to
    /// [`DpuArch::b4096`]); smaller sizes use the PG338 canonical
    /// parallelism with the misc engine narrowed in proportion to OCP
    /// and the on-chip store scaled with array capacity.  The DDR
    /// streaming bandwidth is a board property and stays fixed.
    pub fn of_size(size: DpuSize, calib: &Calibration, clock_hz: f64) -> DpuArch {
        if size == DpuSize::B4096 {
            return DpuArch::b4096(calib, clock_hz);
        }
        let (pp, icp, ocp) = size.dims();
        let frac = size.frac();
        DpuArch {
            pp,
            icp,
            ocp,
            clock_hz,
            misc_elems_per_cycle: calib.dpu_misc_elems_per_cycle
                * (ocp as f64 / calib.dpu_ocp as f64),
            ddr_bytes_per_cycle: calib.dpu_ddr_bytes_per_cycle,
            onchip_bytes: ((165.0 * frac).round() as u64) * 4608
                + ((92.0 * frac).round() as u64) * 36_864,
        }
    }

    /// MACs retired per cycle when every dimension is filled.
    pub fn macs_per_cycle(&self) -> u64 {
        self.pp * self.icp * self.ocp
    }

    /// Table II row for B4096 (the IP's measured footprint), scaled
    /// down for smaller family members: the MAC array, weight store,
    /// and load/save engines shrink with capacity while the scheduler,
    /// instruction fetch, and AXI shell are a fixed floor (the split is
    /// anchored so the B4096 numbers reproduce Table II exactly).
    pub fn resources(&self) -> PlResources {
        let frac = self.macs_per_cycle() as f64 / 2048.0;
        if frac >= 1.0 {
            return PlResources {
                luts: 102_154,
                ffs: 199_192,
                dsps: 1_420,
                brams: 165.0,
                urams: 92,
            };
        }
        PlResources {
            luts: 30_000 + (72_154.0 * frac).round() as u64,
            ffs: 40_000 + (159_192.0 * frac).round() as u64,
            dsps: 100 + (1_320.0 * frac).round() as u64,
            brams: 25.0 + 140.0 * frac,
            urams: (92.0 * frac).round() as u64,
        }
    }

    /// Peak INT8 TOPS (2 ops per MAC).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.macs_per_cycle() as f64 * self.clock_hz / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b4096_peak() {
        let a = DpuArch::b4096(&Calibration::default(), 300e6);
        assert_eq!(a.macs_per_cycle(), 2048);
        // ~1.23 TOPS INT8 at 300 MHz — the commonly quoted B4096 figure
        assert!((a.peak_tops() - 1.2288).abs() < 1e-6);
    }

    #[test]
    fn onchip_store_about_3_92_mb() {
        let a = DpuArch::b4096(&Calibration::default(), 300e6);
        let mb = a.onchip_bytes as f64 / (1024.0 * 1024.0);
        assert!((mb - 3.92).abs() < 0.1, "{mb}");
    }

    #[test]
    fn family_of_size_b4096_is_the_anchor() {
        let c = Calibration::default();
        let anchor = DpuArch::b4096(&c, 300e6);
        let via = DpuArch::of_size(DpuSize::B4096, &c, 300e6);
        assert_eq!(via.pp, anchor.pp);
        assert_eq!(via.onchip_bytes, anchor.onchip_bytes);
        assert_eq!(via.resources(), anchor.resources());
        // Table II exactly
        let r = anchor.resources();
        assert_eq!((r.luts, r.ffs, r.dsps), (102_154, 199_192, 1_420));
        assert_eq!(r.brams, 165.0);
        assert_eq!(r.urams, 92);
    }

    #[test]
    fn family_scales_monotonically() {
        let c = Calibration::default();
        let archs: Vec<DpuArch> = DpuSize::ALL
            .iter()
            .map(|&s| DpuArch::of_size(s, &c, 300e6))
            .collect();
        for pair in archs.windows(2) {
            assert!(pair[0].macs_per_cycle() < pair[1].macs_per_cycle());
            assert!(pair[0].peak_tops() < pair[1].peak_tops());
            assert!(pair[0].onchip_bytes < pair[1].onchip_bytes);
            let (a, b) = (pair[0].resources(), pair[1].resources());
            assert!(a.luts < b.luts && a.dsps < b.dsps && a.brams < b.brams);
        }
        // PG338 peak-ops naming: macs/cycle * 2 == the size's number
        assert_eq!(archs[0].macs_per_cycle(), 256);
        assert_eq!(archs[1].macs_per_cycle(), 512);
        assert_eq!(archs[2].macs_per_cycle(), 1152);
        assert_eq!(archs[3].macs_per_cycle(), 2048);
    }

    #[test]
    fn frac_is_relative_capacity() {
        assert_eq!(DpuSize::B512.frac(), 0.125);
        assert_eq!(DpuSize::B1024.frac(), 0.25);
        assert_eq!(DpuSize::B2304.frac(), 0.5625);
        assert_eq!(DpuSize::B4096.frac(), 1.0);
        assert_eq!(DpuSize::B4096.target_name(), "dpu");
        assert_eq!(DpuSize::B512.target_name(), "dpu-b512");
    }
}
