//! DPUCZDX8G B4096 architecture description (paper §II-B.1, PG338).
//!
//! The B4096 configuration executes 4096 INT8 ops (2048 MACs) per MAC-array
//! clock, organized as *pixel parallelism × input-channel parallelism ×
//! output-channel parallelism* = 8 × 16 × 16.  Work that does not fill a
//! dimension is padded to it — the mechanism behind the paper's
//! observation that CNetPlusScalar (wide channels) speeds up more than the
//! VAE encoder (3-channel input layer wastes 13/16 of ICP).

use crate::board::Calibration;
use crate::board::zcu104::PlResources;

/// Fixed architectural description of the instantiated DPU IP.
#[derive(Debug, Clone, Copy)]
pub struct DpuArch {
    /// Pixel parallelism (output pixels per cycle).
    pub pp: u64,
    /// Input-channel parallelism.
    pub icp: u64,
    /// Output-channel parallelism.
    pub ocp: u64,
    /// MAC-array clock (Hz).
    pub clock_hz: f64,
    /// Misc-engine throughput (elements/cycle) for pool / elementwise.
    pub misc_elems_per_cycle: f64,
    /// Feature-map DDR streaming bandwidth (bytes/cycle).
    pub ddr_bytes_per_cycle: f64,
    /// On-chip weight/activation store (bytes) — BRAM + URAM of the IP.
    pub onchip_bytes: u64,
}

impl DpuArch {
    /// The B4096 configuration the paper instantiates (8×16×16).
    pub fn b4096(calib: &Calibration, clock_hz: f64) -> DpuArch {
        DpuArch {
            pp: calib.dpu_pp,
            icp: calib.dpu_icp,
            ocp: calib.dpu_ocp,
            clock_hz,
            misc_elems_per_cycle: calib.dpu_misc_elems_per_cycle,
            ddr_bytes_per_cycle: calib.dpu_ddr_bytes_per_cycle,
            // 165 BRAM36 + 92 URAM (Table II) ~= 3.92 MB
            onchip_bytes: 165 * 4608 + 92 * 36_864,
        }
    }

    /// MACs retired per cycle when every dimension is filled.
    pub fn macs_per_cycle(&self) -> u64 {
        self.pp * self.icp * self.ocp
    }

    /// Table II row: the B4096 IP's PL footprint (fixed property of the
    /// IP configuration, from the paper's implementation).
    pub fn resources(&self) -> PlResources {
        PlResources {
            luts: 102_154,
            ffs: 199_192,
            dsps: 1_420,
            brams: 165.0,
            urams: 92,
        }
    }

    /// Peak INT8 TOPS (2 ops per MAC).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.macs_per_cycle() as f64 * self.clock_hz / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b4096_peak() {
        let a = DpuArch::b4096(&Calibration::default(), 300e6);
        assert_eq!(a.macs_per_cycle(), 2048);
        // ~1.23 TOPS INT8 at 300 MHz — the commonly quoted B4096 figure
        assert!((a.peak_tops() - 1.2288).abs() < 1e-6);
    }

    #[test]
    fn onchip_store_about_3_92_mb() {
        let a = DpuArch::b4096(&Calibration::default(), 300e6);
        let mb = a.onchip_bytes as f64 / (1024.0 * 1024.0);
        assert!((mb - 3.92).abs() < 0.1, "{mb}");
    }
}
