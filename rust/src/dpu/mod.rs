//! Vitis-AI DPUCZDX8G simulator — the paper's high-throughput path,
//! generalized to the PG338 size family (B512–B4096) for the backend
//! registry.

pub mod arch;
pub mod isa;
pub mod schedule;

pub use arch::{DpuArch, DpuSize};
pub use isa::{DpuInstr, DpuProgram};
pub use schedule::{DpuSchedule, LayerTiming};
