//! Vitis-AI DPUCZDX8G B4096 simulator (the paper's high-throughput path).

pub mod arch;
pub mod isa;
pub mod schedule;

pub use arch::DpuArch;
pub use isa::{DpuInstr, DpuProgram};
pub use schedule::{DpuSchedule, LayerTiming};
