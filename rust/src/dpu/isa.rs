//! DPU instruction-stream generation.
//!
//! The real Vitis-AI flow compiles an `.xmodel` into DPU instructions
//! (LOAD / CONV / POOL / ELEW / SAVE) that the IP fetches over AXI.  The
//! coordinator uses this program form for two things: the per-layer
//! instruction-dispatch overhead in the timing model, and the `inspect`
//! subcommand's human-readable program dump (the analogue of
//! `xdputil xmodel -l`).

use anyhow::Result;

use super::arch::DpuArch;
use super::schedule::DpuSchedule;
use crate::model::{LayerKind, Manifest};

/// One DPU instruction (coarse, layer-granular like the real compiler's
/// superinstructions).
#[derive(Debug, Clone, PartialEq)]
pub enum DpuInstr {
    /// Stage input feature map: bytes.
    Load { bytes: u64 },
    /// Convolution layer: output channels, kernel volume, cycles.
    Conv { cout: u64, kvol: u64, cycles: u64 },
    /// Fully-connected layer (1x1 conv path).
    Fc { din: u64, dout: u64, cycles: u64 },
    /// Misc engine: pooling / reshape.
    Misc { kind: &'static str, cycles: u64 },
    /// Write output back: bytes.
    Save { bytes: u64 },
}

/// A compiled DPU program.
#[derive(Debug, Clone)]
pub struct DpuProgram {
    /// Model the program was compiled from.
    pub model: String,
    /// Layer-granular instruction stream (load, per-layer ops, save).
    pub instrs: Vec<DpuInstr>,
}

impl DpuProgram {
    /// Compile a manifest + schedule into the instruction stream.
    pub fn compile(man: &Manifest, sched: &DpuSchedule) -> Result<DpuProgram> {
        let mut instrs = vec![DpuInstr::Load { bytes: man.input_bytes() }];
        for (l, t) in man.layers.iter().zip(&sched.layers) {
            let instr = match l.kind {
                LayerKind::Conv2d => {
                    let cout = *l.out_shape.last().unwrap() as u64;
                    DpuInstr::Conv { cout, kvol: l.params / cout - 1, cycles: t.cycles }
                }
                LayerKind::Dense | LayerKind::DenseHeads => DpuInstr::Fc {
                    din: l.in_shape[1] as u64,
                    dout: l.out_shape[1] as u64,
                    cycles: t.cycles,
                },
                LayerKind::MaxPool2d => DpuInstr::Misc { kind: "maxpool", cycles: t.cycles },
                LayerKind::Flatten => DpuInstr::Misc { kind: "reshape", cycles: t.cycles },
                LayerKind::ConcatScalar => DpuInstr::Misc { kind: "concat", cycles: t.cycles },
                other => anyhow::bail!("DPU ISA has no encoding for {other:?}"),
            };
            instrs.push(instr);
        }
        instrs.push(DpuInstr::Save { bytes: man.output_elems() * 4 });
        Ok(DpuProgram { model: man.name.clone(), instrs })
    }

    /// Pretty listing (for `spaceinfer inspect`).
    pub fn listing(&self) -> String {
        let mut out = format!("DPU program for {:?}:\n", self.model);
        for (i, ins) in self.instrs.iter().enumerate() {
            out.push_str(&format!("  {i:3}: {ins:?}\n"));
        }
        out
    }

    /// Total compute cycles in the stream.
    pub fn cycles(&self) -> u64 {
        self.instrs
            .iter()
            .map(|i| match i {
                DpuInstr::Conv { cycles, .. }
                | DpuInstr::Fc { cycles, .. }
                | DpuInstr::Misc { cycles, .. } => *cycles,
                _ => 0,
            })
            .sum()
    }
}

/// Check a manifest fits the DPU's on-chip weight store (the paper notes
/// both DPU models "fit on chip" — this is the gate that verified it).
pub fn weights_fit_onchip(man: &Manifest, arch: &DpuArch) -> bool {
    man.weight_bytes <= arch.onchip_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::board::Calibration;
    use crate::util::json::Json;

    fn mini() -> Manifest {
        Manifest::from_json(
            &Json::parse(crate::model::manifest::testdata::MINI).unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn program_shape() {
        let c = Calibration::default();
        let man = mini();
        let arch = DpuArch::b4096(&c, 300e6);
        let sched = DpuSchedule::new(&man, arch, &c, 2e9).unwrap();
        let prog = DpuProgram::compile(&man, &sched).unwrap();
        // load + 3 layers + save
        assert_eq!(prog.instrs.len(), 5);
        assert!(matches!(prog.instrs[0], DpuInstr::Load { .. }));
        assert!(matches!(prog.instrs[4], DpuInstr::Save { .. }));
        assert_eq!(prog.cycles(), sched.total_cycles());
        assert!(prog.listing().contains("Conv"));
    }

    #[test]
    fn onchip_gate() {
        let c = Calibration::default();
        let arch = DpuArch::b4096(&c, 300e6);
        let man = mini();
        assert!(weights_fit_onchip(&man, &arch));
    }
}
