//! `spaceinfer` — reproduction of *"Evaluating Four FPGA-accelerated Space
//! Use Cases based on Neural Network Algorithms for On-board Inference"*
//! (Antunes et al., MCSoC 2025).
//!
//! Layer 3 of the rust + JAX + Pallas stack: the on-board inference
//! coordinator, the simulated ZCU104 testbed (ARM A53 / Vitis-AI DPU /
//! Vitis-HLS custom IP), the power and resource models, and the report
//! harness that regenerates every table and figure of the paper's
//! evaluation section.  Numerics run for real (AOT-lowered HLO on the PJRT
//! CPU client); latency and power come from the calibrated analytic
//! simulators — see DESIGN.md §2 for the substitution table.
//!
//! Execution targets live behind the [`backend`] layer: an
//! [`backend::AccelModel`] trait + [`backend::TargetRegistry`] that the
//! coordinator dispatches over by index — the paper's A53 / B4096 DPU /
//! naive-HLS triple is just the default registry, with the full DPU
//! size family and a pipelined-HLS variant behind `--targets all`.
//! Operator support is *per layer* ([`backend::AccelModel::supports_layer`]),
//! and the [`plan`] layer partitions operator-incompatible models into
//! hybrid execution plans (DPU subgraphs + fallback segments, the
//! paper's Vitis-AI graph-splitting behavior) that the dispatcher
//! scores alongside whole-model deployments (`spaceinfer plan`,
//! `pipeline --plan`).
//!
//! Mission conditions change *inside* a run: the pipeline is a
//! steppable state machine ([`coordinator::Pipeline::begin`] /
//! [`coordinator::PipelineRun::tick`]) whose policy, power budget,
//! deadline, cadence, and per-target availability are mutable between
//! ticks, and the [`scenario`] layer drives it from declarative mission
//! timelines (`spaceinfer scenario <name>`), producing phase-segmented
//! reports.
//!
//! The [`fleet`] layer scales one scenario to a constellation:
//! `spaceinfer fleet` shards N spacecraft (stream-split seeds, one
//! [`coordinator::OwnedPipelineRun`] each) across a zero-dependency
//! work-stealing pool, arbitrates shared ground-station passes
//! deterministically at epoch barriers, and rolls per-craft reports
//! into a [`fleet::FleetReport`] that is bit-identical at any thread
//! count.
//!
//! The [`serve`] layer turns the closed loop into a request-driven
//! service: `spaceinfer serve` is a zero-dependency HTTP/JSON
//! front-end (std::net + a compute-worker pool) with per-tenant
//! bounded admission queues and continuous cross-tenant batching —
//! concurrent tenants' requests join the next flush in flight, while
//! each response's `result` payload stays bit-identical to running
//! the same request solo through the pipeline.
//!
//! Faults are first-class: the [`fault`] layer injects a seeded,
//! deterministic fault vocabulary (transient execution failures,
//! timeouts, SEU corruption scaled by essential bits, thermal
//! throttling, brownout, downlink dropout) through dispatch, and a
//! [`fault::RecoveryPolicy`] answers with bounded retries, escalation,
//! quarantine-and-scrub, TMR voting, and degraded-mode dispatch
//! (`pipeline --faults <seed>`, `spaceinfer fuzz`).
//!
//! Start with `docs/ARCHITECTURE.md` for the module map, the
//! batch-native dispatch lifecycle, and the cost-model dispatch flow.

#![warn(missing_docs)]

pub mod util;
pub mod model;
pub mod board;
pub mod cpu;
pub mod dpu;
pub mod hls;
pub mod power;
pub mod rad;
pub mod resources;
pub mod backend;
pub mod fault;
pub mod plan;
pub mod runtime;
pub mod sensors;
pub mod telemetry;
pub mod coordinator;
pub mod scenario;
pub mod fleet;
pub mod report;
pub mod serve;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
