//! Threaded executor: a dedicated worker thread owns the PJRT engine and
//! serves inference requests over channels (std::sync::mpsc — tokio is
//! not in the offline registry, and PJRT-CPU execution is internally
//! multi-threaded anyway, so one submission thread is the right shape:
//! it mirrors the single DPU runner the paper drives from PYNQ).

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::model::Precision;

use super::client::Engine;

/// A request to execute one model on one input set.
pub struct ExecRequest {
    pub model: String,
    pub precision: Precision,
    /// Flat f32 buffers, manifest input order.
    pub inputs: Vec<Vec<f32>>,
    /// Where to send the result.
    pub reply: mpsc::Sender<ExecResult>,
    /// Opaque request id (round-trips to the reply).
    pub id: u64,
}

/// The outcome of one execution.
pub struct ExecResult {
    pub id: u64,
    pub model: String,
    pub output: Result<Vec<f32>>,
    /// Host wall-clock spent inside PJRT execute (for coordinator
    /// telemetry; *not* the simulated ZCU104 latency).
    pub host_elapsed: Duration,
}

enum Msg {
    Exec(ExecRequest),
    Shutdown,
}

/// The executor pool (single worker owning the engine).
pub struct ExecutorPool {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn the worker. `preload` compiles the given (name, precision)
    /// variants up front so the request path never hits the compiler.
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        preload: Vec<(String, Precision)>,
    ) -> Result<ExecutorPool> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let engine = match Engine::new(&artifacts_dir) {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for (name, prec) in &preload {
                    if let Err(e) = engine.load(name, *prec) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Exec(req) => {
                            let t0 = Instant::now();
                            let output = engine
                                .load(&req.model, req.precision)
                                .and_then(|m| {
                                    let slices: Vec<&[f32]> =
                                        req.inputs.iter().map(|v| v.as_slice()).collect();
                                    m.run(&slices)
                                });
                            let _ = req.reply.send(ExecResult {
                                id: req.id,
                                model: req.model,
                                output,
                                host_elapsed: t0.elapsed(),
                            });
                        }
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor worker died during startup"))??;
        Ok(ExecutorPool { tx, handle: Some(handle) })
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: ExecRequest) -> Result<()> {
        self.tx
            .send(Msg::Exec(req))
            .map_err(|_| anyhow!("executor worker gone"))
    }

    /// Convenience: synchronous round trip.
    pub fn run_sync(
        &self,
        model: &str,
        precision: Precision,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.submit(ExecRequest {
            model: model.to_string(),
            precision,
            inputs,
            reply,
            id: 0,
        })?;
        let res = rx
            .recv()
            .map_err(|_| anyhow!("executor dropped the reply channel"))?;
        res.output
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
