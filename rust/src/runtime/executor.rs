//! Batch-native sharded executor pool.
//!
//! A `Batch` is the unit of execution end to end: the coordinator
//! submits one `ExecRequest` per flushed batch (input buffers are
//! `Arc`-shared — no per-event copies on the hot path) and reaps one
//! `ExecResult` per batch, so event generation, batching, and execution
//! overlap.  The pool runs N worker threads (std::sync::mpsc — tokio is
//! not in the offline registry) over one shared `Engine`, whose
//! read-mostly cache means cache hits never serialize on a lock.
//!
//! Requests shard by model tag (FNV-1a % workers): every batch of a
//! given variant lands on the same worker, keeping that variant's
//! dispatch strictly ordered — the semantics of the single DPU runner
//! the paper drives from PYNQ — while different variants execute
//! concurrently on their own workers.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::model::Precision;
use crate::util::hash::fnv1a;

use super::client::{Backend, Engine, InputSet};

/// A request to execute one model on a whole batch of input sets.
pub struct ExecRequest {
    /// Model name to execute.
    pub model: String,
    /// Variant precision (selects the artifact).
    pub precision: Precision,
    /// One entry per event, batch order; buffers `Arc`-shared with the
    /// producer (zero-copy request path).
    pub items: Vec<InputSet>,
    /// Where to send the result (the caller's reap channel).
    pub reply: mpsc::Sender<ExecResult>,
    /// Opaque batch id (round-trips to the reply).
    pub id: u64,
}

/// The outcome of one batch execution.
pub struct ExecResult {
    /// Batch id echoed from the request.
    pub id: u64,
    /// Model the batch ran.
    pub model: String,
    /// One flat f32 output per item, batch order; a batch fails as a
    /// unit (the coordinator never half-processes a batch).
    pub outputs: Result<Vec<Vec<f32>>>,
    /// The request's input sets, handed back so the producer can
    /// recycle the underlying frame buffers (the coordinator's frame
    /// pool); consumers that don't recycle just drop them.
    pub items: Vec<InputSet>,
    /// Host wall-clock for the whole batch inside the worker (for
    /// coordinator telemetry; *not* the simulated ZCU104 latency).
    pub host_elapsed: Duration,
    /// Index of the worker that executed the batch.
    pub worker: usize,
}

/// Typed batch-execution failures the pool surfaces through
/// [`ExecResult::outputs`].  The coordinator downcasts these to record
/// a survivable error in the run report instead of aborting a mission
/// run with healthy batches still in flight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecError {
    /// The worker thread panicked mid-batch (poisoned lock, FFI abort).
    WorkerPanic {
        /// Index of the worker that panicked.
        worker: usize,
        /// Model the batch was running.
        model: String,
    },
    /// The engine failed to load or execute the model.
    Engine {
        /// Model the batch was running.
        model: String,
        /// Underlying engine error, rendered with its cause chain.
        detail: String,
    },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::WorkerPanic { worker, model } => {
                write!(f, "executor worker {worker} panicked executing {model}")
            }
            ExecError::Engine { model, detail } => {
                write!(f, "engine failed executing {model}: {detail}")
            }
        }
    }
}

impl std::error::Error for ExecError {}

enum Msg {
    Exec(ExecRequest),
    Shutdown,
}

/// Pool construction knobs.
pub struct PoolConfig {
    /// Worker threads; `ExecutorPool::default_workers()` when 0.
    pub workers: usize,
    /// Execution backend for the shared engine.
    pub backend: Backend,
    /// (name, precision) variants compiled before any request is served.
    pub preload: Vec<(String, Precision)>,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            workers: ExecutorPool::default_workers(),
            backend: Backend::default(),
            preload: Vec::new(),
        }
    }
}

struct Worker {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// The executor pool: N workers sharing one engine.
pub struct ExecutorPool {
    workers: Vec<Worker>,
    engine: Arc<Engine>,
    submitted: AtomicU64,
}

impl ExecutorPool {
    /// Default worker count: the machine's parallelism, capped — PJRT
    /// CPU execution is internally multi-threaded, so a modest pool
    /// (sharding + dispatch overlap) beats one thread per core.
    pub fn default_workers() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .clamp(1, 8)
    }

    /// Spawn with defaults.  `preload` compiles the given variants up
    /// front so the request path never hits the compiler.
    pub fn spawn(
        artifacts_dir: PathBuf,
        preload: Vec<(String, Precision)>,
    ) -> Result<ExecutorPool> {
        ExecutorPool::with_config(artifacts_dir, PoolConfig { preload, ..Default::default() })
    }

    /// Spawn with explicit worker count / backend.
    pub fn with_config(artifacts_dir: PathBuf, cfg: PoolConfig) -> Result<ExecutorPool> {
        let engine = Arc::new(Engine::with_backend(&artifacts_dir, cfg.backend)?);
        for (name, prec) in &cfg.preload {
            engine.load(name, *prec)?;
        }
        let n = if cfg.workers == 0 { ExecutorPool::default_workers() } else { cfg.workers };
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let (tx, rx) = mpsc::channel::<Msg>();
            let eng = engine.clone();
            let handle = std::thread::Builder::new()
                .name(format!("executor-{idx}"))
                .spawn(move || worker_loop(idx, eng, rx))?;
            workers.push(Worker { tx, handle: Some(handle) });
        }
        Ok(ExecutorPool { workers, engine, submitted: AtomicU64::new(0) })
    }

    /// Number of worker threads in the pool.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Batches submitted so far (dispatch counter; the coordinator's
    /// one-request-per-batch invariant is asserted against this).
    pub fn batches_submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    /// The shared engine (platform queries, direct loads in benches).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Worker a model variant shards to.
    pub fn shard_of(&self, model: &str, precision: Precision) -> usize {
        let h = fnv1a(model.bytes().chain(precision.as_str().bytes()));
        (h % self.workers.len() as u64) as usize
    }

    /// Submit a batch (non-blocking); the result arrives on
    /// `req.reply`.  Routed by model affinity.
    pub fn submit(&self, req: ExecRequest) -> Result<()> {
        let w = self.shard_of(&req.model, req.precision);
        self.workers[w]
            .tx
            .send(Msg::Exec(req))
            .map_err(|_| anyhow!("executor worker {w} gone"))?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Synchronous whole-batch round trip.
    pub fn run_batch_sync(
        &self,
        model: &str,
        precision: Precision,
        items: Vec<InputSet>,
    ) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.submit(ExecRequest {
            model: model.to_string(),
            precision,
            items,
            reply,
            id: 0,
        })?;
        rx.recv()
            .map_err(|_| anyhow!("executor dropped the reply channel"))?
            .outputs
    }

    /// Convenience: synchronous single-event round trip.
    pub fn run_sync(
        &self,
        model: &str,
        precision: Precision,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<f32>> {
        let mut outs =
            self.run_batch_sync(model, precision, vec![Arc::new(inputs)])?;
        outs.pop().ok_or_else(|| anyhow!("empty batch result"))
    }
}

fn worker_loop(idx: usize, engine: Arc<Engine>, rx: mpsc::Receiver<Msg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Exec(req) => {
                let ExecRequest { model, precision, items, reply, id } = req;
                let t0 = Instant::now();
                // a panic (poisoned lock, FFI abort) must still produce
                // a reply — reapers block on exactly one result per
                // submitted batch and hold their own sender, so a
                // swallowed request would hang them forever
                let outputs = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        engine
                            .load(&model, precision)
                            .and_then(|m| m.run_batch(&items))
                            .map_err(|e| {
                                anyhow::Error::new(ExecError::Engine {
                                    model: model.clone(),
                                    detail: format!("{e:#}"),
                                })
                            })
                    }),
                )
                .unwrap_or_else(|_| {
                    Err(anyhow::Error::new(ExecError::WorkerPanic {
                        worker: idx,
                        model: model.clone(),
                    }))
                });
                let _ = reply.send(ExecResult {
                    id,
                    model,
                    outputs,
                    items,
                    host_elapsed: t0.elapsed(),
                    worker: idx,
                });
            }
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::testdata::MINI;

    /// Temp artifacts dir with surrogate-loadable manifests.
    fn mini_dir(label: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spaceinfer_pool_{label}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mini.fp32.manifest.json"), MINI).unwrap();
        std::fs::write(
            dir.join("mini2.fp32.manifest.json"),
            MINI.replace("\"name\":\"mini\"", "\"name\":\"mini2\""),
        )
        .unwrap();
        dir
    }

    fn surrogate_pool(label: &str, workers: usize) -> ExecutorPool {
        ExecutorPool::with_config(
            mini_dir(label),
            PoolConfig {
                workers,
                backend: Backend::Surrogate,
                preload: vec![("mini".into(), Precision::Fp32)],
            },
        )
        .unwrap()
    }

    #[test]
    fn batch_round_trip_and_shutdown() {
        let pool = surrogate_pool("roundtrip", 2);
        assert_eq!(pool.worker_count(), 2);
        let items: Vec<InputSet> =
            (0..4).map(|i| Arc::new(vec![vec![i as f32; 16]])).collect();
        let outs = pool
            .run_batch_sync("mini", Precision::Fp32, items)
            .unwrap();
        assert_eq!(outs.len(), 4);
        assert!(outs.iter().all(|o| o.len() == 2));
        assert_eq!(pool.batches_submitted(), 1);
        drop(pool); // clean shutdown must not hang
    }

    #[test]
    fn affinity_keeps_model_on_one_worker() {
        let pool = surrogate_pool("affinity", 4);
        let (reply, rx) = mpsc::channel();
        for id in 0..16 {
            pool.submit(ExecRequest {
                model: "mini".into(),
                precision: Precision::Fp32,
                items: vec![Arc::new(vec![vec![0.5; 16]])],
                reply: reply.clone(),
                id,
            })
            .unwrap();
        }
        let expect = pool.shard_of("mini", Precision::Fp32);
        let mut seen_ids = Vec::new();
        for _ in 0..16 {
            let res = rx.recv().unwrap();
            assert_eq!(res.worker, expect, "model must pin to its shard");
            seen_ids.push(res.id);
        }
        // single shard -> FIFO completion order
        assert_eq!(seen_ids, (0..16).collect::<Vec<u64>>());
        assert_eq!(pool.batches_submitted(), 16);
    }

    #[test]
    fn concurrent_submitters_get_matching_ids() {
        let pool = Arc::new(surrogate_pool("concurrent", 4));
        let threads: Vec<_> = (0..4u64)
            .map(|t| {
                let pool = pool.clone();
                std::thread::spawn(move || {
                    let (reply, rx) = mpsc::channel();
                    let model = if t % 2 == 0 { "mini" } else { "mini2" };
                    for k in 0..25u64 {
                        pool.submit(ExecRequest {
                            model: model.into(),
                            precision: Precision::Fp32,
                            items: vec![Arc::new(vec![vec![(t * 100 + k) as f32; 16]])],
                            reply: reply.clone(),
                            id: t * 1000 + k,
                        })
                        .unwrap();
                    }
                    let mut ids: Vec<u64> =
                        (0..25).map(|_| rx.recv().unwrap().id).collect();
                    ids.sort_unstable();
                    let want: Vec<u64> =
                        (0..25).map(|k| t * 1000 + k).collect();
                    assert_eq!(ids, want, "thread {t} lost or crossed replies");
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert_eq!(pool.batches_submitted(), 100);
    }

    #[test]
    fn results_hand_input_frames_back() {
        let pool = surrogate_pool("handback", 1);
        let (reply, rx) = mpsc::channel();
        let item: InputSet = Arc::new(vec![vec![0.25; 16]]);
        pool.submit(ExecRequest {
            model: "mini".into(),
            precision: Precision::Fp32,
            items: vec![item.clone()],
            reply,
            id: 7,
        })
        .unwrap();
        let res = rx.recv().unwrap();
        assert_eq!(res.id, 7);
        assert_eq!(res.items.len(), 1);
        assert!(
            Arc::ptr_eq(&res.items[0], &item),
            "the result must return the submitted frames for recycling"
        );
    }

    #[test]
    fn batch_outputs_deterministic_across_paths() {
        let pool = surrogate_pool("determinism", 3);
        let item: InputSet = Arc::new(vec![vec![0.75; 16]]);
        let via_batch = pool
            .run_batch_sync("mini", Precision::Fp32, vec![item.clone(), item.clone()])
            .unwrap();
        let via_single = pool
            .run_sync("mini", Precision::Fp32, vec![vec![0.75; 16]])
            .unwrap();
        assert_eq!(via_batch[0], via_single);
        assert_eq!(via_batch[0], via_batch[1]);
    }

    #[test]
    fn unknown_model_errors_without_killing_worker() {
        let pool = surrogate_pool("unknown", 1);
        assert!(pool
            .run_sync("nope", Precision::Fp32, vec![vec![0.0; 16]])
            .is_err());
        // worker survives the error and serves the next request
        assert!(pool
            .run_sync("mini", Precision::Fp32, vec![vec![0.0; 16]])
            .is_ok());
    }
}
