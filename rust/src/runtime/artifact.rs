//! Golden input/output pairs emitted by the AOT path
//! (`<tag>.io.json`): the runtime's startup self-check and the
//! integration tests' ground truth (python-executed outputs must match
//! rust-executed outputs on the same HLO).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One named input tensor.
#[derive(Debug, Clone)]
pub struct IoSpec {
    /// HLO parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Flat row-major values.
    pub data: Vec<f32>,
}

/// Golden IO pair for one artifact.
#[derive(Debug, Clone)]
pub struct GoldenIo {
    /// Input tensors in manifest order.
    pub inputs: Vec<IoSpec>,
    /// Shape of the expected output.
    pub expected_shape: Vec<usize>,
    /// Expected output values (python-executed oracle).
    pub expected: Vec<f32>,
}

impl GoldenIo {
    /// Parse a `<tag>.io.json` file.
    pub fn load(path: &Path) -> Result<GoldenIo> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading golden IO {}", path.display()))?;
        let j = Json::parse(&text)?;
        let inputs = j
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(|inp| {
                Ok(IoSpec {
                    name: inp.req("name")?.as_str()?.to_string(),
                    shape: inp.req("shape")?.as_shape()?,
                    data: inp
                        .req("data")?
                        .as_f64_vec()?
                        .into_iter()
                        .map(|v| v as f32)
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let exp = j.req("expected")?;
        Ok(GoldenIo {
            inputs,
            expected_shape: exp.req("shape")?.as_shape()?,
            expected: exp
                .req("data")?
                .as_f64_vec()?
                .into_iter()
                .map(|v| v as f32)
                .collect(),
        })
    }

    /// Input slices in manifest order, for `LoadedModel::run`.
    pub fn input_slices(&self) -> Vec<&[f32]> {
        self.inputs.iter().map(|i| i.data.as_slice()).collect()
    }

    /// Inputs as one shareable set in manifest order, for
    /// `LoadedModel::run_batch` / `ExecRequest` items.
    pub fn input_set(&self) -> std::sync::Arc<Vec<Vec<f32>>> {
        std::sync::Arc::new(self.inputs.iter().map(|i| i.data.clone()).collect())
    }

    /// Max |a-b| against the expected output.
    pub fn max_abs_err(&self, got: &[f32]) -> f64 {
        self.expected
            .iter()
            .zip(got)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_golden_io() {
        let src = r#"{
          "inputs":[{"name":"x","shape":[1,2],"data":[1.5,-2.0]}],
          "expected":{"shape":[1,1],"data":[3.25]}}"#;
        let dir = std::env::temp_dir().join("spaceinfer_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.io.json");
        std::fs::write(&p, src).unwrap();
        let io = GoldenIo::load(&p).unwrap();
        assert_eq!(io.inputs.len(), 1);
        assert_eq!(io.inputs[0].data, vec![1.5, -2.0]);
        assert_eq!(io.expected, vec![3.25]);
        assert_eq!(io.max_abs_err(&[3.0]), 0.25);
        let set = io.input_set();
        assert_eq!(set.len(), 1);
        assert_eq!(set[0], vec![1.5, -2.0]);
    }
}
