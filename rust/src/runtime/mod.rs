//! Runtime layer: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! manifests) and serves batch-native inference from the request path.
//!
//! Python never appears here — the HLO text was produced once by
//! `make artifacts`; the PJRT backend compiles it at startup and an
//! N-worker, model-affinity-sharded pool serves whole batches
//! (`Vec<InputSet> -> Vec<Vec<f32>>`) with `Arc`-shared input buffers.
//! A pure-Rust surrogate backend covers timing-only runs and
//! `--no-default-features` builds.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{GoldenIo, IoSpec};
pub use client::{Backend, Engine, InputSet, LoadedModel};
pub use executor::{ExecError, ExecRequest, ExecResult, ExecutorPool, PoolConfig};
