//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client from the request path.
//!
//! Python never appears here — the HLO text was produced once by
//! `make artifacts`; this module compiles it at startup and serves
//! `Vec<f32> -> Vec<f32>` inference calls.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{GoldenIo, IoSpec};
pub use client::{Engine, LoadedModel};
pub use executor::{ExecRequest, ExecResult, ExecutorPool};
