//! PJRT CPU client wrapper: compile HLO text once, execute many times.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §3).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{Manifest, Precision};

/// A compiled, executable model.
pub struct LoadedModel {
    pub tag: String,
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
    /// Input element counts per HLO parameter (manifest order).
    input_elems: Vec<usize>,
    input_shapes: Vec<Vec<usize>>,
    output_elems: usize,
}

impl LoadedModel {
    /// Execute with flat f32 buffers (one per model input, manifest
    /// order).  Returns the flat f32 output.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        if inputs.len() != self.input_elems.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.tag,
                self.input_elems.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, shape)) in inputs.iter().zip(&self.input_shapes).enumerate() {
            if buf.len() != self.input_elems[i] {
                bail!(
                    "{}: input {i} has {} elements, expected {}",
                    self.tag,
                    buf.len(),
                    self.input_elems[i]
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // lowered with return_tuple=True -> 1-tuple
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != self.output_elems {
            bail!(
                "{}: output has {} elements, expected {}",
                self.tag,
                values.len(),
                self.output_elems
            );
        }
        Ok(values)
    }
}

/// The inference engine: one PJRT CPU client + a cache of compiled models.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: std::path::PathBuf,
    models: Mutex<BTreeMap<String, std::sync::Arc<LoadedModel>>>,
}

impl Engine {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            models: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (or fetch cached) a model variant.
    pub fn load(&self, name: &str, precision: Precision) -> Result<std::sync::Arc<LoadedModel>> {
        let tag = format!("{name}.{}", precision.as_str());
        if let Some(m) = self.models.lock().unwrap().get(&tag) {
            return Ok(m.clone());
        }
        let hlo_path = self.artifacts_dir.join(format!("{tag}.hlo.txt"));
        let man_path = self.artifacts_dir.join(format!("{tag}.manifest.json"));
        let manifest = Manifest::load(&man_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .with_context(|| format!("non-utf8 path {hlo_path:?}"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {tag}: {e}"))?;
        let input_shapes: Vec<Vec<usize>> =
            manifest.inputs.iter().map(|(_, s)| s.clone()).collect();
        let input_elems = input_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect();
        let output_elems = manifest.output_elems() as usize;
        let model = std::sync::Arc::new(LoadedModel {
            tag: tag.clone(),
            manifest,
            exe,
            input_elems,
            input_shapes,
            output_elems,
        });
        self.models.lock().unwrap().insert(tag, model.clone());
        Ok(model)
    }

    /// Tags currently compiled.
    pub fn loaded_tags(&self) -> Vec<String> {
        self.models.lock().unwrap().keys().cloned().collect()
    }
}
