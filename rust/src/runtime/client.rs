//! Inference engine: compile each model once, execute many times.
//!
//! Two backends sit behind one `Engine` API:
//!
//! * `Backend::Pjrt` (feature `xla`, the default) — the real numerics
//!   path.  Interchange is HLO *text* (not serialized protos): jax >=
//!   0.5 emits protos with 64-bit instruction ids that xla_extension
//!   0.5.1 rejects; `HloModuleProto::from_text_file` reassigns ids (see
//!   /opt/xla-example/README.md and DESIGN.md §3).
//! * `Backend::Surrogate` — a pure-Rust fallback that loads the same
//!   manifests and serves deterministic stand-in outputs (a hash of the
//!   input bits seeds an xorshift stream).  It keeps the timing-only
//!   pipeline, the executor-pool tests, and `--no-default-features`
//!   builds running without artifacts' HLO or the PJRT runtime.
//!
//! The model cache is read-mostly: the hot path clones an `Arc`
//! snapshot of the whole map under a briefly-held read lock, so
//! concurrent executor workers never serialize on each other's cache
//! hits.  Compilation happens outside any lock; a racing load keeps the
//! first inserted executable.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use anyhow::{anyhow, Context};

use crate::model::{Manifest, Precision};
use crate::util::hash::{fnv1a, Fnv1a};
use crate::util::prng::Prng;

/// One event's input tensors (manifest input order), shared without
/// copying between the batcher, the executor queue, and the workers.
pub type InputSet = Arc<Vec<Vec<f32>>>;

/// Which execution backend an `Engine` uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Real PJRT CPU execution of the AOT HLO (requires feature `xla`).
    Pjrt,
    /// Deterministic pure-Rust stand-in (timing-only runs, tests, CI).
    Surrogate,
}

impl Default for Backend {
    fn default() -> Backend {
        if cfg!(feature = "xla") {
            Backend::Pjrt
        } else {
            Backend::Surrogate
        }
    }
}

impl Backend {
    /// CLI / report spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Surrogate => "surrogate",
        }
    }
}

enum Exec {
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtLoadedExecutable),
    /// Seeded per model tag so different variants disagree.
    Surrogate { seed: u64 },
}

/// A compiled, executable model.
pub struct LoadedModel {
    /// Artifact tag ("name.precision").
    pub tag: String,
    /// The variant's manifest (shapes, counts).
    pub manifest: Manifest,
    /// Input element counts per HLO parameter (manifest order).
    input_elems: Vec<usize>,
    /// Reshape dims per parameter, precomputed once at load.
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    input_dims: Vec<Vec<i64>>,
    output_elems: usize,
    exec: Exec,
}

impl LoadedModel {
    fn check(&self, inputs: &[&[f32]]) -> Result<()> {
        if inputs.len() != self.input_elems.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.tag,
                self.input_elems.len(),
                inputs.len()
            );
        }
        for (i, buf) in inputs.iter().enumerate() {
            if buf.len() != self.input_elems[i] {
                bail!(
                    "{}: input {i} has {} elements, expected {}",
                    self.tag,
                    buf.len(),
                    self.input_elems[i]
                );
            }
        }
        Ok(())
    }

    /// Execute pre-validated inputs.
    fn execute(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        match &self.exec {
            #[cfg(feature = "xla")]
            Exec::Pjrt(exe) => {
                let mut literals = Vec::with_capacity(inputs.len());
                for (buf, dims) in inputs.iter().zip(&self.input_dims) {
                    literals.push(xla::Literal::vec1(buf).reshape(dims)?);
                }
                let result = exe.execute::<xla::Literal>(&literals)?[0][0]
                    .to_literal_sync()?;
                // lowered with return_tuple=True -> 1-tuple
                let out = result.to_tuple1()?;
                let values = out.to_vec::<f32>()?;
                if values.len() != self.output_elems {
                    bail!(
                        "{}: output has {} elements, expected {}",
                        self.tag,
                        values.len(),
                        self.output_elems
                    );
                }
                Ok(values)
            }
            Exec::Surrogate { seed } => {
                // FNV-1a over the input bits: same inputs -> same
                // outputs, on any worker thread.
                let mut h = Fnv1a::seeded(*seed);
                for buf in inputs {
                    for v in *buf {
                        h.write_u64(v.to_bits() as u64);
                    }
                }
                let mut rng = Prng::new(h.finish());
                Ok((0..self.output_elems)
                    .map(|_| rng.f32() * 2.0 - 1.0)
                    .collect())
            }
        }
    }

    /// Execute with flat f32 buffers (one per model input, manifest
    /// order).  Returns the flat f32 output.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        self.check(inputs)?;
        self.execute(inputs)
    }

    /// Execute a whole batch in one pass: every item is shape-checked
    /// up front (a malformed item fails the batch before any compute),
    /// then executed back to back against the hot executable with no
    /// cache lookups or lock traffic in between.
    ///
    /// The AOT artifacts are lowered with a fixed leading batch dim of
    /// 1, so a stacked `[N, ...]` literal would not match the
    /// executable's parameter shapes; until batch-N artifact variants
    /// exist this is the tight literal-reuse loop, and the one-dispatch
    /// amortization lives at the executor-pool layer.
    pub fn run_batch(&self, items: &[InputSet]) -> Result<Vec<Vec<f32>>> {
        let mut slices: Vec<&[f32]> = Vec::with_capacity(self.input_elems.len());
        for item in items {
            slices.clear();
            slices.extend(item.iter().map(|v| v.as_slice()));
            self.check(&slices)?;
        }
        let mut outputs = Vec::with_capacity(items.len());
        for item in items {
            slices.clear();
            slices.extend(item.iter().map(|v| v.as_slice()));
            outputs.push(self.execute(&slices)?);
        }
        Ok(outputs)
    }
}

type ModelMap = BTreeMap<String, Arc<LoadedModel>>;

/// The inference engine: one backend + a read-mostly cache of compiled
/// models shared by every executor worker.
pub struct Engine {
    backend: Backend,
    #[cfg(feature = "xla")]
    client: Option<xla::PjRtClient>,
    artifacts_dir: std::path::PathBuf,
    models: RwLock<Arc<ModelMap>>,
}

impl Engine {
    /// Default backend (PJRT when built with the `xla` feature).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        Engine::with_backend(artifacts_dir, Backend::default())
    }

    /// Engine with an explicit backend.
    pub fn with_backend(artifacts_dir: &Path, backend: Backend) -> Result<Engine> {
        #[cfg(feature = "xla")]
        let client = match backend {
            Backend::Pjrt => Some(
                xla::PjRtClient::cpu()
                    .map_err(|e| anyhow!("PJRT CPU client: {e}"))?,
            ),
            Backend::Surrogate => None,
        };
        #[cfg(not(feature = "xla"))]
        if backend == Backend::Pjrt {
            bail!("PJRT backend requires building with the `xla` feature");
        }
        Ok(Engine {
            backend,
            #[cfg(feature = "xla")]
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            models: RwLock::new(Arc::new(BTreeMap::new())),
        })
    }

    /// Which backend this engine executes on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Human-readable execution platform name.
    pub fn platform(&self) -> String {
        match self.backend {
            #[cfg(feature = "xla")]
            Backend::Pjrt => self.client.as_ref().unwrap().platform_name(),
            #[cfg(not(feature = "xla"))]
            Backend::Pjrt => unreachable!("constructor rejects Pjrt without xla"),
            Backend::Surrogate => "surrogate-cpu (pure-rust fallback)".into(),
        }
    }

    /// Load + compile (or fetch cached) a model variant.  The cache hit
    /// path clones an `Arc` snapshot under a briefly-held read lock —
    /// no serialization between concurrent callers.
    pub fn load(&self, name: &str, precision: Precision) -> Result<Arc<LoadedModel>> {
        let tag = format!("{name}.{}", precision.as_str());
        let snapshot = self.models.read().unwrap().clone();
        if let Some(m) = snapshot.get(&tag) {
            return Ok(m.clone());
        }
        self.load_slow(tag)
    }

    /// Cache miss: compile outside any lock, then publish a new map
    /// snapshot.  If another thread won the race, keep its executable.
    fn load_slow(&self, tag: String) -> Result<Arc<LoadedModel>> {
        let man_path = self.artifacts_dir.join(format!("{tag}.manifest.json"));
        let manifest = Manifest::load(&man_path)?;
        let exec = self.compile(&tag)?;
        let input_dims: Vec<Vec<i64>> = manifest
            .inputs
            .iter()
            .map(|(_, s)| s.iter().map(|&d| d as i64).collect())
            .collect();
        let input_elems = manifest
            .inputs
            .iter()
            .map(|(_, s)| s.iter().product())
            .collect();
        let output_elems = manifest.output_elems() as usize;
        let model = Arc::new(LoadedModel {
            tag: tag.clone(),
            manifest,
            input_elems,
            input_dims,
            output_elems,
            exec,
        });
        let mut guard = self.models.write().unwrap();
        if let Some(existing) = guard.get(&tag) {
            return Ok(existing.clone());
        }
        let mut next = (**guard).clone();
        next.insert(tag, model.clone());
        *guard = Arc::new(next);
        Ok(model)
    }

    fn compile(&self, tag: &str) -> Result<Exec> {
        match self.backend {
            #[cfg(feature = "xla")]
            Backend::Pjrt => {
                let hlo_path = self.artifacts_dir.join(format!("{tag}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    hlo_path
                        .to_str()
                        .with_context(|| format!("non-utf8 path {hlo_path:?}"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e}", hlo_path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .as_ref()
                    .unwrap()
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {tag}: {e}"))?;
                Ok(Exec::Pjrt(exe))
            }
            #[cfg(not(feature = "xla"))]
            Backend::Pjrt => unreachable!("constructor rejects Pjrt without xla"),
            Backend::Surrogate => Ok(Exec::Surrogate { seed: fnv1a(tag.bytes()) }),
        }
    }

    /// Tags currently compiled.
    pub fn loaded_tags(&self) -> Vec<String> {
        self.models.read().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::testdata::MINI;

    fn mini_dir(label: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spaceinfer_client_{label}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mini.fp32.manifest.json"), MINI).unwrap();
        dir
    }

    #[test]
    fn surrogate_engine_loads_and_runs() {
        let dir = mini_dir("basic");
        let engine = Engine::with_backend(&dir, Backend::Surrogate).unwrap();
        assert_eq!(engine.backend(), Backend::Surrogate);
        assert!(engine.platform().contains("surrogate"));
        let m = engine.load("mini", Precision::Fp32).unwrap();
        let out = m.run(&[&[0.5; 16]]).unwrap();
        assert_eq!(out.len(), 2); // mini output_shape [1,2]
        // deterministic: same inputs, same outputs
        assert_eq!(out, m.run(&[&[0.5; 16]]).unwrap());
        // different inputs, (almost surely) different outputs
        assert_ne!(out, m.run(&[&[0.25; 16]]).unwrap());
        assert_eq!(engine.loaded_tags(), vec!["mini.fp32".to_string()]);
    }

    #[test]
    fn surrogate_rejects_bad_shapes() {
        let dir = mini_dir("shapes");
        let engine = Engine::with_backend(&dir, Backend::Surrogate).unwrap();
        let m = engine.load("mini", Precision::Fp32).unwrap();
        assert!(m.run(&[&[0.0; 5]]).is_err());
        assert!(m.run(&[]).is_err());
        // a malformed item anywhere fails run_batch before any compute
        let good: InputSet = Arc::new(vec![vec![0.0; 16]]);
        let bad: InputSet = Arc::new(vec![vec![0.0; 3]]);
        assert!(m.run_batch(&[good.clone(), bad]).is_err());
        assert!(m.run_batch(&[good]).is_ok());
    }

    #[test]
    fn run_batch_matches_single_runs() {
        let dir = mini_dir("batch");
        let engine = Engine::with_backend(&dir, Backend::Surrogate).unwrap();
        let m = engine.load("mini", Precision::Fp32).unwrap();
        let items: Vec<InputSet> = (0..5)
            .map(|i| Arc::new(vec![vec![i as f32 * 0.1; 16]]))
            .collect();
        let batched = m.run_batch(&items).unwrap();
        for (item, out) in items.iter().zip(&batched) {
            let slices: Vec<&[f32]> = item.iter().map(|v| v.as_slice()).collect();
            assert_eq!(out, &m.run(&slices).unwrap());
        }
    }

    #[test]
    fn cache_snapshot_is_shared() {
        let dir = mini_dir("cache");
        let engine = Engine::with_backend(&dir, Backend::Surrogate).unwrap();
        let a = engine.load("mini", Precision::Fp32).unwrap();
        let b = engine.load("mini", Precision::Fp32).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second load must hit the snapshot");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn pjrt_backend_requires_feature() {
        let dir = mini_dir("nofeat");
        assert!(Engine::with_backend(&dir, Backend::Pjrt).is_err());
    }
}
