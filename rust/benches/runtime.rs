//! Bench target: the PJRT execute hot path — per-model inference
//! wall-clock through the compiled HLO (host numbers; the ZCU104 numbers
//! come from the simulators).  This is the coordinator's real serving
//! cost and the perf-pass (§Perf L3) primary probe.

use spaceinfer::model::catalog::Catalog;
use spaceinfer::model::Precision;
use spaceinfer::runtime::{Engine, GoldenIo};
use spaceinfer::util::benchkit::{bench, throughput};

fn main() {
    let dir = std::path::Path::new("artifacts");
    let catalog = match Catalog::load(dir) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench runtime: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let engine = Engine::new(dir).expect("PJRT CPU client");
    println!("platform: {}\n", engine.platform());

    // compile cost first (paid once at startup)
    for tag in &catalog.executable {
        let (name, prec) = tag.rsplit_once('.').unwrap();
        let prec = Precision::parse(prec).unwrap();
        let t0 = std::time::Instant::now();
        engine.load(name, prec).expect("load");
        println!("compile {tag:<22} {:>10.1?}", t0.elapsed());
    }
    println!();

    // execute hot path (fewer samples for the heavyweights)
    for tag in &catalog.executable {
        let (name, prec) = tag.rsplit_once('.').unwrap();
        let prec = Precision::parse(prec).unwrap();
        let model = engine.load(name, prec).unwrap();
        let io = GoldenIo::load(&catalog.io_path(tag)).expect("golden io");
        let inputs = io.input_slices();
        let n = if model.manifest.total_macs > 100_000_000 { 5 } else { 30 };
        let s = bench(&format!("execute {tag}"), 2, n, || {
            model.run(&inputs).expect("run");
        });
        let med = s.median();
        println!("{}  -> {:.1} inf/s host", s.report(), throughput(1, med));
    }
}
