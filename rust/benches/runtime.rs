//! Bench target: the execute hot path — per-model inference wall-clock
//! through the compiled HLO (host numbers; the ZCU104 numbers come from
//! the simulators), plus the executor pool's dispatch-amortization
//! claim: batch-N through one `ExecRequest` vs N single-event submits.
//!
//! Emits machine-readable `BENCH_runtime.json` at the repo root so the
//! perf trajectory is comparable across PRs.  The `targets` section —
//! one row per backend-registry target per use case (predicted latency,
//! energy per inference, active power) — is emitted even without
//! `make artifacts` (synthetic stand-in catalog), so the full target
//! matrix is tracked on every machine.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Instant;

use spaceinfer::backend::{AccelModel, TargetRegistry, TargetSet};
use spaceinfer::board::Calibration;
use spaceinfer::coordinator::{
    AccelTimeline, DispatchCache, Dispatcher, Pipeline, PipelineConfig, Policy, Router,
};
use spaceinfer::fleet::{self, FleetConfig};
use spaceinfer::model::catalog::Catalog;
use spaceinfer::model::{Precision, UseCase};
use spaceinfer::plan::Planner;
use spaceinfer::rad::ScrubPolicy;
use spaceinfer::scenario::{Phase, Scenario};
use spaceinfer::runtime::{Engine, ExecutorPool, GoldenIo, InputSet, PoolConfig};
use spaceinfer::serve::{ServeConfig, Server};
use spaceinfer::util::benchkit::{bench, throughput};
use spaceinfer::util::json::Json;

/// Batch size for the amortization comparison.
const BATCH_N: usize = 8;

/// CI regression floor: the cached dispatch hot path must clear this
/// many × the uncached decision rate on both the whole-model
/// (`policies`) and plan-mode (`plan`) paths.  Relative, so the gate is
/// machine-independent; enforced only under `BENCH_ENFORCE_CACHE=1`.
const MIN_CACHE_SPEEDUP_X: f64 = 5.0;

/// CI regression floor for the steady-state cache hit rate.
const MIN_CACHE_HIT_RATE: f64 = 0.5;

/// Consecutive decisions per queue state in the steady-state stream —
/// what a run's flush cadence produces (drained queues re-seen batch
/// after batch).
const CACHE_REPEAT: usize = 16;

/// Events per timing-only run in the tick-loop section.
const TICK_EVENTS: usize = 256;

/// CI regression floor: the allocation-free tick loop (frame pool +
/// interned counters + husked image synthesis) must clear this many ×
/// the pool-off events/sec on the image-heavy use cases (vae, cnet).
/// Relative, so machine-independent; enforced only under
/// `BENCH_ENFORCE_TICK=1`.
const MIN_TICK_SPEEDUP_X: f64 = 5.0;

/// Constellation size for the fleet-scaling section.
const FLEET_CRAFTS: usize = 64;

/// CI regression floor: the work-stealing fleet pool must clear this
/// many × the single-thread craft rate at available parallelism.
/// Enforced only under `BENCH_ENFORCE_FLEET=1` *and* on runners with at
/// least [`MIN_FLEET_GATE_CORES`] cores — a 4x floor is meaningless on
/// a 2-core box, so smaller machines report but never fail.
const MIN_FLEET_SPEEDUP_X: f64 = 4.0;

/// Minimum core count for the fleet speedup gate to be binding.
const MIN_FLEET_GATE_CORES: usize = 8;

/// Concurrent clients in the serve-scaling section's high arm.
const SERVE_CLIENTS: usize = 32;

/// Requests each concurrent client sends in the high arm.
const SERVE_REQS_PER_CLIENT: usize = 8;

/// Requests the single sequential client sends in the low arm.
const SERVE_REQS_1C: usize = 32;

/// CI regression floor: requests/sec at [`SERVE_CLIENTS`] concurrent
/// clients must clear this many × the single-client rate — the
/// continuous-batching concurrency claim.  Enforced only under
/// `BENCH_ENFORCE_SERVE=1` *and* on runners with at least
/// [`MIN_SERVE_GATE_CORES`] cores (same reasoning as the fleet gate:
/// a 4x concurrency floor is unreachable on a 2-core box).
const MIN_SERVE_SPEEDUP_X: f64 = 4.0;

/// Minimum core count for the serve speedup gate to be binding.
const MIN_SERVE_GATE_CORES: usize = 8;

fn repo_root() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for dir in [cwd.clone(), cwd.join("..")] {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
    }
    cwd
}

/// One row per registered target per use case: the simulator-predicted
/// operating point the dispatcher scores at runtime.
fn target_matrix_rows(catalog: &Catalog) -> BTreeMap<String, Json> {
    let calib = Calibration::default();
    let router = Router::default(); // mms -> baseline
    let mut rows = BTreeMap::new();
    for uc in UseCase::ALL {
        let route = router.route(uc, 0).expect("route");
        let registry =
            TargetRegistry::build(&route.model, catalog, &calib, &TargetSet::All)
                .expect("registry");
        for target in registry.targets() {
            let mut row = BTreeMap::new();
            row.insert("latency_s".to_string(), Json::Num(target.batch_latency_s(1)));
            row.insert(
                "energy_per_inf_j".to_string(),
                Json::Num(target.batch_energy_j(1)),
            );
            row.insert(
                "active_power_w".to_string(),
                Json::Num(target.active_power_w()),
            );
            rows.insert(
                format!("{}.{}", route.model, target.name()),
                Json::Obj(row),
            );
            println!(
                "target {:<10} {:<10} {:>12.6} s/inf  {:>10.4} mJ/inf  {:>5.2} W",
                route.model,
                target.name(),
                target.batch_latency_s(1),
                target.batch_energy_j(1) * 1e3,
                target.active_power_w(),
            );
        }
    }
    rows
}

/// One row per use case: the best whole-model plan vs the best plan
/// overall (hybrid allowed) under min-latency at `BATCH_N` — the
/// partitioning win the plan layer buys, tracked per PR.
fn plan_rows(catalog: &Catalog) -> BTreeMap<String, Json> {
    let calib = Calibration::default();
    let router = Router::default(); // mms -> baseline
    let n = BATCH_N as u64;
    let mut rows = BTreeMap::new();
    for uc in UseCase::ALL {
        let route = router.route(uc, 0).expect("route");
        let registry =
            TargetRegistry::build(&route.model, catalog, &calib, &TargetSet::Default)
                .expect("registry");
        let planner =
            Planner::build(&route.model, catalog, &calib, &registry, &TargetSet::Default)
                .expect("planner");
        let best = |hybrid_ok: bool| {
            planner
                .plans()
                .iter()
                .filter(|p| hybrid_ok || !p.is_hybrid())
                .min_by(|a, b| a.batch_latency_s(n).total_cmp(&b.batch_latency_s(n)))
                .expect("at least one plan")
        };
        let whole = best(false);
        let any = best(true);
        let mut row = BTreeMap::new();
        row.insert("whole_latency_s".to_string(), Json::Num(whole.batch_latency_s(n)));
        row.insert("plan_latency_s".to_string(), Json::Num(any.batch_latency_s(n)));
        row.insert(
            "speedup_x".to_string(),
            Json::Num(whole.batch_latency_s(n) / any.batch_latency_s(n).max(1e-18)),
        );
        row.insert("whole_energy_j".to_string(), Json::Num(whole.batch_energy_j(n)));
        row.insert("plan_energy_j".to_string(), Json::Num(any.batch_energy_j(n)));
        row.insert("hybrid".to_string(), Json::Num(any.is_hybrid() as u8 as f64));
        row.insert("partition".to_string(), Json::Str(any.describe()));
        println!(
            "plan {:<10} whole {:>10.4} ms  best {:>10.4} ms  {:>6.2}x  [{}]",
            route.model,
            whole.batch_latency_s(n) * 1e3,
            any.batch_latency_s(n) * 1e3,
            whole.batch_latency_s(n) / any.batch_latency_s(n).max(1e-18),
            any.describe(),
        );
        rows.insert(route.model.clone(), Json::Obj(row));
    }
    rows
}

/// Dispatch hot-path section: decisions (batches) per second scored
/// fresh vs through the [`DispatchCache`], on the whole-model
/// (`policies`) path over the full target set and on the plan-mode
/// (`plan`) path.  Returns the JSON rows and whether the CI gate holds.
fn cache_rows(catalog: &Catalog) -> (BTreeMap<String, Json>, bool) {
    let calib = Calibration::default();
    let mut rows = BTreeMap::new();
    let mut gate_ok = true;

    // ---- whole-model (`policies`) path: vae over the full target set
    let d = Dispatcher::new(
        "vae",
        catalog,
        &calib,
        Policy::MinLatency,
        0.5,
        Some(4.0),
        &TargetSet::All,
    )
    .expect("dispatcher");
    // a handful of queue states, each re-seen for a stretch of
    // consecutive batches — the steady-state decision stream
    let mut states: Vec<Vec<AccelTimeline>> = Vec::new();
    for k in 0..4usize {
        let mut tls = d.timelines();
        if k > 0 {
            let lane = k % tls.len();
            tls[lane].schedule(0.0, 4 * k as u64, d.run_of(lane));
        }
        states.push(tls);
    }
    let decisions = (states.len() * CACHE_REPEAT) as u64;
    // accumulate picks so the optimizer cannot drop the pure scoring
    let mut acc = 0usize;
    let before = bench("dispatch.choose uncached (vae, all targets)", 20, 200, || {
        for tls in &states {
            for _ in 0..CACHE_REPEAT {
                acc += d.choose(tls, 0.5, 0.45, 8).index;
            }
        }
    });
    let mut cache = DispatchCache::new(true);
    let after = bench("dispatch.choose cached   (vae, all targets)", 20, 200, || {
        for tls in &states {
            for _ in 0..CACHE_REPEAT {
                acc += d.choose_cached(&mut cache, tls, 0.5, 0.45, 8).index;
            }
        }
    });
    let bps_before = throughput(decisions, before.median());
    let bps_after = throughput(decisions, after.median());
    let speedup = bps_after / bps_before.max(1e-12);
    let hit_rate = cache.stats().hit_rate();
    println!("{}  -> {:.0} batches/s", before.report(), bps_before);
    println!("{}  -> {:.0} batches/s", after.report(), bps_after);
    println!(
        "  policies path: {speedup:.2}x  hit rate {:.1}%  (acc {acc})",
        100.0 * hit_rate
    );
    rows.insert("policies_batches_per_s_before".into(), Json::Num(bps_before));
    rows.insert("policies_batches_per_s_after".into(), Json::Num(bps_after));
    rows.insert("policies_speedup_x".into(), Json::Num(speedup));
    rows.insert("policies_hit_rate".into(), Json::Num(hit_rate));
    gate_ok &= speedup >= MIN_CACHE_SPEEDUP_X && hit_rate >= MIN_CACHE_HIT_RATE;

    // ---- plan-mode (`plan`) path: the hybrid-partitioned mms baseline
    let d = Dispatcher::new(
        "baseline",
        catalog,
        &calib,
        Policy::MinLatency,
        0.5,
        Some(4.0),
        &TargetSet::Default,
    )
    .expect("dispatcher");
    let planner =
        Planner::build("baseline", catalog, &calib, &d.registry, &TargetSet::Default)
            .expect("planner");
    let mut states: Vec<Vec<AccelTimeline>> = Vec::new();
    for k in 0..4usize {
        let mut tls = d.timelines();
        for name in planner.derived_lane_names() {
            tls.push(AccelTimeline::new(name));
        }
        if k > 0 {
            let lane = k % d.registry.len();
            tls[lane].schedule(0.0, 4 * k as u64, d.run_of(lane));
        }
        states.push(tls);
    }
    let mut acc = 0usize;
    let before = bench("dispatch.choose_plan uncached (baseline)", 20, 200, || {
        for tls in &states {
            for _ in 0..CACHE_REPEAT {
                acc += d.choose_plan(&planner, tls, 0.5, 0.45, 8).index;
            }
        }
    });
    let mut cache = DispatchCache::new(true);
    let after = bench("dispatch.choose_plan cached   (baseline)", 20, 200, || {
        for tls in &states {
            for _ in 0..CACHE_REPEAT {
                acc += d.choose_plan_cached(&mut cache, &planner, tls, 0.5, 0.45, 8).index;
            }
        }
    });
    let bps_before = throughput(decisions, before.median());
    let bps_after = throughput(decisions, after.median());
    let speedup = bps_after / bps_before.max(1e-12);
    let hit_rate = cache.stats().hit_rate();
    println!("{}  -> {:.0} batches/s", before.report(), bps_before);
    println!("{}  -> {:.0} batches/s", after.report(), bps_after);
    println!(
        "  plan path: {speedup:.2}x  hit rate {:.1}%  (acc {acc})",
        100.0 * hit_rate
    );
    rows.insert("plan_batches_per_s_before".into(), Json::Num(bps_before));
    rows.insert("plan_batches_per_s_after".into(), Json::Num(bps_after));
    rows.insert("plan_speedup_x".into(), Json::Num(speedup));
    rows.insert("plan_hit_rate".into(), Json::Num(hit_rate));
    gate_ok &= speedup >= MIN_CACHE_SPEEDUP_X && hit_rate >= MIN_CACHE_HIT_RATE;

    rows.insert("min_speedup_x".into(), Json::Num(MIN_CACHE_SPEEDUP_X));
    rows.insert("min_hit_rate".into(), Json::Num(MIN_CACHE_HIT_RATE));
    rows.insert("gate_ok".into(), Json::Num(gate_ok as u8 as f64));
    (rows, gate_ok)
}

/// Tick-loop section: end-to-end timing-only pipeline events/sec per
/// use case with the frame pool off (the old allocating hot path) vs
/// on (pooled frames, interned counters, husked image synthesis).
/// Returns the JSON rows and whether the ≥[`MIN_TICK_SPEEDUP_X`] gate
/// holds on the image-heavy use cases.
fn tick_rows(catalog: &Catalog) -> (BTreeMap<String, Json>, bool) {
    let calib = Calibration::default();
    let mut rows = BTreeMap::new();
    let mut gate_ok = true;
    for uc in UseCase::ALL {
        let run = |pool: bool| {
            let cfg = PipelineConfig {
                use_case: uc,
                n_events: TICK_EVENTS,
                frame_pool: pool,
                ..Default::default()
            };
            Pipeline::new(cfg, catalog, &calib)
                .expect("pipeline")
                .run(None)
                .expect("run");
        };
        let before = bench(&format!("tick loop pool-off {uc}"), 1, 5, || run(false));
        let after = bench(&format!("tick loop pool-on  {uc}"), 1, 5, || run(true));
        let eps_before = throughput(TICK_EVENTS as u64, before.median());
        let eps_after = throughput(TICK_EVENTS as u64, after.median());
        let speedup = eps_after / eps_before.max(1e-12);
        let gated = matches!(uc, UseCase::Vae | UseCase::Cnet);
        if gated {
            gate_ok &= speedup >= MIN_TICK_SPEEDUP_X;
        }
        println!("{}  -> {:.0} events/s", before.report(), eps_before);
        println!("{}  -> {:.0} events/s", after.report(), eps_after);
        println!(
            "  tick path {uc}: {speedup:.2}x{}",
            if gated { "  (gated)" } else { "" }
        );
        let mut row = BTreeMap::new();
        row.insert("events_per_s_before".into(), Json::Num(eps_before));
        row.insert("events_per_s_after".into(), Json::Num(eps_after));
        row.insert("speedup_x".into(), Json::Num(speedup));
        row.insert("gated".into(), Json::Num(gated as u8 as f64));
        rows.insert(format!("{uc}"), Json::Obj(row));
    }
    rows.insert("events".into(), Json::Num(TICK_EVENTS as f64));
    rows.insert("min_speedup_x".into(), Json::Num(MIN_TICK_SPEEDUP_X));
    rows.insert("gate_ok".into(), Json::Num(gate_ok as u8 as f64));
    (rows, gate_ok)
}

/// Fleet-scaling section: crafts/s for a contested constellation at 1
/// worker thread vs available parallelism, plus the bit-identity
/// cross-check (parallelism must be pure speedup).  Returns the JSON
/// rows and whether the ≥[`MIN_FLEET_SPEEDUP_X`] gate holds.
fn fleet_rows(catalog: &Catalog) -> (BTreeMap<String, Json>, bool) {
    let calib = Calibration::default();
    // a compact contested mission: tight per-craft downlink so pass
    // arbitration always has demand, three phases so the epoch barrier
    // fires more than once
    let sc = Scenario {
        name: "bench-fleet".into(),
        summary: "fleet-scaling bench mission".into(),
        config: PipelineConfig {
            use_case: UseCase::Esperta,
            cadence_s: 0.1,
            downlink_budget: 64,
            policy: Policy::Static,
            ..Default::default()
        },
        scrub: ScrubPolicy { period_s: 60.0 },
        phases: vec![
            Phase::new("cruise", 30, vec![]),
            Phase::new("dense", 40, vec![]),
            Phase::new("quiet", 10, vec![]),
        ],
    };
    let avail =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cfg = |threads: usize| FleetConfig {
        crafts: FLEET_CRAFTS,
        threads,
        master_seed: 42,
        pass_budget_bytes: 4_096,
        pass_link_bytes_per_s: 125_000.0,
        relay: true,
        planes: 4,
        stagger_events: 7,
    };
    // determinism cross-check first: the parallel report must be
    // byte-identical to the serial one before its speed means anything
    let serial = fleet::run_fleet(&sc, catalog, &calib, &cfg(1)).expect("fleet");
    let parallel =
        fleet::run_fleet(&sc, catalog, &calib, &cfg(avail)).expect("fleet");
    assert_eq!(
        serial.render(),
        parallel.render(),
        "fleet report diverged between 1 and {avail} threads"
    );

    let s1 = bench(&format!("fleet {FLEET_CRAFTS} crafts, 1 thread"), 2, 8, || {
        fleet::run_fleet(&sc, catalog, &calib, &cfg(1)).expect("fleet");
    });
    let sn = bench(
        &format!("fleet {FLEET_CRAFTS} crafts, {avail} threads"),
        2,
        8,
        || {
            fleet::run_fleet(&sc, catalog, &calib, &cfg(avail)).expect("fleet");
        },
    );
    let cps1 = throughput(FLEET_CRAFTS as u64, s1.median());
    let cpsn = throughput(FLEET_CRAFTS as u64, sn.median());
    let speedup = cpsn / cps1.max(1e-12);
    println!("{}  -> {:.1} crafts/s", s1.report(), cps1);
    println!("{}  -> {:.1} crafts/s", sn.report(), cpsn);
    println!("  fleet scaling: {speedup:.2}x on {avail} core(s)");

    let gate_ok = speedup >= MIN_FLEET_SPEEDUP_X;
    let mut rows = BTreeMap::new();
    rows.insert("crafts".into(), Json::Num(FLEET_CRAFTS as f64));
    rows.insert("threads".into(), Json::Num(avail as f64));
    rows.insert("crafts_per_s_1t".into(), Json::Num(cps1));
    rows.insert("crafts_per_s_nt".into(), Json::Num(cpsn));
    rows.insert("speedup_x".into(), Json::Num(speedup));
    rows.insert("min_speedup_x".into(), Json::Num(MIN_FLEET_SPEEDUP_X));
    rows.insert(
        "gate_cores_min".into(),
        Json::Num(MIN_FLEET_GATE_CORES as f64),
    );
    rows.insert("gate_ok".into(), Json::Num(gate_ok as u8 as f64));
    (rows, gate_ok)
}

/// One blocking `/infer` round trip against the bench server.  Panics
/// on anything but a 200 — the scaling numbers are meaningless if any
/// request was rejected.
fn infer_once(addr: SocketAddr, tenant: usize, seed: u64) {
    let body = format!(r#"{{"tenant":"c{tenant}","use_case":"esperta","seed":{seed}}}"#);
    let mut stream = TcpStream::connect(addr).expect("connect serve bench");
    let _ = stream.set_nodelay(true);
    let msg = format!(
        "POST /infer HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(msg.as_bytes()).expect("write request");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).expect("status line");
    assert!(line.contains(" 200 "), "serve bench request failed: {line}");
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).expect("header");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().expect("content length");
        }
    }
    let mut raw = vec![0u8; len];
    reader.read_exact(&mut raw).expect("response body");
}

/// Serve-scaling section: requests/sec through a live loopback server
/// with 1 sequential client vs [`SERVE_CLIENTS`] concurrent clients on
/// distinct tenants — the win continuous cross-tenant batching plus
/// the worker pool buys over round-tripping one request at a time.
/// Returns the JSON rows and whether the ≥[`MIN_SERVE_SPEEDUP_X`] gate
/// holds.
fn serve_rows(catalog: &Catalog) -> (BTreeMap<String, Json>, bool) {
    let calib = Calibration::default();
    let server = Server::bind(ServeConfig::default(), catalog, &calib)
        .expect("bind serve bench");
    let addr = server.local_addr();
    let handle = server.handle();
    let workers = ServeConfig::default().workers;
    let (rps_1, rps_n, stats) = std::thread::scope(|scope| {
        let run = scope.spawn(|| server.run().expect("serve run"));
        // warm the per-worker lane pipelines out of the measurement
        for seed in 0..8u64 {
            infer_once(addr, 0, seed);
        }
        let arm = |clients: usize, per_client: usize| {
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for c in 0..clients {
                    s.spawn(move || {
                        for i in 0..per_client {
                            infer_once(addr, c, (c * per_client + i) as u64);
                        }
                    });
                }
            });
            throughput((clients * per_client) as u64, t0.elapsed())
        };
        let rps_1 = arm(1, SERVE_REQS_1C);
        let rps_n = arm(SERVE_CLIENTS, SERVE_REQS_PER_CLIENT);
        handle.shutdown();
        let stats = run.join().expect("serve thread");
        (rps_1, rps_n, stats)
    });
    assert!(
        stats.conserved(),
        "serve bench violated request conservation: {stats:?}"
    );
    let speedup = rps_n / rps_1.max(1e-12);
    println!("serve 1 client  x{SERVE_REQS_1C:<3}            -> {rps_1:.0} req/s");
    println!(
        "serve {SERVE_CLIENTS} clients x{SERVE_REQS_PER_CLIENT:<3}            \
         -> {rps_n:.0} req/s"
    );
    println!("  serve scaling: {speedup:.2}x on {workers} worker(s)");

    let gate_ok = speedup >= MIN_SERVE_SPEEDUP_X;
    let mut rows = BTreeMap::new();
    rows.insert("clients_hi".into(), Json::Num(SERVE_CLIENTS as f64));
    rows.insert("workers".into(), Json::Num(workers as f64));
    rows.insert("rps_1c".into(), Json::Num(rps_1));
    rows.insert("rps_nc".into(), Json::Num(rps_n));
    rows.insert("speedup_x".into(), Json::Num(speedup));
    rows.insert("min_speedup_x".into(), Json::Num(MIN_SERVE_SPEEDUP_X));
    rows.insert(
        "gate_cores_min".into(),
        Json::Num(MIN_SERVE_GATE_CORES as f64),
    );
    rows.insert("gate_ok".into(), Json::Num(gate_ok as u8 as f64));
    (rows, gate_ok)
}

fn main() {
    let dir = std::path::Path::new("artifacts");
    let have_artifacts = Catalog::is_present(dir);
    let catalog = Catalog::load_or_synthetic(dir).expect("catalog");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("runtime".to_string()));
    doc.insert("batch_n".to_string(), Json::Num(BATCH_N as f64));

    // full target matrix first: runs with or without artifacts
    println!("== backend target matrix (simulated ZCU104 operating points) ==");
    doc.insert("targets".to_string(), Json::Obj(target_matrix_rows(&catalog)));
    println!();

    // execution-plan section: hybrid vs whole-model per use case
    // (artifact-free — the perf trajectory of the partitioning win)
    println!("== execution plans (hybrid vs whole-model, batch-{BATCH_N}) ==");
    doc.insert("plans".to_string(), Json::Obj(plan_rows(&catalog)));
    println!();

    // dispatch-cache section: cached vs uncached decision rate on the
    // policies and plan hot paths (artifact-free; CI gates on it)
    println!("== dispatch cache (batches/s, cached vs uncached) ==");
    let (cache_section, cache_gate_ok) = cache_rows(&catalog);
    doc.insert("cache".to_string(), Json::Obj(cache_section));
    println!();

    // tick-loop section: the allocation-free steady-state hot path,
    // pool-off vs pool-on events/sec per use case (artifact-free;
    // CI gates on the image-heavy cases)
    println!("== tick loop (events/s, frame pool off vs on) ==");
    let (tick_section, tick_gate_ok) = tick_rows(&catalog);
    doc.insert("tick".to_string(), Json::Obj(tick_section));
    println!();

    // fleet-scaling section: work-stealing constellation shards,
    // 1 thread vs available parallelism (artifact-free; CI gates on it
    // when the runner has enough cores)
    println!("== fleet scaling (crafts/s, 1 thread vs available parallelism) ==");
    let (fleet_section, fleet_gate_ok) = fleet_rows(&catalog);
    doc.insert("fleet".to_string(), Json::Obj(fleet_section));
    println!();

    // serve-scaling section: live loopback server, 1 sequential client
    // vs concurrent clients on distinct tenants (artifact-free; CI
    // gates on it when the runner has enough cores)
    println!("== serve scaling (req/s, 1 client vs {SERVE_CLIENTS} clients) ==");
    let (serve_section, serve_gate_ok) = serve_rows(&catalog);
    doc.insert("serve".to_string(), Json::Obj(serve_section));
    println!();

    let mut model_rows: BTreeMap<String, Json> = BTreeMap::new();
    if !have_artifacts {
        eprintln!(
            "bench runtime: no artifacts in {} — skipping the host execute \
             and pool-amortization sections (run `make artifacts` for them)",
            dir.display()
        );
    } else {
        let engine = Engine::new(dir).expect("engine");
        println!("platform: {}\n", engine.platform());

        // compile cost first (paid once at startup)
        for tag in &catalog.executable {
            let (name, prec) = tag.rsplit_once('.').unwrap();
            let prec = Precision::parse(prec).unwrap();
            let t0 = std::time::Instant::now();
            engine.load(name, prec).expect("load");
            println!("compile {tag:<22} {:>10.1?}", t0.elapsed());
        }
        println!();

        // execute hot path (fewer samples for the heavyweights)
        for tag in &catalog.executable {
            let (name, prec) = tag.rsplit_once('.').unwrap();
            let prec = Precision::parse(prec).unwrap();
            let model = engine.load(name, prec).unwrap();
            let io = GoldenIo::load(&catalog.io_path(tag)).expect("golden io");
            let inputs = io.input_slices();
            let n = if model.manifest.total_macs > 100_000_000 { 5 } else { 30 };
            let s = bench(&format!("execute {tag}"), 2, n, || {
                model.run(&inputs).expect("run");
            });
            let med = s.median();
            println!("{}  -> {:.1} inf/s host", s.report(), throughput(1, med));
        }
        println!();

        // dispatch amortization through the pool: batch-1 submit-per-event
        // (the old hot path: one channel round trip + input copy per event)
        // vs one whole-batch ExecRequest with Arc-shared buffers
        let pool = ExecutorPool::with_config(dir.to_path_buf(), PoolConfig::default())
            .expect("executor pool");
        println!(
            "pool: {} workers, backend {}\n",
            pool.worker_count(),
            pool.engine().backend().as_str()
        );
        for tag in &catalog.executable {
            let (name, prec) = tag.rsplit_once('.').unwrap();
            let prec = Precision::parse(prec).unwrap();
            let model = engine.load(name, prec).unwrap();
            if model.manifest.total_macs > 100_000_000 {
                continue; // amortization story is about the small nets
            }
            let io = GoldenIo::load(&catalog.io_path(tag)).expect("golden io");
            let set = io.input_set();
            let raw: Vec<Vec<f32>> = (*set).clone();
            let items: Vec<InputSet> = vec![set; BATCH_N];

            let samples = 20;
            let s1 =
                bench(&format!("submit-per-event x{BATCH_N} {tag}"), 2, samples, || {
                    for _ in 0..BATCH_N {
                        // per-event dispatch pays the input clone + round
                        // trip, exactly what the pre-batch-native pipeline paid
                        pool.run_sync(name, prec, raw.clone()).expect("run_sync");
                    }
                });
            let s8 = bench(&format!("one batch-{BATCH_N} request {tag}"), 2, samples, || {
                pool.run_batch_sync(name, prec, items.clone()).expect("run_batch");
            });
            let eps1 = throughput(BATCH_N as u64, s1.median());
            let eps8 = throughput(BATCH_N as u64, s8.median());
            println!("{} -> {:.0} events/s", s1.report(), eps1);
            println!("{} -> {:.0} events/s", s8.report(), eps8);
            println!("  amortization: {:.2}x\n", eps8 / eps1.max(1e-12));

            let mut row = BTreeMap::new();
            row.insert("batch1_events_per_s".to_string(), Json::Num(eps1));
            row.insert(format!("batch{BATCH_N}_events_per_s"), Json::Num(eps8));
            row.insert(
                "amortization_x".to_string(),
                Json::Num(eps8 / eps1.max(1e-12)),
            );
            model_rows.insert(tag.clone(), Json::Obj(row));
        }
        doc.insert("platform".to_string(), Json::Str(engine.platform()));
        doc.insert(
            "backend".to_string(),
            Json::Str(pool.engine().backend().as_str().to_string()),
        );
        doc.insert(
            "pool_workers".to_string(),
            Json::Num(pool.worker_count() as f64),
        );
    }
    doc.insert("models".to_string(), Json::Obj(model_rows));

    let out = repo_root().join("BENCH_runtime.json");
    match std::fs::write(&out, Json::Obj(doc).to_string()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }

    // regression gate (opt-in so dev boxes under load don't flake):
    // `BENCH_ENFORCE_CACHE=1 cargo bench --bench runtime` fails the
    // build when the cached hot path regresses below the committed
    // floors — CI sets it.
    if std::env::var("BENCH_ENFORCE_CACHE").is_ok_and(|v| v == "1") && !cache_gate_ok {
        eprintln!(
            "cache gate FAILED: cached dispatch must clear \
             {MIN_CACHE_SPEEDUP_X}x uncached and a {MIN_CACHE_HIT_RATE} hit rate \
             (see the cache section of {})",
            out.display()
        );
        std::process::exit(1);
    }

    // tick gate (opt-in): `BENCH_ENFORCE_TICK=1` fails the build when
    // the allocation-free tick loop regresses below the floor on the
    // image-heavy use cases — CI sets it.
    if std::env::var("BENCH_ENFORCE_TICK").is_ok_and(|v| v == "1") && !tick_gate_ok {
        eprintln!(
            "tick gate FAILED: the pooled tick loop must clear \
             {MIN_TICK_SPEEDUP_X}x the pool-off events/sec on vae and cnet \
             (see the tick section of {})",
            out.display()
        );
        std::process::exit(1);
    }

    // fleet gate (opt-in + core-gated): `BENCH_ENFORCE_FLEET=1` fails
    // the build when the work-stealing pool scales below the floor,
    // but only on runners with enough cores for the floor to be
    // physically reachable — small machines report, never fail.
    if std::env::var("BENCH_ENFORCE_FLEET").is_ok_and(|v| v == "1") {
        let cores =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores < MIN_FLEET_GATE_CORES {
            eprintln!(
                "fleet gate skipped: {cores} core(s) < {MIN_FLEET_GATE_CORES} \
                 (the {MIN_FLEET_SPEEDUP_X}x floor assumes >= \
                 {MIN_FLEET_GATE_CORES}-core runners)"
            );
        } else if !fleet_gate_ok {
            eprintln!(
                "fleet gate FAILED: {FLEET_CRAFTS}-craft fleet must clear \
                 {MIN_FLEET_SPEEDUP_X}x the single-thread craft rate \
                 (see the fleet section of {})",
                out.display()
            );
            std::process::exit(1);
        }
    }

    // serve gate (opt-in + core-gated): `BENCH_ENFORCE_SERVE=1` fails
    // the build when concurrent serving throughput falls below the
    // floor over the single-client rate — CI sets it; small machines
    // report, never fail.
    if std::env::var("BENCH_ENFORCE_SERVE").is_ok_and(|v| v == "1") {
        let cores =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        if cores < MIN_SERVE_GATE_CORES {
            eprintln!(
                "serve gate skipped: {cores} core(s) < {MIN_SERVE_GATE_CORES} \
                 (the {MIN_SERVE_SPEEDUP_X}x floor assumes >= \
                 {MIN_SERVE_GATE_CORES}-core runners)"
            );
        } else if !serve_gate_ok {
            eprintln!(
                "serve gate FAILED: {SERVE_CLIENTS} concurrent clients must \
                 clear {MIN_SERVE_SPEEDUP_X}x the single-client req/s \
                 (see the serve section of {})",
                out.display()
            );
            std::process::exit(1);
        }
    }
}
