//! Bench target: coordinator hot paths in isolation — router, batcher,
//! bounded queue, downlink manager, decision logic, full timing-only
//! pipeline.  §Perf L3 requires coordinator overhead << model execute
//! time; this bench proves it.

use spaceinfer::board::Calibration;
use spaceinfer::coordinator::backpressure::OverflowPolicy;
use spaceinfer::coordinator::decision::decide;
use spaceinfer::coordinator::{
    Batcher, BoundedQueue, DownlinkManager, Pipeline, PipelineConfig, Router,
};
use spaceinfer::model::catalog::Catalog;
use spaceinfer::model::UseCase;
use spaceinfer::runtime::{Backend, ExecutorPool, PoolConfig};
use spaceinfer::sensors::SensorStream;
use spaceinfer::util::benchkit::{bench, throughput};
use spaceinfer::util::prng::Prng;

fn main() {
    let router = Router::default();
    let s = bench("router.route", 100, 1000, || {
        router.route(UseCase::Mms, 3).unwrap();
    });
    println!("{}", s.report());

    let mut stream = SensorStream::new(UseCase::Esperta, 1, 0.001);
    let events: Vec<_> = stream.take(4096);
    let s = bench("batcher offer+flush x4096 (esperta)", 2, 50, || {
        let mut b = Batcher::new("esperta", 8, 0.5);
        for (i, ev) in events.iter().cloned().enumerate() {
            let _ = b.offer(ev, i as f64 * 0.001);
        }
        let _ = b.flush(10.0);
    });
    println!("{} -> {:.0} events/s", s.report(),
             throughput(4096, s.median()));

    let s = bench("bounded queue push/pop x4096", 2, 50, || {
        let mut q = BoundedQueue::new(512, OverflowPolicy::DropOldest);
        for i in 0..4096u32 {
            q.push(i);
            if i % 2 == 0 {
                q.pop();
            }
        }
    });
    println!("{}", s.report());

    let mut rng = Prng::new(5);
    let outputs: Vec<Vec<f32>> = (0..1024)
        .map(|_| (0..12).map(|_| rng.f32()).collect())
        .collect();
    let s = bench("decide+downlink x1024 (esperta)", 2, 50, || {
        let mut dl = DownlinkManager::new(1 << 20);
        let mut r = Prng::new(9);
        for out in &outputs {
            let d = decide(UseCase::Esperta, out, &mut r);
            dl.offer(&d, 12);
        }
    });
    println!("{} -> {:.0} decisions/s", s.report(),
             throughput(1024, s.median()));

    // full timing-only pipeline (sim clock, surrogate outputs)
    if let Ok(catalog) = Catalog::load(std::path::Path::new("artifacts")) {
        let calib = Calibration::default();
        let cfg = PipelineConfig {
            use_case: UseCase::Mms,
            n_events: 1000,
            ..Default::default()
        };
        let mut pipeline = Pipeline::new(cfg, &catalog, &calib).unwrap();
        let s = bench("pipeline 1000 events (sim-only, mms)", 1, 20, || {
            pipeline.run(None).unwrap();
        });
        println!("{} -> {:.0} events/s simulated pipeline", s.report(),
                 throughput(1000, s.median()));

        // batch-size sweep: per-batch dispatch means coordinator
        // overhead scales with batches, not events
        for max_batch in [1usize, 8] {
            let cfg = PipelineConfig {
                use_case: UseCase::Mms,
                mms_model: "logistic".into(),
                n_events: 1000,
                max_batch,
                ..Default::default()
            };
            let mut p = Pipeline::new(cfg, &catalog, &calib).unwrap();
            let s = bench(
                &format!("pipeline 1000 events (sim-only, max_batch={max_batch})"),
                1,
                20,
                || {
                    p.run(None).unwrap();
                },
            );
            println!("{} -> {:.0} events/s", s.report(),
                     throughput(1000, s.median()));
        }

        // executor-backed pipeline: one ExecRequest per batch through
        // the sharded pool (surrogate backend so the bench isolates
        // dispatch + coordination cost from PJRT compute)
        let cfg = PipelineConfig {
            use_case: UseCase::Mms,
            mms_model: "logistic".into(),
            n_events: 1000,
            ..Default::default()
        };
        let mut p = Pipeline::new(cfg, &catalog, &calib).unwrap();
        let pool = ExecutorPool::with_config(
            std::path::PathBuf::from("artifacts"),
            PoolConfig {
                backend: Backend::Surrogate,
                preload: vec![(p.route.model.clone(), p.route.precision)],
                ..Default::default()
            },
        )
        .unwrap();
        let (warmup, samples) = (1, 20);
        let s = bench("pipeline 1000 events (pool, surrogate engine)", warmup, samples, || {
            p.run(Some(&pool)).unwrap();
        });
        println!("{} -> {:.0} events/s", s.report(),
                 throughput(1000, s.median()));
        let runs = (warmup + samples) as f64;
        println!(
            "  ({} batches dispatched over {} runs -> {:.1} events/request)",
            pool.batches_submitted(),
            warmup + samples,
            1000.0 * runs / pool.batches_submitted().max(1) as f64
        );
    } else {
        eprintln!("(skipping pipeline bench: run `make artifacts` first)");
    }
}
