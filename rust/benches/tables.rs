//! Bench target: regenerate Tables I–V end-to-end and time the harness.
//!
//! `cargo bench --bench tables` prints every paper table (ours | paper)
//! and reports how long each regeneration takes (criterion is absent
//! offline; util::benchkit provides the measurement kit).

use spaceinfer::board::Calibration;
use spaceinfer::model::catalog::Catalog;
use spaceinfer::report::{related, tables};
use spaceinfer::util::benchkit::bench;

fn main() {
    let dir = std::path::Path::new("artifacts");
    let catalog = match Catalog::load(dir) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench tables: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let calib = Calibration::default();

    println!("{}", tables::table1(&catalog).unwrap().render());
    println!("{}", tables::table2(&catalog, &calib).unwrap().render());
    println!("{}", tables::table3(&catalog, &calib).unwrap().render());
    println!("{}", tables::dpu_utilization_note(&catalog, &calib).unwrap());
    println!("{}", tables::hls_spill_note(&catalog, &calib).unwrap());
    println!("{}", related::table4(&catalog, &calib).unwrap().render());
    println!("{}", related::table5(&catalog, &calib).unwrap().render());
    print!("{}", tables::table3_shape_check(&catalog, &calib).unwrap());

    println!("\n-- harness timings --");
    for s in [
        bench("table1", 2, 20, || {
            tables::table1(&catalog).unwrap();
        }),
        bench("table2 (bram alloc + estimate)", 2, 20, || {
            tables::table2(&catalog, &calib).unwrap();
        }),
        bench("table3 (all simulators)", 2, 20, || {
            tables::table3(&catalog, &calib).unwrap();
        }),
        bench("table4+5", 2, 20, || {
            related::table4(&catalog, &calib).unwrap();
            related::table5(&catalog, &calib).unwrap();
        }),
    ] {
        println!("{}", s.report());
    }
}
