//! Bench target: regenerate Figures 9–13 (power traces), write CSVs to
//! reports/, and time the trace generator.

use spaceinfer::board::Calibration;
use spaceinfer::model::catalog::Catalog;
use spaceinfer::report::figures;
use spaceinfer::util::benchkit::bench;

fn main() {
    let dir = std::path::Path::new("artifacts");
    let catalog = match Catalog::load(dir) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench figures: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let calib = Calibration::default();
    std::fs::create_dir_all("reports").unwrap();

    for (name, csv, ascii) in figures::all_figures(&catalog, &calib).unwrap() {
        std::fs::write(format!("reports/{name}.csv"), &csv).unwrap();
        println!("== {name} == ({} samples -> reports/{name}.csv)",
                 csv.lines().count() - 1);
        println!("{ascii}");
    }

    println!("-- harness timings --");
    let s = bench("all five figures", 1, 10, || {
        figures::all_figures(&catalog, &calib).unwrap();
    });
    println!("{}", s.report());
}
