//! Bench target: the analytic simulators themselves (DPU schedule, HLS
//! synthesis, BRAM allocation, CPU model, power traces).  These run per
//! coordinator decision, so they must be microsecond-cheap.

use spaceinfer::board::{Calibration, Zcu104};
use spaceinfer::cpu::A53Model;
use spaceinfer::dpu::{DpuArch, DpuSchedule};
use spaceinfer::hls::{BramAllocator, HlsDesign};
use spaceinfer::model::catalog::{Catalog, MODELS};
use spaceinfer::model::Precision;
use spaceinfer::power::{PowerModel, TraceBuilder, Implementation};
use spaceinfer::util::benchkit::bench;

fn main() {
    let dir = std::path::Path::new("artifacts");
    let catalog = match Catalog::load(dir) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bench simulators: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let calib = Calibration::default();
    let board = Zcu104::default();

    let cnet = catalog.manifest("cnet", Precision::Int8).unwrap();
    let s = bench("DpuSchedule::new(cnet)", 10, 200, || {
        DpuSchedule::new(
            cnet,
            DpuArch::b4096(&calib, board.dpu_clock_hz),
            &calib,
            board.axi_bandwidth,
        )
        .unwrap();
    });
    println!("{}", s.report());

    let baseline = catalog.manifest("baseline", Precision::Fp32).unwrap();
    let s = bench("HlsDesign::synthesize(baseline)", 10, 200, || {
        HlsDesign::synthesize(baseline, &board, &calib);
    });
    println!("{}", s.report());

    let s = bench("BramAllocator::allocate(baseline)", 10, 500, || {
        BramAllocator::new(&board.pl).allocate(baseline);
    });
    println!("{}", s.report());

    let s = bench("A53Model::calibrated x6", 10, 200, || {
        for info in MODELS {
            let man = catalog.manifest(info.name, Precision::Fp32).unwrap();
            A53Model::calibrated(man, &calib, info.paper.cpu_fps);
        }
    });
    println!("{}", s.report());

    let s = bench("power trace (standard_run, 1000 inputs)", 2, 50, || {
        let b = TraceBuilder::new(PowerModel::new(calib.clone()), 1);
        b.standard_run(&Implementation::Dpu { mac_duty: 0.3 }, 2.75, 1000,
                       0.04, 1e-4, 1.6e-3);
    });
    println!("{}", s.report());
}
