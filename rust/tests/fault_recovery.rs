//! Fault-injection + recovery guarantees, self-provisioning (synthetic
//! catalog, timing-only — no artifacts):
//!
//! * **Determinism** — the same `--faults` seed replays a bit-identical
//!   fault timeline and report, twice over.
//! * **Inertness** — with the injector disabled, non-default fault
//!   profiles and recovery policies change nothing: reports are
//!   bit-identical to a plain default config (the golden pin for the
//!   fault-layer refactor).
//! * **Recovery mechanics** — retry stays on the faulted target,
//!   escalation lands on the documented fallback (next-best available
//!   target), a fault streak quarantines the target until the scrub
//!   window reinstates it, and TMR outvotes a single corrupted replica.
//! * **Fuzz** — a slice of the seeded scenario fuzzer runs per build.

use spaceinfer::backend::TargetSet;
use spaceinfer::board::Calibration;
use spaceinfer::coordinator::{Pipeline, PipelineConfig, PipelineReport, Policy};
use spaceinfer::fault::{FaultProfile, RecoveryPolicy};
use spaceinfer::model::{Catalog, UseCase};
use spaceinfer::scenario::fuzz;

fn catalog() -> Catalog {
    Catalog::synthetic()
}

fn report(cfg: PipelineConfig) -> PipelineReport {
    Pipeline::new(cfg, &catalog(), &Calibration::default())
        .unwrap()
        .run(None)
        .unwrap()
}

/// Bit equality of the aggregate report, the phase slices, and the
/// fault accounting (f64 by bit pattern).
fn assert_identical(a: &PipelineReport, b: &PipelineReport) {
    assert_eq!(a.target_mix, b.target_mix);
    assert_eq!(a.events, b.events);
    assert_eq!(a.sim_elapsed_s.to_bits(), b.sim_elapsed_s.to_bits());
    assert_eq!(a.mean_latency_s.to_bits(), b.mean_latency_s.to_bits());
    assert_eq!(a.p95_latency_s.to_bits(), b.p95_latency_s.to_bits());
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    assert_eq!(a.predicted_energy_j.to_bits(), b.predicted_energy_j.to_bits());
    assert_eq!(a.deadline_misses, b.deadline_misses);
    assert_eq!(a.power_sheds, b.power_sheds);
    assert_eq!(a.downlink_sent, b.downlink_sent);
    assert_eq!(a.downlink_shed, b.downlink_shed);
    assert_eq!(a.decisions, b.decisions);
    assert_eq!(a.phases, b.phases);
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.exec_errors, b.exec_errors);
}

fn stormy_cfg() -> PipelineConfig {
    PipelineConfig {
        use_case: UseCase::Esperta,
        n_events: 200,
        cadence_s: 0.1,
        policy: Policy::MinLatency,
        fault_seed: Some(99),
        fault_profile: FaultProfile {
            exec_fail_p: 0.3,
            timeout_p: 0.1,
            seu_corrupt_p: 0.2,
            thermal_p: 0.1,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn same_fault_seed_replays_bit_identically() {
    let (a, b) = (report(stormy_cfg()), report(stormy_cfg()));
    assert!(a.faults.faults_injected > 0, "storm rates must inject faults");
    assert_identical(&a, &b);
}

#[test]
fn distinct_fault_seeds_diverge() {
    let a = report(stormy_cfg());
    let b = report(PipelineConfig { fault_seed: Some(100), ..stormy_cfg() });
    assert_ne!(
        a.faults, b.faults,
        "different seeds must draw different fault timelines"
    );
}

#[test]
fn disabled_injector_is_bit_identical_to_default_config() {
    // non-default fault knobs with no seed must change NOTHING: the
    // fault checks on the dispatch path draw no RNG and no float ops
    let plain = report(PipelineConfig::default());
    let armed_but_off = report(PipelineConfig {
        fault_seed: None,
        fault_profile: FaultProfile {
            exec_fail_p: 0.9,
            timeout_p: 0.9,
            ..Default::default()
        },
        recovery: RecoveryPolicy {
            tmr: true,
            quarantine_threshold: 1,
            max_retries_per_target: 3,
            ..Default::default()
        },
        ..Default::default()
    });
    assert_identical(&plain, &armed_but_off);
}

fn two_target_cfg(recovery: RecoveryPolicy) -> PipelineConfig {
    PipelineConfig {
        use_case: UseCase::Esperta,
        n_events: 40,
        cadence_s: 0.15,
        policy: Policy::Static,
        targets: TargetSet::parse("cpu,hls").unwrap(),
        recovery,
        ..Default::default()
    }
}

#[test]
fn escalation_lands_on_the_next_best_target() {
    // zero retries: the forced fault on the static primary (hls) must
    // escalate straight to the only other registered target (cpu)
    let cfg = two_target_cfg(RecoveryPolicy {
        max_retries_per_target: 0,
        ..Default::default()
    });
    let mut p = Pipeline::new(cfg, &catalog(), &Calibration::default()).unwrap();
    let mut run = p.begin(None);
    let hls = run.target_index("hls").unwrap();
    run.inject_transient_fault(hls).unwrap();
    for _ in 0..40 {
        run.tick().unwrap();
    }
    let r = run.finish().unwrap();
    assert_eq!(r.faults.redispatches, 1, "{:?}", r.faults);
    assert_eq!(r.faults.retries, 0);
    assert_eq!(r.target_mix.get("cpu"), Some(&1), "{:?}", r.target_mix);
    assert!(r.target_mix.get("hls").copied().unwrap_or(0) > 0);
}

#[test]
fn retry_stays_on_the_faulted_target() {
    let cfg = two_target_cfg(RecoveryPolicy {
        max_retries_per_target: 2,
        ..Default::default()
    });
    let mut p = Pipeline::new(cfg, &catalog(), &Calibration::default()).unwrap();
    let mut run = p.begin(None);
    let hls = run.target_index("hls").unwrap();
    run.inject_transient_fault(hls).unwrap();
    for _ in 0..40 {
        run.tick().unwrap();
    }
    let r = run.finish().unwrap();
    assert_eq!(r.faults.retries, 1, "{:?}", r.faults);
    assert_eq!(r.faults.redispatches, 0);
    assert_eq!(r.target_mix.get("cpu"), None, "{:?}", r.target_mix);
}

#[test]
fn tmr_outvotes_a_single_corrupted_replica() {
    let cfg = two_target_cfg(RecoveryPolicy { tmr: true, ..Default::default() });
    let mut p = Pipeline::new(cfg, &catalog(), &Calibration::default()).unwrap();
    let mut run = p.begin(None);
    let hls = run.target_index("hls").unwrap();
    run.inject_corruption(hls).unwrap();
    for _ in 0..40 {
        run.tick().unwrap();
    }
    let r = run.finish().unwrap();
    assert_eq!(r.faults.tmr_masked, 1, "{:?}", r.faults);
    assert_eq!(r.faults.retries, 0, "a masked fault must not retry");
    assert_eq!(r.faults.redispatches, 0);
    assert!(r.faults.tmr_batches > 0);
    assert_eq!(r.target_mix.get("cpu"), None, "{:?}", r.target_mix);
}

#[test]
fn without_tmr_the_same_corruption_costs_a_retry() {
    let cfg = two_target_cfg(RecoveryPolicy {
        tmr: false,
        max_retries_per_target: 1,
        ..Default::default()
    });
    let mut p = Pipeline::new(cfg, &catalog(), &Calibration::default()).unwrap();
    let mut run = p.begin(None);
    let hls = run.target_index("hls").unwrap();
    run.inject_corruption(hls).unwrap();
    for _ in 0..40 {
        run.tick().unwrap();
    }
    let r = run.finish().unwrap();
    assert_eq!(r.faults.tmr_masked, 0);
    assert_eq!(r.faults.retries, 1, "{:?}", r.faults);
}

#[test]
fn fault_streak_quarantines_until_the_scrub_window() {
    // two forced faults on hls with one retry allowed: fault, retry,
    // fault again -> streak 2 hits the threshold, hls quarantines, the
    // batch escalates to cpu, and the 2 s scrub cadence reinstates hls
    // well inside the 18 s run
    let cfg = PipelineConfig {
        n_events: 120,
        recovery: RecoveryPolicy {
            max_retries_per_target: 1,
            quarantine_threshold: 2,
            quarantine_scrub_period_s: 2.0,
            ..Default::default()
        },
        ..two_target_cfg(RecoveryPolicy::default())
    };
    let mut p = Pipeline::new(cfg, &catalog(), &Calibration::default()).unwrap();
    let mut run = p.begin(None);
    let hls = run.target_index("hls").unwrap();
    run.inject_transient_fault(hls).unwrap();
    run.inject_transient_fault(hls).unwrap();
    for _ in 0..120 {
        run.tick().unwrap();
    }
    let r = run.finish().unwrap();
    assert_eq!(r.faults.quarantines, 1, "{:?}", r.faults);
    assert_eq!(r.faults.reinstates, 1, "scrub must reinstate the target");
    assert_eq!(r.faults.retries, 1);
    assert_eq!(r.faults.redispatches, 1);
    assert!(r.target_mix.contains_key("cpu"), "{:?}", r.target_mix);
    assert!(
        r.target_mix.get("hls").copied().unwrap_or(0) > 1,
        "reinstated target must serve again: {:?}",
        r.target_mix
    );
}

#[test]
fn plan_mode_rejects_fault_injection() {
    let cfg = PipelineConfig {
        plan_mode: true,
        fault_seed: Some(1),
        ..Default::default()
    };
    let err = Pipeline::new(cfg, &catalog(), &Calibration::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("plan mode"), "{err}");
}

#[test]
fn fuzz_slice_holds_all_invariants() {
    let outcomes =
        fuzz::fuzz_many(1, 8, &catalog(), &Calibration::default()).unwrap();
    assert_eq!(outcomes.len(), 8);
    assert!(
        outcomes.iter().any(|o| o.faults.faults_injected > 0),
        "eight armed campaigns should inject at least one fault"
    );
}
