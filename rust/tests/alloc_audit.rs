//! Zero-allocation audit of the steady-state tick loop.
//!
//! Built only under `--features alloc-audit`, which swaps in a counting
//! global allocator: every `alloc` / `realloc` / `alloc_zeroed` bumps a
//! process-wide counter.  The single test (one `#[test]` fn, so no
//! parallel test thread can pollute the counter) runs a timing-only
//! pipeline per builtin use case, warms the run up past every one-time
//! allocation — frame-pool priming, first-fill `Vec` growth, the
//! dispatch cache's first miss, the `OnceLock` synthesis table — and
//! then asserts that 1000 further ticks allocate **nothing**:
//!
//! * frames recycle through the [`FramePool`] (or are husked entirely
//!   on timing-only image streams),
//! * every hot-path counter is an interned `MetricBank` slot,
//! * batcher / executor-item / surrogate scratch vectors cycle their
//!   capacity instead of reallocating,
//! * steady-state dispatch is a dispatch-cache hit (exact-bit keys,
//!   Static-policy relaxation collapses to one entry).
//!
//! `max_wait_s` is pinned huge so every flush is a full-batch `offer`
//! flush: the drained event vector is always restocked before the next
//! push, keeping the accumulate/flush cycle allocation-free.
//!
//! [`FramePool`]: spaceinfer::sensors::FramePool

#![cfg(feature = "alloc-audit")]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use spaceinfer::board::Calibration;
use spaceinfer::coordinator::{Pipeline, PipelineConfig};
use spaceinfer::model::{Catalog, UseCase};

/// System allocator wrapper that counts every allocation call.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static AUDIT: CountingAlloc = CountingAlloc;

/// Ticks before the counter snapshot: covers pool priming (several
/// full batch cycles), first-fill buffer growth, and the dispatch
/// cache's first miss.
const WARMUP_TICKS: usize = 64;

/// Ticks measured under the zero-allocation assertion.
const MEASURED_TICKS: usize = 1000;

#[test]
fn steady_state_ticks_do_not_allocate() {
    let catalog = Catalog::synthetic();
    let calib = Calibration::default();
    for uc in UseCase::ALL {
        let cfg = PipelineConfig {
            use_case: uc,
            // sized so the preallocated latency buffers cover every tick
            n_events: WARMUP_TICKS + MEASURED_TICKS + 8,
            // full-batch offer flushes only: the drained event vector is
            // restocked before the next push (a timer flush would force
            // the open batch to regrow from zero capacity)
            max_wait_s: 1e9,
            ..Default::default()
        };
        let mut p = Pipeline::new(cfg, &catalog, &calib).unwrap();
        let mut run = p.begin(None);
        for _ in 0..WARMUP_TICKS {
            run.tick().unwrap();
        }
        let before = ALLOCATIONS.load(Ordering::Relaxed);
        for _ in 0..MEASURED_TICKS {
            run.tick().unwrap();
        }
        let delta = ALLOCATIONS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "{uc}: {delta} heap allocations across {MEASURED_TICKS} \
             steady-state ticks (the tick hot path must be allocation-free)"
        );
        run.finish().unwrap();
    }
}
