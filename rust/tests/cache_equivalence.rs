//! Dispatch-cache regression harness: the cache must be a pure
//! throughput knob — **zero behavioral drift**.
//!
//! Three layers of evidence, from end to end down to single decisions:
//!
//! * **Pipeline bit-identity** — `dispatch_cache: true` vs `false` over
//!   the full grid of policies × use cases × target sets × plan mode ×
//!   power budgets, every built-in scenario, and ≥8 fuzz seeds (armed
//!   and defused): every behavioral `PipelineReport` field must match
//!   bit for bit (`f64` compared by bit pattern).  The `cache` counter
//!   block is the *only* field allowed to differ.
//! * **Invalidation exactness** — each knob setter (`set_policy`,
//!   `set_power_budget_w`, `set_deadline_s`, `set_target_available`)
//!   drops exactly the entries the mutated knob orphaned, verified by
//!   counting live entries around mid-run mutations.
//! * **Staleness impossible by construction** — a deterministic knob
//!   storm mutates policy / budget / deadline / availability between
//!   decisions and compares the cached pick against a fresh-computed
//!   one at *every* step; a second storm never invalidates at all, so
//!   any stale-entry reuse the key structure permitted would surface as
//!   a divergence.

use spaceinfer::backend::TargetSet;
use spaceinfer::board::Calibration;
use spaceinfer::coordinator::{
    choices_identical, plan_choices_identical, AccelTimeline, CacheStats, DispatchCache,
    Dispatcher, Pipeline, PipelineConfig, PipelineReport, Policy, ScheduledRun,
};
use spaceinfer::model::{Catalog, UseCase};
use spaceinfer::plan::Planner;
use spaceinfer::scenario::{self, fuzz};
use spaceinfer::util::prng::Prng;

const POLICIES: [Policy; 4] =
    [Policy::Static, Policy::MinLatency, Policy::MinEnergy, Policy::Deadline];

fn catalog() -> Catalog {
    Catalog::synthetic()
}

fn calib() -> Calibration {
    Calibration::default()
}

/// Run `cfg` with the dispatch cache forced on or off.
fn run_with_cache(cfg: &PipelineConfig, cache_on: bool) -> PipelineReport {
    let mut cfg = cfg.clone();
    cfg.dispatch_cache = cache_on;
    Pipeline::new(cfg, &catalog(), &calib())
        .unwrap()
        .run(None)
        .unwrap()
}

/// Every behavioral report field must match bit for bit; only the
/// `cache` counter block may differ between a cached and an uncached
/// run.
fn assert_behavior_identical(a: &PipelineReport, b: &PipelineReport, ctx: &str) {
    assert_eq!(a.use_case, b.use_case, "{ctx}: use_case");
    assert_eq!(a.model, b.model, "{ctx}: model");
    assert_eq!(a.slot, b.slot, "{ctx}: slot");
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.target_mix, b.target_mix, "{ctx}: target_mix");
    assert_eq!(a.events, b.events, "{ctx}: events");
    assert_eq!(
        a.sim_elapsed_s.to_bits(),
        b.sim_elapsed_s.to_bits(),
        "{ctx}: sim_elapsed_s"
    );
    assert_eq!(
        a.mean_latency_s.to_bits(),
        b.mean_latency_s.to_bits(),
        "{ctx}: mean_latency_s"
    );
    assert_eq!(
        a.p95_latency_s.to_bits(),
        b.p95_latency_s.to_bits(),
        "{ctx}: p95_latency_s"
    );
    assert_eq!(a.busy_fps.to_bits(), b.busy_fps.to_bits(), "{ctx}: busy_fps");
    assert_eq!(
        a.accel_utilization.to_bits(),
        b.accel_utilization.to_bits(),
        "{ctx}: accel_utilization"
    );
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{ctx}: energy_j");
    assert_eq!(
        a.predicted_energy_j.to_bits(),
        b.predicted_energy_j.to_bits(),
        "{ctx}: predicted_energy_j"
    );
    assert_eq!(a.deadline_misses, b.deadline_misses, "{ctx}: deadline_misses");
    assert_eq!(a.power_sheds, b.power_sheds, "{ctx}: power_sheds");
    assert_eq!(a.ingress_accepted, b.ingress_accepted, "{ctx}: ingress_accepted");
    assert_eq!(a.ingress_dropped, b.ingress_dropped, "{ctx}: ingress_dropped");
    assert_eq!(a.plan_batches, b.plan_batches, "{ctx}: plan_batches");
    assert_eq!(
        a.plan_hybrid_batches, b.plan_hybrid_batches,
        "{ctx}: plan_hybrid_batches"
    );
    assert_eq!(
        a.plan_transfer_s.to_bits(),
        b.plan_transfer_s.to_bits(),
        "{ctx}: plan_transfer_s"
    );
    assert_eq!(a.downlink_sent, b.downlink_sent, "{ctx}: downlink_sent");
    assert_eq!(a.downlink_shed, b.downlink_shed, "{ctx}: downlink_shed");
    assert_eq!(
        a.downlink_sent_bytes, b.downlink_sent_bytes,
        "{ctx}: downlink_sent_bytes"
    );
    assert_eq!(
        a.compression_ratio.to_bits(),
        b.compression_ratio.to_bits(),
        "{ctx}: compression_ratio"
    );
    assert_eq!(
        a.accuracy.map(f64::to_bits),
        b.accuracy.map(f64::to_bits),
        "{ctx}: accuracy"
    );
    assert_eq!(a.decisions, b.decisions, "{ctx}: decisions");
    assert_eq!(a.phases, b.phases, "{ctx}: phases");
    assert_eq!(a.faults, b.faults, "{ctx}: faults");
    assert_eq!(a.exec_errors, b.exec_errors, "{ctx}: exec_errors");
    assert_eq!(
        a.metrics.counter("batches"),
        b.metrics.counter("batches"),
        "{ctx}: batches counter"
    );
}

#[test]
fn cache_on_and_off_runs_are_bit_identical_across_the_grid() {
    for use_case in [UseCase::Vae, UseCase::Cnet, UseCase::Esperta, UseCase::Mms] {
        for policy in POLICIES {
            for targets in [TargetSet::Default, TargetSet::All] {
                for plan_mode in [false, true] {
                    for budget in [None, Some(4.0)] {
                        let cfg = PipelineConfig {
                            use_case,
                            n_events: 96,
                            policy,
                            targets: targets.clone(),
                            plan_mode,
                            power_budget_w: budget,
                            ..Default::default()
                        };
                        let on = run_with_cache(&cfg, true);
                        let off = run_with_cache(&cfg, false);
                        let ctx = format!(
                            "{use_case} {policy:?} {targets:?} plan={plan_mode} \
                             budget={budget:?}"
                        );
                        assert_behavior_identical(&on, &off, &ctx);
                        // the cache-off leg must not count anything ...
                        assert_eq!(off.cache, CacheStats::default(), "{ctx}: off");
                        // ... and the cache-on leg must actually engage
                        assert!(
                            on.cache.lookups() + on.cache.bypasses > 0,
                            "{ctx}: cache never consulted"
                        );
                        if matches!(targets, TargetSet::Default) {
                            assert!(
                                on.cache.hits > 0,
                                "{ctx}: steady-state run never hit the cache"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn builtin_scenarios_are_bit_identical_with_cache_on_and_off() {
    for name in scenario::builtin_names() {
        let mut sc = scenario::builtin(name).unwrap();
        sc.config.dispatch_cache = true;
        let on = scenario::run_scenario(&sc, &catalog(), &calib(), None).unwrap();
        sc.config.dispatch_cache = false;
        let off = scenario::run_scenario(&sc, &catalog(), &calib(), None).unwrap();
        assert_behavior_identical(&on, &off, name);
        assert_eq!(off.cache, CacheStats::default(), "{name}: off leg counted");
    }
}

#[test]
fn fuzz_scenarios_are_bit_identical_with_cache_on_and_off() {
    // the generated scenarios always arm the fault injector, so every
    // batch takes the recovery path: the cache must stand aside
    // (bypasses only) and change nothing
    for seed in 1..=10u64 {
        let mut sc = fuzz::generate(seed);
        sc.config.dispatch_cache = true;
        let on = scenario::run_scenario(&sc, &catalog(), &calib(), None).unwrap();
        sc.config.dispatch_cache = false;
        let off = scenario::run_scenario(&sc, &catalog(), &calib(), None).unwrap();
        let ctx = format!("fuzz seed {seed}");
        assert_behavior_identical(&on, &off, &ctx);
        assert_eq!(on.cache.lookups(), 0, "{ctx}: armed runs must bypass");
        assert!(on.cache.bypasses > 0, "{ctx}: bypasses uncounted");
    }
}

#[test]
fn defused_fuzz_scenarios_engage_the_cache_and_stay_bit_identical() {
    // strip the injector seed so the generated mission timelines (knob
    // storms included: SetPolicy, Brownout, EnterEclipse, throttle and
    // SEU events) exercise the *cached* dispatch path for real
    let mut total_lookups = 0u64;
    for seed in 1..=10u64 {
        let mut sc = fuzz::generate(seed);
        sc.config.fault_seed = None;
        sc.config.dispatch_cache = true;
        let on = scenario::run_scenario(&sc, &catalog(), &calib(), None).unwrap();
        sc.config.dispatch_cache = false;
        let off = scenario::run_scenario(&sc, &catalog(), &calib(), None).unwrap();
        let ctx = format!("defused fuzz seed {seed}");
        assert_behavior_identical(&on, &off, &ctx);
        assert!(
            on.cache.lookups() + on.cache.bypasses > 0,
            "{ctx}: no batch dispatched"
        );
        total_lookups += on.cache.lookups();
    }
    assert!(total_lookups > 0, "no defused seed ever consulted the cache");
}

#[test]
fn pipeline_knob_setters_invalidate_exactly_the_affected_entries() {
    let catalog = catalog();
    let calib = calib();
    let cfg = PipelineConfig {
        use_case: UseCase::Vae,
        n_events: 600,
        policy: Policy::MinLatency,
        ..Default::default()
    };
    let mut p = Pipeline::new(cfg, &catalog, &calib).unwrap();
    let mut run = p.begin(None);
    for _ in 0..150 {
        run.tick().unwrap();
    }
    let entries = run.cache_entries();
    assert!(entries > 0, "steady-state ticks populated no entries");
    let inv0 = run.cache_stats().invalidations;

    // the deadline knob cannot orphan min-latency entries: zero drops
    run.set_deadline_s(0.123).unwrap();
    assert_eq!(run.cache_entries(), entries, "deadline dropped min-latency entries");
    assert_eq!(run.cache_stats().invalidations, inv0);

    // the budget knob orphans every dynamic-policy entry keyed under
    // another budget — here, all of them
    run.set_power_budget_w(Some(3.0));
    assert_eq!(run.cache_entries(), 0, "budget flip must drop dynamic entries");
    assert_eq!(run.cache_stats().invalidations, inv0 + entries as u64);

    // repopulate, then a policy switch drops every entry keyed under
    // another policy (no min-energy entries exist yet)
    for _ in 0..150 {
        run.tick().unwrap();
    }
    let repop = run.cache_entries();
    assert!(repop > 0, "post-invalidation ticks repopulated nothing");
    let inv1 = run.cache_stats().invalidations;
    run.set_policy(Policy::MinEnergy);
    assert_eq!(run.cache_entries(), 0, "policy switch must drop old-policy entries");
    assert_eq!(run.cache_stats().invalidations, inv1 + repop as u64);

    // an availability flip changes the mask in every key: nothing survives
    for _ in 0..150 {
        run.tick().unwrap();
    }
    assert!(run.cache_entries() > 0);
    run.set_target_available(0, false);
    assert_eq!(run.cache_entries(), 0, "mask flip must drop every entry");
    run.set_target_available(0, true);

    let report = run.finish().unwrap();
    assert!(report.cache.hits > 0, "the run never hit the cache");
    assert!(report.cache.invalidations > 0);
}

/// One deterministic storm step: maybe mutate a knob, maybe grow a
/// queue, then pick the next decision point.  `invalidate: false`
/// leaves every stale entry in the table — correctness must not care.
fn storm_step(
    rng: &mut Prng,
    d: &mut Dispatcher,
    cache: &mut DispatchCache,
    tls: &mut [AccelTimeline],
    now_s: f64,
    invalidate: bool,
) {
    match rng.below(8) {
        0 => {
            let policy = POLICIES[rng.below(4)];
            d.policy = policy;
            if invalidate {
                cache.invalidate_policy(policy);
            }
        }
        1 => {
            let budget =
                if rng.chance(0.5) { Some(rng.range_f64(1.0, 8.0)) } else { None };
            d.power_budget_w = budget;
            if invalidate {
                cache.invalidate_power_budget(budget);
            }
        }
        2 => {
            let deadline_s = rng.range_f64(0.001, 1.0);
            d.deadline_s = deadline_s;
            if invalidate {
                cache.invalidate_deadline(deadline_s);
            }
        }
        3 => {
            let index = rng.below(d.registry.len());
            d.registry.set_available(index, rng.chance(0.7));
            if invalidate {
                cache.invalidate_availability(DispatchCache::availability_mask(
                    &d.registry,
                ));
            }
        }
        _ => {}
    }
    if rng.chance(0.5) {
        let index = rng.below(d.registry.len());
        let run = d.run_of(index);
        tls[index].schedule(now_s, 1 + rng.below(16) as u64, run);
    }
}

#[test]
fn knob_storm_lockstep_cached_equals_fresh_every_step() {
    for model in ["vae", "esperta", "baseline"] {
        let mut d = Dispatcher::new(
            model,
            &catalog(),
            &calib(),
            Policy::MinLatency,
            0.5,
            None,
            &TargetSet::Default,
        )
        .unwrap();
        let mut tls = d.timelines();
        let mut cache = DispatchCache::new(true);
        let mut rng = Prng::new(0xCAC4E ^ model.len() as u64);
        let mut now_s = 0.0;
        for step in 0..400 {
            storm_step(&mut rng, &mut d, &mut cache, &mut tls, now_s, true);
            let n = [1u64, 4, 8][rng.below(3)];
            let wait_s = rng.range_f64(0.0, 0.4);
            let fresh = d.choose(&tls, now_s, now_s - wait_s, n);
            let cached = d.choose_cached(&mut cache, &tls, now_s, now_s - wait_s, n);
            assert!(
                choices_identical(&fresh, &cached),
                "{model} step {step}: cached decision diverged"
            );
            now_s += rng.range_f64(0.0, 0.05);
        }
        assert!(cache.stats().hits > 0, "{model}: the storm never hit the cache");
        assert!(cache.stats().misses > 0, "{model}");
    }
}

#[test]
fn stale_entries_without_invalidation_are_unreachable() {
    // invalidation bounds memory — it is *not* what keeps the cache
    // correct.  Run the same knob storm but never invalidate: every
    // orphaned entry stays in the table, and the key structure alone
    // must keep it unreachable under the mutated knobs.
    let mut d = Dispatcher::new(
        "vae",
        &catalog(),
        &calib(),
        Policy::Deadline,
        0.05,
        Some(4.0),
        &TargetSet::Default,
    )
    .unwrap();
    let mut tls = d.timelines();
    let mut cache = DispatchCache::new(true);
    let mut rng = Prng::new(0x57A1E);
    let mut now_s = 0.0;
    for step in 0..400 {
        storm_step(&mut rng, &mut d, &mut cache, &mut tls, now_s, false);
        let n = [1u64, 4, 8][rng.below(3)];
        let wait_s = rng.range_f64(0.0, 0.4);
        let fresh = d.choose(&tls, now_s, now_s - wait_s, n);
        let cached = d.choose_cached(&mut cache, &tls, now_s, now_s - wait_s, n);
        assert!(
            choices_identical(&fresh, &cached),
            "step {step}: a stale entry leaked through the key"
        );
        now_s += rng.range_f64(0.0, 0.05);
    }
    assert_eq!(cache.stats().invalidations, 0, "this storm never invalidates");
    assert!(cache.stats().hits > 0);
}

#[test]
fn plan_mode_knob_storm_cached_equals_fresh_every_step() {
    for model in ["vae", "baseline"] {
        let mut d = Dispatcher::new(
            model,
            &catalog(),
            &calib(),
            Policy::Static,
            0.5,
            None,
            &TargetSet::Default,
        )
        .unwrap();
        let planner =
            Planner::build(model, &catalog(), &calib(), &d.registry, &TargetSet::Default)
                .unwrap();
        let mut tls = d.timelines();
        for name in planner.derived_lane_names() {
            tls.push(AccelTimeline::new(name));
        }
        let mut cache = DispatchCache::new(true);
        let mut rng = Prng::new(0x9_1A4 ^ model.len() as u64);
        let mut now_s = 0.0;
        for step in 0..300 {
            match rng.below(8) {
                0 => {
                    let policy = POLICIES[rng.below(4)];
                    d.policy = policy;
                    cache.invalidate_policy(policy);
                }
                1 => {
                    let budget =
                        if rng.chance(0.5) { Some(rng.range_f64(1.0, 8.0)) } else { None };
                    d.power_budget_w = budget;
                    cache.invalidate_power_budget(budget);
                }
                2 => {
                    let index = rng.below(d.registry.len());
                    d.registry.set_available(index, rng.chance(0.7));
                    cache.invalidate_availability(DispatchCache::availability_mask(
                        &d.registry,
                    ));
                }
                _ => {}
            }
            if rng.chance(0.5) {
                // grow a queue: registry lanes charge their own run, the
                // derived lanes a filler of the same shape
                let index = rng.below(tls.len());
                let run = if index < d.registry.len() {
                    d.run_of(index)
                } else {
                    ScheduledRun {
                        setup_s: rng.range_f64(0.001, 0.05),
                        per_item_s: 0.0,
                        power_w: 0.0,
                    }
                };
                tls[index].schedule(now_s, 1 + rng.below(16) as u64, run);
            }
            let n = [1u64, 4, 8][rng.below(3)];
            let wait_s = rng.range_f64(0.0, 0.4);
            let fresh = d.choose_plan(&planner, &tls, now_s, now_s - wait_s, n);
            let cached =
                d.choose_plan_cached(&mut cache, &planner, &tls, now_s, now_s - wait_s, n);
            assert!(
                plan_choices_identical(&fresh, &cached),
                "{model} step {step}: cached plan decision diverged"
            );
            now_s += rng.range_f64(0.0, 0.05);
        }
        assert!(cache.stats().hits > 0, "{model}: the storm never hit the cache");
        assert!(cache.stats().misses > 0, "{model}");
    }
}
