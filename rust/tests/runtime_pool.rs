//! Executor-pool tests that run without `make artifacts`: they
//! provision a temp artifacts dir holding only manifests and drive the
//! pool on the pure-Rust surrogate backend, so they cover the batching,
//! sharding, and reaping machinery under every feature combination
//! (CI runs them with `--no-default-features`).

use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use spaceinfer::model::Precision;
use spaceinfer::runtime::{
    Backend, Engine, ExecRequest, ExecutorPool, InputSet, PoolConfig,
};

/// Mirror of the crate-private `model::manifest::testdata::MINI`
/// fixture (unit-test fixtures aren't visible across crate boundaries).
const MINI: &str = r#"{
  "name":"mini","precision":"fp32",
  "inputs":{"x":[1,4,4,1]},
  "input_order":["x"],
  "output_shape":[1,2],
  "layers":[
    {"kind":"conv2d","in_shape":[1,4,4,1],"out_shape":[1,4,4,2],
     "macs":288,"ops":640,"params":20,"weight_bytes":80,
     "act_bytes":128,"act":"relu"},
    {"kind":"flatten","in_shape":[1,4,4,2],"out_shape":[1,32],
     "macs":0,"ops":0,"params":0,"weight_bytes":0,
     "act_bytes":128,"act":"none"},
    {"kind":"dense","in_shape":[1,32],"out_shape":[1,2],
     "macs":64,"ops":130,"params":66,"weight_bytes":264,
     "act_bytes":8,"act":"none"}],
  "total_macs":352,"total_ops":770,"total_params":86,
  "weight_bytes":344}"#;

fn mini_dir(label: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spaceinfer_itest_{label}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for name in ["mini", "mini2", "mini3"] {
        std::fs::write(
            dir.join(format!("{name}.fp32.manifest.json")),
            MINI.replace("\"name\":\"mini\"", &format!("\"name\":\"{name}\"")),
        )
        .unwrap();
    }
    dir
}

fn pool(label: &str, workers: usize) -> ExecutorPool {
    ExecutorPool::with_config(
        mini_dir(label),
        PoolConfig {
            workers,
            backend: Backend::Surrogate,
            preload: vec![("mini".into(), Precision::Fp32)],
        },
    )
    .unwrap()
}

fn item(fill: f32) -> InputSet {
    Arc::new(vec![vec![fill; 16]])
}

#[test]
fn m_threads_times_k_submits_results_match_ids() {
    let pool = Arc::new(pool("mxk", 4));
    let (reply, rx) = mpsc::channel();
    let threads: Vec<_> = (0..5u64)
        .map(|t| {
            let pool = pool.clone();
            let reply = reply.clone();
            std::thread::spawn(move || {
                let model = format!("mini{}", if t % 3 == 0 { "" } else { "2" });
                for k in 0..20u64 {
                    let id = t * 100 + k;
                    pool.submit(ExecRequest {
                        model: model.clone(),
                        precision: Precision::Fp32,
                        items: vec![item(id as f32), item(id as f32 + 0.5)],
                        reply: reply.clone(),
                        id,
                    })
                    .unwrap();
                }
            })
        })
        .collect();
    for th in threads {
        th.join().unwrap();
    }
    drop(reply);
    let mut ids = Vec::new();
    while let Ok(res) = rx.recv() {
        let outputs = res.outputs.unwrap();
        assert_eq!(outputs.len(), 2, "two items in, two outputs out");
        assert!(outputs.iter().all(|o| o.len() == 2));
        ids.push(res.id);
        if ids.len() == 100 {
            break;
        }
    }
    ids.sort_unstable();
    let want: Vec<u64> =
        (0..5).flat_map(|t| (0..20).map(move |k| t * 100 + k)).collect();
    assert_eq!(ids, want, "every submit must reap exactly once");
    assert_eq!(pool.batches_submitted(), 100);
}

#[test]
fn run_batch_equals_n_single_runs() {
    let dir = mini_dir("equiv");
    let engine = Engine::with_backend(&dir, Backend::Surrogate).unwrap();
    let model = engine.load("mini", Precision::Fp32).unwrap();
    let items: Vec<InputSet> = (0..6).map(|i| item(i as f32 * 0.3)).collect();
    let batched = model.run_batch(&items).unwrap();
    assert_eq!(batched.len(), 6);
    for (set, out) in items.iter().zip(&batched) {
        let slices: Vec<&[f32]> = set.iter().map(|v| v.as_slice()).collect();
        assert_eq!(out, &model.run(&slices).unwrap(), "batch != single");
    }
    // and the same equivalence through the pool's sync entry points
    let p = pool("equiv_pool", 2);
    let via_batch = p
        .run_batch_sync("mini", Precision::Fp32, vec![item(0.9), item(0.1)])
        .unwrap();
    assert_eq!(
        via_batch[0],
        p.run_sync("mini", Precision::Fp32, vec![vec![0.9; 16]]).unwrap()
    );
    assert_eq!(
        via_batch[1],
        p.run_sync("mini", Precision::Fp32, vec![vec![0.1; 16]]).unwrap()
    );
}

#[test]
fn sharding_is_stable_and_total() {
    let p = pool("shard", 3);
    for model in ["mini", "mini2", "mini3"] {
        let s = p.shard_of(model, Precision::Fp32);
        assert!(s < 3);
        assert_eq!(s, p.shard_of(model, Precision::Fp32), "shard must be stable");
    }
    // int8 is a different variant and may shard elsewhere, but must be
    // in range too
    assert!(p.shard_of("mini", Precision::Int8) < 3);
}

#[test]
fn submit_reap_is_async() {
    let p = pool("async", 2);
    let (reply, rx) = mpsc::channel();
    // submit everything before reaping anything: the queue decouples
    // producers from workers
    for id in 0..10 {
        p.submit(ExecRequest {
            model: "mini".into(),
            precision: Precision::Fp32,
            items: vec![item(id as f32)],
            reply: reply.clone(),
            id,
        })
        .unwrap();
    }
    let mut got: Vec<u64> = (0..10).map(|_| rx.recv().unwrap().id).collect();
    got.sort_unstable();
    assert_eq!(got, (0..10).collect::<Vec<u64>>());
}

#[test]
fn preload_compiles_up_front() {
    let p = pool("preload", 1);
    assert_eq!(
        p.engine().loaded_tags(),
        vec!["mini.fp32".to_string()],
        "preload must compile before the first request"
    );
}

#[test]
fn default_backend_tracks_feature() {
    if cfg!(feature = "xla") {
        assert_eq!(Backend::default(), Backend::Pjrt);
    } else {
        assert_eq!(Backend::default(), Backend::Surrogate);
    }
}
